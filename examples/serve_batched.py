"""Batched serving of a federated-fine-tuned backbone: prefill + ring-cache
decode, optional NF4 backbone, across any assigned architecture.

  PYTHONPATH=src python examples/serve_batched.py --arch h2o-danube-3-4b \
      --batch 4 --gen 16 --quant 4
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
