"""End-to-end TriplePlay federated training (the paper's main pipeline).

Frozen NF4 CLIP backbone + attention adapter + LoRA per client, client-side
conditional GANs rebalancing the long-tail class, quantized updates
aggregated by sample-count weighting — compared against the FedCLIP and
QLoRA-no-GAN arms.

  PYTHONPATH=src python examples/fl_tripleplay.py --rounds 12 --clients 5
  PYTHONPATH=src python examples/fl_tripleplay.py --strategy fedclip
"""
import argparse

import numpy as np

from repro.fl.simulator import FLConfig, run_federated


def ascii_curve(vals, width=48, height=8):
    lo, hi = min(vals), max(vals) + 1e-9
    grid = [[" "] * width for _ in range(height)]
    for i, v in enumerate(vals):
        x = int(i / max(len(vals) - 1, 1) * (width - 1))
        y = int((v - lo) / (hi - lo) * (height - 1))
        grid[height - 1 - y][x] = "*"
    return "\n".join("".join(r) for r in grid) + \
        f"\n[{lo:.3f} .. {hi:.3f}]"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="tripleplay",
                    choices=["fedclip", "qlora_nogan", "tripleplay"])
    ap.add_argument("--dataset", default="pacs",
                    choices=["pacs", "officehome"])
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=6)
    ap.add_argument("--gan-steps", type=int, default=250)
    ap.add_argument("--n-per-class", type=int, default=32)
    args = ap.parse_args()

    h = run_federated(FLConfig(
        dataset=args.dataset, strategy=args.strategy,
        n_clients=args.clients, rounds=args.rounds,
        local_steps=args.local_steps, gan_steps=args.gan_steps,
        n_per_class=args.n_per_class, lr=3e-3))
    print(f"\n=== {args.strategy} on {args.dataset} ===")
    print(f"trainable params: {h.meta['trainable_params']:,} "
          f"(backbone {h.meta['frozen_params']:,} frozen, "
          f"{h.meta['backbone_bytes']/2**20:.1f} MiB stored)")
    print(f"uplink/round: {np.mean(h.uplink_bytes)/2**20:.2f} MiB")
    print(f"server accuracy by round: "
          f"{['%.3f' % a for a in h.server_acc]}")
    print(ascii_curve(h.server_acc))
    print(f"final: acc={h.server_acc[-1]:.3f} loss={h.server_loss[-1]:.3f}")


if __name__ == "__main__":
    main()
