"""Long-tail rebalancing with the conditional GAN (paper §III-B, Fig 1b).

Shows the class histogram before/after GAN over-sampling and the effect on
a zero-shot-style classifier trained on the (re)balanced pool.

  PYTHONPATH=src python examples/longtail_gan.py --gan-steps 300
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clip as clip_lib
from repro.core import gan as gan_lib
from repro.data.synthetic import make_dataset, make_eval_set
from repro.fl.client import Client, forward_logits, init_trainable
from repro.fl.simulator import pretrained_clip
from repro.fl.strategies import STRATEGIES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gan-steps", type=int, default=300)
    ap.add_argument("--train-steps", type=int, default=60)
    args = ap.parse_args()

    data = make_dataset("pacs", n_per_class=48, seed=0, longtail_gamma=8.0)
    n_classes = data["spec"].n_classes
    hist = np.bincount(data["labels"], minlength=n_classes)
    print("class histogram (long-tail):", hist.tolist())

    client = Client(cid=0, images=data["images"], labels=data["labels"],
                    n_classes=n_classes,
                    strategy=STRATEGIES["tripleplay"])
    client.prepare_gan(jax.random.PRNGKey(0), steps=args.gan_steps)
    aug_hist = np.bincount(
        np.concatenate([data["labels"], client.aug_labels]),
        minlength=n_classes)
    print("after GAN rebalancing:      ", aug_hist.tolist())
    print(f"synthesized {len(client.aug_labels)} samples "
          f"(range [{float(client.aug_images.min()):.2f}, "
          f"{float(client.aug_images.max()):.2f}])")

    # downstream: adapter fine-tuning with vs without the synthetic pool
    ccfg = clip_lib.CLIPConfig()
    frozen = pretrained_clip("pacs", ccfg)
    from repro.data.synthetic import class_tokens
    class_emb = clip_lib.text_embedding(
        frozen, ccfg, jnp.asarray(class_tokens(data["spec"],
                                               np.arange(n_classes))))
    eval_set = make_eval_set("pacs", seed=1)

    for use_gan, label in ((False, "no GAN"), (True, "with GAN")):
        c = Client(cid=0, images=data["images"], labels=data["labels"],
                   n_classes=n_classes,
                   strategy=STRATEGIES["tripleplay" if use_gan
                                       else "qlora_nogan"])
        if use_gan:
            c.aug_images, c.aug_labels = client.aug_images, \
                client.aug_labels
        tr = init_trainable(jax.random.PRNGKey(1), ccfg,
                            STRATEGIES["qlora_nogan"])
        tr, m = c.local_train(frozen, tr, class_emb, ccfg,
                              steps=args.train_steps, batch_size=32,
                              lr=3e-3, seed=0)
        logits = forward_logits(frozen, tr, ccfg,
                                jnp.asarray(eval_set["images"]), class_emb)
        acc = float((jnp.argmax(logits, -1) ==
                     jnp.asarray(eval_set["labels"])).mean())
        # accuracy on the long-tail class specifically
        mask = eval_set["labels"] == 0
        tail = float((jnp.argmax(logits, -1)[mask] == 0).mean())
        print(f"{label:9s}: eval acc={acc:.3f}, tail-class acc={tail:.3f}")


if __name__ == "__main__":
    main()
