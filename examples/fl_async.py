"""TriplePlay under realistic client availability: full-sync vs
sync-partial vs async-buffered scheduling (fl.sched).

Runs the same non-IID long-tail PACS instance under a skewed
availability trace (Zipf participation, lognormal speeds) with each
scheduler policy and reports the two quantities the scheduler trades
off: communication rounds to a target server accuracy, and the total
uplink payload spent getting there — plus each policy's one-time fixed
cost from the bucketed program runtime's ledger (program count, compile
seconds, and the GAN engine's share), which steady-state round times
alone would hide. Async rows also show the staleness profile of
committed updates.

  PYTHONPATH=src python examples/fl_async.py --rounds 12 --clients 8
  PYTHONPATH=src python examples/fl_async.py --beta 0  # pure FedBuff->FedAvg
  PYTHONPATH=src python examples/fl_async.py --chaos heavy  # fault injection
"""
import argparse

import numpy as np

from repro.fl.simulator import FLConfig, run_federated


def rounds_to_target(hist, target: float):
    for r, acc in zip(hist.rounds, hist.server_acc):
        if acc >= target:
            return r + 1
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="tripleplay",
                    choices=["fedclip", "qlora_nogan", "tripleplay"])
    ap.add_argument("--dataset", default="pacs",
                    choices=["pacs", "officehome"])
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--clients-per-round", type=int, default=3)
    ap.add_argument("--beta", type=float, default=0.5)
    ap.add_argument("--local-steps", type=int, default=6)
    ap.add_argument("--gan-steps", type=int, default=150)
    ap.add_argument("--n-per-class", type=int, default=24)
    ap.add_argument("--target-acc", type=float, default=0.0,
                    help="0 = 90%% of the best final accuracy")
    ap.add_argument("--chaos", nargs="?", const="light", default=None,
                    choices=["light", "heavy"],
                    help="inject faults (dropouts, stragglers, lost "
                         "uplinks) from a named preset; bare --chaos "
                         "means light")
    args = ap.parse_args()

    base = dict(dataset=args.dataset, strategy=args.strategy,
                n_clients=args.clients, rounds=args.rounds,
                local_steps=args.local_steps, gan_steps=args.gan_steps,
                n_per_class=args.n_per_class, lr=3e-3, trace="skewed",
                staleness_beta=args.beta, chaos=args.chaos)
    runs = {
        "full-sync": FLConfig(**base, participation="full"),
        "sync-partial": FLConfig(**base, participation="sync-partial",
                                 clients_per_round=args.clients_per_round),
        "async-buffered": FLConfig(**base, participation="async",
                                   clients_per_round=args.clients_per_round),
    }
    hists = {name: run_federated(cfg) for name, cfg in runs.items()}

    target = args.target_acc or 0.9 * max(
        h.server_acc[-1] for h in hists.values())
    print(f"\n=== {args.strategy} on {args.dataset}, skewed trace, "
          f"N={args.clients}, K={args.clients_per_round}, "
          f"beta={args.beta} ===")
    print(f"target accuracy: {target:.3f}")
    hdr = (f"{'policy':15s} {'final_acc':>9s} {'rounds->tgt':>11s} "
           f"{'uplink MiB':>10s} {'mean stale':>10s} "
           f"{'compiles':>8s} {'compile s':>9s} {'gan cmp s':>9s}")
    print(hdr + "\n" + "-" * len(hdr))
    for name, h in hists.items():
        r2t = rounds_to_target(h, target)
        taus = [t for taus in h.staleness for t in taus]
        print(f"{name:15s} {h.server_acc[-1]:9.3f} "
              f"{('%d' % r2t) if r2t else 'n/a':>11s} "
              f"{sum(h.uplink_bytes)/2**20:10.2f} "
              f"{np.mean(taus) if taus else 0.0:10.2f} "
              f"{h.meta['n_compiles']:8d} "
              f"{h.meta['compile_time_s']:9.1f} "
              f"{h.meta.get('gan_compile_time_s', 0.0):9.1f}")
    # the fixed cost the bucketed runtime amortizes: which programs each
    # policy actually compiled (one entry per shape *bucket*, so e.g.
    # every K in a power-of-two bucket shares one subset_round entry)
    print("\ncompiled programs per policy "
          "(kind: count, from History.meta['n_compiles_by_kind']):")
    for name, h in hists.items():
        kinds = ", ".join(f"{k}: {v}" for k, v in
                          h.meta["n_compiles_by_kind"].items())
        print(f"  {name:15s} {kinds}")
    # the pipelined round loop's overlap ledger: how many times each
    # policy's loop blocked the host per round (0 = fully overlapped
    # steady state), which events synced, and how many rounds had their
    # selection pre-drawn before the loop started
    print("\npipeline overlap/sync ledger (meta['sync_counts']):")
    for name, h in hists.items():
        counts = ", ".join(f"{k}: {v}" for k, v in
                           sorted(h.meta["sync_counts"].items()))
        print(f"  {name:15s} mode={h.meta['pipeline']} "
              f"syncs/round={h.meta['syncs_per_round']:.2f} "
              f"prepared={h.meta['prepared_rounds']} "
              f"loop_wall={h.meta['loop_wall_s']:.2f}s  "
              f"[{counts or 'no syncs'}]")
    async_h = hists["async-buffered"]
    print(f"\nasync virtual timeline: commits at "
          f"{['%.1f' % t for t in async_h.vtime]}")
    print(f"async staleness per commit: {async_h.staleness}")
    if args.chaos:
        # what the chaos layer actually did to each policy: every fault
        # is deterministic (same seed -> same ledger) and recovered
        # from, never silently dropped on the floor
        print(f"\nfault ledger per policy (--chaos {args.chaos}):")
        for name, h in hists.items():
            led = h.meta["fault_ledger"]
            line = ", ".join(f"{k}: {v}" for k, v in led.items() if v)
            print(f"  {name:15s} {line or '(no faults fired)'}")


if __name__ == "__main__":
    main()
