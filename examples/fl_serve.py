"""Personalized-adapter serving walkthrough (fl.serve): train a small
multi-tenant population, then replay a diurnal Zipf request trace
through the batched serving plane and read every number it produces.

The pipeline this demonstrates end to end:

 1. training handoff — one cohort wave per tenant family produces a
    per-user personalized tree (``global + dequant(delta_i)``);
 2. AdapterStore — the trees live quantized-at-rest (int8 blockwise) in
    stacked device slabs behind a global LRU; shrink ``--cache`` below
    the population to watch evictions appear while answers stay exact
    to tolerance (evicted users re-quantize from backing on return);
 3. ServeEngine — each flight of ragged requests buckets to a
    power-of-two width and is answered by ONE fused program per tenant
    family, vmapped over the adapter axis against the hoisted frozen
    CLIP prefix;
 4. replay — the diurnal trace drives flights on the scheduler's
    virtual clock, so latency percentiles are reproducible numbers, not
    wall-clock noise;
 5. parity — the same stream through the per-user sequential oracle
    bounds the batched plane's logit error.

  PYTHONPATH=src python examples/fl_serve.py
  PYTHONPATH=src python examples/fl_serve.py --users 12 --cache 4
  PYTHONPATH=src python examples/fl_serve.py --quant 0   # fp at rest
"""
import argparse

import numpy as np

from repro.fl import serve as serve_lib
from repro.fl.serve import engine as engine_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=8)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--cache", type=int, default=0,
                    help="adapter-cache capacity (0 = population)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--quant", type=int, default=8, choices=[0, 4, 8])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"training {args.users} personalized tenants "
          "(two families: adapter-only + LoRA)...")
    plane = serve_lib.demo_plane(
        args.users, mixed=args.users >= 2, seed=args.seed,
        quant_bits=args.quant, max_entries=args.cache or None,
        max_batch=args.max_batch)
    store, engine, rt = plane["store"], plane["engine"], plane["runtime"]

    trace = serve_lib.zipf_request_trace(
        args.users, args.requests, seed=args.seed, rate=250.0,
        period=1.0, amplitude=0.6)
    images = serve_lib.request_images(plane, trace, seed=args.seed)
    print(f"\nreplaying {trace.name}: {trace.n} requests over "
          f"{trace.concurrency()} concurrent tenants "
          f"(diurnal rate modulation, Zipf popularity)")
    rec = serve_lib.replay(engine, trace, images)

    print(f"  flights            {rec['n_flights']} "
          f"(buckets {sorted(set(f['bucket'] for f in rec['flights']))})")
    print(f"  virtual latency    p50 {rec['lat_v_p50']*1e3:7.2f} ms   "
          f"p99 {rec['lat_v_p99']*1e3:7.2f} ms")
    print(f"  virtual throughput {rec['throughput_v']:.0f} req/s")
    print(f"  measured wall      {rec['wall_s']:.2f} s "
          f"({rec['throughput_wall']:.0f} req/s)")

    st = store.stats()
    print("\nadapter cache (quantized at rest, "
          f"{store.quant_bits or 'fp32'}-bit):")
    print(f"  capacity {store.max_entries} / population {args.users}; "
          f"resident {st['resident']} in {st['families']} families")
    print(f"  hits {st['hits']}  misses {st['misses']}  "
          f"evictions {st['evictions']}  "
          f"hit_rate {store.hit_rate():.2f}")
    print(f"  bytes at rest {store.bytes_at_rest():,}")

    print("\ncompile ledger (one runtime across train handoff + serve):")
    for kind, row in sorted(rt.stats().items()):
        extras = {k: v for k, v in row.items()
                  if k not in ("n_compiles", "compile_time_s")}
        line = (f"  {kind:14s} n_compiles={row['n_compiles']:2d} "
                f"compile_time={row['compile_time_s']:6.2f}s")
        if extras:
            line += "  " + " ".join(f"{k}={v}" for k, v in
                                    sorted(extras.items()))
        print(line)

    ref = engine_lib.serve_sequential(
        plane["frozen"], plane["ccfg"], plane["class_emb"],
        plane["backing"],
        [(int(u), im) for u, im in zip(trace.uid, images)])
    err = float(np.max(np.abs(rec["logits"] - ref)))
    print(f"\nparity vs per-user sequential oracle: "
          f"max |logit err| = {err:.2e} "
          f"({'fp-exact' if args.quant == 0 else 'int8-at-rest'} mode)")


if __name__ == "__main__":
    main()
