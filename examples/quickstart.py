"""Quickstart: the TriplePlay pieces in five minutes on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import optim
from repro.core.quant import QTensor, quantize_tree, tree_bytes
from repro.models import build_model

# 1. Build a reduced assigned architecture with a QLoRA (NF4) backbone.
cfg = get_reduced("yi-9b").replace(quant_bits=4, quant_mode="nf4",
                                   quant_block=64)
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
frozen, trainable = params["frozen"], params["trainable"]
print(f"backbone: {tree_bytes(frozen)/2**20:.2f} MiB (NF4-quantized)")
print(f"trainable (LoRA+adapter): {tree_bytes(trainable)/2**20:.2f} MiB")

# 2. One local training step — gradients flow ONLY to LoRA + adapter.
rng = np.random.RandomState(0)
toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 33)), jnp.int32)
batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
         "mask": jnp.ones((2, 32), jnp.float32)}
opt = optim.adam_init(trainable)
trainable, opt, metrics = jax.jit(model.train_step)(
    frozen, trainable, opt, batch)
print(f"local step: loss={float(metrics['loss']):.4f} "
      f"grad_norm={float(metrics['grad_norm']):.4f}")

# 3. Serve: prefill a prompt, decode a few tokens from the ring cache.
logits, cache = model.prefill(frozen, trainable, {"tokens": toks[:, :16]},
                              max_len=24)
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
for i in range(4):
    logits, cache = model.decode_step(frozen, trainable, cache, tok,
                                      jnp.asarray(16 + i, jnp.int32))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
print("decoded token ids:", int(tok[0, 0]), int(tok[1, 0]))

# 4. The federated round: quantize the update, weighted-average it.
delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32),
                     trainable, params["trainable"])
q = quantize_tree(delta, bits=8, block=64, min_size=256,
                  skip_names=("slot",))
print(f"uplink payload: fp32={tree_bytes(delta)/2**10:.0f} KiB -> "
      f"int8={tree_bytes(q)/2**10:.0f} KiB")
from repro.fl import server
new_global = server.aggregate(params["trainable"], [(10, q), (30, q)])
print("aggregated: ok —",
      jax.tree_util.tree_structure(new_global).num_leaves, "leaves")
