"""End-to-end driver: federated fine-tuning of a ~100M-parameter dense
backbone (yi-9b family scaled to ~100M) for a configurable number of
rounds/steps — the \"train a ~100M model\" end-to-end example, sized so a
few hundred steps are feasible on real hardware (defaults here are small
for the CPU container; raise --rounds/--local-steps to paper scale).

  PYTHONPATH=src python examples/train_100m.py --rounds 2 --local-steps 3
"""
import argparse

from repro.configs import get_config
from repro.launch.train import aggregate, client_update, \
    synthetic_token_stream
from repro.core.quant import tree_bytes
from repro.models import build_model

import jax
import numpy as np


def cfg_100m():
    return get_config("yi-9b").replace(
        name="yi-100m", n_layers=10, d_model=640, n_heads=10, n_kv_heads=2,
        head_dim=64, d_ff=1792, vocab_size=32000, quant_bits=4,
        quant_mode="nf4", quant_block=64, dtype="float32",
        seq_shard=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=3)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = cfg_100m()
    model = build_model(cfg)
    n = cfg.param_count()
    print(f"model: {n/1e6:.0f}M params ({cfg.n_layers}L d={cfg.d_model})")
    params = model.init_params(jax.random.PRNGKey(0))
    frozen, global_tr = params["frozen"], params["trainable"]
    print(f"backbone storage {tree_bytes(frozen)/2**20:.0f} MiB (NF4), "
          f"trainable {tree_bytes(global_tr)/2**20:.1f} MiB")

    rng = np.random.RandomState(0)
    data = synthetic_token_stream(rng, cfg.vocab_size, args.clients,
                                  seq=args.seq)
    for rnd in range(args.rounds):
        updates, losses = [], []
        for c in range(args.clients):
            d, nbytes, loss = client_update(
                model, frozen, global_tr, data[c],
                steps=args.local_steps, batch=args.batch, lr=1e-3,
                comm_bits=8, seed=rnd * 10 + c)
            updates.append((len(data[c]), d))
            losses.append(loss)
        global_tr = aggregate(global_tr, updates)
        print(f"round {rnd}: client losses="
              f"{['%.3f' % l for l in losses]}", flush=True)
    print("done")


if __name__ == "__main__":
    main()
