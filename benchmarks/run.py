"""Benchmark harness — one module per paper table/figure plus the roofline
and kernel microbenches. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run                 # quick scale
  REPRO_BENCH_SCALE=paper PYTHONPATH=src python -m benchmarks.run
  PYTHONPATH=src python -m benchmarks.run --only fig4,comm
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time
import traceback

BENCHES = ("kernel", "comm", "roofline", "fig3", "fig4", "fig5", "fig6",
           "fig7")


def _roofline_rows() -> list[str]:
    from benchmarks import roofline
    path = pathlib.Path("dryrun_baseline.jsonl")
    if not path.exists():
        return ["roofline/missing,0,run repro.launch.dryrun --all first"]
    recs = roofline.load(str(path))
    rows = []
    for r in recs:
        t = roofline.terms(r)
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        rows.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{bound*1e6:.1f},dominant={t['dominant']};"
            f"useful={t['useful_ratio']:.2f};hbm={t['hbm_gib']:.1f}GiB")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list from: " + ",".join(BENCHES))
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else set(BENCHES)

    print("name,us_per_call,derived")
    failures = []
    for name in BENCHES:
        if name not in want:
            continue
        t0 = time.time()
        try:
            if name == "kernel":
                from benchmarks.kernel_bench import run
            elif name == "comm":
                from benchmarks.comm_cost import run
            elif name == "roofline":
                run = _roofline_rows
            elif name == "fig3":
                from benchmarks.fig3_resource import run
            elif name == "fig4":
                from benchmarks.fig4_pacs import run
            elif name == "fig5":
                from benchmarks.fig5_officehome import run
            elif name == "fig6":
                from benchmarks.fig6_clients import run
            elif name == "fig7":
                from benchmarks.fig7_scalability import run
            for row in run():
                print(row, flush=True)
            print(f"# {name} done in {time.time()-t0:.0f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
