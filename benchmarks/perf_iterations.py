"""§Perf hillclimbing driver (deliverable g/h).

Runs named experiment variants of the three hillclimb pairs through the
dry-run + calibrated-cost machinery and appends records (tagged with the
experiment name and hypothesis) to perf_iterations.jsonl. EXPERIMENTS.md
§Perf narrates the resulting before/after table.

  PYTHONPATH=src python -m benchmarks.perf_iterations          # all
  PYTHONPATH=src python -m benchmarks.perf_iterations --only A
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json

from repro.launch.dryrun import run_one

# experiment registry: (pair, name, hypothesis, arch, shape, kwargs)
EXPERIMENTS = [
    # --- Pair A: yi-9b × train_4k (paper-representative federated QLoRA)
    ("A", "A0-baseline-bf16",
     "bf16 backbone + f32 trainables (FedCLIP-style arm)",
     "yi-9b", "train_4k", {}),
    ("A", "A1-qlora-nf4",
     "paper-faithful QLoRA: NF4 backbone cuts weight reads/storage 4x; "
     "memory term drops a little (activations dominate), HBM headroom up",
     "yi-9b", "train_4k", dict(quant_bits=4, quant_mode="nf4")),
    ("A", "A2-qlora-bf16-trainables",
     "f32 LoRA/adapter promote several GB of collectives to f32; bf16 "
     "trainables should halve the collective term's big members",
     "yi-9b", "train_4k", dict(quant_bits=4, quant_mode="nf4",
                               trainable_dtype="bfloat16")),
    ("A", "A3-plus-grad-accum4",
     "4 microbatches cut activation working set ~4x (temp -> fits HBM); "
     "HBM traffic roughly unchanged, weights re-read 4x (cheap in NF4)",
     "yi-9b", "train_4k", dict(quant_bits=4, quant_mode="nf4",
                               trainable_dtype="bfloat16", grad_accum=4)),
    # --- Pair B: kimi-k2 × train_4k (worst roofline fraction)
    ("B", "B0-baseline-bf16",
     "bf16 1T MoE: per-expert FSDP weight gathers dominate collectives; "
     "84.6 GiB/device is far over HBM",
     "kimi-k2-1t-a32b", "train_4k", {}),
    ("B", "B1-int4-experts",
     "int4 expert storage: the FSDP all-gather moves the packed int4 "
     "payload -> collective bytes / ~4, resident weights 7.7 -> 1.9 GiB",
     "kimi-k2-1t-a32b", "train_4k", dict(quant_bits=4)),
    ("B", "B2-plus-grad-accum4",
     "4 microbatches cut the dispatch/activation transients ~4x -> "
     "temp memory toward HBM budget; collectives re-run 4x smaller each",
     "kimi-k2-1t-a32b", "train_4k", dict(quant_bits=4, grad_accum=4)),
    ("B", "B3-plus-bf16-trainables",
     "same f32->bf16 collective halving as A2 on the attention/adapter "
     "paths",
     "kimi-k2-1t-a32b", "train_4k",
     dict(quant_bits=4, grad_accum=4, trainable_dtype="bfloat16")),
    # --- Pair C: kimi-k2 × decode_32k (most collective-bound)
    ("C", "C0-baseline-bf16",
     "decode gathers FULL expert weights per layer for ~8 tokens/device "
     "— collective-crushed (4.8 s/step roofline)",
     "kimi-k2-1t-a32b", "decode_32k", {}),
    ("C", "C1-int4-experts",
     "int4 experts: weight gathers shrink ~4x (gather happens on packed "
     "payload, dequant after)",
     "kimi-k2-1t-a32b", "decode_32k", dict(quant_bits=4)),
    ("C", "C2-plus-int8-kv",
     "int8 KV cache halves the resident cache and its read traffic "
     "(paper-aligned quantization applied to serving state)",
     "kimi-k2-1t-a32b", "decode_32k", dict(quant_bits=4, kv_quant=8)),
    # --- Pair B round 2 (after B1-B3 measurements)
    ("B2x", "B4-int8-dispatch",
     "MoE all-to-all payloads ride in int8 (per-row scales, custom-VJP "
     "so cotangents are also int8) — DeepSeek-V3-style; expect the "
     "all-to-all share of the collective term to halve",
     "kimi-k2-1t-a32b", "train_4k",
     dict(quant_bits=4, grad_accum=4,
          extra_cfg={"moe_dispatch_bits": 8})),
    ("B2x", "B5-accum16",
     "39.9 GiB/device is still 2.5x HBM; 16 microbatches shrink the "
     "dispatch/activation transients linearly",
     "kimi-k2-1t-a32b", "train_4k",
     dict(quant_bits=4, grad_accum=16,
          extra_cfg={"moe_dispatch_bits": 8})),
    ("C2x", "C3-int8-dispatch-decode",
     "int8 dispatch on the decode path too (collective no longer "
     "dominant; expect a small further drop)",
     "kimi-k2-1t-a32b", "decode_32k",
     dict(quant_bits=4, kv_quant=8,
          extra_cfg={"moe_dispatch_bits": 8})),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--out", default="perf_iterations.jsonl")
    args = ap.parse_args()
    for pair, name, hyp, arch, shape, kw in EXPERIMENTS:
        if args.only and pair not in args.only.split(","):
            continue
        print(f"\n### {name}: {hyp}", flush=True)
        try:
            rec = run_one(arch, shape, multi_pod=False, **kw)
            rec.update({"experiment": name, "pair": pair,
                        "hypothesis": hyp})
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
        except Exception as e:  # noqa: BLE001
            print(f"!! {name} failed: {e!r}"[:400], flush=True)


if __name__ == "__main__":
    main()
