"""Ablation: non-IID severity (Dirichlet alpha) × strategy arm.

The paper claims TriplePlay handles heterogeneous data distributions; the
ablation sweeps alpha ∈ {0.1, 0.5, 5.0} (harsh → mild skew) and reports
final server accuracy per arm. Not part of the default `benchmarks.run`
set (runtime); invoke directly:

  PYTHONPATH=src python -m benchmarks.ablation_noniid
"""
from __future__ import annotations

from benchmarks.fl_common import fl_config, save
from repro.fl.simulator import run_federated


def run(alphas=(0.1, 0.5, 5.0),
        strategies=("fedclip", "tripleplay")) -> list[str]:
    rows, out = [], {}
    for alpha in alphas:
        for strat in strategies:
            h = run_federated(fl_config("pacs", strat, alpha=alpha))
            out[f"{strat}_a{alpha}"] = {
                "server_acc": h.server_acc, "server_loss": h.server_loss}
            rows.append(f"ablate/alpha{alpha}/{strat},"
                        f"{h.server_acc[-1]*1e6:.0f},"
                        f"final_loss={h.server_loss[-1]:.3f}")
    save("ablation_noniid", out)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for r in run():
        print(r, flush=True)
