"""Fig. 4: server accuracy — FedCLIP vs QLoRA-no-GAN vs TriplePlay on the
PACS-like long-tail dataset."""
from __future__ import annotations

from benchmarks.fl_common import fl_config, hist_dict, save
from repro.fl.simulator import run_federated


def run(dataset: str = "pacs", tag: str = "fig4") -> list[str]:
    rows, out = [], {}
    for strat in ("fedclip", "qlora_nogan", "tripleplay"):
        h = run_federated(fl_config(dataset, strat))
        out[strat] = hist_dict(h)
        # paper claim: TriplePlay converges fastest (GAN rebalancing);
        # report rounds-to-best-half and final accuracy
        accs = h.server_acc
        target = 0.5 * max(max(accs), 1e-9)
        t2t = next((r for r, a in zip(h.rounds, accs) if a >= target),
                   h.rounds[-1])
        rows.append(f"{tag}/{dataset}/{strat}/final_acc,"
                    f"{accs[-1]*1e6:.0f},rounds_to_half_best={t2t};"
                    f"tail_acc={h.tail_acc[-1]:.3f}")
    save(f"{tag}_{dataset}", out)
    return rows
