"""Roofline analysis over the dry-run sweep (deliverable g).

Reads the jsonl records produced by ``repro.launch.dryrun`` and derives,
per (arch × shape × mesh):

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = Σ_ops ring_factor(op) · bytes_per_device / link_bw

with TPU v5e constants (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
cost_analysis FLOPs/bytes are per-device for SPMD executables; collective
bytes are parsed from the partitioned HLO (output-buffer sizes), converted
to wire traffic with standard ring factors:

  all-gather       (n-1)/n · out_bytes      (received)
  reduce-scatter   (n-1)   · out_bytes      (out is the scattered shard)
  all-reduce       2(n-1)/n · bytes
  all-to-all       (n-1)/n · bytes
  collective-permute  1 · bytes

MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params,
D = tokens — the useful-work yardstick against compiled HLO FLOPs.
"""
from __future__ import annotations

import json
import sys
from dataclasses import dataclass

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link

RING = {"all-gather": lambda n: (n - 1) / max(n, 1),
        "reduce-scatter": lambda n: (n - 1),
        "all-reduce": lambda n: 2 * (n - 1) / max(n, 1),
        "all-to-all": lambda n: (n - 1) / max(n, 1),
        "collective-permute": lambda n: 1.0}


def model_flops(rec) -> float:
    n_act = rec["params_active"]
    tokens = rec["global_batch"] * (
        rec["seq_len"] if rec["kind"] in ("train", "prefill") else 1)
    mult = 6 if rec["kind"] == "train" else 2
    return mult * n_act * tokens


def terms(rec) -> dict:
    """Prefers the loop-calibrated costs (see launch/dryrun.py); the raw
    scanned-graph numbers undercount loop bodies. 'bytes accessed' counts
    every operand/result, so the memory term is a conservative upper bound
    on HBM traffic (fusion reduces it on real hardware)."""
    n_dev = rec["n_devices"]
    flops = rec.get("hlo_flops_cal", rec["hlo_flops"])
    nbytes = rec.get("hlo_bytes_cal", rec["hlo_bytes"])
    colls = rec.get("collectives_cal", rec["collectives"])
    compute = flops / PEAK_FLOPS
    memory = nbytes / HBM_BW
    coll = 0.0
    for kind, v in colls.items():
        n = max(v.get("gsize", 0), 2)
        coll += RING[kind](n) * v["bytes"] / LINK_BW
    dom = max(("compute", compute), ("memory", memory),
              ("collective", coll), key=lambda t: t[1])
    mf = model_flops(rec)
    hlo_global = flops * n_dev
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": coll, "dominant": dom[0],
            "model_flops": mf, "hlo_flops_global": hlo_global,
            "useful_ratio": mf / hlo_global if hlo_global else 0.0,
            "hbm_gib": (rec["argument_bytes"] + rec["output_bytes"] +
                        rec["temp_bytes"]) / 2**30}


def load(path: str):
    with open(path) as f:
        return [json.loads(l) for l in f]


def table(records, mesh="16x16") -> str:
    rows = []
    head = (f"| arch | shape | compute s | memory s | collective s | "
            f"dominant | 6ND/HLO |")
    sep = "|---" * 7 + "|"
    for r in records:
        if r["mesh"] != mesh:
            continue
        t = terms(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"**{t['dominant']}** | {t['useful_ratio']:.2f} |")
    return "\n".join([head, sep] + rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_baseline.jsonl"
    recs = load(path)
    print(table(recs, "16x16"))
    print()
    print("name,us_per_call,derived")
    for r in recs:
        t = terms(r)
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
              f"{bound*1e6:.1f},dominant={t['dominant']};"
              f"useful={t['useful_ratio']:.2f}")


if __name__ == "__main__":
    main()
