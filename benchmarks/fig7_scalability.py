"""Fig. 7: TriplePlay scalability — 5 vs 10 FL clients (PACS)."""
from __future__ import annotations

from benchmarks.fl_common import fl_config, hist_dict, save
from repro.fl.simulator import run_federated


def run() -> list[str]:
    rows, out = [], {}
    for n in (5, 10):
        h = run_federated(fl_config("pacs", "tripleplay", n_clients=n,
                                    n_per_class=48))
        out[f"clients_{n}"] = hist_dict(h)
        rows.append(f"fig7/clients{n}/final_acc,"
                    f"{h.server_acc[-1]*1e6:.0f},"
                    f"final_loss={h.server_loss[-1]:.3f}")
    save("fig7_scalability", out)
    return rows
