"""Fig. 7: TriplePlay scalability — 5 vs 10 FL clients (PACS), plus the
scheduler sweep: at fixed N, vary ``clients_per_round`` across
sync-partial and async-buffered policies (skewed availability trace) to
track accuracy-vs-uplink under partial participation."""
from __future__ import annotations

from benchmarks.fl_common import fl_config, hist_dict, save
from repro.fl.simulator import run_federated


def run() -> list[str]:
    rows, out = [], {}
    for n in (5, 10):
        h = run_federated(fl_config("pacs", "tripleplay", n_clients=n,
                                    n_per_class=48))
        out[f"clients_{n}"] = hist_dict(h)
        rows.append(f"fig7/clients{n}/final_acc,"
                    f"{h.server_acc[-1]*1e6:.0f},"
                    f"final_loss={h.server_loss[-1]:.3f}")

    # scheduler sweep: fixed N=10 population, varying cohort width K
    n_fixed = 10
    for policy in ("sync-partial", "async"):
        for k in (2, 5, 10):
            h = run_federated(fl_config(
                "pacs", "tripleplay", n_clients=n_fixed,
                n_per_class=48, participation=policy,
                clients_per_round=k, trace="skewed"))
            tag = f"{policy}_k{k}"
            out[tag] = hist_dict(h)
            rows.append(
                f"fig7/{tag}/final_acc,{h.server_acc[-1]*1e6:.0f},"
                f"uplink_mib={sum(h.uplink_bytes)/2**20:.2f}")
    save("fig7_scalability", out)
    return rows
