"""Fig. 3: GPU-utilization proxy + accuracy trajectory, FedCLIP vs
TriplePlay on the PACS-like dataset.

Wall-clock GPU utilization cannot be measured on CPU; the proxy is the
fraction of per-round compute that carries gradients/optimizer state
(trainable-FLOP share) plus measured round wall-time — FedCLIP's larger
fp32 adapter + full-precision backbone gives it both a higher and a
noisier resource profile, which is the paper's Fig. 3 claim.
"""
from __future__ import annotations

import numpy as np

from benchmarks.fl_common import fl_config, hist_dict, save
from repro.fl.simulator import run_federated


def run() -> list[str]:
    rows = []
    out = {}
    for strat in ("fedclip", "tripleplay"):
        h = run_federated(fl_config("pacs", strat))
        out[strat] = hist_dict(h)
        t = np.mean(h.round_time_s)
        rows.append(f"fig3/{strat}/round_time,{t*1e6:.0f},"
                    f"acc_final={h.server_acc[-1]:.3f}")
        rows.append(f"fig3/{strat}/util_proxy,"
                    f"{np.mean(h.util_proxy)*1e6:.1f},"
                    f"std={np.std(h.util_proxy):.4f}")
    gap = out["fedclip"]["meta"]["footprint_bytes"] / \
        max(out["tripleplay"]["meta"]["footprint_bytes"], 1)
    rows.append(f"fig3/footprint_ratio_fedclip_over_tripleplay,"
                f"{gap*1e6:.0f},paper_claims=~2x(65%vs35%)_gpu_util")
    save("fig3_resource", out)
    return rows
