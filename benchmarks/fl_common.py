"""Shared config/scaling for the federated benchmarks (Figs. 3-7).

REPRO_BENCH_SCALE=quick (default) runs CPU-sized rounds; =paper runs the
500-round protocol of the paper (hours on this container).
"""
from __future__ import annotations

import json
import os
import pathlib

from repro.fl.simulator import FLConfig

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
RESULTS.mkdir(exist_ok=True)

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")

PRESET = {
    "quick": dict(rounds=12, local_steps=6, n_per_class=32,
                  gan_steps=250, eval_every=1),
    "paper": dict(rounds=500, local_steps=10, n_per_class=60,
                  gan_steps=600, eval_every=10),
}[SCALE]


def fl_config(dataset: str, strategy: str, n_clients: int = 5,
              **kw) -> FLConfig:
    base = dict(PRESET)
    base.update(kw)
    return FLConfig(dataset=dataset, strategy=strategy,
                    n_clients=n_clients, lr=3e-3, **base)


def save(name: str, payload) -> None:
    with open(RESULTS / f"{name}.json", "w") as f:
        json.dump(payload, f, indent=1)


def hist_dict(h) -> dict:
    return {"rounds": h.rounds, "server_acc": h.server_acc,
            "tail_acc": h.tail_acc,
            "server_loss": h.server_loss, "client_loss": h.client_loss,
            "client_acc": h.client_acc, "uplink_bytes": h.uplink_bytes,
            "round_time_s": h.round_time_s, "util_proxy": h.util_proxy,
            "participation": h.participation, "staleness": h.staleness,
            "vtime": h.vtime, "meta": h.meta}
