"""Communication cost: uplink bytes per round per strategy arm, at the
simulation scale AND projected to every assigned full-size backbone
(trainable LoRA+adapter payload, fp32 vs int8 vs int4/NF4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.fl_common import save
from repro.configs import ARCHS, get_config
from repro.core.quant import quantize_tree, tree_bytes
from repro.models import build_model
from repro.models.model import _lora_layer_specs  # trainable spec source
from repro.core import adapter as adapter_lib


def _trainable_bytes(arch: str) -> dict:
    cfg = get_config(arch)
    model = build_model(cfg)
    specs = model.param_specs()["trainable"]
    fp32 = sum(int(jnp.prod(jnp.asarray(l.shape))) * 4
               for l in jax.tree.leaves(specs))
    # quantized payload sizes computed on a structurally identical tree
    zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), specs)
    q8 = tree_bytes(quantize_tree(zeros, bits=8, block=64, min_size=256,
                                  skip_names=("slot",)))
    q4 = tree_bytes(quantize_tree(zeros, bits=4, block=64, min_size=256,
                                  skip_names=("slot",)))
    backbone = cfg.param_count() * 2  # bf16 — what naive FL would ship
    return {"fp32": fp32, "int8": q8, "int4": q4, "backbone_bf16": backbone}


def run() -> list[str]:
    rows, out = [], {}
    for arch in ARCHS:
        b = _trainable_bytes(arch)
        out[arch] = b
        rows.append(
            f"comm/{arch}/uplink_int8,{b['int8']/1e3:.0f},"
            f"fp32={b['fp32']/2**20:.1f}MiB;int4={b['int4']/2**20:.1f}MiB;"
            f"vs_backbone={b['backbone_bf16']/max(b['int8'],1):.0f}x")
    save("comm_cost", out)
    return rows
