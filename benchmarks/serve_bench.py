"""Serving-plane benchmark: multi-tenant batched inference (fl.serve)
vs per-user sequential dispatch, over a Zipf/diurnal request trace.

The multi-tenancy claim this pins: one fused serve program answering a
flight of requests against the stacked adapter slabs must beat the
sequential oracle (one ``encode -> adapter -> logits`` dispatch per
request) on wall-clock throughput at >= 16 concurrent personalized
tenants, while matching its logits to quantized-at-rest tolerance.

Measured per point (population size N over a fixed-length trace), at
two offered loads — a *moderate* rate where flights stay small (the
latency-relevant regime) and a *saturating* rate where the queue keeps
flights at ``max_batch`` (the regime the throughput claim is about;
at light load a mostly-empty padded flight costs more per request than
a batch-1 dispatch, and batching buys nothing by construction):

- batched: steady-state wall throughput at both loads (req/s,
  post-compile replay), closed-loop per-request wall latency p50/p99 at
  the moderate load (cumulative dispatch completion minus arrival,
  arrivals rescaled onto the measured wall rate), virtual-clock p50/p99
  from the deterministic replay, adapter cache hit rate + evictions,
  and the serve-side compile ledger
  (``serve_batch``/``stage_encode``/``serve_store`` kinds);
- sequential: wall throughput + closed-loop p50/p99 on the same
  request stream (both tenant-family towers warmed before timing);
- parity: max |batched - sequential| logit error;
- speedup: saturated batched throughput / sequential throughput.

The small point is mixed-tenancy (adapter-only + LoRA families, the
parity-coverage case); the >=16-concurrency points are adapter-only
populations — that's where batching the hoisted-prefix head pays,
whereas a LoRA tenant's request runs the full per-user transformer
tower whether batched or not, so mixed speedup is bounded by the
family mix, not by the serving plane.

A ``refresh_point`` exercises the trainer->store handoff mid-service:
replay half the load, install a fresh trainables snapshot for every
tenant (``AdapterStore.refresh`` — resident slots re-quantized in
place, non-blocking), replay the rest, and check the refreshed plane
still matches the sequential oracle on the *refreshed* backing —
refresh is a latency event (its dispatch wall is recorded), never a
correctness event.

Writes ``BENCH_serve.json`` at the repo root. REPRO_BENCH_SCALE=quick
(default) replays 128 requests over N in {8 mixed, 24}; =paper 512
requests over N in {8 mixed, 24, 48}.
"""
from __future__ import annotations

import json
import os
import pathlib
import time

import jax
import numpy as np

from repro.fl import serve as serve_lib
from repro.fl.serve import engine as engine_lib

ROOT = pathlib.Path(__file__).resolve().parent.parent
_SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")
# (population, mixed tenancy?) per point
POINTS = {"quick": ((8, True), (24, False)),
          "paper": ((8, True), (24, False), (48, False))}[_SCALE]
N_REQUESTS = {"quick": 128, "paper": 512}[_SCALE]
MAX_BATCH = 16
CACHE_FRAC = 0.75          # cache capacity as a fraction of population
RATE_MODERATE = 400.0      # req/s: small flights, latency regime
RATE_SATURATED = 20000.0   # req/s: full flights, throughput regime


def _closed_loop_latency(arrivals, spans):
    """Per-request wall latency when the service runs the trace
    closed-loop at its measured speed: arrival times rescaled so the
    offered load matches the measured service rate, each request done
    at its dispatch's cumulative completion time. ``spans`` is
    [(n_requests, wall_s)] per dispatch in trace order."""
    total_n = sum(n for n, _ in spans)
    total_w = sum(w for _, w in spans)
    at = np.asarray(arrivals, np.float64)
    span_v = at[-1] - at[0] if len(at) > 1 else 0.0
    scale = total_w / span_v if span_v > 0 else 0.0
    at = (at - at[0]) * scale
    lat, done, i = [], 0.0, 0
    for n, w in spans:
        start = max(done, at[i])
        done = start + w
        lat.extend(done - at[i + j] for j in range(n))
        i += n
    return np.asarray(lat)


def bench_point(n_users: int, mixed: bool):
    plane = serve_lib.demo_plane(
        n_users, mixed=mixed, seed=0, quant_bits=8,
        max_entries=max(MAX_BATCH, int(n_users * CACHE_FRAC)),
        max_batch=MAX_BATCH)
    trace = serve_lib.zipf_request_trace(
        n_users, N_REQUESTS, seed=1, rate=RATE_MODERATE, period=1.0,
        amplitude=0.5)
    images = serve_lib.request_images(plane, trace, seed=1)
    trace_sat = serve_lib.zipf_request_trace(
        n_users, N_REQUESTS, seed=1, rate=RATE_SATURATED)
    images_sat = serve_lib.request_images(plane, trace_sat, seed=1)

    # warm every compile + the cache's steady state, then measure
    serve_lib.replay(plane["engine"], trace, images,
                     collect_logits=False)
    serve_lib.replay(plane["engine"], trace_sat, images_sat,
                     collect_logits=False)
    rec = serve_lib.replay(plane["engine"], trace, images)
    rec_sat = serve_lib.replay(plane["engine"], trace_sat, images_sat,
                               collect_logits=False)

    reqs = [(int(u), im) for u, im in zip(trace.uid, images)]
    # sequential oracle: warm the per-request jit for BOTH tenant
    # families (adapter-only and LoRA trees trace separately), then
    # time each dispatch for its closed-loop latency profile
    warm_uids = {("lora" in plane["backing"][int(u)]): i
                 for i, (u, _) in enumerate(reqs)}
    engine_lib.serve_sequential(
        plane["frozen"], plane["ccfg"], plane["class_emb"],
        plane["backing"], [reqs[i] for i in warm_uids.values()])
    seq_spans, seq_out = [], []
    t0 = time.perf_counter()
    for r in reqs:
        s0 = time.perf_counter()
        seq_out.append(engine_lib.serve_sequential(
            plane["frozen"], plane["ccfg"], plane["class_emb"],
            plane["backing"], [r])[0])
        seq_spans.append((1, time.perf_counter() - s0))
    seq_wall = time.perf_counter() - t0
    seq_out = np.stack(seq_out)

    bat_spans = [(f["n"], f["wall_s"]) for f in rec["flights"]]
    lat_b = _closed_loop_latency(trace.t, bat_spans)
    lat_s = _closed_loop_latency(trace.t, seq_spans)
    ledger = {k: v for k, v in plane["runtime"].stats().items()
              if k in ("serve_batch", "serve_store", "stage_encode")}
    return {
        "n_users": n_users,
        "mixed": mixed,
        "concurrency": rec["concurrency"],
        "n_requests": trace.n,
        "max_batch": MAX_BATCH,
        "cache_entries": plane["store"].max_entries,
        "quant_bits": plane["store"].quant_bits,
        "batched": {
            "wall_s": rec["wall_s"],
            "throughput_req_s": rec["throughput_wall"],
            "throughput_saturated_req_s": rec_sat["throughput_wall"],
            "mean_flight": trace.n / rec["n_flights"],
            "mean_flight_saturated": trace_sat.n / rec_sat["n_flights"],
            "lat_wall_p50_ms": float(np.percentile(lat_b, 50)) * 1e3,
            "lat_wall_p99_ms": float(np.percentile(lat_b, 99)) * 1e3,
            "lat_v_p50_ms": rec["lat_v_p50"] * 1e3,
            "lat_v_p99_ms": rec["lat_v_p99"] * 1e3,
            "n_flights": rec["n_flights"],
            "hit_rate": rec["store"]["hit_rate"],
            "evictions": rec["store"]["evictions"],
            "bytes_at_rest": plane["store"].bytes_at_rest(),
        },
        "sequential": {
            "wall_s": seq_wall,
            "throughput_req_s": trace.n / max(seq_wall, 1e-12),
            "lat_wall_p50_ms": float(np.percentile(lat_s, 50)) * 1e3,
            "lat_wall_p99_ms": float(np.percentile(lat_s, 99)) * 1e3,
        },
        "speedup": rec_sat["throughput_wall"] /
                   (trace.n / max(seq_wall, 1e-12)),
        "max_abs_logit_err": float(
            np.max(np.abs(rec["logits"] - seq_out))),
        "ledger": ledger,
    }


def refresh_point(n_users: int = 8):
    """Mid-replay store refresh: serve, install new snapshots for every
    tenant, keep serving — refreshed tenants must still match the
    sequential oracle run on the refreshed backing."""
    plane = serve_lib.demo_plane(
        n_users, mixed=False, seed=0, quant_bits=8,
        max_entries=max(MAX_BATCH, int(n_users * CACHE_FRAC)),
        max_batch=MAX_BATCH)
    store = plane["store"]
    trace_a = serve_lib.zipf_request_trace(
        n_users, N_REQUESTS // 2, seed=2, rate=RATE_MODERATE,
        period=1.0, amplitude=0.5)
    images_a = serve_lib.request_images(plane, trace_a, seed=2)
    trace_b = serve_lib.zipf_request_trace(
        n_users, N_REQUESTS // 2, seed=3, rate=RATE_MODERATE,
        period=1.0, amplitude=0.5)
    images_b = serve_lib.request_images(plane, trace_b, seed=3)

    serve_lib.replay(plane["engine"], trace_a, images_a,
                     collect_logits=False)     # warm + populate cache
    n_res_before = len(store)
    # new trainables snapshot for every tenant (same slab families)
    updates = {uid: jax.tree.map(lambda l: l * 1.01 + 0.003, tree)
               for uid, tree in store.backing.items()}
    t0 = time.perf_counter()
    n_rewritten = store.refresh(updates)
    refresh_dispatch_s = time.perf_counter() - t0   # non-blocking wall
    rec_b = serve_lib.replay(plane["engine"], trace_b, images_b)

    reqs_b = [(int(u), im) for u, im in zip(trace_b.uid, images_b)]
    seq_out = np.stack(engine_lib.serve_sequential(
        plane["frozen"], plane["ccfg"], plane["class_emb"],
        store.backing, reqs_b))
    err = float(np.max(np.abs(rec_b["logits"] - seq_out)))
    s = store.stats()
    return {
        "n_users": n_users,
        "n_requests_each_half": N_REQUESTS // 2,
        "resident_at_refresh": n_res_before,
        "refreshes": s["refreshes"],
        "refreshed_resident": n_rewritten,
        "refresh_dispatch_s": refresh_dispatch_s,
        "post_refresh_throughput_req_s": rec_b["throughput_wall"],
        "post_refresh_hit_rate": rec_b["store"]["hit_rate"],
        "max_abs_logit_err_after_refresh": err,
    }


def main():
    points = []
    for n, mixed in POINTS:
        p = bench_point(n, mixed)
        points.append(p)
        print(f"N={n:3d}{'m' if mixed else ' '} "
              f"concurrency={p['concurrency']:3d} "
              f"batched={p['batched']['throughput_saturated_req_s']:8.1f}"
              f" req/s (sat, flight "
              f"{p['batched']['mean_flight_saturated']:.1f}) "
              f"sequential={p['sequential']['throughput_req_s']:8.1f} "
              f"speedup={p['speedup']:.2f}x "
              f"hit_rate={p['batched']['hit_rate']:.2f} "
              f"err={p['max_abs_logit_err']:.2e}")
    rp = refresh_point()
    print(f"refresh N={rp['n_users']:3d} resident={rp['resident_at_refresh']} "
          f"rewritten={rp['refreshed_resident']} "
          f"dispatch={rp['refresh_dispatch_s']*1e3:.1f} ms "
          f"err={rp['max_abs_logit_err_after_refresh']:.2e}")
    assert rp["refreshed_resident"] == rp["resident_at_refresh"]
    out = {"scale": _SCALE, "n_requests": N_REQUESTS,
           "points": points, "refresh_point": rp}
    path = ROOT / "BENCH_serve.json"
    path.write_text(json.dumps(out, indent=1))
    print(f"wrote {path}")
    big = [p for p in points if p["concurrency"] >= 16]
    assert big, "no point reached 16 concurrent tenants"
    assert all(p["speedup"] > 1.0 for p in big), \
        "batched serving failed to beat sequential dispatch"


if __name__ == "__main__":
    main()
