"""Kernel microbenchmarks.

On this CPU container the Pallas kernels execute in interpret mode (Python
— not performance-representative), so wall-clock is measured on the jnp
reference path (the dry-run execution path) and the Pallas kernels are
timed in interpret mode only for regression tracking. The TPU-relevant
numbers are the analytic VMEM/MXU tile schedules reported as `derived`.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as qlib
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.quant_matmul import quant_matmul as qmm_pallas


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run() -> list[str]:
    rows = []
    rng = np.random.RandomState(0)
    # flash attention ref path (B, S, H, D)
    for S in (512, 2048):
        q = jnp.asarray(rng.randn(2, S, 8, 64), jnp.float32)
        k = jnp.asarray(rng.randn(2, S, 2, 64), jnp.float32)
        f = jax.jit(lambda q, k: ref.flash_attention(q, k, k, causal=True))
        t = _time(f, q, k)
        flops = 4 * 2 * 8 * S * S * 64 / 2
        rows.append(f"kernel/flash_ref/S{S},{t*1e6:.0f},"
                    f"gflops={flops/t/1e9:.1f}")
    # quant matmul ref vs dense
    x = jnp.asarray(rng.randn(512, 1024), jnp.float32)
    w = jnp.asarray(rng.randn(1024, 1024), jnp.float32)
    for bits, mode in ((8, "linear"), (4, "nf4")):
        qt = qlib.quantize(w, bits=bits, block=128, mode=mode)
        f = jax.jit(lambda x, qt=qt: ref.quant_matmul(x, qt))
        t = _time(f, x)
        dense_t = _time(jax.jit(lambda x: x @ w), x)
        rows.append(f"kernel/qmm_ref/{mode}{bits},{t*1e6:.0f},"
                    f"dense_us={dense_t*1e6:.0f};"
                    f"bytes_saved={1 - (qt.nbytes_packed() / w.nbytes):.2f}")
    # quant matmul Pallas path (interpret mode — regression tracking for
    # the kernel body itself, not performance; the ref row above is the
    # CPU execution path)
    xs = jnp.asarray(rng.randn(32, 256), jnp.float32)
    ws = jnp.asarray(rng.randn(256, 256), jnp.float32)
    qts = qlib.quantize(ws, bits=4, block=128, mode="nf4")
    f = jax.jit(lambda x: qmm_pallas(x, qts, block_m=32, block_n=128,
                                     interpret=True))
    rows.append(f"kernel/qmm_pallas_interpret/nf44,{_time(f, xs)*1e6:.0f},"
                f"shape=32x256x256")
    # fused LoRA matmul vs the legacy einsum chain (jitted CPU execution
    # paths: ops.lora_matmul's fused ref vs base-matmul + separate
    # delta), forward and forward+backward
    K, N, r, scale = 1024, 1024, 8, 2.0
    a = jnp.asarray(rng.randn(K, r) * 0.1, jnp.float32)
    b = jnp.asarray(rng.randn(r, N) * 0.1, jnp.float32)
    ct = jnp.asarray(rng.randn(512, N), jnp.float32)

    def _best2(fa, fb, *args, reps=3, iters=10):
        # interleaved min-over-repeats: this 2-core container's
        # scheduler noise easily dwarfs the fused-vs-chain delta, and
        # timing one side to completion first biases against it
        ta, tb = [], []
        for _ in range(reps):
            ta.append(_time(fa, *args, iters=iters))
            tb.append(_time(fb, *args, iters=iters))
        return min(ta), min(tb)

    for bits, mode in ((8, "linear"), (4, "nf4")):
        qt = qlib.quantize(w, bits=bits, block=128, mode=mode)

        def chain(x, a, b, qt=qt):
            xf = x.astype(jnp.float32)
            base = ref.quant_matmul(xf, qt)
            h = jnp.einsum("mk,kr->mr", xf, a)
            return (base + scale * (h @ b)).astype(x.dtype)

        def fused(x, a, b, qt=qt):
            return kops.lora_matmul(x, qt, a, b, scale=scale)

        # the two fwd programs compile to identical HLO on CPU (both
        # execute the fp32-fused ref path), so extra reps just converge
        # the mins of the same program
        t_f, t_c = _best2(jax.jit(fused), jax.jit(chain), x, a, b,
                          reps=5, iters=20)
        rows.append(f"kernel/lora_fused_fwd/{mode}{bits},{t_f*1e6:.0f},"
                    f"chain_us={t_c*1e6:.0f};"
                    f"speedup={t_c/t_f:.2f}x")
        # value_and_grad so the training step's forward gemm can't be
        # dead-coded, and ct passed as a traced argument — a closed-over
        # cotangent is a compile-time constant and XLA folds the whole
        # g @ Wᵀ gemm away, timing neither path's backward
        gf = jax.jit(jax.value_and_grad(
            lambda x, a, b, ct: (fused(x, a, b) * ct).sum(),
            argnums=(0, 1, 2)))
        gc = jax.jit(jax.value_and_grad(
            lambda x, a, b, ct: (chain(x, a, b) * ct).sum(),
            argnums=(0, 1, 2)))
        t_fb, t_cb = _best2(gf, gc, x, a, b, ct)
        rows.append(f"kernel/lora_fused_bwd/{mode}{bits},{t_fb*1e6:.0f},"
                    f"chain_us={t_cb*1e6:.0f};"
                    f"speedup={t_cb/t_fb:.2f}x")
    # int8 quantized-compute GAN gemm vs fp gemm conv
    from repro.kernels import gan_conv
    xg = jnp.asarray(rng.randn(8, 16, 16, 32), jnp.float32)
    wg = jnp.asarray(rng.randn(4, 4, 32, 64) * 0.1, jnp.float32)
    t8 = _time(jax.jit(gan_conv.conv4x4_s2_int8), xg, wg)
    tf = _time(jax.jit(gan_conv.conv4x4_s2), xg, wg)
    rows.append(f"kernel/gan_conv_int8,{t8*1e6:.0f},"
                f"fp_us={tf*1e6:.0f};shape=8x16x16x32->64")
    # blockwise quant
    g = jnp.asarray(rng.randn(4096, 512), jnp.float32)
    f = jax.jit(lambda g: jax.tree.leaves(qlib.quantize(g, bits=8,
                                                        block=128))[0])
    rows.append(f"kernel/blockwise_quant,{_time(f, g)*1e6:.0f},"
                f"tensor=4096x512")
    # selective scan (oracle path — the CPU execution path of the model)
    B, S, di, N = 2, 512, 128, 16
    dt = jnp.asarray(np.abs(rng.randn(B, S, di)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.randn(B, S, di), jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(di, N)), jnp.float32)
    f = jax.jit(lambda *a: ref.selective_scan(*a)[0])
    t = _time(f, dt, x, Bm, Cm, A)
    rows.append(f"kernel/selective_scan_ref,{t*1e6:.0f},"
                f"elems={B*S*di*N};Mstate_upd_per_s="
                f"{B*S*di*N/t/1e6:.0f}")
    return rows
