"""Kernel microbenchmarks.

On this CPU container the Pallas kernels execute in interpret mode (Python
— not performance-representative), so wall-clock is measured on the jnp
reference path (the dry-run execution path) and the Pallas kernels are
timed in interpret mode only for regression tracking. The TPU-relevant
numbers are the analytic VMEM/MXU tile schedules reported as `derived`.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as qlib
from repro.kernels import ref


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run() -> list[str]:
    rows = []
    rng = np.random.RandomState(0)
    # flash attention ref path (B, S, H, D)
    for S in (512, 2048):
        q = jnp.asarray(rng.randn(2, S, 8, 64), jnp.float32)
        k = jnp.asarray(rng.randn(2, S, 2, 64), jnp.float32)
        f = jax.jit(lambda q, k: ref.flash_attention(q, k, k, causal=True))
        t = _time(f, q, k)
        flops = 4 * 2 * 8 * S * S * 64 / 2
        rows.append(f"kernel/flash_ref/S{S},{t*1e6:.0f},"
                    f"gflops={flops/t/1e9:.1f}")
    # quant matmul ref vs dense
    x = jnp.asarray(rng.randn(512, 1024), jnp.float32)
    w = jnp.asarray(rng.randn(1024, 1024), jnp.float32)
    for bits, mode in ((8, "linear"), (4, "nf4")):
        qt = qlib.quantize(w, bits=bits, block=128, mode=mode)
        f = jax.jit(lambda x, qt=qt: ref.quant_matmul(x, qt))
        t = _time(f, x)
        dense_t = _time(jax.jit(lambda x: x @ w), x)
        rows.append(f"kernel/qmm_ref/{mode}{bits},{t*1e6:.0f},"
                    f"dense_us={dense_t*1e6:.0f};"
                    f"bytes_saved={1 - (qt.nbytes_packed() / w.nbytes):.2f}")
    # blockwise quant
    g = jnp.asarray(rng.randn(4096, 512), jnp.float32)
    f = jax.jit(lambda g: jax.tree.leaves(qlib.quantize(g, bits=8,
                                                        block=128))[0])
    rows.append(f"kernel/blockwise_quant,{_time(f, g)*1e6:.0f},"
                f"tensor=4096x512")
    # selective scan (oracle path — the CPU execution path of the model)
    B, S, di, N = 2, 512, 128, 16
    dt = jnp.asarray(np.abs(rng.randn(B, S, di)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.randn(B, S, di), jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(di, N)), jnp.float32)
    f = jax.jit(lambda *a: ref.selective_scan(*a)[0])
    t = _time(f, dt, x, Bm, Cm, A)
    rows.append(f"kernel/selective_scan_ref,{t*1e6:.0f},"
                f"elems={B*S*di*N};Mstate_upd_per_s="
                f"{B*S*di*N/t/1e6:.0f}")
    return rows
