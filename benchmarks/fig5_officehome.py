"""Fig. 5: the three-arm comparison on the Office-Home-like dataset.

Office-Home has more classes (16 in our stand-in) so the quick protocol
needs more rounds/data per class than PACS to rise above chance — the
overrides below; REPRO_BENCH_SCALE=paper removes the difference."""
from __future__ import annotations

from benchmarks.fl_common import SCALE, fl_config, hist_dict, save
from repro.fl.simulator import run_federated


def run() -> list[str]:
    rows, out = [], {}
    boost = dict(rounds=20, n_per_class=48, local_steps=8,
                 gan_steps=300) if SCALE == "quick" else {}
    for strat in ("fedclip", "qlora_nogan", "tripleplay"):
        h = run_federated(fl_config("officehome", strat, **boost))
        out[strat] = hist_dict(h)
        accs = h.server_acc
        target = 0.5 * max(max(accs), 1e-9)
        t2t = next((r for r, a in zip(h.rounds, accs) if a >= target),
                   h.rounds[-1])
        rows.append(f"fig5/officehome/{strat}/final_acc,"
                    f"{accs[-1]*1e6:.0f},rounds_to_half_best={t2t}")
    save("fig5_officehome", out)
    return rows
