"""Round-time benchmark: sequential per-client loop vs the batched
cohort engine (fl.cohort), across cohort sizes.

Measures steady-state (post-compile) mean round time for
``n_clients in {2, 8, 32}`` on three arms — fedclip (adapter-only,
where staging lets the engine hoist the whole frozen backbone out of
the training loop), qlora_nogan (adapter + LoRA + int8 uplink
quantization, where only the patch embedding hoists), and tripleplay
(qlora + client-side GAN rebalancing; capped at 8 clients to keep the
GAN-prep wall-clock sane) — and writes ``BENCH_fl_round.json`` at the
repo root so the perf trajectory is tracked from this PR onward. Both
paths compute the same local-training math (see the
cohort-vs-sequential parity tests). Tripleplay points record GAN prep
separately from round time (``gan_prep_time_s`` steady-state,
``gan_compile_time_s`` one-time — the ``History.meta["compile_time_s"]``
hygiene).

A second sweep holds the population fixed (N = max(N_CLIENTS)) and
varies ``clients_per_round``: sync-partial rounds gather K rows of the
already-staged pools inside the fused program, so round time should
scale with K while staging cost stays one-time. Results land in the
same ``BENCH_fl_round.json`` under ``partial_points``.

A third comparison (``gan_points``) times the fleet-GAN engine
(``fl.fleetgan``: every client's conditional GAN trained/synthesized in
stacked fused programs) against the sequential per-client
``prepare_gan`` loop at 8 clients, both steady-state.

Every arm also records the bucketed program runtime's compile ledger
(``fl.runtime``): ``n_compiles``/``compile_time_s`` per cohort point,
the cumulative subset-round compile count across the K sweep (which
plateaus at the power-of-two bucket count instead of growing per K),
and the fleet-GAN ``gan_*`` program count (one train + one synthesis
whatever the batch-size split) — so ``BENCH_fl_round.json`` tracks the
fixed-cost drop alongside the steady-state speedups.

A fourth comparison (``chaos_points``) runs the full simulator under
fault injection (``fl.sched.chaos``: deterministic dropouts,
device-class stragglers, lost uplinks) on one shared diurnal trace and
records, for sync-partial vs async-buffered, the wall-clock round
time, the final virtual-clock time (where the policies actually
diverge — a sync barrier pays every straggler, the async buffer does
not), the final tail accuracy, and the fault ledger.

A fifth section (``mesh_points``) re-execs this script in a child
interpreter with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the flag must be set before jax imports) and times the mesh-scaled
runtime: a **1024-client population** running sync-partial K=64 rounds
with the cohort axis sharded over an 8-device data mesh (hierarchical
tree aggregation, shard-multiple width buckets) plus a sharded
fleet-GAN prep next to its unsharded twin, each with its compile
ledger. These are the paper-scale benchmark points ROADMAP's
mesh-scaling item asks for — real measurements, not aspirations.

A sixth section (``pipeline_points``, also runnable alone via
``--pipeline-only``) times the simulator's round *loop* itself:
barrier (serial, one host sync + blocking eval fetch per round) vs
pipelined (non-blocking handles, pre-drawn selections, deferred ring
metrics) over a steady-state multi-round run on one shared runtime —
asserting bitwise History parity, zero new compiles, and a sync-free
pipelined steady state while reporting the loop-wall speedup and the
share of eval cost the overlap hides.

REPRO_BENCH_SCALE=quick (default) times 3 rounds per point; =paper 10.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clip as clip_lib
from repro.data.synthetic import class_tokens, make_dataset
from repro.fl import client as client_lib
from repro.fl import cohort as cohort_lib
from repro.fl import fleetgan
from repro.fl import partition, server
from repro.fl import strategies as strategies_lib
from repro.fl.strategies import STRATEGIES

ROOT = pathlib.Path(__file__).resolve().parent.parent
N_CLIENTS = (2, 8, 32)
CLIENTS_PER_ROUND = (2, 4, 8, 16)   # sync-partial sweep at fixed N
LOCAL_STEPS = 6
BATCH = 32
LR = 3e-3
_SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")
ROUNDS = {"quick": 3, "paper": 10}[_SCALE]
GAN_STEPS = {"quick": 20, "paper": 150}[_SCALE]
GAN_N_CLIENTS = 8                    # fleet-vs-sequential GAN point


def _gan_keys(n: int):
    return [jax.random.fold_in(jax.random.PRNGKey(7),
                               strategies_lib.GAN_RNG_OFFSET + i)
            for i in range(n)]


def _setup(arm: str, n_clients: int, *, gan_prep: bool = True):
    strat = STRATEGIES[arm]
    ccfg = clip_lib.CLIPConfig()
    frozen = clip_lib.init_clip(jax.random.PRNGKey(3), ccfg)
    data = make_dataset("pacs", n_per_class=60, seed=0,
                        longtail_gamma=8.0)
    spec = data["spec"]
    class_emb = clip_lib.text_embedding(
        frozen, ccfg,
        jnp.asarray(class_tokens(spec, np.arange(spec.n_classes))))
    parts = partition.dirichlet_partition(data["labels"], n_clients, 0.5,
                                          seed=0)
    # participation = clients that actually hold data (high client counts
    # leave some Dirichlet shards empty; neither path can train on zero
    # samples)
    clients = [client_lib.Client(
        cid=i, images=data["images"][idx], labels=data["labels"][idx],
        n_classes=spec.n_classes, strategy=strat)
        for i, idx in enumerate(parts) if len(idx) > 0]
    gan_rep = None
    if strat.use_gan and gan_prep:
        # fleet-GAN rebalancing before staging, so both round paths
        # train on the same augmented pools; timing is reported
        # separately from round time
        gan_rep = fleetgan.prepare_gan_fleet(
            clients, _gan_keys(len(clients)), steps=GAN_STEPS)
    tr = client_lib.init_trainable(jax.random.PRNGKey(1), ccfg, strat)
    return strat, ccfg, frozen, class_emb, clients, tr, gan_rep


def time_sequential(frozen, tr, class_emb, ccfg, clients) -> float:
    def one_round(tr, rnd):
        updates = []
        for i, c in enumerate(clients):
            after, _ = c.local_train(frozen, tr, class_emb, ccfg,
                                     steps=LOCAL_STEPS, batch_size=BATCH,
                                     lr=LR, seed=rnd * 100 + i)
            upd, _ = c.make_update(tr, after)
            updates.append((c.n, upd))
        return server.aggregate(tr, updates)

    tr = jax.block_until_ready(one_round(tr, 999))      # compile/warmup
    t0 = time.perf_counter()
    for rnd in range(ROUNDS):
        tr = one_round(tr, rnd)
    jax.block_until_ready(tr)
    return (time.perf_counter() - t0) / ROUNDS


def time_cohort(strat, frozen, tr, class_emb, ccfg, clients):
    """Returns (steady-state round seconds, runtime compile stats) —
    the fresh per-arm ProgramRuntime makes n_compiles/compile seconds a
    cold measurement of the arm's fixed cost."""
    engine = cohort_lib.CohortEngine(
        frozen=frozen, ccfg=ccfg, class_emb=class_emb, clients=clients,
        cfg=cohort_lib.CohortConfig(strategy=strat,
                                    local_steps=LOCAL_STEPS,
                                    batch_size=BATCH, lr=LR))
    key = jax.random.PRNGKey(0)
    tr = jax.tree.map(jnp.copy, tr)      # run_round donates its input
    tr, _ = engine.run_round(tr, jax.random.fold_in(key, 999))  # warmup
    jax.block_until_ready(tr)
    t0 = time.perf_counter()
    for rnd in range(ROUNDS):
        tr, _ = engine.run_round(tr, jax.random.fold_in(key, rnd))
    jax.block_until_ready(tr)
    rt = engine.runtime
    return ((time.perf_counter() - t0) / ROUNDS,
            {"n_compiles": rt.n_compiles,
             "compile_time_s": rt.compile_time_s})


def time_subset(engine, tr, k: int) -> tuple[float, int]:
    """Steady-state sync-partial round time at cohort width k: the
    fused subset program compiles once per k; each round indexes a
    fresh selection of the device-staged pools (no re-upload). The
    engine is shared across widths — staging is one-time per arm."""
    rs = np.random.RandomState(0)
    sels = [rs.choice(engine.n_clients, k, replace=False)
            for _ in range(ROUNDS + 1)]
    key = jax.random.PRNGKey(0)
    tr = jax.tree.map(jnp.copy, tr)
    tr, m = engine.run_subset_round(tr, sels[0],
                                    jax.random.fold_in(key, 999))
    jax.block_until_ready(jax.tree.leaves(tr))          # compile/warmup
    t0 = time.perf_counter()
    for rnd in range(ROUNDS):
        tr, m = engine.run_subset_round(tr, sels[rnd + 1],
                                        jax.random.fold_in(key, rnd))
    jax.block_until_ready(jax.tree.leaves(tr))
    return (time.perf_counter() - t0) / ROUNDS, int(m["uplink_bytes"])


def time_gan_sequential(n_clients: int) -> float:
    """Steady-state sequential per-client ``prepare_gan`` loop: a first
    pass over identically-shaped clients warms every per-step
    ``train_step`` / ``synthesize`` compile, then a fresh population is
    timed."""
    keys = None
    for attempt in range(2):
        _, _, _, _, clients, _, _ = _setup("tripleplay", n_clients,
                                           gan_prep=False)
        keys = _gan_keys(len(clients))
        steps = 2 if attempt == 0 else GAN_STEPS   # warmup pass first
        t0 = time.perf_counter()
        for i, c in enumerate(clients):
            if c.n >= strategies_lib.GAN_MIN_POOL:
                c.prepare_gan(keys[i], steps=steps)
        dt = time.perf_counter() - t0
    return dt


def time_gan_fleet(n_clients: int) -> fleetgan.FleetGANReport:
    """Fleet-GAN prep on a fresh identical population; the report splits
    one-time compile cost from steady-state prep. The executable cache
    is dropped first so ``fleet_gan_compile_s`` records the true cold
    cost even though the tripleplay round points above already warmed
    identical shapes."""
    fleetgan.clear_cache()
    _, _, _, _, clients, _, _ = _setup("tripleplay", n_clients,
                                       gan_prep=False)
    return fleetgan.prepare_gan_fleet(
        clients, _gan_keys(len(clients)), steps=GAN_STEPS)


def _time_cohort_best(strat, frozen, tr, class_emb, ccfg, clients,
                      reps=3):
    """``time_cohort`` with min-over-repeats steady state: the fused-
    vs-chain LoRA delta is a few percent of a full training round, so
    one-shot means on this container drown it in scheduler noise."""
    engine = cohort_lib.CohortEngine(
        frozen=frozen, ccfg=ccfg, class_emb=class_emb, clients=clients,
        cfg=cohort_lib.CohortConfig(strategy=strat,
                                    local_steps=LOCAL_STEPS,
                                    batch_size=BATCH, lr=LR))
    key = jax.random.PRNGKey(0)
    tr = jax.tree.map(jnp.copy, tr)
    tr, _ = engine.run_round(tr, jax.random.fold_in(key, 999))  # warmup
    jax.block_until_ready(tr)
    best = float("inf")
    for rep in range(reps):
        t0 = time.perf_counter()
        for rnd in range(ROUNDS):
            tr, _ = engine.run_round(
                tr, jax.random.fold_in(key, rep * ROUNDS + rnd))
        jax.block_until_ready(tr)
        best = min(best, (time.perf_counter() - t0) / ROUNDS)
    rt = engine.runtime
    return best, {"n_compiles": rt.n_compiles,
                  "compile_time_s": rt.compile_time_s}


def qlora_fused_points():
    """Fused-LoRA vs einsum-chain cohort rounds on the qlora arm.

    ``REPRO_LORA_FUSED`` toggles the routing inside ``core.lora.linear``
    at trace time; the cohort static key includes it, so the two
    engines compile apart instead of sharing stale executables. The
    kernel-trace counters assert each engine actually took its path —
    a silent fallback here would time the same program twice and
    report a fake 1.0x."""
    from repro.kernels import ops as kops
    saved = os.environ.get("REPRO_LORA_FUSED")
    pts = []
    try:
        for n in N_CLIENTS:
            strat, ccfg, frozen, class_emb, clients, tr, _ = _setup(
                "qlora_nogan", n)
            times = {}
            for impl, env in (("fused", "1"), ("chain", "0")):
                os.environ["REPRO_LORA_FUSED"] = env
                kops.reset_kernel_traces()
                coh, stats = _time_cohort_best(strat, frozen, tr,
                                               class_emb, ccfg, clients)
                took = f"lora_linear_{impl}"
                other = ("lora_linear_chain" if impl == "fused"
                         else "lora_linear_fused")
                assert kops.KERNEL_TRACES.get(took, 0) > 0 and \
                    kops.KERNEL_TRACES.get(other, 0) == 0, \
                    (impl, dict(kops.KERNEL_TRACES))
                times[impl] = (coh, stats)
            point = {"strategy": "qlora_nogan", "n_clients": n,
                     "n_clients_effective": len(clients),
                     "cohort_round_s_fused": times["fused"][0],
                     "cohort_round_s_chain": times["chain"][0],
                     "lora_fused_speedup":
                         times["chain"][0] / times["fused"][0],
                     "n_compiles_fused": times["fused"][1]["n_compiles"],
                     "n_compiles_chain": times["chain"][1]["n_compiles"]}
            pts.append(point)
            print(f"qlora-fused  n_clients={n:3d}  "
                  f"fused={times['fused'][0]*1e3:7.1f} ms  "
                  f"chain={times['chain'][0]*1e3:7.1f} ms  "
                  f"speedup={point['lora_fused_speedup']:.2f}x")
    finally:
        if saved is None:
            os.environ.pop("REPRO_LORA_FUSED", None)
        else:
            os.environ["REPRO_LORA_FUSED"] = saved
    return pts


def _merge_qlora_points(results: dict, pts: list) -> None:
    """Attach the fused/chain timings to the matching qlora cohort rows
    and keep the dedicated section."""
    results["qlora_fused_points"] = pts
    for p in results.get("points", []):
        if p.get("strategy") != "qlora_nogan":
            continue
        for q in pts:
            if q["n_clients"] == p["n_clients"]:
                p["cohort_round_s_fused"] = q["cohort_round_s_fused"]
                p["cohort_round_s_chain"] = q["cohort_round_s_chain"]
                p["lora_fused_speedup"] = q["lora_fused_speedup"]


def qlora_only_main():
    """Re-run just the qlora fused-vs-chain points and merge them into
    the existing ``BENCH_fl_round.json`` (the full bench keeps its
    mesh/chaos/GAN sections from the last complete run)."""
    out = ROOT / "BENCH_fl_round.json"
    results = (json.load(open(out)) if out.exists()
               else {"config": {}, "points": []})
    _merge_qlora_points(results, qlora_fused_points())
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {out}")


PIPE_N = 12                   # pipelined-loop point: population,
PIPE_K = 4                    # cohort width,
PIPE_ROUNDS = {"quick": 12, "paper": 30}[_SCALE]   # timed rounds


def pipeline_points():
    """Steady-state R-round loop wall: the barrier (serial) round loop
    vs the pipelined one (``fl.simulator`` ``cfg.pipeline``), on the
    sync-partial arm with server eval every round — the configuration
    where the serial loop pays a host sync + Python row assembly +
    blocking eval fetch per round while the device sits idle.

    Both modes share one ProgramRuntime (a barrier warmup run compiles
    every program both loops use — identical kinds/shapes by
    construction), so ``meta['loop_wall_s']`` is a pure steady-state
    measurement, and the zero-new-compiles claim is checked rather than
    assumed. History parity is asserted bitwise: the speedup below is
    for the *same* computation, fetched late.

    The wall-clock delta measures how much host time the barrier loop
    spends blocked while the device could be fed: it scales with the
    cores available to overlap host and device work. ``n_cpus`` is
    recorded with the point — on a 1-CPU container the loop is
    work-conserving either way (nothing to overlap with, speedup
    ~1.0x) and the machine-independent signal is the sync ledger:
    barrier blocks the host 1+ times per round, pipelined zero."""
    import os

    from repro.fl import runtime as runtime_lib
    from repro.fl.simulator import FLConfig, run_federated

    base = dict(dataset="pacs", strategy="fedclip", n_clients=PIPE_N,
                rounds=PIPE_ROUNDS, local_steps=2, n_per_class=12,
                batch_size=8, lr=LR, participation="sync-partial",
                clients_per_round=PIPE_K, trace="skewed")
    rt = runtime_lib.ProgramRuntime()
    run_federated(FLConfig(**base, eval_every=1, pipeline="barrier"),
                  runtime=rt)                      # compile warmup
    n_compiles0 = rt.n_compiles

    def best(cfg, reps=3):
        runs = [run_federated(cfg, runtime=rt) for _ in range(reps)]
        return min(runs, key=lambda h: h.meta["loop_wall_s"])

    hb = best(FLConfig(**base, eval_every=1, pipeline="barrier"))
    hp = best(FLConfig(**base, eval_every=1, pipeline="pipelined"))
    # eval off (only the mandatory last-round eval): isolates how much
    # of the barrier loop's wall is eval the pipelined loop overlaps
    hb0 = best(FLConfig(**base, eval_every=PIPE_ROUNDS + 1,
                        pipeline="barrier"))
    hp0 = best(FLConfig(**base, eval_every=PIPE_ROUNDS + 1,
                        pipeline="pipelined"))
    assert rt.n_compiles == n_compiles0, \
        ("pipelined loop introduced new compiles",
         n_compiles0, rt.n_compiles)
    for f in ("rounds", "server_acc", "server_loss", "tail_acc",
              "client_loss", "client_acc", "uplink_bytes",
              "participation", "staleness", "vtime"):
        assert getattr(hb, f) == getattr(hp, f), \
            ("pipelined/barrier History mismatch", f)
    assert hp.meta["syncs_per_round"] == 0.0, hp.meta["sync_counts"]

    wb, wp = hb.meta["loop_wall_s"], hp.meta["loop_wall_s"]
    eval_cost_barrier = max(wb - hb0.meta["loop_wall_s"], 0.0)
    eval_cost_pipe = max(wp - hp0.meta["loop_wall_s"], 0.0)
    point = {
        "strategy": "fedclip", "participation": "sync-partial",
        "n_clients": PIPE_N, "clients_per_round": PIPE_K,
        "rounds": PIPE_ROUNDS, "eval_every": 1,
        "n_cpus": len(os.sched_getaffinity(0)),
        "barrier_loop_wall_s": wb, "pipelined_loop_wall_s": wp,
        "pipeline_speedup": wb / wp,
        "barrier_syncs_per_round": hb.meta["syncs_per_round"],
        "pipelined_syncs_per_round": hp.meta["syncs_per_round"],
        "barrier_sync_counts": hb.meta["sync_counts"],
        "pipelined_sync_counts": hp.meta["sync_counts"],
        "prepared_rounds": hp.meta["prepared_rounds"],
        # share of the barrier loop's per-round eval cost the pipelined
        # loop hides under the next round's train dispatch
        "barrier_eval_cost_s": eval_cost_barrier,
        "pipelined_eval_cost_s": eval_cost_pipe,
        "eval_overlap_share": (
            (eval_cost_barrier - eval_cost_pipe) / eval_cost_barrier
            if eval_cost_barrier > 0 else 0.0),
        "history_bitwise_equal": True,
        "new_compiles_vs_barrier": 0}
    print(f"pipeline     N={PIPE_N} K={PIPE_K} R={PIPE_ROUNDS}  "
          f"barrier={wb*1e3:7.1f} ms  pipelined={wp*1e3:7.1f} ms  "
          f"speedup={wb/wp:.2f}x  "
          f"syncs/round barrier={hb.meta['syncs_per_round']:.1f} "
          f"pipelined={hp.meta['syncs_per_round']:.1f}  "
          f"eval_overlap={point['eval_overlap_share']:.2f}  "
          f"(cpus={point['n_cpus']})")
    if point["n_cpus"] < 2:
        print("  note: 1-CPU container — host/device overlap has no "
              "core to run on, so loop-wall speedup is bounded at "
              "~1.0x here; the sync-count delta above is the "
              "machine-independent pipelining signal")
    return [point]


def pipeline_only_main():
    """Re-run just the pipelined-vs-barrier loop point and merge it
    into the existing ``BENCH_fl_round.json``."""
    out = ROOT / "BENCH_fl_round.json"
    results = (json.load(open(out)) if out.exists()
               else {"config": {}, "points": []})
    results["pipeline_points"] = pipeline_points()
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {out}")


MESH_DEVICES = 8
MESH_N_CLIENTS = 1024
MESH_K = 64
MESH_GAN_N = 16
_MESH_MARK = "MESH_JSON::"


def _mesh_child():
    """Runs in the forced-8-device child interpreter: the mesh-scale
    benchmark points. Prints one marker-prefixed JSON line the parent
    collects into ``results['mesh_points']``."""
    from repro.fl import runtime as runtime_lib
    from repro.launch.mesh import make_data_mesh

    assert len(jax.devices()) >= MESH_DEVICES, jax.devices()
    mesh = make_data_mesh(MESH_DEVICES)
    out = {"n_devices": MESH_DEVICES, "backend": jax.default_backend()}

    # -- 1024-client sync-partial round on the mesh -------------------
    strat = STRATEGIES["fedclip"]
    ccfg = clip_lib.CLIPConfig()
    frozen = clip_lib.init_clip(jax.random.PRNGKey(3), ccfg)
    P = 2                     # images per client: population scale is
    data = make_dataset(      # the point here, not per-client depth
        "pacs", n_per_class=(MESH_N_CLIENTS * P + 6) // 7, seed=0,
        longtail_gamma=1.0)
    spec = data["spec"]
    class_emb = clip_lib.text_embedding(
        frozen, ccfg,
        jnp.asarray(class_tokens(spec, np.arange(spec.n_classes))))
    clients = [client_lib.Client(
        cid=i, images=data["images"][P * i:P * i + P],
        labels=data["labels"][P * i:P * i + P],
        n_classes=spec.n_classes, strategy=strat)
        for i in range(MESH_N_CLIENTS)]
    tr = client_lib.init_trainable(jax.random.PRNGKey(1), ccfg, strat)
    rt = runtime_lib.ProgramRuntime()
    t0 = time.perf_counter()
    engine = cohort_lib.CohortEngine(
        frozen=frozen, ccfg=ccfg, class_emb=class_emb, clients=clients,
        cfg=cohort_lib.CohortConfig(strategy=strat,
                                    local_steps=LOCAL_STEPS,
                                    batch_size=BATCH, lr=LR, mesh=mesh),
        runtime=rt)
    stage_s = time.perf_counter() - t0
    shard_rows = engine.pool_staged.sharding.shard_shape(
        engine.pool_staged.shape)[0]
    assert shard_rows * MESH_DEVICES == MESH_N_CLIENTS, \
        ("mesh bench silently unsharded", shard_rows)
    sub, uplink = time_subset(engine, tr, MESH_K)
    stats = rt.stats()
    out["sync_partial_1024"] = {
        "n_clients": MESH_N_CLIENTS, "clients_per_round": MESH_K,
        "shards": engine.shards, "aggregation": "tree",
        "bucket_width": cohort_lib.runtime_lib.bucket_width(
            MESH_K, MESH_N_CLIENTS, shards=engine.shards),
        "stage_s": stage_s, "subset_round_s": sub,
        "uplink_bytes": uplink,
        "n_compiles": rt.n_compiles,
        "compile_time_s": rt.compile_time_s,
        "n_round_compiles": int(stats["subset_round"]["n_compiles"])}

    # -- sharded fleet-GAN vs its unsharded twin ----------------------
    def mk_gan_clients():
        gstrat = STRATEGIES["tripleplay"]
        per = 24
        return [client_lib.Client(
            cid=i, images=data["images"][per * i:per * i + per],
            labels=data["labels"][per * i:per * i + per],
            n_classes=spec.n_classes, strategy=gstrat)
            for i in range(MESH_GAN_N)]

    keys = _gan_keys(MESH_GAN_N)
    rep_u = fleetgan.prepare_gan_fleet(
        mk_gan_clients(), keys, steps=GAN_STEPS,
        runtime=runtime_lib.ProgramRuntime())
    rt_s = runtime_lib.ProgramRuntime()
    rep_s = fleetgan.prepare_gan_fleet(
        mk_gan_clients(), keys, steps=GAN_STEPS,
        fleet_cfg=fleetgan.FleetGANConfig(mesh=mesh), runtime=rt_s)
    out["fleet_gan_sharded"] = {
        "n_clients": MESH_GAN_N, "gan_steps": GAN_STEPS,
        "shards": MESH_DEVICES,
        "n_eligible": rep_s.n_eligible,
        "groups": [list(g) for g in rep_s.groups],
        "n_synth": rep_s.n_synth,
        "sharded_prep_s": rep_s.prep_time_s,
        "sharded_compile_s": rep_s.compile_time_s,
        "unsharded_prep_s": rep_u.prep_time_s,
        "unsharded_compile_s": rep_u.compile_time_s,
        "gan_train_compiles":
            int(rt_s.stats()["gan_train"]["n_compiles"])}
    print(_MESH_MARK + json.dumps(out))


def _run_mesh_points() -> dict:
    """Re-exec this script with the 8-fake-device flag (it must be set
    before jax initializes, hence the child interpreter)."""
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count"
                  f"={MESH_DEVICES}",
        PYTHONPATH=str(ROOT / "src") + (
            os.pathsep + os.environ["PYTHONPATH"]
            if os.environ.get("PYTHONPATH") else ""))
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--mesh-child"],
        env=env, capture_output=True, text=True, cwd=str(ROOT))
    if proc.returncode != 0:
        raise RuntimeError(
            f"mesh-points child failed:\n{proc.stderr[-4000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith(_MESH_MARK):
            return json.loads(line[len(_MESH_MARK):])
    raise RuntimeError(
        f"mesh-points child printed no result:\n{proc.stdout[-2000:]}")


def main():
    results = {"config": {"local_steps": LOCAL_STEPS, "batch": BATCH,
                          "rounds_timed": ROUNDS,
                          "gan_steps": GAN_STEPS,
                          "backend": jax.default_backend()},
               "points": []}
    for arm in ("fedclip", "qlora_nogan", "tripleplay"):
        # tripleplay pays n_clients GAN trainings per point; 32-client
        # GAN prep would dominate the bench wall-clock for no extra
        # signal (the GAN engine has its own sweep below)
        for n in (N_CLIENTS if arm != "tripleplay" else
                  tuple(x for x in N_CLIENTS if x <= GAN_N_CLIENTS)):
            strat, ccfg, frozen, class_emb, clients, tr, gan_rep = \
                _setup(arm, n)
            seq = time_sequential(frozen, tr, class_emb, ccfg, clients)
            coh, compile_stats = time_cohort(strat, frozen, tr,
                                             class_emb, ccfg, clients)
            point = {"strategy": arm, "n_clients": n,
                     "n_clients_effective": len(clients),
                     "sequential_round_s": seq, "cohort_round_s": coh,
                     "speedup": seq / coh, **compile_stats}
            if gan_rep is not None:
                point.update({
                    "gan_engine": "fleet",
                    "gan_prep_time_s": gan_rep.prep_time_s,
                    "gan_compile_time_s": gan_rep.compile_time_s,
                    "gan_eligible": gan_rep.n_eligible,
                    "gan_synth": gan_rep.n_synth})
            results["points"].append(point)
            print(f"{arm:12s} n_clients={n:3d} ({len(clients):3d} with "
                  f"data)  sequential={seq*1e3:8.1f} ms  "
                  f"cohort={coh*1e3:7.1f} ms  speedup={seq/coh:5.1f}x")

    # fleet-GAN engine vs the sequential per-client prepare_gan loop
    seq_gan = time_gan_sequential(GAN_N_CLIENTS)
    rep = time_gan_fleet(GAN_N_CLIENTS)
    gan_rt = fleetgan.default_runtime()
    gan_stats = gan_rt.stats()
    gan_n_compiles, _ = gan_rt.subtotal("gan_")
    none = {"n_compiles": 0}
    results["gan_points"] = [{
        "n_clients": GAN_N_CLIENTS, "gan_steps": GAN_STEPS,
        "n_eligible": rep.n_eligible,
        "groups": [list(g) for g in rep.groups],
        "sequential_gan_prep_s": seq_gan,
        "fleet_gan_prep_s": rep.prep_time_s,
        "fleet_gan_compile_s": rep.compile_time_s,
        # the bucketed-runtime guarantee: one train + one synthesis
        # program for the whole fleet (the remaining gan_* entries are
        # the tiny per-true-batch-size key/index/noise pre-draws)
        "fleet_gan_train_compiles":
            int(gan_stats.get("gan_train", none)["n_compiles"]),
        "fleet_gan_synth_compiles":
            int(gan_stats.get("gan_synth", none)["n_compiles"]),
        "fleet_gan_n_compiles": int(gan_n_compiles),
        "speedup": seq_gan / rep.prep_time_s}]
    print(f"fleet-GAN    n_clients={GAN_N_CLIENTS:3d} "
          f"sequential={seq_gan:7.2f} s  fleet={rep.prep_time_s:7.2f} s "
          f"(+{rep.compile_time_s:.2f} s compile)  "
          f"speedup={seq_gan/rep.prep_time_s:5.1f}x")

    # sync-partial sweep: fixed population, varying cohort width K
    n_fixed = max(N_CLIENTS)
    results["partial_points"] = []
    for arm in ("fedclip", "qlora_nogan"):
        strat, ccfg, frozen, class_emb, clients, tr, _ = _setup(arm,
                                                                n_fixed)
        engine = cohort_lib.CohortEngine(
            frozen=frozen, ccfg=ccfg, class_emb=class_emb,
            clients=clients,
            cfg=cohort_lib.CohortConfig(strategy=strat,
                                        local_steps=LOCAL_STEPS,
                                        batch_size=BATCH, lr=LR))
        for k in (*CLIENTS_PER_ROUND, len(clients)):
            if k > len(clients):
                continue
            sub, uplink = time_subset(engine, tr, k)
            # cumulative compile ledger across the K sweep: the count
            # plateaus once every power-of-two width bucket is built —
            # the fixed-cost drop the bucketed runtime exists for
            sweep_stats = engine.runtime.stats().get(
                "subset_round", {"n_compiles": 0, "compile_time_s": 0.0})
            point = {"strategy": arm, "n_clients": n_fixed,
                     "n_clients_effective": len(clients),
                     "clients_per_round": k,
                     "subset_round_s": sub, "uplink_bytes": uplink,
                     "n_round_compiles_cum":
                         int(sweep_stats["n_compiles"]),
                     "round_compile_s_cum":
                         sweep_stats["compile_time_s"]}
            results["partial_points"].append(point)
            print(f"{arm:12s} N={len(clients):3d} K={k:3d}  "
                  f"subset={sub*1e3:7.1f} ms  "
                  f"uplink={uplink/2**20:6.2f} MiB  "
                  f"round_compiles={point['n_round_compiles_cum']}")
    # chaos: sync-partial vs async under one fault schedule + diurnal
    # trace — same population, same seed, same ChaosConfig; the ledger
    # shows both policies absorbing the same fault pressure while the
    # virtual clock shows what each policy pays for it
    from repro.fl.sched import ChaosConfig
    from repro.fl.simulator import FLConfig, run_federated

    chaos = ChaosConfig(dropout_prob=0.25, straggler_sigma=0.5,
                        uplink_loss_prob=0.1)
    # 6 rounds minimum: faults are drawn per (round, client) at the
    # population shape but only fire for selected participants — too
    # few K=3 rounds can miss every faulted (round, client) pair and
    # record a legitimately-empty ledger, which reads like chaos was
    # silently off
    cbase = dict(dataset="pacs", strategy="fedclip", n_clients=8,
                 rounds=max(ROUNDS, 6), local_steps=LOCAL_STEPS,
                 n_per_class=24, batch_size=BATCH, lr=LR,
                 trace="diurnal", chaos=chaos, clients_per_round=3)
    results["chaos_points"] = []
    for policy in ("sync-partial", "async"):
        t0 = time.perf_counter()
        h = run_federated(FLConfig(**cbase, participation=policy))
        wall = time.perf_counter() - t0
        point = {"policy": policy,
                 "rounds": len(h.rounds),
                 "round_time_s": wall / max(len(h.rounds), 1),
                 "vtime_final": float(h.vtime[-1]),
                 "tail_acc_final": float(h.tail_acc[-1]),
                 "server_acc_final": float(h.server_acc[-1]),
                 "uplink_bytes": int(sum(h.uplink_bytes)),
                 "fault_ledger": h.meta["fault_ledger"]}
        results["chaos_points"].append(point)
        print(f"chaos {policy:13s} round={point['round_time_s']*1e3:8.1f}"
              f" ms  vtime={point['vtime_final']:7.1f}  "
              f"tail_acc={point['tail_acc_final']:.3f}  "
              f"faults={sum(point['fault_ledger'].values())}")
    # pipelined vs barrier round-loop wall (same math, fetched late)
    results["pipeline_points"] = pipeline_points()
    # fused-LoRA vs einsum-chain cohort timings on the qlora arm
    _merge_qlora_points(results, qlora_fused_points())
    # mesh-scale points (forced-8-device child interpreter)
    results["mesh_points"] = _run_mesh_points()
    sp, fg = (results["mesh_points"]["sync_partial_1024"],
              results["mesh_points"]["fleet_gan_sharded"])
    print(f"mesh 1024-client K={sp['clients_per_round']} "
          f"round={sp['subset_round_s']*1e3:8.1f} ms  "
          f"shards={sp['shards']}  compiles={sp['n_compiles']}")
    print(f"mesh fleet-GAN n={fg['n_clients']} "
          f"sharded={fg['sharded_prep_s']:7.2f} s  "
          f"unsharded={fg['unsharded_prep_s']:7.2f} s")
    out = ROOT / "BENCH_fl_round.json"
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    if "--mesh-child" in sys.argv:
        _mesh_child()
    elif "--qlora-only" in sys.argv:
        qlora_only_main()
    elif "--pipeline-only" in sys.argv:
        pipeline_only_main()
    else:
        main()
