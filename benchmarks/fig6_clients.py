"""Fig. 6: per-client loss/accuracy trajectories under TriplePlay (PACS)."""
from __future__ import annotations

import numpy as np

from benchmarks.fl_common import fl_config, hist_dict, save
from repro.fl.simulator import run_federated


def run() -> list[str]:
    h = run_federated(fl_config("pacs", "tripleplay"))
    save("fig6_clients", hist_dict(h))
    rows = []
    cl = np.asarray(h.client_loss)        # (rounds, clients)
    ca = np.asarray(h.client_acc)
    for c in range(cl.shape[1]):
        monotone = float(cl[-1, c] < cl[0, c])
        rows.append(f"fig6/client{c}/loss_drop,"
                    f"{(cl[0, c]-cl[-1, c])*1e6:.0f},"
                    f"final_acc={ca[-1, c]:.3f};decreased={bool(monotone)}")
    return rows
