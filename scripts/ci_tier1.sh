#!/usr/bin/env bash
# Tier-1 gate: full unit suite, then 2-round smoke runs through the
# public simulator entry point — full-sync cohort engine with fleet-GAN
# rebalancing, plus the sync-partial and async-buffered scheduler
# policies (fl.sched) and the pipelined round loop (sync-free steady
# state, bitwise History parity, zero new compiles vs barrier).
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
from repro.fl.simulator import FLConfig, run_federated

h = run_federated(FLConfig(
    dataset="pacs", strategy="tripleplay", n_clients=2, rounds=2,
    local_steps=3, n_per_class=12, batch_size=8, gan_steps=10,
    lr=3e-3))
assert h.meta["engine"] == "cohort"
assert h.meta["participation"] == "full-sync"
assert h.meta["compile_time_s"] > 0
assert len(h.client_loss) == 2 and len(h.client_loss[0]) == 2
assert all(b > 0 for b in h.uplink_bytes)
# fleet-GAN smoke: the tripleplay arm must run its rebalancing through
# the fused cohort-wide engine — fail loudly if the sequential oracle
# path was silently taken, and require the compile/steady-state timing
# split to be populated
assert h.meta["gan_engine"] == "fleet", h.meta.get("gan_engine")
assert h.meta["gan_eligible"] == 2 and h.meta["gan_groups"]
assert h.meta["gan_prep_time_s"] > 0
assert h.meta["gan_compile_time_s"] > 0
# unified compile ledger: one bucketed train + one synthesis program
# for the whole fleet, whatever the batch-size split
assert h.meta["n_compiles_by_kind"]["gan_train"] == 1
assert h.meta["n_compiles_by_kind"]["gan_synth"] == 1
assert h.meta["n_compiles"] >= 1 and h.meta["compile_time_s"] > 0
assert len(h.tail_acc) == len(h.rounds)
print("cohort+fleet-GAN smoke run OK:",
      {"server_loss": h.server_loss, "uplink_bytes": h.uplink_bytes,
       "gan_groups": h.meta["gan_groups"],
       "n_compiles": h.meta["n_compiles"],
       "gan_prep_time_s": round(h.meta["gan_prep_time_s"], 3)})

from repro.fl.runtime import ProgramRuntime

# sync-partial smoke doubles as the bucketed-runtime K sweep: two runs
# at K=2 and K=3 share one ProgramRuntime, and both widths land in the
# same power-of-two bucket — the cache must hold exactly ONE
# subset-round program after the sweep (a second entry means a silent
# per-K recompile regression)
rt = ProgramRuntime()
base = dict(dataset="pacs", strategy="fedclip", n_clients=4, rounds=2,
            local_steps=3, n_per_class=12, batch_size=8, lr=3e-3,
            participation="sync-partial", trace="skewed")
h = run_federated(FLConfig(**base, clients_per_round=2), runtime=rt)
assert h.meta["participation"] == "sync-partial"
assert all(len(p) == 2 for p in h.participation)
assert all(b > 0 for b in h.uplink_bytes)
assert h.meta["n_compiles_by_kind"]["subset_round"] == 1, h.meta
h2 = run_federated(FLConfig(**base, clients_per_round=3), runtime=rt)
assert all(len(p) == 3 for p in h2.participation)
assert h2.meta["n_compiles_by_kind"]["subset_round"] == 1, \
    ("K=3 recompiled the round program despite sharing K=2's bucket",
     h2.meta["n_compiles_by_kind"])
print("sync-partial smoke run OK:",
      {"participation": h.participation,
       "n_compiles_by_kind": h2.meta["n_compiles_by_kind"]})

# pipeline smoke: the pipelined round loop must not degenerate to the
# serial path (zero host syncs per steady-state round — the trace
# counter catches a reintroduced per-round float()/block_until_ready),
# must produce bitwise the barrier History, and — sharing the runtime
# above — must add ZERO new program kinds or compiles vs barrier
pbase = dict(base, clients_per_round=2, rounds=3, eval_every=1)
hb = run_federated(FLConfig(**pbase, pipeline="barrier"), runtime=rt)
compiles_after_barrier = rt.n_compiles
hp = run_federated(FLConfig(**pbase, pipeline="pipelined"), runtime=rt)
assert hp.meta["pipeline"] == "pipelined"
assert hp.meta["loop_syncs"] == 0 and hp.meta["syncs_per_round"] == 0, \
    ("pipelined loop degenerated to serial (host syncs per round)",
     hp.meta["sync_counts"])
assert hp.meta["prepared_rounds"] == pbase["rounds"]
for f in ("rounds", "server_acc", "server_loss", "tail_acc",
          "client_loss", "client_acc", "uplink_bytes", "participation",
          "staleness", "vtime", "class_counts", "class_acc"):
    assert getattr(hb, f) == getattr(hp, f), \
        ("pipelined History diverged from the barrier oracle", f)
assert rt.n_compiles == compiles_after_barrier, \
    ("pipelined loop compiled new programs vs barrier",
     compiles_after_barrier, rt.n_compiles)
assert set(hp.meta["n_compiles_by_kind"]) == \
    set(hb.meta["n_compiles_by_kind"]), \
    (hb.meta["n_compiles_by_kind"], hp.meta["n_compiles_by_kind"])
print("pipeline smoke OK:",
      {"syncs_per_round": hp.meta["syncs_per_round"],
       "sync_counts": hp.meta["sync_counts"],
       "barrier_sync_counts": hb.meta["sync_counts"],
       "loop_wall_s": round(hp.meta["loop_wall_s"], 3)})

h = run_federated(FLConfig(
    dataset="pacs", strategy="fedclip", n_clients=4, rounds=2,
    local_steps=3, n_per_class=12, batch_size=8, lr=3e-3,
    participation="async", clients_per_round=2, trace="skewed"))
assert h.meta["participation"] == "async"
assert all(t >= 0 for taus in h.staleness for t in taus)
assert h.vtime == sorted(h.vtime) and h.vtime[0] > 0
print("async smoke run OK:", {"participation": h.participation,
                              "staleness": h.staleness,
                              "vtime": h.vtime})

import numpy as np

from repro.fl.sched import ChaosConfig

# chaos smoke: a seeded dropout+straggler+lost-uplink sync-partial run
# must finish, match the sequential oracle client-for-client, report a
# non-empty fault ledger, and stay on the fused wave program — if chaos
# silently fell back to the fault-free subset_round path, fail loudly
chaos = ChaosConfig(dropout_prob=0.4, straggler_sigma=0.5,
                    uplink_loss_prob=0.4, max_retries=2)
cbase = dict(dataset="pacs", strategy="fedclip", n_clients=4, rounds=3,
             local_steps=3, n_per_class=12, batch_size=8, lr=3e-3,
             participation="sync-partial", clients_per_round=2,
             trace="skewed", chaos=chaos)
h = run_federated(FLConfig(**cbase))
hs = run_federated(FLConfig(**cbase, engine="sequential"))
led = h.meta["fault_ledger"]
assert sum(led.values()) > 0, ("chaos run fired no faults", led)
assert led == hs.meta["fault_ledger"], (led, hs.meta["fault_ledger"])
assert h.participation == hs.participation
for a, b in zip(h.client_loss, hs.client_loss):
    np.testing.assert_allclose(a, b, atol=1e-3)
assert "wave_round" in h.meta["n_compiles_by_kind"], h.meta
assert "subset_round" not in h.meta["n_compiles_by_kind"], \
    ("chaos sync round silently took the fault-free subset path",
     h.meta["n_compiles_by_kind"])
print("sync-partial chaos smoke OK:", {"fault_ledger": led,
      "participation": h.participation})

# async chaos: a lost uplink must be retried on the virtual clock and
# eventually delivered — the run finishes with a sorted timeline
h = run_federated(FLConfig(
    dataset="pacs", strategy="fedclip", n_clients=4, rounds=3,
    local_steps=3, n_per_class=12, batch_size=8, lr=3e-3,
    participation="async", clients_per_round=2, trace="skewed",
    chaos=ChaosConfig(uplink_loss_prob=0.6, max_retries=2)))
led = h.meta["fault_ledger"]
assert led["uplinks_lost"] >= 1, led
assert led["n_retries"] >= 1, led
assert h.vtime == sorted(h.vtime)
print("async chaos smoke OK:", {"fault_ledger": led,
                                "vtime": h.vtime})
EOF

# forced-8-device sharded smoke: the mesh-scaled round path must really
# shard (fail loudly on a silent unsharded fallback), keep the K-sweep
# compile-count bound with a mesh attached, and stay in parity with the
# unsharded engine. XLA_FLAGS must be set before jax imports, hence the
# dedicated interpreter.
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF3'
import jax, jax.numpy as jnp
import numpy as np

from repro.core import clip as clip_lib
from repro.data.synthetic import class_tokens, make_dataset
from repro.fl import client as client_lib, cohort as cohort_lib
from repro.fl import runtime as runtime_lib
from repro.fl.strategies import STRATEGIES
from repro.launch.mesh import make_data_mesh

assert len(jax.devices()) == 8, jax.devices()
strat = STRATEGIES["fedclip"]
ccfg = clip_lib.CLIPConfig()
frozen = clip_lib.init_clip(jax.random.PRNGKey(3), ccfg)
data = make_dataset("pacs", n_per_class=12, seed=0, longtail_gamma=1.0)
spec = data["spec"]
class_emb = clip_lib.text_embedding(
    frozen, ccfg,
    jnp.asarray(class_tokens(spec, np.arange(spec.n_classes))))
clients = [client_lib.Client(
    cid=i, images=data["images"][4 * i:4 * i + 4],
    labels=data["labels"][4 * i:4 * i + 4],
    n_classes=spec.n_classes, strategy=strat) for i in range(16)]
tr = client_lib.init_trainable(jax.random.PRNGKey(1), ccfg, strat)

rt = runtime_lib.ProgramRuntime()   # ONE runtime: sharded + unsharded
mk = lambda mesh: cohort_lib.CohortEngine(
    frozen=frozen, ccfg=ccfg, class_emb=class_emb, clients=clients,
    cfg=cohort_lib.CohortConfig(strategy=strat, local_steps=2,
                                batch_size=4, lr=3e-3, mesh=mesh,
                                donate=False),
    runtime=rt)
e_s, e_u = mk(make_data_mesh(8)), mk(None)

# silent-fallback guard: the sharded engine's staged cohort arrays must
# actually live on all 8 devices and aggregate through 8 shards
assert e_s.shards == 8 and e_u.shards == 1
assert len(e_s.pool_staged.sharding.device_set) == 8, \
    ("sharded engine silently fell back to a single device",
     e_s.pool_staged.sharding)

# K sweep on the mesh: K=2 and K=3 both bucket to the 8-shard multiple
# 8, so the sharded sweep adds exactly ONE subset-round program next to
# the unsharded engine's one — 2 total, never colliding (cache keys
# carry sharding identity), never recompiling per K
sweep = {}
for k in (2, 3):
    sel = list(range(0, 2 * k, 2))
    key = jax.random.PRNGKey(k)
    t_s, m_s = e_s.run_subset_round(tr, sel, key)
    t_u, m_u = e_u.run_subset_round(tr, sel, key)
    for a, b in zip(jax.tree.leaves(t_s), jax.tree.leaves(t_u)):
        assert float(jnp.abs(a - b).max()) < 1e-5
    assert float(jnp.abs(m_s["loss"] - m_u["loss"]).max()) < 1e-4
    sweep[k] = [float(x) for x in np.asarray(m_s["loss"])]
stats = rt.stats()
assert stats["subset_round"]["n_compiles"] == 2, \
    ("mesh K-sweep broke the compile bound (want sharded+unsharded = "
     "2 programs)", stats["subset_round"])
assert runtime_lib.bucket_width(2, 16, shards=8) == \
    runtime_lib.bucket_width(3, 16, shards=8) == 8
print("forced-8-device sharded smoke OK:",
      {"shards": e_s.shards,
       "subset_round_compiles": stats["subset_round"]["n_compiles"],
       "loss_by_k": sweep})
EOF3

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF4'
import os
import tempfile

import jax, jax.numpy as jnp
import numpy as np

from repro.core import clip as clip_lib
from repro.data.synthetic import class_tokens, make_dataset
from repro.fl import client as client_lib, cohort as cohort_lib
from repro.fl.runtime import ProgramRuntime
from repro.fl.strategies import STRATEGIES
from repro.kernels import autotune, ops as kops

# fused-LoRA smoke: the qlora arm's cohort round must route every LoRA
# projection through the fused kernels.ops.lora_matmul — if the legacy
# einsum chain is silently taken, the trace counters catch it here
strat = STRATEGIES["qlora_nogan"]
ccfg = clip_lib.CLIPConfig()
frozen = clip_lib.init_clip(jax.random.PRNGKey(3), ccfg)
data = make_dataset("pacs", n_per_class=12, seed=0, longtail_gamma=1.0)
spec = data["spec"]
class_emb = clip_lib.text_embedding(
    frozen, ccfg,
    jnp.asarray(class_tokens(spec, np.arange(spec.n_classes))))
clients = [client_lib.Client(
    cid=i, images=data["images"][6 * i:6 * i + 6],
    labels=data["labels"][6 * i:6 * i + 6],
    n_classes=spec.n_classes, strategy=strat) for i in range(2)]
tr = client_lib.init_trainable(jax.random.PRNGKey(1), ccfg, strat)
kops.reset_kernel_traces()
engine = cohort_lib.CohortEngine(
    frozen=frozen, ccfg=ccfg, class_emb=class_emb, clients=clients,
    cfg=cohort_lib.CohortConfig(strategy=strat, local_steps=2,
                                batch_size=4, lr=3e-3))
tr, m = engine.run_round(tr, jax.random.PRNGKey(0))
assert np.isfinite(np.asarray(m["loss"])).all()
assert kops.KERNEL_TRACES.get("lora_linear_fused", 0) > 0, \
    ("qlora cohort round never traced the fused LoRA op",
     dict(kops.KERNEL_TRACES))
assert kops.KERNEL_TRACES.get("lora_linear_chain", 0) == 0, \
    ("qlora cohort round silently took the einsum chain",
     dict(kops.KERNEL_TRACES))

# autotune smoke: a block-shape sweep persists its winners; repeating
# the same sweep must be pure cache hits — zero candidate timings, zero
# new entries in the compile ledger
with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "autotune.json")
    x = jnp.asarray(np.random.RandomState(0).randn(32, 256), jnp.float32)
    from repro.core import quant as qlib
    from repro.kernels.quant_matmul import quant_matmul as qmm
    qt = qlib.quantize(
        jnp.asarray(np.random.RandomState(1).randn(256, 128), jnp.float32),
        bits=8, block=128, mode="linear")

    def build(bm, bn):
        f = jax.jit(lambda x: qmm(x, qt, block_m=bm, block_n=bn,
                                  interpret=True))
        return lambda: jax.block_until_ready(f(x))

    autotune.clear(in_process_only=True)
    rt = ProgramRuntime()
    r1 = autotune.sweep("quant_matmul", build, 32, 256, 128, bits=8,
                        mode="linear", candidates=((32, 64), (32, 128)),
                        runtime=rt, path=path)
    assert r1.swept and r1.n_candidates == 2, r1
    led1 = rt.stats()["autotune_quant_matmul"]
    assert led1["n_compiles"] == 2 and led1["compile_time_s"] > 0, led1
    autotune.clear(in_process_only=True)   # drop RAM, keep the JSON
    r2 = autotune.sweep("quant_matmul", build, 32, 256, 128, bits=8,
                        mode="linear", candidates=((32, 64), (32, 128)),
                        runtime=rt, path=path)
    assert not r2.swept and r2.best == r1.best, (r1, r2)
    led2 = rt.stats()["autotune_quant_matmul"]
    assert led2 == led1, \
        ("repeated autotune sweep charged the compile ledger again",
         led1, led2)
print("fused-LoRA + autotune smoke OK:",
      {"lora_traces": {k: v for k, v in kops.KERNEL_TRACES.items()
                       if k.startswith("lora")},
       "autotune_best": r1.best, "second_sweep_hit": not r2.swept})
EOF4

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF2'
import numpy as np

from repro.fl import serve as serve_lib
from repro.fl.serve import engine as engine_lib

# serving-plane smoke: a Zipf trace over a mixed-tenancy population
# must be answered by the BATCHED plane (fused multi-request programs),
# match the per-user sequential oracle under int8-at-rest adapters, and
# charge every cache/compile event to the shared ledger. Fails loudly
# if batching silently degenerates to per-user dispatch.
plane = serve_lib.demo_plane(6, mixed=True, seed=0, quant_bits=8,
                             max_entries=4, max_batch=4)
trace = serve_lib.zipf_request_trace(6, 24, seed=1, rate=200.0,
                                     period=1.0, amplitude=0.5)
images = serve_lib.request_images(plane, trace, seed=1)
rec = serve_lib.replay(plane["engine"], trace, images)
eng = plane["engine"]
kinds = plane["runtime"].stats()
assert "serve_batch" in kinds, ("serve plane never compiled a fused "
                                "program", sorted(kinds))
# batched means strictly fewer dispatches than requests — equality is
# the silent per-user-fallback regression this smoke exists to catch
assert eng.n_requests == trace.n
assert eng.n_dispatches < eng.n_requests, \
    ("batched serving degenerated to per-user dispatch",
     eng.n_dispatches, eng.n_requests)
assert kinds["serve_batch"]["n_requests"] == trace.n
assert kinds["serve_batch"]["n_groups"] == eng.n_dispatches
st = plane["store"].stats()
assert st["hits"] + st["misses"] == trace.n
assert st["resident"] <= 4 and st["evictions"] >= 0
ref = engine_lib.serve_sequential(
    plane["frozen"], plane["ccfg"], plane["class_emb"],
    plane["backing"], [(int(u), im) for u, im in zip(trace.uid, images)])
err = float(np.max(np.abs(rec["logits"] - ref)))
assert err < 5e-2, f"batched/sequential parity broke: {err}"
print("serve smoke OK:",
      {"flights": rec["n_flights"], "dispatches": eng.n_dispatches,
       "requests": eng.n_requests, "hit_rate": round(
           plane["store"].hit_rate(), 3),
       "max_err": round(err, 5),
       "lat_v_p50_ms": round(rec["lat_v_p50"] * 1e3, 3)})
EOF2
