#!/usr/bin/env bash
# Tier-1 gate: full unit suite, then 2-round smoke runs through the
# public simulator entry point — full-sync cohort engine with fleet-GAN
# rebalancing, plus the sync-partial and async-buffered scheduler
# policies (fl.sched).
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
from repro.fl.simulator import FLConfig, run_federated

h = run_federated(FLConfig(
    dataset="pacs", strategy="tripleplay", n_clients=2, rounds=2,
    local_steps=3, n_per_class=12, batch_size=8, gan_steps=10,
    lr=3e-3))
assert h.meta["engine"] == "cohort"
assert h.meta["participation"] == "full-sync"
assert h.meta["compile_time_s"] > 0
assert len(h.client_loss) == 2 and len(h.client_loss[0]) == 2
assert all(b > 0 for b in h.uplink_bytes)
# fleet-GAN smoke: the tripleplay arm must run its rebalancing through
# the fused cohort-wide engine — fail loudly if the sequential oracle
# path was silently taken, and require the compile/steady-state timing
# split to be populated
assert h.meta["gan_engine"] == "fleet", h.meta.get("gan_engine")
assert h.meta["gan_eligible"] == 2 and h.meta["gan_groups"]
assert h.meta["gan_prep_time_s"] > 0
assert h.meta["gan_compile_time_s"] > 0
assert len(h.tail_acc) == len(h.rounds)
print("cohort+fleet-GAN smoke run OK:",
      {"server_loss": h.server_loss, "uplink_bytes": h.uplink_bytes,
       "gan_groups": h.meta["gan_groups"],
       "gan_prep_time_s": round(h.meta["gan_prep_time_s"], 3)})

h = run_federated(FLConfig(
    dataset="pacs", strategy="fedclip", n_clients=4, rounds=2,
    local_steps=3, n_per_class=12, batch_size=8, lr=3e-3,
    participation="sync-partial", clients_per_round=2, trace="skewed"))
assert h.meta["participation"] == "sync-partial"
assert all(len(p) == 2 for p in h.participation)
assert all(b > 0 for b in h.uplink_bytes)
print("sync-partial smoke run OK:", {"participation": h.participation})

h = run_federated(FLConfig(
    dataset="pacs", strategy="fedclip", n_clients=4, rounds=2,
    local_steps=3, n_per_class=12, batch_size=8, lr=3e-3,
    participation="async", clients_per_round=2, trace="skewed"))
assert h.meta["participation"] == "async"
assert all(t >= 0 for taus in h.staleness for t in taus)
assert h.vtime == sorted(h.vtime) and h.vtime[0] > 0
print("async smoke run OK:", {"participation": h.participation,
                              "staleness": h.staleness,
                              "vtime": h.vtime})
EOF
