#!/usr/bin/env bash
# Tier-1 gate: full unit suite, then a 2-client/2-round cohort-engine
# smoke run through the public simulator entry point.
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
from repro.fl.simulator import FLConfig, run_federated

h = run_federated(FLConfig(
    dataset="pacs", strategy="tripleplay", n_clients=2, rounds=2,
    local_steps=3, n_per_class=12, batch_size=8, gan_steps=30,
    lr=3e-3))
assert h.meta["engine"] == "cohort"
assert len(h.client_loss) == 2 and len(h.client_loss[0]) == 2
assert all(b > 0 for b in h.uplink_bytes)
print("cohort smoke run OK:", {"server_loss": h.server_loss,
                               "uplink_bytes": h.uplink_bytes})
EOF
