"""Chaos layer (fl.sched.chaos): deterministic fault schedules,
partial-work recovery, lost/corrupt uplinks with bounded retry, fused
vs sequential parity under chaos, LRU runtime eviction, trace realism
(diurnal cycle + JSON replay), and the run_federated acceptance
scenario (bit-determinism, fault ledger, zero extra compiles).

Bitwise discipline: fault schedules (draw vectors, cut points, dark
windows, loss/corruption indicators) are pure functions of (chaos key,
fault tag, client position) and asserted bitwise; trained values that
flow through the fused engines are pinned at the usual 5e-4/1e-3
parity tolerances (XLA fusion is not bitwise-stable across loop->scan
restructuring).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import clip as clip_lib
from repro.core import gan as gan_lib
from repro.core import optim
from repro.core.quant import quantize_tree
from repro.data.synthetic import class_tokens, make_dataset
from repro.fl import client as client_lib
from repro.fl import cohort as cohort_lib
from repro.fl import fleetgan, server
from repro.fl import sched as sched_lib
from repro.fl.runtime import ProgramRuntime
from repro.fl.sched import chaos as chaos_lib
from repro.fl import partition
from repro.fl.strategies import GAN_MIN_POOL, STRATEGIES

N_CLIENTS = 4
STEPS, BATCH, LR = 4, 8, 3e-3

_SETUPS = {}


def _setup(arm="fedclip"):
    """Small FL instance with both executors over shared clients; the
    engine stages the masked (force_het) programs chaos cut profiles
    dispatch."""
    if arm in _SETUPS:
        return _SETUPS[arm]
    strat = STRATEGIES[arm]
    ccfg = clip_lib.CLIPConfig()
    frozen = clip_lib.init_clip(jax.random.PRNGKey(3), ccfg)
    data = make_dataset("pacs", n_per_class=14, seed=0,
                        longtail_gamma=4.0)
    spec = data["spec"]
    class_emb = clip_lib.text_embedding(
        frozen, ccfg,
        jnp.asarray(class_tokens(spec, np.arange(spec.n_classes))))
    parts = partition.dirichlet_partition(data["labels"], N_CLIENTS,
                                          0.5, seed=0)
    clients = [client_lib.Client(
        cid=i, images=data["images"][idx], labels=data["labels"][idx],
        n_classes=spec.n_classes, strategy=strat)
        for i, idx in enumerate(parts)]
    global_tr = client_lib.init_trainable(jax.random.PRNGKey(1), ccfg,
                                          strat)
    engine = cohort_lib.CohortEngine(
        frozen=frozen, ccfg=ccfg, class_emb=class_emb, clients=clients,
        cfg=cohort_lib.CohortConfig(strategy=strat, local_steps=STEPS,
                                    batch_size=BATCH, lr=LR,
                                    donate=False, force_het=True))
    out = dict(
        strat=strat, ccfg=ccfg, frozen=frozen, class_emb=class_emb,
        clients=clients, global_tr=global_tr, engine=engine,
        cohort_exec=sched_lib.CohortExec(engine),
        seq_exec=sched_lib.SequentialExec(
            clients=clients, frozen=frozen, ccfg=ccfg,
            class_emb=class_emb, local_steps=STEPS, batch_size=BATCH,
            lr=LR))
    _SETUPS[arm] = out
    return out


def _trace(n=N_CLIENTS):
    return sched_lib.uniform_trace(n)


def _chaos(trace, seed=0, **kw):
    return sched_lib.ChaosSchedule(sched_lib.ChaosConfig(**kw),
                                   jax.random.PRNGKey(seed), trace)


def _assert_tree_close(a, b, atol, msg=""):
    flat_b = dict((jax.tree_util.keystr(p), l) for p, l in
                  jax.tree_util.tree_leaves_with_path(b))
    for p, leaf in jax.tree_util.tree_leaves_with_path(a):
        np.testing.assert_allclose(
            np.asarray(leaf),
            np.asarray(flat_b[jax.tree_util.keystr(p)]),
            atol=atol, rtol=0, err_msg=f"{msg}{jax.tree_util.keystr(p)}")


# -- config + schedule determinism --------------------------------------

def test_chaos_config_validation_and_presets():
    with pytest.raises(ValueError):
        sched_lib.ChaosConfig(dropout_prob=1.5)
    with pytest.raises(ValueError):
        sched_lib.ChaosConfig(unavail_len=0)
    with pytest.raises(ValueError):
        sched_lib.ChaosConfig(max_retries=0)
    with pytest.raises(ValueError):
        sched_lib.ChaosConfig(retry_backoff=0.0)
    with pytest.raises(ValueError):
        sched_lib.ChaosConfig(class_mult=(1.0, -2.0))
    assert sched_lib.resolve_chaos(None) is None
    assert sched_lib.resolve_chaos("light").dropout_prob == 0.1
    cfg = sched_lib.ChaosConfig(dropout_prob=0.2)
    assert sched_lib.resolve_chaos(cfg) is cfg
    with pytest.raises(ValueError, match="preset"):
        sched_lib.resolve_chaos("cataclysmic")
    with pytest.raises(ValueError):
        sched_lib.resolve_chaos(42)


def test_fault_schedule_is_population_shaped_and_deterministic():
    """Fault draws are functions of (key, tag, client position) alone:
    the same client sees the same fault regardless of who else is in
    the cohort (draws happen at the true population shape — threefry is
    not shape-stable — and cohorts index the vector), and two schedules
    built from the same (cfg, key, trace) agree bitwise."""
    tr = _trace(8)
    a = _chaos(tr, seed=7, dropout_prob=0.5, straggler_sigma=0.4,
               uplink_loss_prob=0.5, corrupt_prob=0.5)
    b = _chaos(tr, seed=7, dropout_prob=0.5, straggler_sigma=0.4,
               uplink_loss_prob=0.5, corrupt_prob=0.5)
    full_steps = np.full(8, 6, np.int64)
    cut_a, drop_a = a.cut_steps(3, np.arange(8), full_steps)
    cut_b, drop_b = b.cut_steps(3, np.arange(8), full_steps)
    np.testing.assert_array_equal(cut_a, cut_b)
    np.testing.assert_array_equal(drop_a, drop_b)
    # sub-cohort draws index the same population vector
    sub = np.array([1, 5, 6])
    cut_s, drop_s = a.cut_steps(3, sub, full_steps[sub])
    np.testing.assert_array_equal(cut_s, cut_a[sub])
    np.testing.assert_array_equal(drop_s, drop_a[sub])
    np.testing.assert_array_equal(a.straggler_mult(2, sub),
                                  b.straggler_mult(2, np.arange(8))[sub])
    for cid in range(8):
        assert a.uplink_lost(4, cid, 0) == b.uplink_lost(4, cid, 0)
        assert a.corrupt_uplink(4, cid) == b.corrupt_uplink(4, cid)


def test_cut_steps_bounds_and_single_step_clients():
    tr = _trace(16)
    ch = _chaos(tr, dropout_prob=1.0)
    full = np.full(16, 6, np.int64)
    cut, dropped = ch.cut_steps(0, np.arange(16), full)
    assert dropped.all()
    assert (cut >= 1).all() and (cut <= 5).all()
    # a 1-step client cannot drop mid-round (no prior step to cut at)
    cut1, drop1 = ch.cut_steps(0, np.arange(16), np.ones(16, np.int64))
    assert not drop1.any() and (cut1 == 1).all()
    # fault-free config: identity
    ch0 = _chaos(tr, dropout_prob=0.0)
    cut0, drop0 = ch0.cut_steps(0, np.arange(16), full)
    np.testing.assert_array_equal(cut0, full)
    assert not drop0.any()


def test_dark_windows_persist_and_cache():
    tr = _trace(64)
    ch = _chaos(tr, unavail_prob=0.3, unavail_len=3)
    starts = {r: np.asarray(ch._u(chaos_lib._DARK_TAG, r)) < 0.3
              for r in range(8)}
    for rnd in range(5, 8):
        expect = np.zeros(64, bool)
        for r in range(rnd - 2, rnd + 1):
            expect |= starts[r]
        np.testing.assert_array_equal(ch.dark_mask(rnd), expect)
        # cached: repeat queries agree bitwise
        np.testing.assert_array_equal(ch.dark_mask(rnd),
                                      ch.dark_mask(rnd))
    assert not _chaos(tr, unavail_prob=0.0).dark_mask(3).any()


def test_uplink_loss_is_bounded_by_max_retries():
    tr = _trace(8)
    ch = _chaos(tr, uplink_loss_prob=1.0, max_retries=3)
    for cid in range(8):
        assert ch.uplink_lost(0, cid, 0)
        assert ch.uplink_lost(0, cid, 2)
        # the attempt at max_retries always delivers: retries bound
        # delay, never liveness
        assert not ch.uplink_lost(0, cid, 3)
        assert not ch.uplink_lost(0, cid, 7)


# -- corrupt deltas + server guard --------------------------------------

def test_corrupt_delta_and_check_delta_guard():
    """Regression: a single NaN delta poisons the aggregated global
    irreversibly — check_delta must catch it before aggregation, on
    plain and quantized trees alike."""
    g = {"w": jnp.zeros((4,)), "b": jnp.zeros((2,))}
    d = {"w": jnp.ones((4,)), "b": jnp.ones((2,))}
    bad = chaos_lib.corrupt_delta(d)
    # exactly one leaf poisoned, treedef/shape preserved
    assert jax.tree.structure(bad) == jax.tree.structure(d)
    nan_leaves = [l for l in jax.tree.leaves(bad)
                  if np.any(np.isnan(np.asarray(l)))]
    assert len(nan_leaves) == 1
    # without the guard, aggregation poisons the global model
    poisoned = server.aggregate(g, [(1.0, bad), (1.0, d)])
    assert any(np.any(np.isnan(np.asarray(l)))
               for l in jax.tree.leaves(poisoned))
    # the guard: loud in strict mode, boolean for skip-and-ledger
    assert server.delta_ok(d, g)
    assert not server.delta_ok(bad, g)
    with pytest.raises(ValueError, match="non-finite"):
        server.check_delta(bad, g, ctx="client 0 delta")
    # shape mismatches against the global trainable also fail loudly
    with pytest.raises(ValueError, match="shape"):
        server.check_delta({"w": jnp.ones((5,)), "b": jnp.ones((2,))}, g)
    with pytest.raises(ValueError, match="leaves"):
        server.check_delta({"w": jnp.ones((4,))}, g)
    # quantized tree: the poison lands in the dequantization scales
    q = quantize_tree({"w": jnp.ones((64, 64))}, bits=8, mode="int",
                      block=64, min_size=0)
    qbad = chaos_lib.corrupt_delta(q)
    assert np.all(np.isnan(np.asarray(qbad["w"].scales)))
    assert not server.delta_ok(qbad)
    with pytest.raises(ValueError, match="no float leaf"):
        chaos_lib.corrupt_delta({"i": jnp.ones((3,), jnp.int32)})


# -- partial-work recovery property (masked scans) ----------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 6), st.integers(0, 2 ** 16))
def test_cut_at_s_is_bitwise_running_s_steps_adam(s, seed):
    """optim.step_mask recovery contract: a fixed-length masked
    adam_scan cut at step s is bitwise a scan of exactly s steps —
    params, both Adam moments, and the step counter."""
    S = 6
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (5,))}
    xs = jax.random.normal(jax.random.fold_in(key, 1), (S, 5))

    def grad_fn(p, x):
        g = jax.grad(lambda q: jnp.sum((q["w"] - x) ** 2))(p)
        return g, 0.0

    p_cut, s_cut, _ = optim.adam_scan(
        grad_fn, params, optim.adam_init(params), xs, lr=0.1,
        active=optim.step_mask(s, S))
    p_ref, s_ref, _ = optim.adam_scan(
        grad_fn, params, optim.adam_init(params), xs[:s], lr=0.1)
    for a, b in zip(jax.tree.leaves((p_cut, s_cut)),
                    jax.tree.leaves((p_ref, s_ref))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 4), st.integers(0, 2 ** 16))
def test_cut_at_s_is_running_s_steps_gan(s, seed):
    """The same recovery contract for the bucketed GAN scan the fleet
    engine dispatches.  Within one compiled program, masked tail steps
    are bitwise no-ops — garbage tail inputs cannot leak into params or
    either Adam state.  Across programs (fixed-length masked scan vs a
    genuinely shorter scan) the conv stacks compile separately, so the
    cross-check is allclose at float32 noise rather than bitwise."""
    S, B, n_true = 4, 8, 5
    cfg = gan_lib.GANConfig(n_classes=3, z_dim=8, g_dim=8, d_dim=8)
    key = jax.random.PRNGKey(seed)
    params = gan_lib.init_gan(key, cfg)
    opt = {"gen": optim.adam_init(params["gen"]),
           "disc": optim.adam_init(params["disc"])}
    images = jax.random.normal(jax.random.fold_in(key, 1),
                               (16, 32, 32, 3))
    labels = jnp.zeros((16,), jnp.int32)
    idx = jax.random.randint(jax.random.fold_in(key, 2), (S, B), 0, 16)
    z = jax.random.normal(jax.random.fold_in(key, 3), (S, B, cfg.z_dim))
    z2 = jax.random.normal(jax.random.fold_in(key, 4),
                           (S, B, cfg.z_dim))
    mask = optim.step_mask(s, S)
    out_cut = gan_lib.gan_scan_bucketed(
        params, opt, cfg, images, labels, idx, z, z2, n_true,
        active=mask)
    # same program, garbage beyond the cut: bitwise identical
    garb = jnp.where(mask[:, None, None], z, 1e6)
    out_garb = gan_lib.gan_scan_bucketed(
        params, opt, cfg, images, labels, idx, garb,
        jnp.where(mask[:, None, None], z2, -1e6), n_true, active=mask)
    for a, b in zip(jax.tree.leaves(out_cut[:2]),
                    jax.tree.leaves(out_garb[:2])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # separately compiled shorter scan: same math, float32 noise only
    out_ref = gan_lib.gan_scan_bucketed(
        params, opt, cfg, images, labels, idx[:s], z[:s], z2[:s],
        n_true)
    for a, b in zip(jax.tree.leaves(out_cut[:2]),
                    jax.tree.leaves(out_ref[:2])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-6, atol=1e-8)


# -- scheduler-level chaos: parity, proration, retries ------------------

_CHAOS_KW = dict(dropout_prob=0.6, straggler_sigma=0.4,
                 uplink_loss_prob=0.4, corrupt_prob=0.0, max_retries=2)


def test_sync_partial_chaos_fused_matches_sequential_oracle():
    """Both executors under one fault schedule: same participation,
    same fault ledger, same uplink bytes, matching globals — the
    sequential loop honors the cut-step schedule by simply running
    fewer steps, the fused engine by masking its fixed-length scan."""
    s = _setup("fedclip")
    tr = _trace()

    def run(ex):
        ch = _chaos(tr, seed=11, **_CHAOS_KW)
        sched = sched_lib.SyncPartialScheduler(
            executor=ex, trace=tr, local_steps=STEPS,
            clients_per_round=2, chaos=ch)
        g = s["global_tr"]
        log = []
        for rnd in range(3):
            g, m = sched.step(g, rnd, jax.random.PRNGKey(rnd))
            log.append((list(m["participation"]), m["vtime"],
                        int(m["uplink_bytes"]), list(m["loss"])))
        return g, log, ch.ledger.as_dict()

    gc, log_c, led_c = run(s["cohort_exec"])
    gs, log_s, led_s = run(s["seq_exec"])
    assert led_c == led_s
    assert led_c["n_dropped"] > 0 or led_c["uplinks_lost"] > 0
    for (pc, vc, bc, lc), (ps, vs, bs, ls) in zip(log_c, log_s):
        assert pc == ps
        assert vc == vs
        assert bc == bs
        np.testing.assert_allclose(lc, ls, atol=1e-3, rtol=1e-4)
    _assert_tree_close(gc, gs, atol=5e-4, msg="sync chaos ")


def test_full_sync_chaos_parity_and_dark_windows():
    s = _setup("fedclip")
    tr = _trace()

    def run(ex):
        ch = _chaos(tr, seed=5, dropout_prob=0.5, unavail_prob=0.4,
                    unavail_len=1)
        sched = sched_lib.FullSyncScheduler(
            executor=ex, trace=tr, local_steps=STEPS, chaos=ch)
        g = s["global_tr"]
        parts = []
        for rnd in range(2):
            g, m = sched.step(g, rnd, jax.random.PRNGKey(rnd))
            parts.append(list(m["participation"]))
        return g, parts, ch.ledger.as_dict()

    gc, pc, led_c = run(s["cohort_exec"])
    gs, ps, led_s = run(s["seq_exec"])
    assert pc == ps and led_c == led_s
    assert led_c["n_dropped"] + led_c["client_rounds_dark"] > 0
    _assert_tree_close(gc, gs, atol=5e-4, msg="full chaos ")


def test_async_chaos_determinism_and_parity():
    """Async under chaos: bit-deterministic across runs (event order,
    retry backoff on the virtual clock, staleness) and fused ==
    sequential on participation, ledger, and globals."""
    s = _setup("fedclip")
    tr = _trace()

    def run(ex):
        ch = _chaos(tr, seed=3, dropout_prob=0.4, straggler_sigma=0.5,
                    uplink_loss_prob=0.5, max_retries=2)
        sched = sched_lib.AsyncBufferedScheduler(
            executor=ex, trace=tr, local_steps=STEPS,
            clients_per_round=1, staleness_beta=0.5, concurrency=2,
            client_n=[c.n for c in s["clients"]], chaos=ch)
        g = s["global_tr"]
        log = []
        for rnd in range(4):
            g, m = sched.step(g, rnd, jax.random.PRNGKey(rnd))
            log.append((list(m["participation"]), list(m["staleness"]),
                        m["vtime"], int(m["uplink_bytes"])))
        return g, log, ch.ledger.as_dict()

    g1, log1, led1 = run(s["cohort_exec"])
    g2, log2, led2 = run(s["cohort_exec"])
    assert log1 == log2 and led1 == led2
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    gs, log_s, led_s = run(s["seq_exec"])
    assert [l[:3] for l in log_s] == [l[:3] for l in log1]
    assert led_s == led1
    assert led1["uplinks_lost"] > 0 and led1["n_retries"] > 0
    # retried deliveries consumed real uplink: bytes exceed the
    # fault-free per-commit payload at least once
    _assert_tree_close(g1, gs, atol=5e-4, msg="async chaos ")


def test_sync_chaos_commit_weights_are_prorated():
    """A dropped client's delta commits with mass scaled by its
    completed-step fraction: the chaos step must equal a hand-built
    wave + commit_buffer with cut/full-prorated, renormalized masses."""
    s = _setup("fedclip")
    tr = _trace()
    key = jax.random.PRNGKey(21)
    kw = dict(dropout_prob=0.7)
    sched = sched_lib.SyncPartialScheduler(
        executor=s["cohort_exec"], trace=tr, local_steps=STEPS,
        clients_per_round=3, chaos=_chaos(tr, seed=9, **kw))
    got, m = sched.step(s["global_tr"], 0, key)
    # replay the same schedule by hand
    ch = _chaos(tr, seed=9, **kw)
    sched2 = sched_lib.SyncPartialScheduler(
        executor=s["cohort_exec"], trace=tr, local_steps=STEPS,
        clients_per_round=3, chaos=ch)
    cohort = sched2.select(0, key)
    full = np.asarray(cohort.n_steps, np.int64)
    cut, dropped = ch.cut_steps(0, cohort.sel, full)
    assert dropped.any(), "p=0.7 over 3 clients should drop someone"
    deltas, _ = s["cohort_exec"].run_wave(
        s["global_tr"],
        sched_lib.Cohort(cohort.sel, cut.astype(np.int32),
                         cohort.staleness), key)
    w = s["cohort_exec"].client_masses()[cohort.sel] * (cut / full)
    w = (w / w.sum()).astype(np.float32)
    ref = s["cohort_exec"].commit_buffer(s["global_tr"], w, deltas)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert list(m["participation"]) == list(cohort.sel)


def test_sync_lost_uplink_retries_next_round_and_delivers():
    """uplink_loss_prob=1, max_retries=2: every client loses attempts 0
    and 1 (re-selected first each next round, nothing committed), and
    the attempt at max_retries is forced through — bounded retry can
    delay a commit, never starve it."""
    s = _setup("fedclip")
    tr = _trace()
    ch = _chaos(tr, seed=1, uplink_loss_prob=1.0, max_retries=2)
    sched = sched_lib.SyncPartialScheduler(
        executor=s["cohort_exec"], trace=tr, local_steps=STEPS,
        clients_per_round=2, chaos=ch)
    g = s["global_tr"]
    parts = []
    for rnd in range(3):
        g, m = sched.step(g, rnd, jax.random.PRNGKey(rnd))
        parts.append(list(m["participation"]))
    assert parts[0] == [] and parts[1] == []
    assert ch.ledger.commits_skipped == 2
    assert len(parts[2]) == 2            # forced delivery at attempt 2
    assert ch.ledger.uplinks_lost == 4   # 2 clients x 2 lost attempts
    assert ch.ledger.n_retries == 4      # both re-selected twice
    # the global model only moved on the delivering round
    assert any((np.asarray(a) != np.asarray(b)).any() for a, b in
               zip(jax.tree.leaves(g), jax.tree.leaves(s["global_tr"])))


def test_strict_mode_raises_on_corrupt_uplink():
    s = _setup("fedclip")
    tr = _trace()
    ch = _chaos(tr, seed=2, corrupt_prob=1.0, tolerate_corrupt=False)
    sched = sched_lib.SyncPartialScheduler(
        executor=s["cohort_exec"], trace=tr, local_steps=STEPS,
        clients_per_round=2, chaos=ch)
    with pytest.raises(ValueError, match="non-finite"):
        sched.step(s["global_tr"], 0, jax.random.PRNGKey(0))
    # tolerant mode skips-and-ledgers the same faults
    ch2 = _chaos(tr, seed=2, corrupt_prob=1.0, tolerate_corrupt=True)
    sched2 = sched_lib.SyncPartialScheduler(
        executor=s["cohort_exec"], trace=tr, local_steps=STEPS,
        clients_per_round=2, chaos=ch2)
    g, m = sched2.step(s["global_tr"], 0, jax.random.PRNGKey(0))
    assert ch2.ledger.deltas_corrupt == 2
    assert ch2.ledger.deltas_skipped == 2
    assert ch2.ledger.commits_skipped == 1
    assert list(m["participation"]) == []
    for a, b in zip(jax.tree.leaves(g),
                    jax.tree.leaves(s["global_tr"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- runtime LRU --------------------------------------------------------

def test_runtime_lru_eviction_is_bounded_and_ledgered():
    rt = ProgramRuntime(max_entries=2)
    build = lambda: (lambda x: x * 2.0)
    a, b, c = (jnp.ones((4,)),), (jnp.ones((8,)),), (jnp.ones((16,)),)
    rt.run("k", build, a)
    rt.run("k", build, b)
    assert rt.n_compiles == 2 and rt.n_evictions == 0
    rt.run("k", build, a)                 # hit: refreshes a's recency
    assert rt.n_compiles == 2
    rt.run("k", build, c)                 # evicts b (LRU), not a
    assert rt.n_evictions == 1
    rt.run("k", build, a)                 # still cached
    assert rt.n_compiles == 3
    rt.run("k", build, b)                 # recompiles, evicts again
    assert rt.n_compiles == 4 and rt.n_evictions == 2
    assert rt.stats()["k"]["n_evicted"] == 2
    # unbounded runtime never evicts; negative bound is rejected
    rt0 = ProgramRuntime()
    for args in (a, b, c):
        rt0.run("k", build, args)
    assert rt0.n_evictions == 0
    with pytest.raises(ValueError):
        ProgramRuntime(max_entries=-1)


# -- traces: diurnal realism + JSON replay ------------------------------

def test_diurnal_trace_cycles_and_roundtrips(tmp_path):
    tr = sched_lib.diurnal_trace(12, seed=4)
    tr2 = sched_lib.diurnal_trace(12, seed=4)
    np.testing.assert_array_equal(tr.availability, tr2.availability)
    np.testing.assert_array_equal(tr.device_class, tr2.device_class)
    assert tr.n_device_classes == 3
    # the cycle modulates availability but keeps it strictly positive
    a0, a12 = tr.availability_at(0.0), tr.availability_at(12.0)
    assert not np.allclose(a0, a12)
    for t in (0.0, 6.0, 12.0, 18.0):
        assert (tr.availability_at(t) > 0).all()
        np.testing.assert_allclose(tr.selection_probs(t).sum(), 1.0,
                                   rtol=1e-12)
    # static traces are inert under the time argument
    u = sched_lib.uniform_trace(4)
    np.testing.assert_array_equal(u.availability_at(0.0),
                                  u.availability_at(99.0))
    # JSON replay: save -> load -> identical schedule inputs
    p = tmp_path / "trace.json"
    sched_lib.save_trace(tr, p)
    lt = sched_lib.load_trace(p)
    for f in ("availability", "speed", "step_mult", "device_class",
              "phase"):
        np.testing.assert_array_equal(getattr(lt, f), getattr(tr, f))
    assert lt.period == tr.period and lt.amplitude == tr.amplitude
    assert sched_lib.resolve_trace(str(p), 12).n == 12
    assert sched_lib.resolve_trace("diurnal", 6).n_device_classes >= 1
    with pytest.raises(ValueError):
        sched_lib.resolve_trace(str(p), 5)    # wrong population
    with pytest.raises(ValueError):           # amplitude >= 1 degenerate
        sched_lib.AvailabilityTrace(
            availability=np.ones(2), speed=np.ones(2),
            step_mult=np.ones(2, np.int32), amplitude=1.0, period=10.0)


# -- fleet-GAN drop between launch and resolve --------------------------

def _gan_clients(sizes, *, seed=0):
    strat = STRATEGIES["tripleplay"]
    data = make_dataset("pacs", n_per_class=30, seed=seed,
                        longtail_gamma=4.0)
    spec = data["spec"]
    out, start = [], 0
    for i, n in enumerate(sizes):
        sl = slice(start, start + n)
        start += n
        out.append(client_lib.Client(
            cid=i, images=data["images"][sl],
            labels=data["labels"][sl], n_classes=spec.n_classes,
            strategy=strat))
    return out


def test_fleetgan_mark_dropped_discards_undelivered_work():
    """A client that drops between GAN launch and resolve gets nothing
    written back — no trained params, no synthesized rebalancing rows —
    exactly as if it vanished before uploading; survivors and the
    report are unaffected except for the n_dropped count."""
    clients = _gan_clients([GAN_MIN_POOL + 6, GAN_MIN_POOL + 4, 4])
    keys = [jax.random.PRNGKey(100 + i) for i in range(len(clients))]
    job = fleetgan.launch_gan_fleet(clients, keys, steps=20)
    assert len(job.need.get(1, ())) > 0, "long-tail shard needs synth"
    job.mark_dropped([1])
    rep = job.resolve()
    assert rep.n_dropped == 1
    assert clients[1].gan_params is None
    assert clients[1].aug_images is None
    assert clients[0].gan_params is not None
    assert clients[0].aug_images is not None
    assert 1 not in rep.d_loss
    with pytest.raises(RuntimeError, match="resolved"):
        job.mark_dropped([0])


def test_cohort_engine_shrinks_pool_for_gan_dropped_client():
    """The padded pool layout reserves synth slots at launch; a dropped
    client's lens must shrink back to its raw pool so the zero-feature
    reserved rows are never sampled — and the fused round then matches
    the sequential oracle whose dropped client simply never ran
    prepare_gan."""
    ccfg = clip_lib.CLIPConfig()
    frozen = clip_lib.init_clip(jax.random.PRNGKey(3), ccfg)
    clients = _gan_clients([GAN_MIN_POOL + 6, GAN_MIN_POOL + 4], seed=1)
    spec_classes = clients[0].n_classes
    class_emb = clip_lib.text_embedding(
        frozen, ccfg, jnp.asarray(class_tokens(
            make_dataset("pacs", n_per_class=2, seed=0)["spec"],
            np.arange(spec_classes))))
    keys = [jax.random.PRNGKey(200 + i) for i in range(len(clients))]
    job = fleetgan.launch_gan_fleet(clients, keys, steps=20)
    need1 = len(job.need.get(1, ()))
    assert need1 > 0
    job.mark_dropped([1])
    strat = STRATEGIES["tripleplay"]
    engine = cohort_lib.CohortEngine(
        frozen=frozen, ccfg=ccfg, class_emb=class_emb, clients=clients,
        cfg=cohort_lib.CohortConfig(strategy=strat, local_steps=STEPS,
                                    batch_size=BATCH, lr=LR,
                                    donate=False),
        gan_job=job)
    lens = np.asarray(engine.lens)
    assert lens[1] == clients[1].n                  # shrunk to raw pool
    assert lens[0] == clients[0].n + len(job.need[0])
    # parity: the sequential pool for the dropped client is its raw data
    global_tr = client_lib.init_trainable(jax.random.PRNGKey(1), ccfg,
                                          strat)
    key = jax.random.PRNGKey(33)
    new_c, mc = engine.run_round(global_tr, key)
    idx = cohort_lib.round_indices(key, np.asarray(engine.lens), STEPS,
                                   BATCH)
    updates, oloss = [], []
    for i, c in enumerate(clients):
        tr_after, m = c.local_train(frozen, global_tr, class_emb, ccfg,
                                    steps=STEPS, batch_size=BATCH,
                                    lr=LR, indices=idx[i])
        upd, _ = c.make_update(global_tr, tr_after)
        updates.append((c.n, upd))
        oloss.append(m["loss"])
    ref = server.aggregate(global_tr, updates)
    np.testing.assert_allclose(mc["loss"], oloss, atol=1e-3, rtol=1e-4)
    _assert_tree_close(new_c, ref, atol=5e-4, msg="gan-drop ")


# -- simulator acceptance ----------------------------------------------

_ACC_CFG = dict(
    dataset="pacs", strategy="fedclip", n_clients=4, rounds=3,
    local_steps=3, n_per_class=12, batch_size=8, lr=3e-3,
    participation="sync-partial", clients_per_round=2, trace="skewed",
    chaos=sched_lib.ChaosConfig(dropout_prob=0.5, straggler_sigma=0.5,
                                uplink_loss_prob=0.5, max_retries=2))


def test_run_federated_chaos_is_bit_deterministic_no_extra_compiles():
    """The acceptance scenario: a seeded chaos run (>=20% dropout +
    lognormal stragglers + lost uplinks) is bit-deterministic across
    two runs, reports a non-empty fault ledger, and compiles exactly
    one wave program — chaos adds zero program kinds beyond the
    existing width/step-profile buckets (no subset_round, no silent
    fault-free fallback)."""
    from repro.fl.simulator import FLConfig, run_federated
    h1 = run_federated(FLConfig(**_ACC_CFG))
    h2 = run_federated(FLConfig(**_ACC_CFG))
    assert h1.participation == h2.participation
    assert h1.vtime == h2.vtime
    assert h1.client_loss == h2.client_loss
    assert h1.server_acc == h2.server_acc
    assert h1.uplink_bytes == h2.uplink_bytes
    assert h1.meta["fault_ledger"] == h2.meta["fault_ledger"]
    led = h1.meta["fault_ledger"]
    assert sum(led.values()) > 0, "chaos run took the fault-free path"
    assert led["uplinks_lost"] > 0 or led["n_dropped"] > 0
    kinds = h1.meta["n_compiles_by_kind"]
    assert kinds.get("wave_round", 0) == 1
    assert "subset_round" not in kinds
    assert h1.meta["chaos"]["dropout_prob"] == 0.5
    # vtime advances by the straggler-stretched barrier each round
    assert all(b > a for a, b in zip(h1.vtime, h1.vtime[1:]))
    assert h1.meta["n_cache_evictions"] == 0


def test_run_federated_chaos_engines_agree():
    """End-to-end satellite parity: cohort vs sequential engine under
    one chaos seed produce the same participation, fault ledger, and
    matching client losses."""
    from repro.fl.simulator import FLConfig, run_federated
    hc = run_federated(FLConfig(**dict(_ACC_CFG, engine="cohort")))
    hs = run_federated(FLConfig(**dict(_ACC_CFG, engine="sequential")))
    assert hc.participation == hs.participation
    assert hc.meta["fault_ledger"] == hs.meta["fault_ledger"]
    assert hc.uplink_bytes == hs.uplink_bytes
    for lc, ls in zip(hc.client_loss, hs.client_loss):
        np.testing.assert_allclose(lc, ls, atol=1e-3, rtol=1e-4)


def test_history_device_class_columns_and_report():
    """Diurnal trace + async chaos: History carries per-device-class
    participation/staleness/accuracy columns every round, and meta
    summarizes population vs participation share per class."""
    from repro.fl.simulator import FLConfig, run_federated
    h = run_federated(FLConfig(
        dataset="pacs", strategy="fedclip", n_clients=5, rounds=3,
        local_steps=3, n_per_class=12, batch_size=8, lr=3e-3,
        participation="async", clients_per_round=1,
        async_concurrency=2, trace="diurnal",
        chaos=sched_lib.ChaosConfig(straggler_sigma=0.5,
                                    uplink_loss_prob=0.4,
                                    max_retries=2,
                                    class_mult=(1.0, 2.0, 4.0))))
    n_dc = h.meta["device_classes"]
    assert n_dc >= 1
    assert len(h.class_counts) == 3
    assert all(len(row) == n_dc for row in h.class_counts)
    assert all(len(row) == n_dc for row in h.class_staleness)
    assert all(len(row) == n_dc for row in h.class_acc)
    # every committed update is attributed to exactly one class
    for counts, parts in zip(h.class_counts, h.participation):
        assert sum(counts) == len(parts)
    rep = h.meta["device_class_report"]
    assert len(rep) == n_dc
    np.testing.assert_allclose(
        sum(r["population_share"] for r in rep), 1.0, rtol=1e-9)
    assert h.meta["fault_ledger"]["uplinks_lost"] >= 0
    assert "chaos" in h.meta


def test_run_federated_chaos_gan_drop_ledger():
    """TriplePlay arm under heavy dropout: clients lost between GAN
    launch and resolve land in the ledger and the run still completes
    with both GAN engines agreeing on the drop set (engine-independent
    schedule)."""
    from repro.fl.simulator import FLConfig, run_federated
    cfg = dict(
        dataset="pacs", strategy="tripleplay", n_clients=3, rounds=1,
        local_steps=2, n_per_class=14, batch_size=8, lr=3e-3,
        gan_steps=20, participation="full",
        chaos=sched_lib.ChaosConfig(dropout_prob=0.9))
    hf = run_federated(FLConfig(**cfg, gan_engine="fleet"))
    hs = run_federated(FLConfig(**cfg, gan_engine="sequential"))
    assert hf.meta["fault_ledger"]["gan_dropped"] == \
        hs.meta["fault_ledger"]["gan_dropped"]
    assert hf.meta["fault_ledger"]["gan_dropped"] > 0
    # fleet vs sequential GAN training differ at float32 reduction
    # order (bucketed masked losses), so the trained pools — and hence
    # client losses — agree only to ~1e-3 relative
    for lc, ls in zip(hf.client_loss, hs.client_loss):
        np.testing.assert_allclose(lc, ls, rtol=1e-3)
