"""Per-kernel validation: Pallas (interpret mode — executes the kernel body
on CPU) vs the pure-jnp oracle in kernels/ref.py, swept over shapes,
dtypes, GQA ratios, masks, and quantization modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant as qlib
from repro.kernels import ref
from repro.kernels.blockwise_quant import blockwise_quant
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quant_matmul import quant_matmul


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (2, 128, 4, 4, 64),      # MHA
    (2, 128, 4, 2, 64),      # GQA 2:1
    (1, 256, 8, 1, 32),      # MQA
    (2, 100, 4, 2, 64),      # non-multiple S (padding path)
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(B, S, H, Hkv, D, causal, window, dtype, rng):
    q = jnp.asarray(rng.randn(B, S, H, D), dtype)
    k = jnp.asarray(rng.randn(B, S, Hkv, D), dtype)
    v = jnp.asarray(rng.randn(B, S, Hkv, D), dtype)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_flash_vs_naive_softmax(rng):
    """The blocked oracle itself against a plain softmax attention."""
    B, S, H, D = 2, 64, 4, 32
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
    s = jnp.where(jnp.tril(jnp.ones((S, S), bool)), s, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd",
                      jax.nn.softmax(s, -1), v)
    got = ref.flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


@pytest.mark.parametrize("bits,mode", [(8, "linear"), (4, "linear"),
                                       (4, "nf4")])
@pytest.mark.parametrize("K,N,block", [(128, 64, 64), (256, 96, 128),
                                       (512, 33, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_vs_ref(bits, mode, K, N, block, dtype, rng):
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    qt = qlib.quantize(w, bits=bits, block=block, mode=mode)
    x = jnp.asarray(rng.randn(2, 7, K), dtype)
    want = ref.quant_matmul(x, qt)
    got = quant_matmul(x, qt, block_m=8, block_n=32, interpret=True)
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol * float(jnp.abs(want).max()))


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("K,N,block", [(128, 32, 64), (256, 100, 128)])
def test_blockwise_quant_vs_ref(bits, K, N, block, rng):
    x = jnp.asarray(rng.randn(K, N), jnp.float32)
    want = ref.blockwise_quant(x, bits=bits, block=block)
    got = blockwise_quant(x, bits=bits, block=block, block_n=32,
                          interpret=True)
    assert (np.asarray(want.q) == np.asarray(got.q)).all()
    np.testing.assert_allclose(np.asarray(want.scales),
                               np.asarray(got.scales), rtol=1e-6)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("K,N,block", [(130, 33, 64), (190, 40, 128),
                                       (70, 20, 64)])
def test_blockwise_quant_odd_K_pads_contraction_dim(bits, K, N, block,
                                                    rng):
    """K not divisible by the block must zero-pad the contraction dim
    (like N already pads to block_n) instead of asserting: the result
    equals the reference on the zero-padded input exactly — pad rows
    never perturb a block's absmax scale — and dequantizes back to the
    original values (zeros past K)."""
    x = jnp.asarray(rng.randn(K, N), jnp.float32)
    got = blockwise_quant(x, bits=bits, block=block, block_n=32,
                          interpret=True)
    blk = min(block, K)
    Kp = -(-K // blk) * blk
    xp = jnp.pad(x, ((0, Kp - K), (0, 0)))
    want = ref.blockwise_quant(xp, bits=bits, block=block)
    assert got.orig_shape == (K, N)
    # the jnp fallback path (ops.blockwise_quant without Pallas) shares
    # the pad contract: odd K works and matches quantizing padded input
    ref_odd = ref.blockwise_quant(x, bits=bits, block=block)
    assert ref_odd.orig_shape == (K, N)
    assert (np.asarray(ref_odd.q) == np.asarray(want.q)).all()
    np.testing.assert_allclose(np.asarray(ref_odd.scales),
                               np.asarray(want.scales), rtol=1e-6)
    assert (np.asarray(want.q) == np.asarray(got.q)).all()
    np.testing.assert_allclose(np.asarray(want.scales),
                               np.asarray(got.scales), rtol=1e-6)
    deq = np.asarray(qlib.dequantize(got))
    assert deq.shape == (Kp, N)
    np.testing.assert_array_equal(deq[K:], 0)
    scale_bound = np.asarray(want.scales).max()
    np.testing.assert_allclose(deq[:K], np.asarray(x),
                               atol=1.2 * scale_bound)
    # both matmul consumers accept the padded-K payload: x's
    # contraction dim pads with zeros (contracts exactly like slicing)
    xin = jnp.asarray(rng.randn(3, K), jnp.float32)
    want_mm = np.asarray(xin) @ deq[:K]
    np.testing.assert_allclose(np.asarray(ref.quant_matmul(xin, got)),
                               want_mm, atol=1e-4, rtol=1e-5)
    got_mm = quant_matmul(xin, got, block_m=8, block_n=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got_mm), want_mm,
                               atol=1e-3 * max(1.0, np.abs(
                                   want_mm).max()))


def test_decode_attention_matches_flash_last_token(rng):
    """decode against a fully-valid cache == last row of full attention."""
    B, S, H, Hkv, D = 2, 32, 4, 2, 16
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, Hkv, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Hkv, D), jnp.float32)
    full = ref.flash_attention(q, k, v, causal=True)
    got = ref.decode_attention(q[:, -1:], k, v,
                               jnp.arange(S, dtype=jnp.int32)[None])
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-5)


@pytest.mark.parametrize("B,S,di,N,bd,ch", [
    (2, 64, 32, 8, 16, 16),
    (1, 50, 16, 4, 16, 32),    # non-multiple S (padding path)
    (2, 96, 64, 16, 32, 48),
])
def test_selective_scan_vs_ref(B, S, di, N, bd, ch, rng):
    dt = jnp.asarray(np.abs(rng.randn(B, S, di)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.randn(B, S, di), jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(di, N)), jnp.float32)
    from repro.kernels.selective_scan import selective_scan as ssk
    y0, h0 = ref.selective_scan(dt, x, Bm, Cm, A)
    y1, h1 = ssk(dt, x, Bm, Cm, A, block_d=bd, chunk=ch, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=1e-5)


def test_selective_scan_ref_matches_mamba_chunked(rng):
    """The Pallas oracle and the model's chunked associative scan agree."""
    from repro.models.ssm import _chunked_ssm_scan
    B, S, di, N = 2, 40, 16, 8
    dt = jnp.asarray(np.abs(rng.randn(B, S, di)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.randn(B, S, di), jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(di, N)), jnp.float32)
    y0, h0 = ref.selective_scan(dt, x, Bm, Cm, A)
    y1, h1 = _chunked_ssm_scan(dt, A, Bm, Cm, x,
                               jnp.zeros((B, di, N)), 16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=1e-4)


def test_decode_attention_partial_combine(rng):
    """flash-decoding: log-sum-exp combination of slot shards == full."""
    B, H, Hkv, D, M = 2, 4, 2, 16, 32
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, M, Hkv, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, M, Hkv, D), jnp.float32)
    sp = jnp.where(jnp.arange(M) < 20, jnp.arange(M), -1)[None]
    want = ref.decode_attention(q, k, v, sp)
    halves = [(k[:, :16], v[:, :16], sp[:, :16]),
              (k[:, 16:], v[:, 16:], sp[:, 16:])]
    parts = [ref.decode_attention_partial(q, *h) for h in halves]
    m = jnp.maximum(parts[0][0], parts[1][0])
    l = sum(p[1] * jnp.exp(p[0] - m) for p in parts)
    acc = sum(p[2] * jnp.exp(p[0] - m)[..., None] for p in parts)
    got = (acc / l[..., None]).reshape(B, 1, H, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


# ---------------------------------------------------------------------
# fused LoRA matmul: one kernel == the einsum chain, forward + backward
# ---------------------------------------------------------------------
from hypothesis import given, settings, strategies as st

from repro.kernels import autotune, ops as kops
from repro.kernels.lora_matmul import lora_matmul, quant_matmul_t


def _lora_chain(x, qt, a, b, scale):
    """The legacy einsum chain core.lora.linear used to build: base
    quant matmul + separately-computed low-rank delta (fp32)."""
    xf = x.astype(jnp.float32)
    base = ref.quant_matmul(xf, qt)
    h = jnp.einsum("...k,kr->...r", xf, a.astype(jnp.float32))
    d = jnp.einsum("...r,rn->...n", h, b.astype(jnp.float32))
    return (base + scale * d).astype(x.dtype)


@pytest.mark.parametrize("bits,mode", [(8, "linear"), (4, "linear"),
                                       (4, "nf4")])
@pytest.mark.parametrize("K,N,r", [(128, 96, 4), (200, 64, 8),
                                   (64, 33, 4)])   # 200: odd-K pad path
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_lora_kernel_vs_chain_forward(bits, mode, K, N, r, dtype,
                                            rng):
    M, scale = 17, 2.0
    x = jnp.asarray(rng.randn(M, K), dtype)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    a = jnp.asarray(rng.randn(K, r) * 0.1, jnp.float32)
    b = jnp.asarray(rng.randn(r, N) * 0.1, jnp.float32)
    qt = ref.blockwise_quant(w, bits=bits, block=128, mode=mode)
    want = _lora_chain(x, qt, a, b, scale)
    got = lora_matmul(x, qt, a, b, scale=scale, block_m=8, block_n=32,
                      interpret=True)
    assert got.dtype == x.dtype
    tol = (1e-5 if dtype == jnp.float32 else 2e-2) * max(
        1.0, float(jnp.abs(want.astype(jnp.float32)).max()))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("bits,mode", [(8, "linear"), (4, "nf4")])
@pytest.mark.parametrize("K", [128, 200])          # 200: odd-K pad path
def test_quant_matmul_t_vs_ref(bits, mode, K, rng):
    N = 96
    g = jnp.asarray(rng.randn(13, N), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    qt = ref.blockwise_quant(w, bits=bits, block=128, mode=mode)
    wd = qlib.dequantize(qt, jnp.float32)           # (Kq, N)
    want = g @ wd.T
    got = quant_matmul_t(g, qt, block_m=8, block_n=32, interpret=True)
    tol = 1e-5 * max(1.0, float(jnp.abs(want).max()))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol)


@pytest.mark.parametrize("force", ["", "interpret"])
@pytest.mark.parametrize("bits,mode,K", [(8, "linear", 128),
                                         (4, "nf4", 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_lora_op_backward_vs_chain(force, bits, mode, K, dtype,
                                         rng, monkeypatch):
    """ops.lora_matmul's custom VJP (dx through Wᵀ + BᵀAᵀ, dA/dB through
    the tiled gemms) == jax.grad of the einsum chain, on both the ref
    path and the Pallas interpret path (which exercises
    quant_matmul_t)."""
    monkeypatch.setattr(kops, "_FORCE", force)
    N, r, scale = 64, 4, 2.0
    x = jnp.asarray(rng.randn(9, K), dtype)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    a = jnp.asarray(rng.randn(K, r) * 0.1, jnp.float32)
    b = jnp.asarray(rng.randn(r, N) * 0.1, jnp.float32)
    qt = ref.blockwise_quant(w, bits=bits, block=128, mode=mode)
    ct = jnp.asarray(rng.randn(9, N), jnp.float32)

    def loss_fused(x, a, b):
        y = kops.lora_matmul(x, qt, a, b, scale=scale)
        return jnp.sum(y.astype(jnp.float32) * ct)

    def loss_chain(x, a, b):
        return jnp.sum(_lora_chain(x, qt, a, b, scale)
                       .astype(jnp.float32) * ct)

    y_f = kops.lora_matmul(x, qt, a, b, scale=scale)
    y_c = _lora_chain(x, qt, a, b, scale)
    ftol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(y_f, np.float32), np.asarray(y_c, np.float32),
        atol=ftol * max(1.0, float(jnp.abs(y_c.astype(jnp.float32)).max())))
    got = jax.grad(loss_fused, argnums=(0, 1, 2))(x, a, b)
    want = jax.grad(loss_chain, argnums=(0, 1, 2))(x, a, b)
    for gf, gc, name in zip(got, want, ("dx", "da", "db")):
        assert gf.dtype == gc.dtype, name
        scale_t = max(1.0, float(jnp.abs(gc.astype(jnp.float32)).max()))
        tol = (1e-5 if gc.dtype == jnp.float32 else 2e-2) * scale_t
        np.testing.assert_allclose(np.asarray(gf, np.float32),
                                   np.asarray(gc, np.float32),
                                   atol=tol, err_msg=name)


def test_fused_lora_dense_w_grad_includes_dw(rng):
    x = jnp.asarray(rng.randn(7, 32), jnp.float32)
    w = jnp.asarray(rng.randn(32, 16), jnp.float32)
    a = jnp.asarray(rng.randn(32, 4) * 0.1, jnp.float32)
    b = jnp.asarray(rng.randn(4, 16) * 0.1, jnp.float32)
    ct = jnp.asarray(rng.randn(7, 16), jnp.float32)
    gw = jax.grad(lambda w: jnp.sum(
        kops.lora_matmul(x, w, a, b, scale=2.0) * ct))(w)
    rw = jax.grad(lambda w: jnp.sum(
        (x @ w + 2.0 * (x @ a) @ b) * ct))(w)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(M=st.integers(1, 24), K=st.sampled_from([64, 128, 150, 256]),
       N=st.sampled_from([32, 64, 96]), r=st.sampled_from([2, 4, 8]),
       bits=st.sampled_from([8, 4]),
       scale=st.floats(0.25, 4.0))
def test_fused_lora_property_fwd_bwd(M, K, N, r, bits, scale):
    """Hypothesis sweep: fused op == chain, forward and backward, over
    random geometry (incl. non-multiple K) on the ref path."""
    rng = np.random.RandomState(M * 1000 + K + N + r + bits)
    x = jnp.asarray(rng.randn(M, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    a = jnp.asarray(rng.randn(K, r) * 0.1, jnp.float32)
    b = jnp.asarray(rng.randn(r, N) * 0.1, jnp.float32)
    qt = ref.blockwise_quant(w, bits=bits, block=128)
    ct = jnp.asarray(rng.randn(M, N), jnp.float32)
    y_f = kops.lora_matmul(x, qt, a, b, scale=scale)
    y_c = _lora_chain(x, qt, a, b, scale)
    s0 = max(1.0, float(jnp.abs(y_c).max()))
    np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_c),
                               atol=1e-5 * s0)
    got = jax.grad(lambda x, a, b: jnp.sum(
        kops.lora_matmul(x, qt, a, b, scale=scale) * ct),
        argnums=(0, 1, 2))(x, a, b)
    want = jax.grad(lambda x, a, b: jnp.sum(
        _lora_chain(x, qt, a, b, scale) * ct),
        argnums=(0, 1, 2))(x, a, b)
    for gf, gc in zip(got, want):
        s1 = max(1.0, float(jnp.abs(gc).max()))
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gc),
                                   atol=2e-5 * s1)


def test_quant_matmul_stacked_takes_pallas_when_forced(rng, monkeypatch):
    """ops.quant_matmul must not silently fall back to ref for the
    stacked (per-client serve) QTensor layout when Pallas is forced —
    it vmaps the kernel over the stack axis, and loudly rejects layouts
    it has no mapping for."""
    monkeypatch.setattr(kops, "_FORCE", "interpret")
    kops.reset_kernel_traces()
    T, K, N = 3, 64, 32
    w = jnp.asarray(rng.randn(T, K, N), jnp.float32)
    qt = qlib.quantize(w, bits=8, block=64)
    assert qt.q.ndim == 4
    x = jnp.asarray(rng.randn(T, K), jnp.float32)
    got = kops.quant_matmul(x, qt)
    wd = qlib.dequantize(qt, jnp.float32)
    want = (x[:, None, :] @ wd)[:, 0, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5 * max(1.0, float(jnp.abs(want).max())))
    assert kops.KERNEL_TRACES.get("quant_matmul_pallas_stacked", 0) >= 1
    assert kops.KERNEL_TRACES.get("quant_matmul_ref", 0) == 0
    # batched rows per stack entry
    xb = jnp.asarray(rng.randn(T, 5, K), jnp.float32)
    got_b = kops.quant_matmul(xb, qt)
    np.testing.assert_allclose(np.asarray(got_b), np.asarray(xb @ wd),
                               atol=1e-4)
    # no mapping for >1 stack axis: loud, not silent
    w5 = jnp.asarray(rng.randn(2, 2, K, N), jnp.float32)
    qt5 = qlib.quantize(w5, bits=8, block=64)
    with pytest.raises(NotImplementedError):
        kops.quant_matmul(jnp.asarray(rng.randn(2, 2, K), jnp.float32),
                          qt5)


# ---------------------------------------------------------------------
# autotune: persisted winners, deterministic second sweep (zero compiles)
# ---------------------------------------------------------------------
def test_autotune_sweep_caches_and_charges(tmp_path, rng, monkeypatch):
    from repro.fl import runtime as runtime_lib
    path = str(tmp_path / "autotune.json")
    autotune.clear()
    rt = runtime_lib.ProgramRuntime()
    x = jnp.asarray(rng.randn(16, 128), jnp.float32)
    w = jnp.asarray(rng.randn(128, 64), jnp.float32)
    qt = ref.blockwise_quant(w, bits=8, block=128)

    def build(bm, bn):
        return lambda: quant_matmul(x, qt, block_m=bm, block_n=bn,
                                    interpret=True)

    r1 = autotune.sweep("quant_matmul", build, 16, 128, 64, bits=8,
                        mode="linear", runtime=rt, path=path,
                        candidates=((8, 32), (16, 64)), iters=1)
    assert r1.swept and r1.n_candidates == 2
    assert rt.stats()["autotune_quant_matmul"]["n_compiles"] == 2
    t1 = rt.compile_time_s
    assert t1 > 0
    # second sweep: pure cache hit — zero new compiles in the ledger
    r2 = autotune.sweep("quant_matmul", build, 16, 128, 64, bits=8,
                        mode="linear", runtime=rt, path=path,
                        candidates=((8, 32), (16, 64)), iters=1)
    assert not r2.swept and r2.best == r1.best
    assert rt.stats()["autotune_quant_matmul"]["n_compiles"] == 2
    assert rt.compile_time_s == t1
    # lookup returns the winner without sweeping; M buckets to pow2
    assert autotune.lookup("quant_matmul", 16, 128, 64, bits=8,
                           mode="linear", path=path) == r1.best
    assert autotune.lookup("quant_matmul", 13, 128, 64, bits=8,
                           mode="linear", path=path) == r1.best
    # unseen shape falls back to the default, still without sweeping
    assert autotune.lookup("quant_matmul", 16, 256, 64, bits=8,
                           mode="linear", path=path) == \
        autotune.DEFAULT_BLOCKS
    # a fresh in-process cache reloads the persisted JSON winners
    autotune.clear()
    assert autotune.lookup("quant_matmul", 16, 128, 64, bits=8,
                           mode="linear", path=path) == r1.best
    autotune.clear()


# ---------------------------------------------------------------------
# int8 quantized-compute GAN gemms
# ---------------------------------------------------------------------
def test_quant_gemm_int8_close_to_fp(rng):
    from repro.kernels import gan_conv
    x = jnp.asarray(rng.randn(37, 100), jnp.float32)
    w = jnp.asarray(rng.randn(100, 24), jnp.float32)
    y8 = gan_conv.quant_gemm_int8(x, w)
    y = x @ w
    rel = float(jnp.abs(y8 - y).max() / jnp.abs(y).max())
    assert rel < 3e-2           # blockwise int8 compute, fp32 accum
    # exact-zero blocks stay exact zeros
    assert float(jnp.abs(gan_conv.quant_gemm_int8(
        jnp.zeros((4, 64)), w[:64])).max()) == 0.0


@pytest.mark.parametrize("op,hw,ci,co", [
    ("conv", 16, 6, 12), ("conv", 8, 16, 24),
    ("convT", 8, 16, 16), ("convT", 16, 16, 3),   # co<8: contrib form
])
def test_gan_conv_int8_close_to_fp_with_grads(op, hw, ci, co, rng):
    from repro.kernels import gan_conv
    x = jnp.asarray(rng.randn(2, hw, hw, ci), jnp.float32)
    w = jnp.asarray(rng.randn(4, 4, ci, co) * 0.1, jnp.float32)
    fp = getattr(gan_conv, f"{'conv' if op == 'conv' else 'convT'}4x4_s2")
    q8 = getattr(gan_conv,
                 f"{'conv' if op == 'conv' else 'convT'}4x4_s2_int8")
    want = fp(x, w)
    got = q8(x, w)
    assert got.shape == want.shape
    rel = float(jnp.abs(got - want).max() /
                max(1e-6, float(jnp.abs(want).max())))
    assert rel < 3e-2
    ct = jnp.asarray(rng.randn(*want.shape), jnp.float32)
    gx, gw = jax.grad(lambda x, w: jnp.sum(q8(x, w) * ct),
                      argnums=(0, 1))(x, w)
    rx, rw = jax.grad(lambda x, w: jnp.sum(fp(x, w) * ct),
                      argnums=(0, 1))(x, w)
    for g, r_ in ((gx, rx), (gw, rw)):
        assert bool(jnp.isfinite(g).all())
        cos = float((g * r_).sum() /
                    (jnp.linalg.norm(g) * jnp.linalg.norm(r_)))
        assert cos > 0.99       # straight-through grads track the fp map
