"""Per-kernel validation: Pallas (interpret mode — executes the kernel body
on CPU) vs the pure-jnp oracle in kernels/ref.py, swept over shapes,
dtypes, GQA ratios, masks, and quantization modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant as qlib
from repro.kernels import ref
from repro.kernels.blockwise_quant import blockwise_quant
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quant_matmul import quant_matmul


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (2, 128, 4, 4, 64),      # MHA
    (2, 128, 4, 2, 64),      # GQA 2:1
    (1, 256, 8, 1, 32),      # MQA
    (2, 100, 4, 2, 64),      # non-multiple S (padding path)
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(B, S, H, Hkv, D, causal, window, dtype, rng):
    q = jnp.asarray(rng.randn(B, S, H, D), dtype)
    k = jnp.asarray(rng.randn(B, S, Hkv, D), dtype)
    v = jnp.asarray(rng.randn(B, S, Hkv, D), dtype)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_flash_vs_naive_softmax(rng):
    """The blocked oracle itself against a plain softmax attention."""
    B, S, H, D = 2, 64, 4, 32
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
    s = jnp.where(jnp.tril(jnp.ones((S, S), bool)), s, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd",
                      jax.nn.softmax(s, -1), v)
    got = ref.flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


@pytest.mark.parametrize("bits,mode", [(8, "linear"), (4, "linear"),
                                       (4, "nf4")])
@pytest.mark.parametrize("K,N,block", [(128, 64, 64), (256, 96, 128),
                                       (512, 33, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_vs_ref(bits, mode, K, N, block, dtype, rng):
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    qt = qlib.quantize(w, bits=bits, block=block, mode=mode)
    x = jnp.asarray(rng.randn(2, 7, K), dtype)
    want = ref.quant_matmul(x, qt)
    got = quant_matmul(x, qt, block_m=8, block_n=32, interpret=True)
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol * float(jnp.abs(want).max()))


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("K,N,block", [(128, 32, 64), (256, 100, 128)])
def test_blockwise_quant_vs_ref(bits, K, N, block, rng):
    x = jnp.asarray(rng.randn(K, N), jnp.float32)
    want = ref.blockwise_quant(x, bits=bits, block=block)
    got = blockwise_quant(x, bits=bits, block=block, block_n=32,
                          interpret=True)
    assert (np.asarray(want.q) == np.asarray(got.q)).all()
    np.testing.assert_allclose(np.asarray(want.scales),
                               np.asarray(got.scales), rtol=1e-6)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("K,N,block", [(130, 33, 64), (190, 40, 128),
                                       (70, 20, 64)])
def test_blockwise_quant_odd_K_pads_contraction_dim(bits, K, N, block,
                                                    rng):
    """K not divisible by the block must zero-pad the contraction dim
    (like N already pads to block_n) instead of asserting: the result
    equals the reference on the zero-padded input exactly — pad rows
    never perturb a block's absmax scale — and dequantizes back to the
    original values (zeros past K)."""
    x = jnp.asarray(rng.randn(K, N), jnp.float32)
    got = blockwise_quant(x, bits=bits, block=block, block_n=32,
                          interpret=True)
    blk = min(block, K)
    Kp = -(-K // blk) * blk
    xp = jnp.pad(x, ((0, Kp - K), (0, 0)))
    want = ref.blockwise_quant(xp, bits=bits, block=block)
    assert got.orig_shape == (K, N)
    # the jnp fallback path (ops.blockwise_quant without Pallas) shares
    # the pad contract: odd K works and matches quantizing padded input
    ref_odd = ref.blockwise_quant(x, bits=bits, block=block)
    assert ref_odd.orig_shape == (K, N)
    assert (np.asarray(ref_odd.q) == np.asarray(want.q)).all()
    np.testing.assert_allclose(np.asarray(ref_odd.scales),
                               np.asarray(want.scales), rtol=1e-6)
    assert (np.asarray(want.q) == np.asarray(got.q)).all()
    np.testing.assert_allclose(np.asarray(want.scales),
                               np.asarray(got.scales), rtol=1e-6)
    deq = np.asarray(qlib.dequantize(got))
    assert deq.shape == (Kp, N)
    np.testing.assert_array_equal(deq[K:], 0)
    scale_bound = np.asarray(want.scales).max()
    np.testing.assert_allclose(deq[:K], np.asarray(x),
                               atol=1.2 * scale_bound)
    # both matmul consumers accept the padded-K payload: x's
    # contraction dim pads with zeros (contracts exactly like slicing)
    xin = jnp.asarray(rng.randn(3, K), jnp.float32)
    want_mm = np.asarray(xin) @ deq[:K]
    np.testing.assert_allclose(np.asarray(ref.quant_matmul(xin, got)),
                               want_mm, atol=1e-4, rtol=1e-5)
    got_mm = quant_matmul(xin, got, block_m=8, block_n=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got_mm), want_mm,
                               atol=1e-3 * max(1.0, np.abs(
                                   want_mm).max()))


def test_decode_attention_matches_flash_last_token(rng):
    """decode against a fully-valid cache == last row of full attention."""
    B, S, H, Hkv, D = 2, 32, 4, 2, 16
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, Hkv, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Hkv, D), jnp.float32)
    full = ref.flash_attention(q, k, v, causal=True)
    got = ref.decode_attention(q[:, -1:], k, v,
                               jnp.arange(S, dtype=jnp.int32)[None])
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-5)


@pytest.mark.parametrize("B,S,di,N,bd,ch", [
    (2, 64, 32, 8, 16, 16),
    (1, 50, 16, 4, 16, 32),    # non-multiple S (padding path)
    (2, 96, 64, 16, 32, 48),
])
def test_selective_scan_vs_ref(B, S, di, N, bd, ch, rng):
    dt = jnp.asarray(np.abs(rng.randn(B, S, di)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.randn(B, S, di), jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(di, N)), jnp.float32)
    from repro.kernels.selective_scan import selective_scan as ssk
    y0, h0 = ref.selective_scan(dt, x, Bm, Cm, A)
    y1, h1 = ssk(dt, x, Bm, Cm, A, block_d=bd, chunk=ch, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=1e-5)


def test_selective_scan_ref_matches_mamba_chunked(rng):
    """The Pallas oracle and the model's chunked associative scan agree."""
    from repro.models.ssm import _chunked_ssm_scan
    B, S, di, N = 2, 40, 16, 8
    dt = jnp.asarray(np.abs(rng.randn(B, S, di)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.randn(B, S, di), jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(di, N)), jnp.float32)
    y0, h0 = ref.selective_scan(dt, x, Bm, Cm, A)
    y1, h1 = _chunked_ssm_scan(dt, A, Bm, Cm, x,
                               jnp.zeros((B, di, N)), 16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=1e-4)


def test_decode_attention_partial_combine(rng):
    """flash-decoding: log-sum-exp combination of slot shards == full."""
    B, H, Hkv, D, M = 2, 4, 2, 16, 32
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, M, Hkv, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, M, Hkv, D), jnp.float32)
    sp = jnp.where(jnp.arange(M) < 20, jnp.arange(M), -1)[None]
    want = ref.decode_attention(q, k, v, sp)
    halves = [(k[:, :16], v[:, :16], sp[:, :16]),
              (k[:, 16:], v[:, 16:], sp[:, 16:])]
    parts = [ref.decode_attention_partial(q, *h) for h in halves]
    m = jnp.maximum(parts[0][0], parts[1][0])
    l = sum(p[1] * jnp.exp(p[0] - m) for p in parts)
    acc = sum(p[2] * jnp.exp(p[0] - m)[..., None] for p in parts)
    got = (acc / l[..., None]).reshape(B, 1, H, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)
