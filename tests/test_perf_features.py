"""§Perf levers: int8 KV cache, gradient accumulation, bf16 trainables —
numerical behaviour on reduced models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import optim
from repro.models import build_model


def _toks(cfg, B, S, rng):
    return jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)


def test_int8_kv_cache_close_to_fp(rng):
    cfg = get_reduced("yi-9b")
    m_fp = build_model(cfg)
    m_q = build_model(cfg.replace(kv_quant_bits=8))
    params = m_fp.init_params(jax.random.PRNGKey(1))
    toks = _toks(cfg, 2, 17, rng)
    outs = {}
    for name, m in (("fp", m_fp), ("q8", m_q)):
        _, cache = m.prefill(params["frozen"], params["trainable"],
                             {"tokens": toks[:, :-1]}, max_len=17)
        got, _ = m.decode_step(params["frozen"], params["trainable"],
                               cache, toks[:, -1:],
                               jnp.asarray(16, jnp.int32))
        outs[name] = np.asarray(got)
    rel = np.abs(outs["fp"] - outs["q8"]).max() / \
        (np.abs(outs["fp"]).max() + 1e-9)
    assert rel < 0.05, rel  # int8 KV: small, bounded degradation


def test_int8_kv_cache_is_int8(rng):
    cfg = get_reduced("h2o-danube-3-4b").replace(kv_quant_bits=8)
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    _, cache = m.prefill(params["frozen"], params["trainable"],
                         {"tokens": _toks(cfg, 2, 16, rng)}, max_len=32)
    assert cache["scan"]["kv"]["k"].dtype == jnp.int8
    assert "k_scale" in cache["scan"]["kv"]


def test_grad_accum_matches_single_shot(rng):
    cfg = get_reduced("yi-9b")
    toks = _toks(cfg, 4, 17, rng)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "mask": jnp.ones((4, 16), jnp.float32)}
    m1 = build_model(cfg)
    m4 = build_model(cfg.replace(grad_accum=4))
    params = m1.init_params(jax.random.PRNGKey(0))
    opt = optim.adam_init(params["trainable"])
    tr1, _, a = m1.train_step(params["frozen"], params["trainable"], opt,
                              batch)
    tr4, _, b = m4.train_step(params["frozen"], params["trainable"], opt,
                              batch)
    assert abs(float(a["loss"]) - float(b["loss"])) < 1e-3
    d = jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), tr1, tr4)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_bf16_trainables_train(rng):
    cfg = get_reduced("yi-9b").replace(trainable_dtype="bfloat16")
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    assert params["trainable"]["adapter"]["wq"].dtype == jnp.bfloat16
    toks = _toks(cfg, 2, 17, rng)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "mask": jnp.ones((2, 16), jnp.float32)}
    opt = optim.adam_init(params["trainable"])
    tr, _, metrics = jax.jit(m.train_step)(
        params["frozen"], params["trainable"], opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert tr["adapter"]["wq"].dtype == jnp.bfloat16
