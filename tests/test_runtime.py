"""Bucketed program runtime (fl.runtime): compile-count regressions
(one cache entry per shape bucket, not per shape), the GAN batch
mean-correction contract, and the bucket arithmetic itself.

The compile-count tests are the guard the tentpole exists for: a
participation sweep over many cohort widths K must compile one fused
round per power-of-two bucket (O(log N), not O(N)), and a fleet-GAN
cohort with several distinct batch-size groups must compile exactly one
train and one synthesis program. A regression here means someone
reintroduced a per-shape compile.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import clip as clip_lib
from repro.core import gan as gan_lib
from repro.core import optim
from repro.data.synthetic import class_tokens, make_dataset
from repro.fl import client as client_lib
from repro.fl import cohort as cohort_lib
from repro.fl import fleetgan
from repro.fl import runtime as runtime_lib
from repro.fl.strategies import STRATEGIES

STEPS, BATCH, LR = 2, 8, 3e-3


# -- bucket arithmetic -------------------------------------------------

def test_bucket_width_powers_of_two_clamped():
    # K=N never pads (keeps the full-sync round gather-exact) ...
    for n in (1, 2, 3, 5, 8, 13):
        assert runtime_lib.bucket_width(n, n) == n
    # ... smaller selections round up to pow2 with a floor of 4,
    # clamped to N
    assert runtime_lib.bucket_width(2, 16) == 4
    assert runtime_lib.bucket_width(3, 16) == 4
    assert runtime_lib.bucket_width(5, 16) == 8
    assert runtime_lib.bucket_width(9, 16) == 16
    assert runtime_lib.bucket_width(2, 3) == 3      # clamp beats floor
    with pytest.raises(ValueError):
        runtime_lib.bucket_width(0, 4)
    with pytest.raises(ValueError):
        runtime_lib.bucket_width(5, 4)


def test_bucket_rows_and_pad_leading():
    assert runtime_lib.bucket_rows(3, 512) == 4
    assert runtime_lib.bucket_rows(512, 512) == 512
    assert runtime_lib.bucket_rows(600, 512) == 512
    a = jnp.arange(6).reshape(3, 2)
    p = runtime_lib.pad_leading(a, 5)
    assert p.shape == (5, 2)
    np.testing.assert_array_equal(np.asarray(p[:3]), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(p[3:]), 0)
    with pytest.raises(ValueError):
        runtime_lib.pad_leading(a, 2)


def test_runtime_cache_and_accounting():
    rt = runtime_lib.ProgramRuntime()
    build = lambda: (lambda x: x * 2.0)
    a = jnp.ones((4,))
    out = rt.run("double", build, (a,))
    np.testing.assert_array_equal(np.asarray(out), 2.0)
    rt.run("double", build, (jnp.zeros((4,)),))     # same shape: hit
    assert rt.n_compiles == 1 and rt.compile_time_s > 0
    rt.run("double", build, (jnp.ones((8,)),))      # new shape: miss
    assert rt.stats()["double"]["n_compiles"] == 2
    h = rt.dispatch("double", build, (a,))
    np.testing.assert_array_equal(np.asarray(h.result()), 2.0)
    rt.clear()
    assert rt.n_compiles == 0 and rt.stats() == {}


def test_shard_multiple_and_sharded_bucket_width():
    """On a mesh the width bucket additionally rounds up to a shard
    multiple (clamped to N) so the bucketed cohort axis always splits
    evenly over the data-parallel shards; K=N still never pads."""
    assert runtime_lib.shard_multiple(5, 1) == 5
    assert runtime_lib.shard_multiple(5, 4) == 8
    assert runtime_lib.shard_multiple(8, 4) == 8
    assert runtime_lib.shard_multiple(9, 8) == 16
    with pytest.raises(ValueError):
        runtime_lib.shard_multiple(5, 0)
    # shards=1 is exactly the unsharded arithmetic
    for k, n in ((2, 16), (5, 16), (9, 16), (3, 3)):
        assert runtime_lib.bucket_width(k, n, shards=1) == \
            runtime_lib.bucket_width(k, n)
    assert runtime_lib.bucket_width(2, 16, shards=8) == 8
    assert runtime_lib.bucket_width(5, 16, shards=8) == 8
    assert runtime_lib.bucket_width(9, 16, shards=8) == 16
    assert runtime_lib.bucket_width(5, 12, shards=4) == 8
    assert runtime_lib.bucket_width(11, 12, shards=4) == 12  # clamp to N
    for n in (8, 12, 16):          # K=N never pads, sharded or not
        assert runtime_lib.bucket_width(n, n, shards=4) == n
    with pytest.raises(ValueError):  # population must shard evenly
        runtime_lib.bucket_width(2, 10, shards=4)


# -- compile-count regression: cohort width buckets --------------------

def _mk_engine(runtime, sizes, arm="fedclip"):
    strat = STRATEGIES[arm]
    ccfg = clip_lib.CLIPConfig()
    frozen = clip_lib.init_clip(jax.random.PRNGKey(3), ccfg)
    data = make_dataset("pacs", n_per_class=12, seed=0,
                        longtail_gamma=4.0)
    spec = data["spec"]
    class_emb = clip_lib.text_embedding(
        frozen, ccfg,
        jnp.asarray(class_tokens(spec, np.arange(spec.n_classes))))
    assert sum(sizes) <= len(data["labels"])
    clients, start = [], 0
    for i, n in enumerate(sizes):
        sl = slice(start, start + n)
        start += n
        clients.append(client_lib.Client(
            cid=i, images=data["images"][sl], labels=data["labels"][sl],
            n_classes=spec.n_classes, strategy=strat))
    engine = cohort_lib.CohortEngine(
        frozen=frozen, ccfg=ccfg, class_emb=class_emb, clients=clients,
        cfg=cohort_lib.CohortConfig(strategy=strat, local_steps=STEPS,
                                    batch_size=BATCH, lr=LR,
                                    donate=False),
        runtime=runtime)
    tr = client_lib.init_trainable(jax.random.PRNGKey(1), ccfg, strat)
    return engine, tr


def test_subset_round_compiles_one_program_per_bucket():
    """A sweep over 4 distinct cohort widths K ∈ {2,3,5,8} on N=9 must
    compile at most 2 subset-round programs (buckets {4, 8}), and
    padding must never leak into metrics or uplink accounting."""
    rt = runtime_lib.ProgramRuntime()
    engine, tr = _mk_engine(rt, (10, 10, 10, 10, 8, 8, 8, 6, 6))
    per_client = engine.per_client_uplink_bytes(tr)
    rs = np.random.RandomState(0)
    for k in (2, 3, 5, 8):
        sel = rs.choice(engine.n_clients, k, replace=False)
        _, m = engine.run_subset_round(tr, sel, jax.random.PRNGKey(k))
        assert len(m["loss"]) == k and len(m["acc"]) == k
        assert int(m["uplink_bytes"]) == k * per_client
        assert sorted(m["sel"]) == sorted(int(s) for s in sel)
    stats = rt.stats()
    assert stats["subset_round"]["n_compiles"] <= 2, stats
    # the tiny index sampler is still per-width (it feeds the true-K
    # draw), but the expensive round program is bucketed
    assert stats["sample_idx"]["n_compiles"] == 4
    # a second sweep over the same widths is all cache hits
    n_before = rt.n_compiles
    for k in (2, 3, 5, 8):
        sel = rs.choice(engine.n_clients, k, replace=False)
        engine.run_subset_round(tr, sel, jax.random.PRNGKey(100 + k))
    assert rt.n_compiles == n_before


def test_wave_round_shares_width_buckets():
    """Async wave widths in one bucket share a compile with each other
    (but not with the aggregate-in-program subset round)."""
    rt = runtime_lib.ProgramRuntime()
    engine, tr = _mk_engine(rt, (10, 10, 10, 10, 8, 8, 8, 6, 6))
    for k in (2, 3, 4):        # all bucket to width 4
        delta, m = engine.run_wave(tr, np.arange(k),
                                   jax.random.PRNGKey(k))
        assert len(m["loss"]) == k
        sliced = cohort_lib.slice_client_delta(delta, k - 1)
        assert all(np.all(np.isfinite(np.asarray(l)))
                   for l in jax.tree.leaves(sliced))
    assert rt.stats()["wave_round"]["n_compiles"] == 1


# -- compile-count regression: fleet-GAN batch bucket ------------------

def test_fleet_gan_skewed_cohort_compiles_one_train_one_synth():
    """A cohort with >= 2 distinct GAN batch-size groups (40 -> b40,
    21 -> b21, 5 -> ineligible rider) must share ONE bucketed train
    program and ONE synthesis program — the mean-correction contract is
    what makes the shared compile legal."""
    strat = STRATEGIES["tripleplay"]
    data = make_dataset("pacs", n_per_class=30, seed=0,
                        longtail_gamma=4.0)
    spec = data["spec"]
    clients, start = [], 0
    for i, n in enumerate((40, 21, 5)):
        sl = slice(start, start + n)
        start += n
        clients.append(client_lib.Client(
            cid=i, images=data["images"][sl], labels=data["labels"][sl],
            n_classes=spec.n_classes, strategy=strat))
    rt = runtime_lib.ProgramRuntime()
    rep = fleetgan.prepare_gan_fleet(
        clients, [jax.random.PRNGKey(100 + i) for i in range(3)],
        steps=4, runtime=rt)
    stats = rt.stats()
    assert stats["gan_train"]["n_compiles"] == 1, stats
    assert stats["gan_synth"]["n_compiles"] == 1, stats
    assert rep.groups == [(40, 3)]        # one bucket, whole cohort
    assert rep.n_eligible == 2
    assert rep.compile_time_s > 0
    # the pre-draws stay per-true-batch-size (threefry shape
    # stability), two distinct sizes -> two tiny programs each
    assert stats["gan_idx"]["n_compiles"] == 2
    assert stats["gan_z"]["n_compiles"] == 2


# -- mean-correction property (hypothesis) -----------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(1, 10), st.integers(0, 12), st.integers(0, 10 ** 6))
def test_mean_corrected_padded_step_matches_unpadded(n, pad, seed):
    """A GAN step on a batch padded to an arbitrary bucket must match
    the unpadded step bit-tight: params AND both Adam states (moments
    and step counters), with the per-step noise pre-drawn at the true
    batch shape. This is the contract that lets every batch-size group
    share one compile."""
    cfg = gan_lib.GANConfig(n_classes=3, g_dim=8, d_dim=8, z_dim=8,
                            conv_impl="gemm")
    rs = np.random.RandomState(seed)
    imgs = jnp.asarray(rs.randn(n, 32, 32, 3).astype(np.float32))
    labs = jnp.asarray(rs.randint(0, 3, n).astype(np.int32))
    rng = jax.random.PRNGKey(seed)
    params = gan_lib.init_gan(jax.random.fold_in(rng, 0), cfg)
    opt = {"gen": optim.adam_init(params["gen"]),
           "disc": optim.adam_init(params["disc"])}
    step_key = jax.random.fold_in(rng, 1)

    # reference: the sequential step draws its noise in-program
    ref_p, ref_o, ref_m = jax.jit(
        lambda p, o: gan_lib.train_step_impl(p, o, (imgs, labs), cfg,
                                             step_key))(params, opt)

    # bucketed: same noise pre-drawn at the TRUE shape, batch padded
    kz, kz2 = jax.random.split(step_key)
    z = jax.random.normal(kz, (n, cfg.z_dim))
    z2 = jax.random.normal(kz2, (n, cfg.z_dim))
    B = n + pad
    pad_rows = lambda a: jnp.pad(
        a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
    got_p, got_o, got_m = jax.jit(
        lambda p, o: gan_lib.train_step_bucketed(
            p, o, (pad_rows(imgs), pad_rows(labs)), cfg, pad_rows(z),
            pad_rows(z2), jnp.asarray(n)))(params, opt)

    np.testing.assert_allclose(float(got_m["d_loss"]),
                               float(ref_m["d_loss"]), atol=1e-5)
    np.testing.assert_allclose(float(got_m["g_loss"]),
                               float(ref_m["g_loss"]), atol=1e-5)
    for (path, a), b in zip(
            jax.tree_util.tree_leaves_with_path((ref_p, ref_o)),
            jax.tree.leaves((got_p, got_o))):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype.kind == "i":      # Adam step counters: exact
            np.testing.assert_array_equal(
                a, b, err_msg=jax.tree_util.keystr(path))
        else:
            np.testing.assert_allclose(
                a, b, atol=1e-5, rtol=0,
                err_msg=jax.tree_util.keystr(path))


# -- hierarchical aggregation == flat aggregation (hypothesis) ----------

def _random_stacked_delta(rs, n):
    """A stacked delta tree with a quantized leaf next to plain floats —
    the layout ``comm_quantize_stacked`` hands ``aggregate_stacked``."""
    from repro.core import quant
    return {
        "adapter": jnp.asarray(rs.randn(n, 6, 3).astype(np.float32)),
        "bias": jnp.asarray(rs.randn(n, 5).astype(np.float32)),
        "lora": quant.quantize(
            jnp.asarray(rs.randn(n, 8, 8).astype(np.float32)),
            bits=8, block=4, mode="linear"),
    }


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 12), st.integers(1, 8), st.integers(0, 10 ** 6))
def test_tree_aggregation_matches_flat(n, n_shards, seed):
    """server.aggregate_tree is a re-association of aggregate_stacked:
    for arbitrary client masses (zero masses included) and arbitrary
    shard splits — even ones the cohort width does not divide — the two
    must agree within fp tolerance. This is the parity oracle that lets
    the mesh engines aggregate hierarchically."""
    from repro.fl import server
    rs = np.random.RandomState(seed)
    delta = _random_stacked_delta(rs, n)
    masses = rs.rand(n).astype(np.float32) * 10
    masses[rs.rand(n) < 0.25] = 0.0          # dropped/zero-weight rows
    if masses.sum() == 0:
        masses[0] = 1.0
    weights = jnp.asarray(masses / masses.sum())
    gt = {"adapter": jnp.asarray(rs.randn(6, 3).astype(np.float32)),
          "bias": jnp.asarray(rs.randn(5).astype(np.float32)),
          "lora": jnp.asarray(rs.randn(8, 8).astype(np.float32))}
    flat = server.aggregate_stacked(gt, weights, delta)
    # tree path takes UNnormalized masses (it normalizes by the total)
    tree = server.aggregate_tree(gt, jnp.asarray(masses), delta,
                                 n_shards=n_shards)
    for (path, a), b in zip(
            jax.tree_util.tree_leaves_with_path(flat),
            jax.tree.leaves(tree)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5,
            err_msg=jax.tree_util.keystr(path))


def test_tree_partials_pad_rows_are_exact_zero():
    """Shard-padding rows (cohort width not a shard multiple) must
    contribute EXACTLY zero partial sum and zero partial mass — not
    fp-tolerance zero — and zero-mass true rows must zero their own
    contribution exactly too."""
    from repro.fl import server
    rs = np.random.RandomState(0)
    n, n_shards = 5, 4               # pads to 8: shard 3+ is half pad
    delta = _random_stacked_delta(rs, n)
    masses = np.asarray([2.0, 1.0, 0.0, 3.0, 1.5], np.float32)
    partials, mass_s = server.tree_partials(
        jnp.asarray(masses), delta, n_shards=n_shards)
    assert mass_s.shape == (n_shards,)
    # rows 0..4 split into groups of 2: [0,1],[2,3],[4,pad],[pad,pad]
    np.testing.assert_array_equal(
        np.asarray(mass_s), [3.0, 3.0, 1.5, 0.0])
    # the all-pad shard's partial sums are bitwise zero on every leaf
    for leaf in jax.tree.leaves(partials):
        assert np.all(np.asarray(leaf)[-1] == 0.0)
    # zero-mass client 2 contributes exactly zero: shard 1's partial is
    # bitwise 3.0 * client 3's delta
    from repro.core.quant import dequantize, QTensor
    for leaf, part in zip(
            jax.tree.leaves(delta,
                            is_leaf=lambda l: isinstance(l, QTensor)),
            jax.tree.leaves(partials)):
        dq = dequantize(leaf, jnp.float32) if isinstance(leaf, QTensor) \
            else np.asarray(leaf, np.float32)
        np.testing.assert_array_equal(np.asarray(part)[1],
                                      3.0 * np.asarray(dq)[3])
    with pytest.raises(ValueError):
        server.tree_partials(jnp.asarray(masses), delta, n_shards=0)
    with pytest.raises(ValueError):   # mass per stacked row, not fewer
        server.tree_partials(jnp.asarray(masses[:3]), delta, n_shards=2)


# -- cache keys carry sharding identity ---------------------------------

def test_runtime_cache_separates_shardings():
    """A sharded and an unsharded program with identical shapes/dtypes
    must not share an executable: AOT-compiled programs bake their input
    shardings in at lower() time, so a collision would hand back an
    executable compiled for the wrong placement. A 1-device mesh
    suffices — NamedSharding identity is part of the signature."""
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_data_mesh(1)
    rt = runtime_lib.ProgramRuntime()
    build = lambda: (lambda x: x * 2.0)
    a = jnp.ones((8, 4))
    a_sharded = jax.device_put(a, mesh_lib.cohort_sharding(mesh, 2))
    rt.run("double", build, (a,))
    rt.run("double", build, (a_sharded,))     # same shape, new sharding
    assert rt.stats()["double"]["n_compiles"] == 2, rt.stats()
    # both placements hit their own entry on re-dispatch
    rt.run("double", build, (jnp.zeros((8, 4)),))
    rt.run("double", build, (jax.device_put(
        jnp.zeros((8, 4)), mesh_lib.cohort_sharding(mesh, 2)),))
    assert rt.stats()["double"]["n_compiles"] == 2
    # a different mesh axis layout is a different signature too
    sig_plain = runtime_lib.ProgramRuntime._sig((a,))
    sig_shard = runtime_lib.ProgramRuntime._sig((a_sharded,))
    assert sig_plain != sig_shard


# -- every fused program reports through one ledger ---------------------

def test_pretrain_and_eval_route_through_runtime_ledger():
    """``pretrained_clip`` (adam_scan) and ``_server_eval`` compile
    through the shared ProgramRuntime, so History.meta's by-kind ledger
    covers them next to the round/staging kinds — no fused program runs
    off the books."""
    from repro.fl import simulator as sim

    # a (dataset, seed, steps) key no other test uses, so _CLIP_CACHE
    # can't short-circuit the compile
    rt = runtime_lib.ProgramRuntime()
    ccfg = clip_lib.CLIPConfig()
    sim.pretrained_clip("pacs", ccfg, seed=4321, steps=3, batch=8,
                        runtime=rt)
    st = rt.stats()
    assert st["clip_pretrain"]["n_compiles"] == 1
    assert st["clip_pretrain"]["compile_time_s"] > 0

    # clip_pretrain appears in the run's meta too unless an earlier
    # test in the process already warmed the params cache (then the
    # program never re-runs)
    was_cached = ("pacs", 1234, 300) in sim._CLIP_CACHE
    h = sim.run_federated(sim.FLConfig(
        dataset="pacs", strategy="qlora_nogan", n_clients=2, rounds=1,
        local_steps=2, n_per_class=12, batch_size=8, lr=3e-3))
    kinds = h.meta["n_compiles_by_kind"]
    assert kinds.get("server_eval", 0) >= 1
    if not was_cached:
        assert kinds.get("clip_pretrain", 0) >= 1


def test_count_accumulates_auxiliary_counters():
    rt = runtime_lib.ProgramRuntime()
    rt.count("serve_store", "hits")
    rt.count("serve_store", "hits", 2)
    rt.count("serve_store", "misses")
    st = rt.stats()["serve_store"]
    assert st["hits"] == 3 and st["misses"] == 1
    assert st["n_compiles"] == 0          # counters don't fake compiles
