"""The assigned architecture table, verified literally."""
import pytest

from repro.configs import ARCHS, INPUT_SHAPES, get_config, get_reduced

EXPECTED = {
    # arch: (family, L, d_model, H, kv, d_ff, vocab)
    "yi-9b": ("dense", 48, 4096, 32, 4, 11008, 64000),
    "qwen3-moe-235b-a22b": ("moe", 94, 4096, 64, 4, 1536, 151936),
    "h2o-danube-3-4b": ("dense", 24, 3840, 32, 8, 10240, 32000),
    "whisper-medium": ("encdec", 24, 1024, 16, 16, 4096, 51865),
    "falcon-mamba-7b": ("ssm", 64, 4096, 0, 0, 0, 65024),
    "llava-next-34b": ("vlm", 60, 7168, 56, 8, 20480, 64000),
    "codeqwen1.5-7b": ("dense", 32, 4096, 32, 32, 13440, 92416),
    "recurrentgemma-2b": ("hybrid", 26, 2560, 10, 1, 7680, 256000),
    "kimi-k2-1t-a32b": ("moe", 61, 7168, 64, 8, 2048, 163840),
    "starcoder2-15b": ("dense", 40, 6144, 48, 4, 24576, 49152),
}


def test_all_ten_assigned():
    assert set(ARCHS) == set(EXPECTED)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_exact_table(arch):
    fam, L, d, H, kv, ff, V = EXPECTED[arch]
    c = get_config(arch)
    assert (c.family, c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.d_ff, c.vocab_size) == (fam, L, d, H, kv, ff, V)
    assert c.source


def test_moe_details():
    q = get_config("qwen3-moe-235b-a22b")
    assert (q.n_experts, q.experts_per_token) == (128, 8)
    k = get_config("kimi-k2-1t-a32b")
    assert (k.n_experts, k.experts_per_token, k.n_shared_experts,
            k.first_k_dense) == (384, 8, 1, 1)


def test_special_structure():
    assert get_config("h2o-danube-3-4b").window == 4096
    assert get_config("recurrentgemma-2b").attn_pattern == (
        "rglru", "rglru", "attn")
    assert get_config("falcon-mamba-7b").ssm_state == 16
    assert get_config("whisper-medium").encoder_layers == 24
    assert get_config("whisper-medium").n_frames == 1500
    assert get_config("llava-next-34b").n_patches == 576


def test_param_counts_plausible():
    """6·N·D sanity: totals within ~25% of the published sizes."""
    approx = {"yi-9b": 8.8e9, "falcon-mamba-7b": 7.3e9,
              "starcoder2-15b": 15e9, "llava-next-34b": 34e9,
              "codeqwen1.5-7b": 7.2e9}
    for arch, want in approx.items():
        n = get_config(arch).param_count()
        assert 0.7 * want < n < 1.35 * want, (arch, n)


def test_kimi_is_trillion_scale():
    n = get_config("kimi-k2-1t-a32b").param_count()
    assert 0.8e12 < n < 1.3e12, n
    a = get_config("kimi-k2-1t-a32b").param_count(active_only=True)
    assert a < 6e10, a


def test_input_shapes_table():
    t = INPUT_SHAPES
    assert (t["train_4k"].seq_len, t["train_4k"].global_batch) == \
        (4096, 256)
    assert (t["prefill_32k"].seq_len, t["prefill_32k"].global_batch) == \
        (32768, 32)
    assert (t["decode_32k"].seq_len, t["decode_32k"].global_batch) == \
        (32768, 128)
    assert (t["long_500k"].seq_len, t["long_500k"].global_batch) == \
        (524288, 1)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_reduced_is_same_family(arch):
    r = get_reduced(arch)
    c = get_config(arch)
    assert r.family == c.family
    assert r.attn_pattern == c.attn_pattern
    assert (r.window is None) == (c.window is None)
