"""Checkpointing, data pipeline, double quantization."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (load_checkpoint, restore_fl_state, save_checkpoint,
                        save_fl_state)
from repro.core import quant as q
from repro.data import pipeline as pl


def test_checkpoint_roundtrip_plain(tmp_path, rng):
    tree = {"a": jnp.asarray(rng.randn(4, 8), jnp.float32),
            "nest": {"b": jnp.arange(5, dtype=jnp.int32)},
            "lst": [jnp.ones((2,)), jnp.zeros((3,))]}
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, tree, extra={"round": 7})
    back, extra = load_checkpoint(p, tree)
    assert extra["round"] == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_roundtrip_qtensor(tmp_path, rng):
    w = jnp.asarray(rng.randn(128, 16), jnp.float32)
    qt = q.quantize(w, bits=4, block=64, mode="nf4")
    p = str(tmp_path / "ckq.npz")
    save_checkpoint(p, {"w": qt})
    back, _ = load_checkpoint(p, {"w": qt})
    assert isinstance(back["w"], q.QTensor)
    assert back["w"].bits == 4 and back["w"].mode == "nf4"
    np.testing.assert_array_equal(np.asarray(qt.q), np.asarray(back["w"].q))
    np.testing.assert_allclose(np.asarray(q.dequantize(qt)),
                               np.asarray(q.dequantize(back["w"])))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, {"a": jnp.ones((3,))})
    with pytest.raises(ValueError):
        load_checkpoint(p, {"a": jnp.ones((4,))})


def test_fl_state_roundtrip(tmp_path, rng):
    tr = {"adapter": jnp.asarray(rng.randn(8, 8), jnp.float32)}
    p = str(tmp_path / "fl.npz")
    save_fl_state(p, round_idx=12, global_trainable=tr,
                  client_sizes=[10, 20])
    tr2, opt2, rnd, sizes = restore_fl_state(p, like_trainable=tr)
    assert rnd == 12 and sizes == [10, 20] and opt2 is None
    np.testing.assert_array_equal(np.asarray(tr["adapter"]),
                                  np.asarray(tr2["adapter"]))


def test_dataset_epochs_cover_everything(rng):
    data = {"x": np.arange(17), "y": np.arange(17) * 2}
    ds = pl.ArrayDataset(data, seed=0)
    seen = []
    for b in ds.batches(4, epochs=1):
        assert len(b["x"]) == 4
        seen.extend(b["x"].tolist())
    assert len(seen) == 16 and len(set(seen)) == 16  # drop-remainder


def test_dataset_split_disjoint():
    data = {"x": np.arange(100)}
    a, b = pl.ArrayDataset(data).split([0.8, 0.2])
    assert a.n == 80 and b.n == 20
    assert not set(a.data["x"]) & set(b.data["x"])


def test_client_streams_respect_partition():
    data = {"x": np.arange(30)}
    parts = [np.arange(0, 10), np.arange(10, 30)]
    s0, s1 = pl.client_streams(data, parts, batch_size=4)
    b0, b1 = next(s0), next(s1)
    assert set(b0["x"]) <= set(range(10))
    assert set(b1["x"]) <= set(range(10, 30))


def test_prefetch_preserves_order():
    out = list(pl.prefetch(iter([{"x": np.full((2,), i)}
                                 for i in range(5)])))
    assert [int(b["x"][0]) for b in out] == list(range(5))


def test_double_quantization(rng):
    w = jnp.asarray(rng.randn(512, 32), jnp.float32)
    qt = q.quantize(w, bits=4, block=64)
    dq = q.double_quantize(qt)
    back = q.double_dequantize(dq)
    # payload identical; scales within int8 error of the originals
    np.testing.assert_array_equal(np.asarray(qt.q), np.asarray(back.q))
    rel = float(jnp.abs(qt.scales - back.scales).max() /
                (jnp.abs(qt.scales).max() + 1e-12))
    assert rel < 0.02
    # end-to-end weight error stays close to single quantization
    e1 = float(jnp.abs(w - q.dequantize(qt)).max())
    e2 = float(jnp.abs(w - q.dequantize(back)).max())
    assert e2 < 1.25 * e1 + 1e-4
    # and it actually saves bytes vs f32 scales
    f32_scale_bytes = qt.scales.size * 4
    dq_scale_bytes = q.double_quant_bytes(dq) - qt.q.size
    assert dq_scale_bytes < f32_scale_bytes / 2
