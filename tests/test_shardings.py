"""Sharding rules: every spec must respect divisibility on the production
mesh for every assigned architecture (this is what makes the 40-combo
dry-run pass; here it's checked leaf-by-leaf without compiling)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.core.quant import QTensor
from repro.launch import shardings as sh
from repro.models import build_model

try:
    MESH = AbstractMesh((16, 16), ("data", "model"))
except TypeError:   # jax<=0.4.x API: tuple of (name, size) pairs
    MESH = AbstractMesh((("data", 16), ("model", 16)))
AXIS = {"data": 16, "model": 16, "pod": 2}


def _check(specs, params):
    flat_s = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda l: isinstance(l, P))[0]
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    spec_by_path = {jax.tree_util.keystr(p): s for p, s in flat_s}
    for path, leaf in flat_p:
        key = jax.tree_util.keystr(path)
        # QTensor params flatten one level deeper than QTensor specs
        spec = spec_by_path.get(key)
        if spec is None:
            continue
        assert len(spec) <= len(leaf.shape), (key, spec, leaf.shape)
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= AXIS[a]
            assert leaf.shape[dim] % n == 0, (key, spec, leaf.shape)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("quant", [0, 4])
def test_param_specs_divisible(arch, quant):
    cfg = get_config(arch)
    if quant:
        cfg = cfg.replace(quant_bits=4, quant_mode="nf4")
    model = build_model(cfg)
    specs = model.param_specs()
    pspec = sh.param_specs_tree(cfg, specs, MESH)
    _check(pspec, specs)


@pytest.mark.parametrize("arch", ["yi-9b", "kimi-k2-1t-a32b",
                                  "falcon-mamba-7b", "whisper-medium"])
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    cache = model.cache_specs(128, 32768)
    cspec = sh.cache_specs_tree(cfg, cache, MESH, ("data",))
    _check(cspec, cache)


def test_trainables_replicated():
    cfg = get_config("yi-9b")
    model = build_model(cfg)
    specs = model.param_specs()
    pspec = sh.param_specs_tree(cfg, specs, MESH)
    for leaf in jax.tree.leaves(pspec["trainable"],
                                is_leaf=lambda l: isinstance(l, P)):
        assert leaf == P(), leaf  # FL communicates these — keep replicated
