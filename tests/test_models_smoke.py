"""Per-architecture smoke tests (deliverable f): a REDUCED variant of every
assigned architecture runs one forward + one train step on CPU with correct
shapes and finite values, and serving (prefill + decode) is consistent with
the training-path forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.core import optim
from repro.models import build_model

B, S = 2, 33


def _batch(cfg, rng):
    S_tok = S - cfg.n_patches if cfg.family == "vlm" else S
    b = {"tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (B, S_tok)), jnp.int32),
         "labels": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
         "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "vlm":
        b["image_embeds"] = jnp.asarray(
            rng.randn(B, cfg.n_patches, cfg.d_model) * 0.02, jnp.float32)
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            rng.randn(B, cfg.n_frames, cfg.d_model) * 0.02, jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    cfg = get_reduced(arch)
    assert cfg.n_layers <= 3 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    full = get_config(arch)
    assert cfg.family == full.family


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch, rng):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    S_total = S
    logits, aux = jax.jit(model.forward)(
        params["frozen"], params["trainable"], batch)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    opt = optim.adam_init(params["trainable"])
    tr, opt, metrics = jax.jit(model.train_step)(
        params["frozen"], params["trainable"], opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0  # adapter/LoRA actually train
    # trainable changed, frozen untouched by construction
    diff = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                        tr, params["trainable"])
    assert max(jax.tree.leaves(diff)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_consistency(arch, rng):
    """prefill(S-1) + decode(last) == training forward's last logits
    (MoE arms use a no-drop capacity factor — token dropping is a
    train-time-only semantic)."""
    cfg = get_reduced(arch)
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = _batch(cfg, rng)
    logits, _ = model.forward(params["frozen"], params["trainable"], batch)
    want = np.asarray(logits[:, -1], np.float32)
    toks = batch["tokens"]
    pre = {k: v for k, v in batch.items()
           if k in ("tokens", "image_embeds", "frames")}
    pre["tokens"] = toks[:, :-1]
    S_total = S
    _, cache = model.prefill(params["frozen"], params["trainable"], pre,
                             max_len=S_total)
    got, _ = model.decode_step(
        params["frozen"], params["trainable"], cache, toks[:, -1:],
        jnp.asarray(S_total - 1, jnp.int32))
    rel = np.abs(np.asarray(got, np.float32) - want).max() / \
        (np.abs(want).max() + 1e-9)
    assert rel < 5e-3, rel


@pytest.mark.parametrize("arch", ["yi-9b", "qwen3-moe-235b-a22b",
                                  "falcon-mamba-7b"])
def test_quantized_backbone_trains(arch, rng):
    """QLoRA configuration: int4/NF4 frozen backbone still trains the
    adapter/LoRA set with finite loss."""
    cfg = get_reduced(arch).replace(quant_bits=4, quant_mode="nf4",
                                    quant_block=64)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    from repro.core.quant import QTensor
    qleaves = [l for l in jax.tree.leaves(
        params["frozen"], is_leaf=lambda x: isinstance(x, QTensor))
        if isinstance(l, QTensor)]
    assert qleaves, "expected quantized backbone leaves"
    batch = _batch(cfg, rng)
    opt = optim.adam_init(params["trainable"])
    _, _, metrics = jax.jit(model.train_step)(
        params["frozen"], params["trainable"], opt, batch)
    assert np.isfinite(float(metrics["loss"]))
