"""Pipelined round loop (PR 10): pipelined vs barrier parity, the
host-sync trace counter, donation-hazard tracking, and the serve
store's trainer->store refresh path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import runtime as runtime_lib
from repro.fl import sched as sched_lib
from repro.fl.simulator import FLConfig, run_federated

# Mirrors the chaos acceptance config (tests/test_chaos.py): small
# enough to run fast, faulty enough that the ledger is non-empty.
_CHAOS = sched_lib.ChaosConfig(dropout_prob=0.5, straggler_sigma=0.5,
                               uplink_loss_prob=0.5, max_retries=2)
_BASE = dict(
    dataset="pacs", strategy="fedclip", n_clients=5, rounds=4,
    local_steps=2, n_per_class=12, batch_size=8, lr=3e-3,
    trace="skewed", eval_every=2)

_HIST_FIELDS = (
    "rounds", "server_acc", "server_loss", "tail_acc", "client_loss",
    "client_acc", "uplink_bytes", "participation", "staleness", "vtime",
    "class_counts", "class_staleness", "class_acc", "util_proxy")


def _kinds(h):
    # clip_pretrain hits the process-global _CLIP_CACHE after the first
    # run in a process, so it is excluded from cross-run comparison
    # (same convention as tests/test_runtime.py).
    return {k: v for k, v in h.meta["n_compiles_by_kind"].items()
            if k != "clip_pretrain"}


def _assert_hist_equal(hb, hp):
    """Bitwise History equality (everything but wall-clock timings)."""
    for f in _HIST_FIELDS:
        assert getattr(hb, f) == getattr(hp, f), f
    assert _kinds(hb) == _kinds(hp)


# ---------------------------------------------------------------------
# pipelined vs barrier parity, all three policies, under chaos
# ---------------------------------------------------------------------

@pytest.mark.parametrize("policy,kw", [
    ("full", {}),
    ("sync-partial", {"clients_per_round": 2}),
    ("async", {"clients_per_round": 2, "async_concurrency": 4}),
])
def test_pipelined_matches_barrier_under_chaos(policy, kw):
    """The tentpole parity claim: pipelined mode defers materialization
    but every History value — per-client metrics, eval accuracy, the
    fault ledger, the per-device-class fairness columns — is bitwise
    the barrier (serial oracle) one, for every policy, with faults
    firing. Chaos entries attribute to the correct round even though
    they materialize rounds later."""
    cfg = dict(_BASE, participation=policy, chaos=_CHAOS, **kw)
    hb = run_federated(FLConfig(**cfg, pipeline="barrier"))
    hp = run_federated(FLConfig(**cfg, pipeline="pipelined"))
    _assert_hist_equal(hb, hp)
    assert hb.meta["fault_ledger"] == hp.meta["fault_ledger"]
    assert hb.meta["device_class_report"] == \
        hp.meta["device_class_report"]
    assert sum(hb.meta["fault_ledger"].values()) > 0


def test_pipelined_matches_barrier_fault_free_and_sync_free():
    """Fault-free sync-partial: bitwise parity AND a completely
    sync-free steady state — the pre-drawn selections plus deferred
    metrics/eval leave zero host syncs inside the round loop (the one
    counted flush happens after it)."""
    cfg = dict(_BASE, participation="sync-partial", clients_per_round=2)
    hb = run_federated(FLConfig(**cfg, pipeline="barrier"))
    hp = run_federated(FLConfig(**cfg, pipeline="pipelined"))
    _assert_hist_equal(hb, hp)
    assert hb.meta["pipeline"] == "barrier"
    assert hp.meta["pipeline"] == "pipelined"
    # barrier syncs every round; pipelined never inside the loop
    assert hb.meta["loop_syncs"] == _BASE["rounds"]
    assert hb.meta["sync_counts"].get("round_barrier", 0) == \
        _BASE["rounds"]
    assert hp.meta["loop_syncs"] == 0
    assert hp.meta["syncs_per_round"] == 0.0
    assert hp.meta["sync_counts"].get("round_barrier", 0) == 0
    # exactly one bulk flush materialized the whole run's metrics
    assert hp.meta["sync_counts"].get("metrics_flush", 0) == 1
    # every round's selection was pre-drawn
    assert hp.meta["prepared_rounds"] == _BASE["rounds"]
    assert hb.meta["prepared_rounds"] == 0


def test_pipelined_periodic_flush_keeps_parity():
    """metrics_flush_every=M materializes the ring mid-run (M counted
    syncs) without changing any History value."""
    cfg = dict(_BASE, participation="sync-partial", clients_per_round=2)
    h0 = run_federated(FLConfig(**cfg, pipeline="pipelined"))
    h2 = run_federated(FLConfig(**cfg, pipeline="pipelined",
                                metrics_flush_every=2))
    _assert_hist_equal(h0, h2)
    assert h2.meta["loop_syncs"] == _BASE["rounds"] // 2


def test_pipelined_sequential_engine_parity():
    """The sequential reference executor runs under the pipelined loop
    too (its internal syncs are its own business) and stays the cohort
    engine's oracle."""
    cfg = dict(_BASE, participation="sync-partial", clients_per_round=2)
    hb = run_federated(FLConfig(**cfg, engine="sequential",
                                pipeline="barrier"))
    hp = run_federated(FLConfig(**cfg, engine="sequential",
                                pipeline="pipelined"))
    _assert_hist_equal(hb, hp)


def test_unknown_pipeline_mode_raises():
    with pytest.raises(ValueError, match="pipeline"):
        run_federated(FLConfig(**_BASE, pipeline="turbo"))


# ---------------------------------------------------------------------
# runtime: sync traces, dependency-tracked handles, donation hazards
# ---------------------------------------------------------------------

def test_sync_traces_counter():
    runtime_lib.reset_sync_traces()
    rt = runtime_lib.ProgramRuntime()
    h = rt.dispatch("dbl", lambda: (lambda a: a * 2), (jnp.ones(4),))
    assert runtime_lib.SYNC_TRACES == {}
    h.result()
    assert runtime_lib.SYNC_TRACES["handle_wait"] == 1
    assert runtime_lib.SYNC_TRACES["handle_wait:dbl"] == 1
    h.result()          # idempotent: a materialized handle is free
    assert runtime_lib.SYNC_TRACES["handle_wait"] == 1
    rt.sync((jnp.zeros(2), np.zeros(2), 3), tag="bulk")
    assert runtime_lib.SYNC_TRACES["bulk"] == 1
    runtime_lib.reset_sync_traces()
    assert runtime_lib.SYNC_TRACES == {}


def test_handle_dependency_tracking():
    rt = runtime_lib.ProgramRuntime()
    h1 = rt.dispatch("a", lambda: (lambda x: x + 1), (jnp.zeros(3),))
    h2 = rt.dispatch("b", lambda: (lambda x: x * 2), (h1,))
    assert h2.deps == (h1,)
    assert h2.kind == "b"
    np.testing.assert_array_equal(np.asarray(h2.result()),
                                  [2.0, 2.0, 2.0])


def test_donation_hazard_blocks_reuse_until_materialized():
    """The regression the tentpole demands: reusing a buffer donated to
    an in-flight dispatch raises loudly; after the donating handle
    materializes, the hazard is cleared (and JAX's own deleted-array
    check takes over where donation really happened)."""
    rt = runtime_lib.ProgramRuntime()
    x = jnp.ones(8)
    h = rt.dispatch("donor", lambda: (lambda a: a + 1), (x,),
                    donate_argnums=(0,))
    with pytest.raises(RuntimeError, match="donation hazard"):
        rt.dispatch("reader", lambda: (lambda a: a * 2), (x,))
    with pytest.raises(RuntimeError, match="donation hazard"):
        rt.run("reader2", lambda: (lambda a: a * 3), (x,))
    h.result()
    assert h.done
    # hazard cleared: a *fresh* buffer of the same shape flows freely
    y = jnp.ones(8)
    rt.run("reader", lambda: (lambda a: a * 2), (y,))


def test_donation_hazard_ignores_unrelated_buffers():
    rt = runtime_lib.ProgramRuntime()
    x, y = jnp.ones(8), jnp.ones(8)
    rt.dispatch("donor", lambda: (lambda a: a + 1), (x,),
                donate_argnums=(0,))
    out = rt.run("reader", lambda: (lambda a: a * 2), (y,))
    np.testing.assert_array_equal(np.asarray(out), np.full(8, 2.0))


# ---------------------------------------------------------------------
# serve store refresh
# ---------------------------------------------------------------------

def _tiny_backing(n=3, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), n)
    return {i: {"w": jax.random.normal(ks[i], (64, 32)),
                "b": jax.random.normal(ks[i], (32,))}
            for i in range(n)}


def test_store_refresh_matches_evict_and_refetch():
    """A refreshed resident's slab rows are bitwise what an evicted
    user would re-quantize to on its next fetch — refresh is a latency
    event, never a correctness event."""
    from repro.fl.serve.store import AdapterStore, take_rows
    back = _tiny_backing()
    store = AdapterStore(dict(back), max_entries=3, quant_bits=8)
    for uid in back:
        store.fetch(uid)
    new0 = jax.tree.map(lambda l: l * 1.5, back[0])
    n = store.refresh({0: new0})
    assert n == 1
    famk, slot = store.fetch(0)
    rows = take_rows(store.family(famk)["slabs"], jnp.asarray([slot]))
    # oracle: a cold store quantizing the new snapshot directly
    cold = AdapterStore({0: new0}, max_entries=1, quant_bits=8)
    cfamk, cslot = cold.fetch(0)
    crows = take_rows(cold.family(cfamk)["slabs"],
                      jnp.asarray([cslot]))
    for a, b in zip(jax.tree.leaves(rows), jax.tree.leaves(crows)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # bookkeeping untouched, ledger charged
    assert store.resident()[-1] == 0          # fetch moved 0 to MRU
    assert store.stats()["refreshes"] == 1
    assert store.stats()["refreshed_resident"] == 1


def test_store_refresh_from_global_rebases():
    """refresh_from_global preserves per-user personalization deltas:
    new_i = old_i + (new_global - base)."""
    from repro.fl.serve.store import AdapterStore
    back = _tiny_backing()
    store = AdapterStore(dict(back), max_entries=2, quant_bits=0)
    g0 = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    assert store.refresh_from_global(g0) == 0     # snapshot only
    g1 = jax.tree.map(lambda l: l + 0.25, g0)
    n = store.refresh_from_global(g1)
    assert n == 0                                  # nothing resident yet
    for uid, old in back.items():
        got = store.backing[uid]
        want = jax.tree.map(lambda o: o + 0.25, old)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)
    assert store.stats()["refreshes"] == len(back)


def test_run_federated_refreshes_serve_store():
    """The simulator's round loop drives the continuous trainer->store
    refresh: every committed round rebases the backing, without
    breaking pipelined parity."""
    from repro.core import clip as clip_lib
    from repro.fl import client as client_lib
    from repro.fl.serve.store import AdapterStore
    from repro.fl.strategies import STRATEGIES
    ccfg = clip_lib.CLIPConfig()
    strat = STRATEGIES["fedclip"]
    back = {i: client_lib.init_trainable(jax.random.PRNGKey(100 + i),
                                         ccfg, strat) for i in range(3)}
    cfg = dict(_BASE, participation="sync-partial", clients_per_round=2,
               rounds=3)
    store = AdapterStore(dict(back), max_entries=2, quant_bits=0)
    h = run_federated(FLConfig(**cfg, pipeline="pipelined"),
                      serve_store=store)
    # first round snapshots, the remaining rounds refresh every uid
    assert h.meta["serve_refreshes"] == (cfg["rounds"] - 1) * len(back)
    href = run_federated(FLConfig(**cfg, pipeline="pipelined"))
    _assert_hist_equal(href, h)
