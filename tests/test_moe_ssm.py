"""MoE routing semantics and recurrent-scan equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib


def _moe_cfg(cf=8.0):
    return get_reduced("qwen3-moe-235b-a22b").replace(capacity_factor=cf)


def test_moe_matches_dense_oracle(rng):
    """With no capacity drops, gather/scatter MoE == per-token loop."""
    cfg = _moe_cfg()
    p = moe_lib.init_experts(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.randn(3, 4, cfg.d_model) * 0.3, jnp.float32)
    y, _ = moe_lib.moe_ffn(p, x, cfg)
    T = 12
    x2 = np.asarray(x.reshape(T, cfg.d_model))
    probs = np.asarray(jax.nn.softmax(x2 @ np.asarray(p["router"]), -1))
    want = np.zeros_like(x2)
    for t in range(T):
        top = np.argsort(-probs[t])[:cfg.experts_per_token]
        gates = probs[t][top] / probs[t][top].sum()
        for g, e in zip(gates, top):
            wg, wu, wd = (np.asarray(p[n][e]) for n in ("wg", "wu", "wd"))
            h = (x2[t] @ wg)
            h = h / (1 + np.exp(-h)) * (x2[t] @ wu)
            want[t] += g * (h @ wd)
    np.testing.assert_allclose(np.asarray(y).reshape(T, -1), want,
                               atol=2e-4)


def test_moe_capacity_drops_tokens(rng):
    """A tiny capacity factor must drop load (output norm decreases)."""
    cfg_hi = _moe_cfg(8.0)
    cfg_lo = _moe_cfg(0.05)
    p = moe_lib.init_experts(jax.random.PRNGKey(0), cfg_hi, jnp.float32)
    x = jnp.asarray(rng.randn(2, 16, cfg_hi.d_model), jnp.float32)
    y_hi, _ = moe_lib.moe_ffn(p, x, cfg_hi)
    y_lo, _ = moe_lib.moe_ffn(p, x, cfg_lo)
    assert float(jnp.linalg.norm(y_lo)) < float(jnp.linalg.norm(y_hi))


def test_moe_aux_loss_balanced_vs_collapsed():
    cfg = _moe_cfg()
    T, E = 512, cfg.n_experts
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(T, cfg.d_model), jnp.float32)
    p = moe_lib.init_experts(jax.random.PRNGKey(0), cfg, jnp.float32)
    _, ids, aux_rand = moe_lib._route(p["router"], x, cfg)
    collapsed = dict(p, router=p["router"] * 0.0 + jnp.eye(
        cfg.d_model, E) * 50.0)
    _, _, aux_coll = moe_lib._route(collapsed["router"], x, cfg)
    assert float(aux_coll) > float(aux_rand)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 100))
def test_slot_assignment_capacity_invariant(T, C, seed):
    rng = np.random.RandomState(seed)
    ids = jnp.asarray(rng.randint(0, 4, T), jnp.int32)
    order, sorted_ids, slot, keep = moe_lib._slot_assignment(ids, 4, C)
    s, sl, kp = (np.asarray(v) for v in (sorted_ids, slot, keep))
    # kept slots are unique per (expert, slot) and below capacity
    pairs = {(int(e), int(x)) for e, x, k in zip(s, sl, kp) if k}
    assert len(pairs) == int(kp.sum())
    assert all(x < C for _, x in pairs)
    # at most C kept per expert
    for e in range(4):
        assert int((kp & (s == e)).sum()) <= C


def test_chunked_scan_matches_loop(rng):
    B, S, D = 2, 37, 5
    a = jnp.asarray(np.exp(-np.abs(rng.randn(B, S, D))), jnp.float32)
    b = jnp.asarray(rng.randn(B, S, D), jnp.float32)
    h0 = jnp.asarray(rng.randn(B, D), jnp.float32)
    h_all, h_last = ssm_lib.chunked_linear_scan(a, b, h0, chunk=8)
    h = np.asarray(h0)
    want = []
    for t in range(S):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        want.append(h.copy())
    want = np.stack(want, 1)
    np.testing.assert_allclose(np.asarray(h_all), want, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), want[:, -1], atol=1e-5)


def test_mamba_block_decode_equivalence(rng):
    cfg = get_reduced("falcon-mamba-7b")
    p = ssm_lib.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 11
    x = jnp.asarray(rng.randn(B, S, cfg.d_model) * 0.3, jnp.float32)
    y_full, cache_full = ssm_lib.mamba_block(p, x, cfg)
    cache = {"h": jnp.zeros((B, cfg.d_inner, cfg.ssm_state)),
             "conv": jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner))}
    outs = []
    for t in range(S):
        o, cache = ssm_lib.mamba_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(o)
    y_step = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(cache["h"]),
                               np.asarray(cache_full["h"]), atol=1e-4)
