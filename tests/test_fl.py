"""Federated runtime: partition invariants (hypothesis), aggregation
semantics, communication compression, and a full round."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quant import QTensor, quantize_tree, tree_bytes
from repro.fl import partition, server
from repro.fl.strategies import STRATEGIES


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.floats(0.05, 10.0), st.integers(0, 100))
def test_dirichlet_partition_preserves_samples(n_clients, alpha, seed):
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, 5, 120)
    parts = partition.dirichlet_partition(labels, n_clients, alpha,
                                          seed=seed)
    allidx = np.concatenate([p for p in parts if len(p)])
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)  # disjoint, complete


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 50))
def test_dirichlet_low_alpha_is_skewed(seed):
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, 4, 400)
    skewed = partition.dirichlet_partition(labels, 4, 0.05, seed=seed)
    uniform = partition.dirichlet_partition(labels, 4, 100.0, seed=seed)

    def skewness(parts):
        h = [partition.class_histogram(labels, p, 4) + 1e-9 for p in parts]
        h = [x / x.sum() for x in h if x.sum() > 1]
        return np.mean([-(x * np.log(x)).sum() for x in h])
    assert skewness(skewed) < skewness(uniform)


def test_domain_partition_disjoint():
    rng = np.random.RandomState(0)
    domains = rng.randint(0, 4, 200)
    parts = partition.domain_partition(domains, 4, seed=0)
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == len(allidx)


def test_aggregate_is_weighted_mean():
    g = {"w": jnp.zeros((4,))}
    d1 = {"w": jnp.ones((4,))}
    d2 = {"w": 3 * jnp.ones((4,))}
    out = server.aggregate(g, [(1, d1), (3, d2)])
    np.testing.assert_allclose(np.asarray(out["w"]), 2.5)  # (1·1+3·3)/4


def test_aggregate_identity_updates():
    g = {"w": jnp.asarray([1.0, 2.0])}
    d = {"w": jnp.asarray([0.5, -0.5])}
    out = server.aggregate(g, [(5, d), (5, d)])
    np.testing.assert_allclose(np.asarray(out["w"]), [1.5, 1.5])


def test_aggregate_quantized_updates(rng):
    g = {"w": jnp.zeros((128, 16))}
    delta = {"w": jnp.asarray(rng.randn(128, 16) * 0.01, jnp.float32)}
    qd = quantize_tree(delta, bits=8, block=64, min_size=16)
    assert isinstance(qd["w"], QTensor)
    out = server.aggregate(g, [(1, qd)])
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(delta["w"]), atol=1e-3)


def test_comm_compression_ratio(rng):
    delta = {"w": jnp.asarray(rng.randn(256, 64), jnp.float32)}
    full = tree_bytes(delta)
    q8 = tree_bytes(quantize_tree(delta, bits=8, block=64, min_size=16))
    q4 = tree_bytes(quantize_tree(delta, bits=4, block=64, min_size=16))
    assert q8 < full / 3 and q4 < full / 6


def test_one_federated_round_improves_loss():
    from repro.fl.simulator import FLConfig, run_federated
    h = run_federated(FLConfig(
        dataset="pacs", strategy="qlora_nogan", n_clients=2, rounds=3,
        local_steps=4, n_per_class=16, batch_size=16, lr=3e-3))
    assert h.server_loss[-1] < h.server_loss[0]
    assert len(h.client_loss) == 3 and len(h.client_loss[0]) == 2
    assert all(b > 0 for b in h.uplink_bytes)


def _parity_setup(strategy_name, *, n_clients=3, seed=0, gan_steps=25):
    """Small FL instance + one engine/oracle round over identical batches."""
    from repro.core import clip as clip_lib
    from repro.data.synthetic import class_tokens, make_dataset
    from repro.fl import client as client_lib
    from repro.fl import cohort as cohort_lib

    strat = STRATEGIES[strategy_name]
    ccfg = clip_lib.CLIPConfig()
    frozen = clip_lib.init_clip(jax.random.PRNGKey(3), ccfg)
    data = make_dataset("pacs", n_per_class=12, seed=seed,
                        longtail_gamma=4.0)
    spec = data["spec"]
    class_emb = clip_lib.text_embedding(
        frozen, ccfg,
        jnp.asarray(class_tokens(spec, np.arange(spec.n_classes))))
    parts = partition.dirichlet_partition(data["labels"], n_clients, 0.5,
                                          seed=seed)
    clients = [client_lib.Client(
        cid=i, images=data["images"][idx], labels=data["labels"][idx],
        n_classes=spec.n_classes, strategy=strat)
        for i, idx in enumerate(parts)]
    if strat.use_gan:
        for i, c in enumerate(clients):
            if c.n >= 8:
                c.prepare_gan(jax.random.PRNGKey(100 + i),
                              steps=gan_steps)
    global_tr = client_lib.init_trainable(jax.random.PRNGKey(1), ccfg,
                                          strat)
    steps, batch, lr = 4, 8, 3e-3
    engine = cohort_lib.CohortEngine(
        frozen=frozen, ccfg=ccfg, class_emb=class_emb, clients=clients,
        cfg=cohort_lib.CohortConfig(strategy=strat, local_steps=steps,
                                    batch_size=batch, lr=lr,
                                    donate=False))
    key = jax.random.PRNGKey(42)
    new_tr, metrics = engine.run_round(global_tr, key)

    # sequential oracle over the engine's exact batch index sequence
    idx = cohort_lib.round_indices(key, np.asarray(engine.lens), steps,
                                   batch)
    updates, oloss, oacc = [], [], []
    for i, c in enumerate(clients):
        tr_after, m = c.local_train(frozen, global_tr, class_emb, ccfg,
                                    steps=steps, batch_size=batch, lr=lr,
                                    indices=idx[i])
        upd, _ = c.make_update(global_tr, tr_after)
        updates.append((c.n, upd))
        oloss.append(m["loss"])
        oacc.append(m["acc"])
    ref_tr = server.aggregate(global_tr, updates)
    ref_bytes = server.secure_sum_bytes(updates)
    return new_tr, metrics, ref_tr, ref_bytes, oloss, oacc


@pytest.mark.parametrize("arm", ["fedclip", "tripleplay"])
def test_cohort_matches_sequential_oracle(arm):
    """The fused vmap/scan round must reproduce the per-client Python
    loop: final global trainables, per-client loss/acc, uplink bytes."""
    new_tr, m, ref_tr, ref_bytes, oloss, oacc = _parity_setup(arm)
    flat_new = jax.tree_util.tree_leaves_with_path(new_tr)
    flat_ref = dict(
        (jax.tree_util.keystr(p), l)
        for p, l in jax.tree_util.tree_leaves_with_path(ref_tr))
    for path, leaf in flat_new:
        ref = flat_ref[jax.tree_util.keystr(path)]
        np.testing.assert_allclose(np.asarray(leaf), np.asarray(ref),
                                   atol=5e-4, rtol=0, err_msg=str(path))
    np.testing.assert_allclose(m["loss"], oloss, atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(m["acc"], oacc, atol=1e-5)
    assert int(m["uplink_bytes"]) == int(ref_bytes)


def test_cohort_engine_default_in_simulator():
    from repro.fl.simulator import FLConfig, run_federated
    h = run_federated(FLConfig(
        dataset="pacs", strategy="fedclip", n_clients=2, rounds=2,
        local_steps=3, n_per_class=12, batch_size=8, lr=3e-3))
    assert h.meta["engine"] == "cohort"
    assert len(h.client_loss) == 2 and len(h.client_loss[0]) == 2
    # Fig. 3 util proxy is the measured footprint constant — no wiggle
    assert h.util_proxy[0] == h.util_proxy[1] == h.meta["util_proxy_const"]


def test_strategy_arms_registered():
    assert set(STRATEGIES) == {"fedclip", "qlora_nogan", "tripleplay"}
    assert STRATEGIES["tripleplay"].use_gan
    assert STRATEGIES["qlora_nogan"].backbone_bits == 4
    assert not STRATEGIES["fedclip"].use_lora
