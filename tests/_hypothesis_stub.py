"""Deterministic fallback for the ``hypothesis`` API surface this suite
uses, installed by conftest.py only when the real package is missing
(this container has no network). ``@given`` degrades to a seeded
pseudo-random sweep of ``max_examples`` draws per strategy — weaker than
real shrinking/search, but the property assertions still execute.
"""
from __future__ import annotations

import random
import types


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def lists(elements, min_size=0, max_size=10):
    return _Strategy(lambda rng: [
        elements.example(rng)
        for _ in range(rng.randint(min_size, max_size))])


def given(*strategies, **kw_strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            rng = random.Random(0)
            for _ in range(wrapper._max_examples):
                ex = tuple(s.example(rng) for s in strategies)
                kex = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*args, *ex, **kwargs, **kex)
        wrapper._max_examples = 10
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


def settings(max_examples=10, **_kw):
    def deco(fn):
        if hasattr(fn, "_max_examples"):
            fn._max_examples = max_examples
        return fn
    return deco


def install(sys_modules):
    """Register this stub as ``hypothesis`` + ``hypothesis.strategies``."""
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans",
                 "lists"):
        setattr(st, name, globals()[name])
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    sys_modules["hypothesis"] = hyp
    sys_modules["hypothesis.strategies"] = st
