"""Multi-device (8 fake CPU devices) correctness: the shard_map paths must
equal the local paths bit-for-bit-ish. Runs in subprocesses because
XLA_FLAGS must be set before jax initializes."""
import os
import subprocess
import sys
import textwrap

import pytest

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")

PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.models import runtime as rt_lib
from repro.launch.mesh import make_debug_mesh
mesh = make_debug_mesh((2, 2, 2), ("pod", "data", "model"))
rt = rt_lib.Runtime(mesh=mesh, dp_axes=("pod", "data"), tp_axis="model")
"""


def _run(body: str):
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(PRELUDE + body)],
        env=ENV, capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]


def test_moe_dist_equals_local():
    _run("""
from repro.models import moe as moe_lib
cfg = get_reduced("qwen3-moe-235b-a22b").replace(capacity_factor=8.0)
p = moe_lib.init_experts(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)) * 0.1
y0, _ = moe_lib.moe_ffn(p, x, cfg)
with rt_lib.runtime(rt), mesh:
    y1, _ = jax.jit(lambda p, x: moe_lib.moe_ffn(p, x, cfg))(p, x)
assert float(jnp.abs(y0 - y1).max()) < 1e-5
""")


def test_attention_dist_equals_local():
    _run("""
from repro.kernels import ops, ref
rng = np.random.RandomState(0)
q = jnp.asarray(rng.randn(4, 16, 6, 16), jnp.float32)
k = jnp.asarray(rng.randn(4, 16, 3, 16), jnp.float32)
v = jnp.asarray(rng.randn(4, 16, 3, 16), jnp.float32)
want = ref.flash_attention(q, k, v, causal=True, window=8)
with rt_lib.runtime(rt), mesh:
    got = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, window=8))(q, k, v)
assert float(jnp.abs(want - got).max()) < 1e-5
""")


def test_recurrent_dist_equals_local():
    _run("""
from repro.models import ssm as ssm_lib, rglru as rglru_lib
cfg = get_reduced("falcon-mamba-7b")
p = ssm_lib.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)) * 0.1
y0, c0 = ssm_lib.mamba_block(p, x, cfg)
with rt_lib.runtime(rt), mesh:
    y1, c1 = jax.jit(lambda p, x: ssm_lib.mamba_block(p, x, cfg))(p, x)
assert float(jnp.abs(y0 - y1).max()) < 1e-5
assert float(jnp.abs(c0["h"] - c1["h"]).max()) < 1e-5
cfg2 = get_reduced("recurrentgemma-2b")
p2 = rglru_lib.init_rglru(jax.random.PRNGKey(0), cfg2, jnp.float32)
x2 = jax.random.normal(jax.random.PRNGKey(2), (4, 8, cfg2.d_model)) * 0.1
y2, _ = rglru_lib.rglru_block(p2, x2, cfg2)
with rt_lib.runtime(rt), mesh:
    y3, _ = jax.jit(lambda p, x: rglru_lib.rglru_block(p, x, cfg2))(p2, x2)
assert float(jnp.abs(y2 - y3).max()) < 1e-5
""")


def test_cohort_round_distributed_matches_local():
    """A cohort-engine round with the cohort axis sharded over the debug
    mesh's (pod, data) axes must match the unsharded engine."""
    _run("""
from repro.core import clip as clip_lib
from repro.data.synthetic import class_tokens, make_dataset
from repro.fl import client as client_lib, cohort as cohort_lib, partition
from repro.fl.strategies import STRATEGIES
strat = STRATEGIES["qlora_nogan"]
ccfg = clip_lib.CLIPConfig()
frozen = clip_lib.init_clip(jax.random.PRNGKey(3), ccfg)
data = make_dataset("pacs", n_per_class=10, seed=0, longtail_gamma=2.0)
spec = data["spec"]
class_emb = clip_lib.text_embedding(
    frozen, ccfg, jnp.asarray(class_tokens(spec, np.arange(spec.n_classes))))
parts = partition.dirichlet_partition(data["labels"], 4, 1.0, seed=0)
clients = [client_lib.Client(
    cid=i, images=data["images"][idx], labels=data["labels"][idx],
    n_classes=spec.n_classes, strategy=strat)
    for i, idx in enumerate(parts)]
tr = client_lib.init_trainable(jax.random.PRNGKey(1), ccfg, strat)
key = jax.random.PRNGKey(7)
def run(mesh_arg):
    eng = cohort_lib.CohortEngine(
        frozen=frozen, ccfg=ccfg, class_emb=class_emb, clients=clients,
        cfg=cohort_lib.CohortConfig(strategy=strat, local_steps=3,
                                    batch_size=8, lr=3e-3,
                                    mesh=mesh_arg, donate=False))
    return eng.run_round(tr, key)
t0, m0 = run(None)
t1, m1 = run(mesh)
for a, b in zip(jax.tree.leaves(t0), jax.tree.leaves(t1)):
    assert float(jnp.abs(a - b).max()) < 1e-5
assert float(jnp.abs(m0["loss"] - m1["loss"]).max()) < 1e-4
assert m0["uplink_bytes"] == m1["uplink_bytes"]
""")


def test_full_train_step_distributed_runs():
    """A reduced full train step executes under the debug mesh with the
    production sharding rules and yields finite loss."""
    _run("""
from repro.configs import get_reduced
from repro.core import optim
from repro.launch import shardings as sh
from repro.models import build_model
cfg = get_reduced("yi-9b").replace(seq_shard=True)
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
toks = jnp.asarray(np.random.RandomState(0).randint(
    0, cfg.vocab_size, (4, 17)), jnp.int32)
batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
         "mask": jnp.ones((4, 16), jnp.float32)}
opt = optim.adam_init(params["trainable"])
with rt_lib.runtime(rt), mesh:
    tr, opt, m = jax.jit(model.train_step)(
        params["frozen"], params["trainable"], opt, batch)
assert np.isfinite(float(m["loss"]))
""")


# shared cohort fixture for the FL mesh-parity tests: 8 clients (the
# debug mesh's 4 dp shards divide it), heterogeneous step multipliers so
# the masked-scan path rides along
FL_COHORT = """
from repro.core import clip as clip_lib
from repro.data.synthetic import class_tokens, make_dataset
from repro.fl import client as client_lib, cohort as cohort_lib, partition
from repro.fl.strategies import STRATEGIES
strat = STRATEGIES["fedclip"]
ccfg = clip_lib.CLIPConfig()
frozen = clip_lib.init_clip(jax.random.PRNGKey(3), ccfg)
data = make_dataset("pacs", n_per_class=16, seed=0, longtail_gamma=2.0)
spec = data["spec"]
class_emb = clip_lib.text_embedding(
    frozen, ccfg, jnp.asarray(class_tokens(spec, np.arange(spec.n_classes))))
parts = partition.dirichlet_partition(data["labels"], 8, 1.0, seed=0)
mult = [2, 1, 1, 1, 2, 1, 1, 1]
clients = [client_lib.Client(
    cid=i, images=data["images"][idx], labels=data["labels"][idx],
    n_classes=spec.n_classes, strategy=strat, step_mult=mult[i])
    for i, idx in enumerate(parts)]
tr = client_lib.init_trainable(jax.random.PRNGKey(1), ccfg, strat)
def mk_engine(mesh_arg):
    return cohort_lib.CohortEngine(
        frozen=frozen, ccfg=ccfg, class_emb=class_emb, clients=clients,
        cfg=cohort_lib.CohortConfig(strategy=strat, local_steps=2,
                                    batch_size=8, lr=3e-3,
                                    mesh=mesh_arg, donate=False))
"""


def test_subset_round_distributed_matches_local():
    """Sync-partial subset rounds (K < N, heterogeneous step counts,
    bucket padding in play) on the sharded engine must match the
    unsharded engine: K=2 buckets to the 4-shard multiple 4, K=5
    buckets to 8 — both exercise shard-pad rows AND the hierarchical
    (tree) aggregation against the flat single-device path."""
    _run(FL_COHORT + """
e0, e1 = mk_engine(None), mk_engine(mesh)
assert e0.shards == 1 and e1.shards == 4
# the staged cohort axis really splits 4 ways (each shard is then
# replicated over the debug mesh's model axis, so it spans all 8
# devices — per-shard shape, not device count, is the guard)
shard_rows = e1.pool_staged.sharding.shard_shape(
    e1.pool_staged.shape)[0]
assert shard_rows * 4 == e1.pool_staged.shape[0], \
    (shard_rows, e1.pool_staged.shape)
from repro.fl import runtime as runtime_lib
assert runtime_lib.bucket_width(2, 8, shards=4) == 4
for sel in ([1, 4], [0, 2, 4, 6, 7]):
    key = jax.random.PRNGKey(10 + len(sel))
    t0, m0 = e0.run_subset_round(tr, sel, key)
    t1, m1 = e1.run_subset_round(tr, sel, key)
    for a, b in zip(jax.tree.leaves(t0), jax.tree.leaves(t1)):
        assert float(jnp.abs(a - b).max()) < 1e-5
    assert float(jnp.abs(m0["loss"] - m1["loss"]).max()) < 1e-4
    assert m0["uplink_bytes"] == m1["uplink_bytes"]
    assert list(m0["sel"]) == list(m1["sel"])
""")


def test_fleetgan_distributed_matches_local():
    """Fleet-GAN training + synthesis on a data mesh (cohort width 5
    pads to the 8-shard multiple 8, one ineligible rider) must match
    the unsharded fleet: trained params within the gemm-reassociation
    tolerance, synthesized rebalancing sets near-bitwise, labels
    bitwise."""
    _run("""
from repro.fl import client as client_lib, fleetgan
from repro.fl import runtime as runtime_lib
from repro.fl import strategies as strategies_lib
from repro.fl.strategies import STRATEGIES
from repro.launch.mesh import make_data_mesh
strat = STRATEGIES["tripleplay"]
def mk():
    rs = np.random.RandomState(0)
    cl = []
    for i, n in enumerate((40, 21, 12, 9, 5)):
        cl.append(client_lib.Client(
            cid=i, images=rs.rand(n, 32, 32, 3).astype(np.float32),
            labels=(np.arange(n) % 3).astype(np.int32), n_classes=7,
            strategy=strat))
    return cl
keys = [jax.random.fold_in(jax.random.PRNGKey(0),
                           strategies_lib.GAN_RNG_OFFSET + i)
        for i in range(5)]
cl0, cl1 = mk(), mk()
rep0 = fleetgan.prepare_gan_fleet(
    cl0, keys, steps=4, runtime=runtime_lib.ProgramRuntime())
rep1 = fleetgan.prepare_gan_fleet(
    cl1, keys, steps=4,
    fleet_cfg=fleetgan.FleetGANConfig(mesh=make_data_mesh(8)),
    runtime=runtime_lib.ProgramRuntime())
assert rep0.n_eligible == rep1.n_eligible == 4
assert rep0.n_synth == rep1.n_synth > 0
assert rep0.groups == rep1.groups        # true cohort width, not padded
for a, b in zip(cl0, cl1):
    if a.gan_params is None:
        assert b.gan_params is None      # the rider stays untouched
        continue
    for la, lb in zip(jax.tree.leaves(a.gan_params),
                      jax.tree.leaves(b.gan_params)):
        assert float(jnp.abs(la - lb).max()) < 2e-3
    np.testing.assert_array_equal(a.aug_labels, b.aug_labels)
    assert float(np.abs(a.aug_images - b.aug_images).max()) < 5e-3
""")


RNG_DIGEST = """
import hashlib
import jax, jax.numpy as jnp, numpy as np
from repro.core import gan as gan_lib
from repro.fl import cohort as cohort_lib
from repro.fl.sched.policies import SyncPartialScheduler
from repro.fl.sched.traces import resolve_trace
h = hashlib.sha256()
sched = SyncPartialScheduler(
    executor=object(), trace=resolve_trace("skewed-het", 16, seed=0),
    local_steps=2, clients_per_round=5)
c = sched.select(0, jax.random.PRNGKey(5))
h.update(np.asarray(c.sel).tobytes())
h.update(np.asarray(c.n_steps).tobytes())
idx = cohort_lib.round_indices(
    jax.random.PRNGKey(6), jnp.asarray([7, 9, 13, 21, 5], jnp.int32),
    4, 8)
h.update(np.asarray(idx).tobytes())
k0, kbs, kss = jax.jit(
    lambda r: gan_lib.gan_key_stream(r, 6))(jax.random.PRNGKey(7))
for a in (k0, kbs, kss):
    h.update(np.asarray(a).tobytes())
h.update(np.asarray(gan_lib.gan_batch_indices(
    kbs, jnp.asarray(17), 8)).tobytes())
z, z2 = gan_lib.gan_z_stream(kss, 8, 16)
h.update(np.asarray(z).tobytes())
h.update(np.asarray(z2).tobytes())
print(len(jax.devices()), h.hexdigest())
"""


def test_rng_streams_mesh_invariant():
    """Client selection, batch-index streams, and GAN key/z streams are
    drawn host-side on replicated inputs — so they must be BITWISE
    identical whether the process sees 1, 2, 4, or 8 devices. This pins
    the RNG discipline ('threefry is neither mesh- nor shape-stable, so
    no draw may live inside a sharded program') with a direct
    multi-device regression."""
    digests = {}
    for n_dev in (1, 2, 4, 8):
        env = dict(ENV, XLA_FLAGS=(
            f"--xla_force_host_platform_device_count={n_dev}"))
        proc = subprocess.run(
            [sys.executable, "-c", RNG_DIGEST], env=env,
            capture_output=True, text=True, timeout=600,
            cwd=os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))))
        assert proc.returncode == 0, proc.stderr[-3000:]
        n, digest = proc.stdout.split()
        assert int(n) == n_dev      # the flag actually took effect
        digests[n_dev] = digest
    assert len(set(digests.values())) == 1, digests


def test_decode_step_distributed_matches_local():
    _run("""
from repro.configs import get_reduced
from repro.models import build_model
cfg = get_reduced("yi-9b")
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(1))
toks = jnp.asarray(np.random.RandomState(0).randint(
    0, cfg.vocab_size, (4, 16)), jnp.int32)
_, cache = model.prefill(params["frozen"], params["trainable"],
                         {"tokens": toks}, max_len=32)
want, _ = model.decode_step(params["frozen"], params["trainable"], cache,
                            toks[:, :1], jnp.asarray(16, jnp.int32))
with rt_lib.runtime(rt), mesh:
    got, _ = jax.jit(model.decode_step)(
        params["frozen"], params["trainable"], cache, toks[:, :1],
        jnp.asarray(16, jnp.int32))
rel = float(jnp.abs(want - got).max() / (jnp.abs(want).max() + 1e-9))
assert rel < 5e-3, rel
""")
