"""Optimizer and loss substrate."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import losses, optim


def test_adam_minimizes_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = optim.adam_init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, state = optim.adam_update(g, state, params, lr=5e-2)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_grad_clip_bounds_update():
    params = {"x": jnp.zeros((4,))}
    state = optim.adam_init(params)
    g = {"x": jnp.full((4,), 1e6)}
    p2, _ = optim.adam_update(g, state, params, lr=1.0, grad_clip=1.0)
    assert float(jnp.abs(p2["x"]).max()) < 10.0


def test_cosine_schedule_endpoints():
    s = optim.cosine_schedule(1.0, warmup=10, total=100)
    assert float(s(jnp.asarray(0.0))) == 0.0
    assert abs(float(s(jnp.asarray(10.0))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(100.0))) < 1e-6


def test_cross_entropy_matches_manual(rng):
    logits = jnp.asarray(rng.randn(5, 7), jnp.float32)
    labels = jnp.asarray(rng.randint(0, 7, 5), jnp.int32)
    want = -np.take_along_axis(
        np.asarray(jax.nn.log_softmax(logits)),
        np.asarray(labels)[:, None], 1).mean()
    got = float(losses.cross_entropy(logits, labels))
    assert abs(got - want) < 1e-5


def test_cross_entropy_mask(rng):
    logits = jnp.asarray(rng.randn(4, 6, 9), jnp.float32)
    labels = jnp.zeros((4, 6), jnp.int32)
    m = jnp.zeros((4, 6)).at[:, 0].set(1.0)
    full = losses.cross_entropy(logits[:, :1], labels[:, :1])
    masked = losses.cross_entropy(logits, labels, m)
    assert abs(float(full) - float(masked)) < 1e-5


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_contrastive_loss_symmetric_identity(seed):
    """Perfectly aligned pairs achieve lower loss than mismatched."""
    rng = np.random.RandomState(seed)
    e = jnp.asarray(rng.randn(6, 8), jnp.float32)
    scale = jnp.asarray(2.0)
    aligned = float(losses.clip_contrastive(e, e, scale))
    shuffled = float(losses.clip_contrastive(e, e[::-1], scale))
    assert aligned < shuffled


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(optim.global_norm(t)) - 5.0) < 1e-6
