"""Optimizer and loss substrate."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import losses, optim


def test_adam_minimizes_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = optim.adam_init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, state = optim.adam_update(g, state, params, lr=5e-2)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_grad_clip_bounds_update():
    params = {"x": jnp.zeros((4,))}
    state = optim.adam_init(params)
    g = {"x": jnp.full((4,), 1e6)}
    p2, _ = optim.adam_update(g, state, params, lr=1.0, grad_clip=1.0)
    assert float(jnp.abs(p2["x"]).max()) < 10.0


def test_adam_scan_matches_loop(rng):
    """The lax.scan-fused Adam (cohort engine / CLIP pretrain substrate)
    must be step-for-step identical to the Python loop of adam_update."""
    params = {"w": jnp.asarray(rng.randn(8, 4), jnp.float32),
              "b": jnp.zeros((4,))}
    xs = jnp.asarray(rng.randn(12, 8), jnp.float32)

    def grad_fn(p, x):
        def loss(q):
            return jnp.mean((x @ q["w"] + q["b"]) ** 2)
        l, g = jax.value_and_grad(loss)(p)
        return g, l

    lp, ls = params, optim.adam_init(params)
    loop_losses = []
    for i in range(xs.shape[0]):
        g, l = grad_fn(lp, xs[i])
        loop_losses.append(float(l))
        lp, ls = optim.adam_update(g, ls, lp, lr=1e-2, grad_clip=1.0)

    sp, ss, saux = optim.adam_scan(grad_fn, params,
                                   optim.adam_init(params), xs,
                                   lr=1e-2, grad_clip=1.0)
    assert int(ss.step) == int(ls.step) == xs.shape[0]
    np.testing.assert_allclose(np.asarray(saux), loop_losses, rtol=1e-6)
    for k in params:
        np.testing.assert_allclose(np.asarray(sp[k]), np.asarray(lp[k]),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(ss.mu[k]),
                                   np.asarray(ls.mu[k]), atol=1e-6)


def test_cosine_schedule_endpoints():
    s = optim.cosine_schedule(1.0, warmup=10, total=100)
    assert float(s(jnp.asarray(0.0))) == 0.0
    assert abs(float(s(jnp.asarray(10.0))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(100.0))) < 1e-6


def test_cross_entropy_matches_manual(rng):
    logits = jnp.asarray(rng.randn(5, 7), jnp.float32)
    labels = jnp.asarray(rng.randint(0, 7, 5), jnp.int32)
    want = -np.take_along_axis(
        np.asarray(jax.nn.log_softmax(logits)),
        np.asarray(labels)[:, None], 1).mean()
    got = float(losses.cross_entropy(logits, labels))
    assert abs(got - want) < 1e-5


def test_cross_entropy_mask(rng):
    logits = jnp.asarray(rng.randn(4, 6, 9), jnp.float32)
    labels = jnp.zeros((4, 6), jnp.int32)
    m = jnp.zeros((4, 6)).at[:, 0].set(1.0)
    full = losses.cross_entropy(logits[:, :1], labels[:, :1])
    masked = losses.cross_entropy(logits, labels, m)
    assert abs(float(full) - float(masked)) < 1e-5


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_contrastive_loss_symmetric_identity(seed):
    """Perfectly aligned pairs achieve lower loss than mismatched."""
    rng = np.random.RandomState(seed)
    e = jnp.asarray(rng.randn(6, 8), jnp.float32)
    scale = jnp.asarray(2.0)
    aligned = float(losses.clip_contrastive(e, e, scale))
    shuffled = float(losses.clip_contrastive(e, e[::-1], scale))
    assert aligned < shuffled


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(optim.global_norm(t)) - 5.0) < 1e-6
