"""Blockwise quantization (§III-C substrate): exactness, error bounds,
tree filtering — including hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant as q


@pytest.mark.parametrize("bits,mode", [(8, "linear"), (4, "linear"),
                                       (4, "nf4")])
@pytest.mark.parametrize("shape", [(128, 64), (256, 30), (3, 128, 16)])
def test_roundtrip_error_bound(bits, mode, shape, rng):
    x = jnp.asarray(rng.randn(*shape), jnp.float32)
    qt = q.quantize(x, bits=bits, block=64, mode=mode)
    xd = q.dequantize(qt)
    assert xd.shape == x.shape
    # per-block absmax bounds the error: linear-int: s/2; nf4: widest gap
    blocks = x.reshape(*shape[:-2], shape[-2] // 64, 64, shape[-1])
    absmax = jnp.max(jnp.abs(blocks), axis=-2, keepdims=True)
    levels = {8: 254, 4: 14}[bits]
    tol = absmax / (levels / 2) if mode == "linear" else absmax * 0.16
    err = jnp.abs((x - xd).reshape(blocks.shape))
    assert bool(jnp.all(err <= tol + 1e-6)), float((err - tol).max())


def test_pack_unpack_exact(rng):
    v = jnp.asarray(rng.randint(-8, 8, (4, 64, 8)), jnp.int8)
    assert bool(jnp.all(q.unpack4(q.pack4(v)) == v))


def test_int4_packed_is_half_size(rng):
    x = jnp.asarray(rng.randn(256, 64), jnp.float32)
    q8 = q.quantize(x, bits=8, block=128)
    q4 = q.quantize(x, bits=4, block=128)
    assert q4.q.size * 2 == q8.q.size
    assert q4.q.dtype == jnp.uint8


def test_quantize_tree_filters(rng):
    tree = {"layers": {"wq": jnp.asarray(rng.randn(128, 128), jnp.float32),
                       "ln1": jnp.zeros((128,)),
                       "router": jnp.asarray(rng.randn(128, 64))},
            "embed": jnp.asarray(rng.randn(128, 128))}
    out = q.quantize_tree(tree, bits=4, block=64)
    assert isinstance(out["layers"]["wq"], q.QTensor)
    assert not isinstance(out["layers"]["ln1"], q.QTensor)
    assert not isinstance(out["layers"]["router"], q.QTensor)
    assert not isinstance(out["embed"], q.QTensor)


def test_tree_bytes_counts_packed(rng):
    x = jnp.asarray(rng.randn(256, 128), jnp.float32)
    full = q.tree_bytes({"w": x})
    qt4 = q.tree_bytes({"w": q.quantize(x, bits=4, block=128)})
    assert qt4 < full / 6  # ~4 bit + scales vs 32 bit


def test_specs_match_real(rng):
    x = jnp.asarray(rng.randn(256, 96), jnp.float32)
    for bits, mode in [(8, "linear"), (4, "nf4")]:
        qt = q.quantize(x, bits=bits, block=128, mode=mode)
        sp = q.qtensor_specs(x.shape, x.dtype, bits=bits, block=128,
                             mode=mode)
        assert sp.q.shape == qt.q.shape and sp.q.dtype == qt.q.dtype
        assert sp.scales.shape == qt.scales.shape


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 6), st.sampled_from([8, 4]),
       st.floats(0.01, 100.0))
def test_property_roundtrip_scale_invariance(gmult, n, bits, scale):
    """Quantization commutes (approximately) with positive scaling and the
    error never exceeds one quantization step per block."""
    rng = np.random.RandomState(gmult * 7 + n)
    K = 64 * gmult
    x = jnp.asarray(rng.randn(K, n) * scale, jnp.float32)
    qt = q.quantize(x, bits=bits, block=64)
    xd = q.dequantize(qt)
    step = qt.scales.max() * (1.0 if bits == 8 else 1.0)
    assert float(jnp.abs(x - xd).max()) <= float(step) + 1e-6
    assert bool(jnp.all(qt.scales > 0))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000))
def test_property_dequant_deterministic(seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(128, 8), jnp.float32)
    a = q.dequantize(q.quantize(x, bits=4, block=64, mode="nf4"))
    b = q.dequantize(q.quantize(x, bits=4, block=64, mode="nf4"))
    assert bool(jnp.all(a == b))
