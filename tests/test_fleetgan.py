"""Fleet-GAN engine (fl.fleetgan) and its substrate: parity against the
sequential ``Client.prepare_gan`` oracle, the gemm conv kernels
(kernels.gan_conv), masked-sampler / masked-step properties, and
tail-accuracy + strategy-flag plumbing through the simulator.

Bitwise discipline mirrors the cohort-engine PRs: everything derived
from RNG streams, integer draws, or layout (key streams, batch indices,
rebalance labels, pool staging, masked no-op steps) is asserted
bitwise; values that flow through the fused gemm kernels (trained
generator params, synthesized images) are pinned at tight tolerances —
XLA fusion is not bitwise-stable across loop->scan/vmap restructuring
even on identical primitives (same caveat as
``test_adam_scan_matches_loop``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax import lax

from repro.core import gan as gan_lib
from repro.core import optim
from repro.data.synthetic import make_dataset, stage_client_pools
from repro.fl import client as client_lib
from repro.fl import fleetgan
from repro.fl import strategies as strategies_lib
from repro.fl.strategies import STRATEGIES
from repro.kernels import gan_conv

MIN = strategies_lib.GAN_MIN_POOL


def _tree_eq(a, b, err=""):
    for (pa, la), lb in zip(jax.tree_util.tree_leaves_with_path(a),
                            jax.tree.leaves(b)):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb),
            err_msg=f"{err}{jax.tree_util.keystr(pa)}")


def _mk_clients(sizes, *, seed=0, strategy="tripleplay"):
    strat = STRATEGIES[strategy]
    data = make_dataset("pacs", n_per_class=30, seed=seed,
                        longtail_gamma=4.0)
    spec = data["spec"]
    assert sum(sizes) <= len(data["labels"])
    out, start = [], 0
    for i, n in enumerate(sizes):
        sl = slice(start, start + n)
        start += n
        out.append(client_lib.Client(
            cid=i, images=data["images"][sl], labels=data["labels"][sl],
            n_classes=spec.n_classes, strategy=strat))
    return out


# -- gemm conv kernels --------------------------------------------------

def _lax_conv(x, w):
    return lax.conv_general_dilated(
        x, w, (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _lax_convT(x, w):
    return lax.conv_transpose(
        x, w, (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))


@pytest.mark.parametrize("b,hw,ci,co", [(3, 32, 3, 16), (2, 16, 16, 24),
                                        (2, 8, 32, 48)])
def test_conv4x4_s2_matches_lax_with_grads(rng, b, hw, ci, co):
    x = jnp.asarray(rng.randn(b, hw, hw, ci).astype(np.float32))
    w = jnp.asarray(rng.randn(4, 4, ci, co).astype(np.float32) * 0.05)
    ct = jnp.asarray(rng.randn(b, hw // 2, hw // 2, co)
                     .astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(gan_conv.conv4x4_s2(x, w)), np.asarray(_lax_conv(x, w)),
        atol=1e-5, rtol=0)
    gx, gw = jax.grad(
        lambda x, w: jnp.sum(gan_conv.conv4x4_s2(x, w) * ct),
        argnums=(0, 1))(x, w)
    rx, rw = jax.grad(lambda x, w: jnp.sum(_lax_conv(x, w) * ct),
                      argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                               atol=5e-4, rtol=0)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw),
                               atol=5e-4, rtol=0)


@pytest.mark.parametrize("b,hw,ci,co", [(3, 4, 48, 16), (2, 8, 16, 16),
                                        (2, 16, 16, 3)])
def test_convT4x4_s2_matches_lax_with_grads(rng, b, hw, ci, co):
    x = jnp.asarray(rng.randn(b, hw, hw, ci).astype(np.float32))
    w = jnp.asarray(rng.randn(4, 4, ci, co).astype(np.float32) * 0.05)
    ct = jnp.asarray(rng.randn(b, hw * 2, hw * 2, co)
                     .astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(gan_conv.convT4x4_s2(x, w)),
        np.asarray(_lax_convT(x, w)), atol=1e-5, rtol=0)
    gx, gw = jax.grad(
        lambda x, w: jnp.sum(gan_conv.convT4x4_s2(x, w) * ct),
        argnums=(0, 1))(x, w)
    rx, rw = jax.grad(lambda x, w: jnp.sum(_lax_convT(x, w) * ct),
                      argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                               atol=5e-4, rtol=0)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw),
                               atol=5e-4, rtol=0)


# -- RNG-stream compatibility ------------------------------------------

def test_gan_key_stream_matches_sequential_splits():
    rng, steps = jax.random.PRNGKey(5), 7
    k0, kbs, kss = gan_lib.gan_key_stream(rng, steps)
    k0_ref, r = jax.random.split(rng)
    np.testing.assert_array_equal(np.asarray(k0), np.asarray(k0_ref))
    for t in range(steps):
        r, kb, ks = jax.random.split(r, 3)
        np.testing.assert_array_equal(np.asarray(kbs[t]), np.asarray(kb))
        np.testing.assert_array_equal(np.asarray(kss[t]), np.asarray(ks))


def test_gan_batch_indices_match_sequential_draws():
    _, kbs, _ = gan_lib.gan_key_stream(jax.random.PRNGKey(3), 5)
    idx = np.asarray(gan_lib.gan_batch_indices(kbs, 13, 9))
    for t in range(5):
        np.testing.assert_array_equal(
            idx[t], np.asarray(jax.random.randint(kbs[t], (9,), 0, 13)))


# -- masked-sampler property (hypothesis) ------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 40), st.integers(0, 32), st.integers(1, 64),
       st.integers(0, 10 ** 6))
def test_masked_sampler_never_draws_padding(n, pad, batch, seed):
    """Pool rows [n, n+pad) of a padded client pool must carry zero
    sampling probability for any (n, pad, batch): indices are drawn in
    [0, n) regardless of the staged (padded) length."""
    kbs = jax.random.split(jax.random.PRNGKey(seed), 3)
    idx = np.asarray(gan_lib.gan_batch_indices(kbs, n, batch))
    assert idx.shape == (3, batch)
    assert idx.min() >= 0
    assert idx.max() < n          # never into the pad tail, any pad


# -- masked gan_scan steps are bitwise no-ops --------------------------

def _tiny_gan(seed=0, n=12, steps=6, batch=5):
    cfg = gan_lib.GANConfig(n_classes=3, g_dim=8, d_dim=8, z_dim=8,
                            conv_impl="gemm")
    rs = np.random.RandomState(seed)
    imgs = jnp.asarray(rs.randn(n, 32, 32, 3).astype(np.float32))
    labs = jnp.asarray(rs.randint(0, 3, n).astype(np.int32))
    k0, kbs, kss = gan_lib.gan_key_stream(jax.random.PRNGKey(seed),
                                          steps)
    idx = gan_lib.gan_batch_indices(kbs, n, batch)
    params = gan_lib.init_gan(k0, cfg)
    opt = {"gen": optim.adam_init(params["gen"]),
           "disc": optim.adam_init(params["disc"])}
    return cfg, imgs, labs, idx, kss, params, opt


def test_all_masked_gan_scan_is_bitwise_noop():
    cfg, imgs, labs, idx, kss, params, opt = _tiny_gan()
    active = jnp.zeros(idx.shape[0], bool)
    p2, o2, ms = jax.jit(
        lambda p, o: gan_lib.gan_scan(p, o, cfg, imgs, labs, idx, kss,
                                      active=active))(params, opt)
    _tree_eq(params, p2, "params/")
    _tree_eq(opt, o2, "opt/")          # moments AND step counters
    assert np.isfinite(np.asarray(ms["d_loss"])).all()


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 6))
def test_masked_tail_steps_ignore_their_inputs(k):
    """With the first k steps active, the masked tail must be a bitwise
    no-op on params + both Adam states: scrambling the masked steps'
    batch indices and RNG keys cannot change the result (same compiled
    program, so equality is exact)."""
    cfg, imgs, labs, idx, kss, params, opt = _tiny_gan()
    active = jnp.arange(idx.shape[0]) < k
    run = jax.jit(lambda ix, ks: gan_lib.gan_scan(
        params, opt, cfg, imgs, labs, ix, ks, active=active)[:2])
    p1, o1 = run(idx, kss)
    p2, o2 = run(idx.at[k:].set(0), kss.at[k:].set(7))
    _tree_eq(p1, p2, "params/")
    _tree_eq(o1, o2, "opt/")


# -- fleet vs sequential prepare_gan parity ----------------------------

@pytest.mark.parametrize("sizes", [(24, 24, 24), (40, 21, 5)],
                         ids=["uniform", "skewed"])
def test_fleet_matches_sequential_prepare_gan(sizes):
    """The stacked fused engine must reproduce the per-client loop on
    the same fold_in key streams: rebalance labels and pool layout
    bitwise, trained generators and synthesized images to fused-kernel
    tolerance. The skewed case carries an ineligible n < MIN client
    that must ride the program fully masked and keep its GAN fields
    unset."""
    steps = 10
    A, B = _mk_clients(sizes), _mk_clients(sizes)
    keys = [jax.random.PRNGKey(100 + i) for i in range(len(sizes))]
    for i, c in enumerate(A):
        if c.n >= MIN:
            c.prepare_gan(keys[i], steps=steps)
    rep = fleetgan.prepare_gan_fleet(B, keys, steps=steps)
    assert rep.n_eligible == sum(c.n >= MIN for c in A)
    assert sum(g for _, g in rep.groups) == len(sizes)  # masked riders in
    for i, (a, b) in enumerate(zip(A, B)):
        if a.n < MIN:
            assert a.gan_params is None and b.gan_params is None
            assert b.aug_images is None and b.aug_labels is None
            continue
        np.testing.assert_array_equal(a.aug_labels, b.aug_labels,
                                      err_msg=f"client {i} labels")
        for (pth, la), lb in zip(
                jax.tree_util.tree_leaves_with_path(a.gan_params["gen"]),
                jax.tree.leaves(b.gan_params["gen"])):
            np.testing.assert_allclose(
                np.asarray(la), np.asarray(lb), atol=2e-3, rtol=0,
                err_msg=f"client {i} gen{jax.tree_util.keystr(pth)}")
        if len(a.aug_labels):
            np.testing.assert_allclose(a.aug_images, b.aug_images,
                                       atol=5e-3, rtol=0,
                                       err_msg=f"client {i} aug images")
    # final staged pools: identical layout, bitwise real rows, synth
    # rows at fused-kernel tolerance
    ia, la, na = stage_client_pools([c.pool() for c in A])
    ib, lb, nb = stage_client_pools([c.pool() for c in B])
    np.testing.assert_array_equal(la, lb)
    np.testing.assert_array_equal(na, nb)
    for i, c in enumerate(A):
        np.testing.assert_array_equal(ia[i, :c.n], ib[i, :c.n],
                                      err_msg=f"client {i} real rows")
    np.testing.assert_allclose(ia, ib, atol=5e-3, rtol=0)


def test_fleet_empty_after_filter():
    """A cohort where every client is below the eligibility threshold
    must be a clean no-op: no programs run, no GAN fields written."""
    clients = _mk_clients((5, 3, 6))
    rep = fleetgan.prepare_gan_fleet(
        clients, [jax.random.PRNGKey(i) for i in range(3)], steps=5)
    assert rep.n_eligible == 0 and rep.groups == []
    assert rep.n_synth == 0
    for c in clients:
        assert c.gan_params is None and c.gan_cfg is None
        assert c.aug_images is None and c.aug_labels is None


def test_fleet_rejects_mismatched_keys():
    """jnp indexing clamps out-of-bounds rows, so a keys list shorter
    than the cohort would silently reuse the last RNG stream — the
    engine must refuse instead."""
    clients = _mk_clients((10, 9))
    with pytest.raises(ValueError, match="one GAN key per client"):
        fleetgan.prepare_gan_fleet(clients, [jax.random.PRNGKey(0)],
                                   steps=3)


def test_fleet_rejects_empty_clients():
    clients = _mk_clients((10, 9))
    clients[1].images = clients[1].images[:0]
    clients[1].labels = clients[1].labels[:0]
    with pytest.raises(ValueError, match="empty"):
        fleetgan.prepare_gan_fleet(
            clients, [jax.random.PRNGKey(0), jax.random.PRNGKey(1)],
            steps=3)


def test_rebalance_labels_tops_up_to_local_max():
    labels = np.array([0, 0, 0, 1, 2, 2], np.int32)
    need = gan_lib.rebalance_labels(labels, 4)
    hist = np.bincount(np.concatenate([labels, need]), minlength=4)
    np.testing.assert_array_equal(hist, [3, 3, 3, 3])
    assert gan_lib.rebalance_labels(np.zeros((0,), np.int32), 3).size == 0


# -- simulator plumbing: tail accuracy + strategy flags ----------------

def test_tripleplay_tracks_tail_acc_and_fleet_meta():
    from repro.fl.simulator import FLConfig, run_federated
    h = run_federated(FLConfig(
        dataset="pacs", strategy="tripleplay", n_clients=2, rounds=2,
        local_steps=2, n_per_class=12, batch_size=8, gan_steps=6,
        lr=3e-3))
    assert h.meta["gan_engine"] == "fleet"
    assert h.meta["gan_eligible"] >= 1 and h.meta["gan_groups"]
    assert h.meta["gan_prep_time_s"] > 0
    assert h.meta["gan_compile_time_s"] >= 0
    # class-0 (long tail) accuracy is tracked every eval round
    assert len(h.tail_acc) == len(h.rounds) >= 1
    assert all(0.0 <= t <= 1.0 for t in h.tail_acc)


def test_use_gan_false_arms_leave_gan_fields_unset():
    from repro.fl.simulator import FLConfig, run_federated
    h = run_federated(FLConfig(
        dataset="pacs", strategy="fedclip", n_clients=2, rounds=1,
        local_steps=2, n_per_class=12, batch_size=8, lr=3e-3))
    assert not any(k.startswith("gan_") for k in h.meta)
    # and at the client level the strategy flag gates the pool
    c = _mk_clients((10,), strategy="fedclip")[0]
    assert c.gan_params is None and c.aug_images is None
    imgs, labs = c.pool()
    np.testing.assert_array_equal(imgs, c.images)
    np.testing.assert_array_equal(labs, c.labels)


def test_simulator_sequential_gan_engine_stays_available():
    from repro.fl.simulator import FLConfig, run_federated
    h = run_federated(FLConfig(
        dataset="pacs", strategy="tripleplay", n_clients=2, rounds=1,
        local_steps=2, n_per_class=12, batch_size=8, gan_steps=4,
        lr=3e-3, gan_engine="sequential"))
    assert h.meta["gan_engine"] == "sequential"
    assert h.meta["gan_prep_time_s"] > 0
    with pytest.raises(ValueError, match="gan_engine"):
        run_federated(FLConfig(
            dataset="pacs", strategy="tripleplay", n_clients=2,
            rounds=1, local_steps=2, n_per_class=12, batch_size=8,
            gan_steps=4, lr=3e-3, gan_engine="bogus"))


# -- FleetGANConfig opt-out (per-group exact programs) ------------------

def test_bucket_optout_matches_bucketed_and_sequential():
    """``FleetGANConfig(bucket_batches=False)`` must reproduce the
    default bucketed prep (fused-kernel tolerance) while paying one
    train compile per distinct batch-size group instead of one total —
    and its RNG stream is bitwise the sequential ``prepare_gan`` one."""
    from repro.fl import runtime as runtime_lib

    sizes = (24, 21, 24)          # two distinct gan_batch_size groups
    steps = 6
    keys = [jax.random.PRNGKey(300 + i) for i in range(len(sizes))]
    A, B, S = _mk_clients(sizes), _mk_clients(sizes), _mk_clients(sizes)

    rt_a = runtime_lib.ProgramRuntime()
    rep_a = fleetgan.prepare_gan_fleet(A, keys, steps=steps,
                                       runtime=rt_a)
    rt_b = runtime_lib.ProgramRuntime()
    rep_b = fleetgan.prepare_gan_fleet(
        B, keys, steps=steps,
        fleet_cfg=fleetgan.FleetGANConfig(bucket_batches=False),
        runtime=rt_b)
    for i, c in enumerate(S):
        c.prepare_gan(keys[i], steps=steps)

    n_groups = len({strategies_lib.gan_batch_size(n) for n in sizes})
    assert n_groups == 2
    assert rt_a.stats()["gan_train"]["n_compiles"] == 1
    assert rt_b.stats()["gan_train"]["n_compiles"] == n_groups
    assert len(rep_b.groups) == n_groups
    assert sum(g for _, g in rep_b.groups) == rep_b.n_eligible
    assert sorted(rep_b.d_loss) == sorted(rep_a.d_loss)
    for i in rep_a.d_loss:
        assert rep_a.d_loss[i] == pytest.approx(rep_b.d_loss[i],
                                                abs=2e-2)
    for i, (a, b, s) in enumerate(zip(A, B, S)):
        np.testing.assert_array_equal(a.aug_labels, b.aug_labels,
                                      err_msg=f"client {i} labels")
        for (pth, la), lb, ls in zip(
                jax.tree_util.tree_leaves_with_path(a.gan_params),
                jax.tree.leaves(b.gan_params),
                jax.tree.leaves(s.gan_params)):
            np.testing.assert_allclose(
                np.asarray(la), np.asarray(lb), atol=2e-3, rtol=0,
                err_msg=f"client {i}{jax.tree_util.keystr(pth)}")
            np.testing.assert_allclose(
                np.asarray(lb), np.asarray(ls), atol=2e-3, rtol=0,
                err_msg=f"client {i} vs seq{jax.tree_util.keystr(pth)}")


def test_bucket_optout_skips_ineligible_clients():
    """Under the opt-out, ineligible clients are left out of the group
    programs entirely (no masked riders) and keep their GAN fields
    unset — same observable contract as the bucketed path."""
    sizes = (24, 5, 12)           # middle client below GAN_MIN_POOL
    clients = _mk_clients(sizes)
    keys = [jax.random.PRNGKey(i) for i in range(len(sizes))]
    rep = fleetgan.prepare_gan_fleet(
        clients, keys, steps=4,
        fleet_cfg=fleetgan.FleetGANConfig(bucket_batches=False))
    assert rep.n_eligible == 2
    assert sum(g for _, g in rep.groups) == 2   # no masked riders
    assert clients[1].gan_params is None
    assert clients[1].aug_images is None
    assert clients[0].gan_params is not None
    assert clients[2].gan_params is not None
    assert 1 not in rep.d_loss
