"""LoRA (§III-C) and the attention adapter (§III-A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adapter as ad
from repro.core import lora


def test_lora_zero_init_is_identity(rng):
    w = jnp.asarray(rng.randn(32, 16), jnp.float32)
    x = jnp.asarray(rng.randn(4, 32), jnp.float32)
    pair = lora.init_pair(jax.random.PRNGKey(0), 32, 16, rank=4)
    y = lora.linear(x, w, pair, alpha=8.0, rank=4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-6)


def test_lora_merge_equals_apply(rng):
    w = jnp.asarray(rng.randn(32, 16), jnp.float32)
    x = jnp.asarray(rng.randn(4, 32), jnp.float32)
    pair = lora.init_pair(jax.random.PRNGKey(0), 32, 16, rank=4)
    pair = {"a": pair["a"], "b": jnp.asarray(rng.randn(4, 16) * 0.1,
                                             jnp.float32)}
    y1 = lora.linear(x, w, pair, alpha=8.0, rank=4)
    y2 = x @ lora.merge(w, pair, alpha=8.0, rank=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_lora_quantized_base(rng):
    from repro.core import quant
    w = jnp.asarray(rng.randn(128, 16), jnp.float32)
    qt = quant.quantize(w, bits=8, block=64)
    x = jnp.asarray(rng.randn(4, 128), jnp.float32)
    pair = lora.init_pair(jax.random.PRNGKey(0), 128, 16, rank=4)
    y = lora.linear(x, qt, pair, alpha=8.0, rank=4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(
        x @ quant.dequantize(qt)), atol=1e-4)


def test_adapter_zero_init_is_identity(rng):
    p = ad.init(jax.random.PRNGKey(0), 32, n_heads=4)
    x = jnp.asarray(rng.randn(2, 8, 32), jnp.float32)
    y = ad.apply(p, x, n_heads=4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_adapter_trains_away_from_identity(rng):
    p = ad.init(jax.random.PRNGKey(0), 32, n_heads=4)
    p = jax.tree.map(lambda l: l + 0.05 * jnp.asarray(
        rng.randn(*l.shape), jnp.float32), p)
    x = jnp.asarray(rng.randn(2, 8, 32), jnp.float32)
    y = ad.apply(p, x, n_heads=4)
    assert float(jnp.abs(y - x).max()) > 1e-3


def test_adapter_prefill_decode_consistency(rng):
    """apply (train path) == prefill+decode composition on the last token."""
    d, h, S = 32, 4, 9
    p = ad.init(jax.random.PRNGKey(0), d, n_heads=h)
    p = jax.tree.map(lambda l: l + 0.05 * jnp.asarray(
        rng.randn(*l.shape), jnp.float32), p)
    x = jnp.asarray(rng.randn(2, S, d), jnp.float32)
    want = ad.apply(p, x, n_heads=h, causal=True)[:, -1:]
    _, cache = ad.prefill(p, x[:, :-1], window=S, n_heads=h)
    got, _ = ad.decode(p, x[:, -1:], cache, jnp.asarray(S - 1), n_heads=h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)
