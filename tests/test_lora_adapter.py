"""LoRA (§III-C) and the attention adapter (§III-A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adapter as ad
from repro.core import lora


def test_lora_zero_init_is_identity(rng):
    w = jnp.asarray(rng.randn(32, 16), jnp.float32)
    x = jnp.asarray(rng.randn(4, 32), jnp.float32)
    pair = lora.init_pair(jax.random.PRNGKey(0), 32, 16, rank=4)
    y = lora.linear(x, w, pair, alpha=8.0, rank=4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-6)


def test_lora_merge_equals_apply(rng):
    w = jnp.asarray(rng.randn(32, 16), jnp.float32)
    x = jnp.asarray(rng.randn(4, 32), jnp.float32)
    pair = lora.init_pair(jax.random.PRNGKey(0), 32, 16, rank=4)
    pair = {"a": pair["a"], "b": jnp.asarray(rng.randn(4, 16) * 0.1,
                                             jnp.float32)}
    y1 = lora.linear(x, w, pair, alpha=8.0, rank=4)
    y2 = x @ lora.merge(w, pair, alpha=8.0, rank=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_lora_quantized_base(rng):
    from repro.core import quant
    w = jnp.asarray(rng.randn(128, 16), jnp.float32)
    qt = quant.quantize(w, bits=8, block=64)
    x = jnp.asarray(rng.randn(4, 128), jnp.float32)
    pair = lora.init_pair(jax.random.PRNGKey(0), 128, 16, rank=4)
    y = lora.linear(x, qt, pair, alpha=8.0, rank=4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(
        x @ quant.dequantize(qt)), atol=1e-4)


def test_adapter_zero_init_is_identity(rng):
    p = ad.init(jax.random.PRNGKey(0), 32, n_heads=4)
    x = jnp.asarray(rng.randn(2, 8, 32), jnp.float32)
    y = ad.apply(p, x, n_heads=4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_adapter_trains_away_from_identity(rng):
    p = ad.init(jax.random.PRNGKey(0), 32, n_heads=4)
    p = jax.tree.map(lambda l: l + 0.05 * jnp.asarray(
        rng.randn(*l.shape), jnp.float32), p)
    x = jnp.asarray(rng.randn(2, 8, 32), jnp.float32)
    y = ad.apply(p, x, n_heads=4)
    assert float(jnp.abs(y - x).max()) > 1e-3


def test_adapter_prefill_decode_consistency(rng):
    """apply (train path) == prefill+decode composition on the last token."""
    d, h, S = 32, 4, 9
    p = ad.init(jax.random.PRNGKey(0), d, n_heads=h)
    p = jax.tree.map(lambda l: l + 0.05 * jnp.asarray(
        rng.randn(*l.shape), jnp.float32), p)
    x = jnp.asarray(rng.randn(2, S, d), jnp.float32)
    want = ad.apply(p, x, n_heads=h, causal=True)[:, -1:]
    _, cache = ad.prefill(p, x[:, :-1], window=S, n_heads=h)
    got, _ = ad.decode(p, x[:, -1:], cache, jnp.asarray(S - 1), n_heads=h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


def test_lora_apply_bf16_params_accumulates_fp32(rng):
    """Regression: ``apply`` promises f32 compute, but it used to run
    the whole chain in ``lora["a"].dtype`` — with bf16 trainables the
    accumulation silently happened in bf16. Pin the fp32-match
    tolerance on the exact bf16-rounded factor values."""
    K, N, r = 256, 64, 8
    x = jnp.asarray(rng.randn(33, K), jnp.float32)
    pair16 = {"a": jnp.asarray(rng.randn(K, r) * 0.1, jnp.bfloat16),
              "b": jnp.asarray(rng.randn(r, N) * 0.1, jnp.bfloat16)}
    # fp32 oracle ON the bf16-rounded values: isolates accumulation
    # dtype from parameter rounding
    a = pair16["a"].astype(jnp.float32)
    b = pair16["b"].astype(jnp.float32)
    want = (x @ a) @ b * (16.0 / r)
    got = lora.apply(x, pair16, alpha=16.0, rank=r)
    assert got.dtype == x.dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5 * float(np.abs(want).max()))


def test_lora_linear_fused_matches_chain_env(rng, monkeypatch):
    """REPRO_LORA_FUSED=0 flips linear back to the einsum chain; both
    routes agree to fp32 tolerance and the trace counters record which
    one ran."""
    from repro.kernels import ops as kops
    w = jnp.asarray(rng.randn(64, 32), jnp.float32)
    x = jnp.asarray(rng.randn(5, 64), jnp.float32)
    pair = {"a": jnp.asarray(rng.randn(64, 4) * 0.1, jnp.float32),
            "b": jnp.asarray(rng.randn(4, 32) * 0.1, jnp.float32)}
    kops.reset_kernel_traces()
    y_fused = lora.linear(x, w, pair, alpha=8.0, rank=4)
    assert kops.KERNEL_TRACES.get("lora_linear_fused", 0) == 1
    monkeypatch.setenv("REPRO_LORA_FUSED", "0")
    y_chain = lora.linear(x, w, pair, alpha=8.0, rank=4)
    assert kops.KERNEL_TRACES.get("lora_linear_chain", 0) == 1
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_chain),
                               atol=1e-5)
