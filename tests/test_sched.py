"""Scheduler subsystem (fl.sched): sync-partial parity with the
sequential oracle, K=N degeneracy to the PR 1 full round, async
virtual-time determinism, staleness-weight semantics, and uplink-byte
accounting under partial participation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clip as clip_lib
from repro.data.synthetic import class_tokens, make_dataset
from repro.fl import client as client_lib
from repro.fl import cohort as cohort_lib
from repro.fl import partition, server
from repro.fl import sched as sched_lib
from repro.fl.strategies import MAX_STEP_MULT, STRATEGIES

N_CLIENTS = 3
STEPS, BATCH, LR = 4, 8, 3e-3

_SETUPS = {}


def _setup(arm, step_mult=None):
    """Small FL instance with both executors over shared clients.
    Cached per (arm, step_mult): the engine restages pools only when the
    heterogeneity profile changes."""
    key = (arm, None if step_mult is None else tuple(step_mult))
    if key in _SETUPS:
        return _SETUPS[key]
    strat = STRATEGIES[arm]
    ccfg = clip_lib.CLIPConfig()
    frozen = clip_lib.init_clip(jax.random.PRNGKey(3), ccfg)
    data = make_dataset("pacs", n_per_class=12, seed=0,
                        longtail_gamma=4.0)
    spec = data["spec"]
    class_emb = clip_lib.text_embedding(
        frozen, ccfg,
        jnp.asarray(class_tokens(spec, np.arange(spec.n_classes))))
    parts = partition.dirichlet_partition(data["labels"], N_CLIENTS, 0.5,
                                          seed=0)
    clients = [client_lib.Client(
        cid=i, images=data["images"][idx], labels=data["labels"][idx],
        n_classes=spec.n_classes, strategy=strat)
        for i, idx in enumerate(parts)]
    if step_mult is not None:
        for c, m in zip(clients, step_mult):
            c.step_mult = int(m)
    if strat.use_gan:
        for i, c in enumerate(clients):
            if c.n >= 8:
                c.prepare_gan(jax.random.PRNGKey(100 + i), steps=25)
    global_tr = client_lib.init_trainable(jax.random.PRNGKey(1), ccfg,
                                          strat)
    engine = cohort_lib.CohortEngine(
        frozen=frozen, ccfg=ccfg, class_emb=class_emb, clients=clients,
        cfg=cohort_lib.CohortConfig(strategy=strat, local_steps=STEPS,
                                    batch_size=BATCH, lr=LR,
                                    donate=False))
    out = dict(
        strat=strat, ccfg=ccfg, frozen=frozen, class_emb=class_emb,
        clients=clients, global_tr=global_tr, engine=engine,
        cohort_exec=sched_lib.CohortExec(engine),
        seq_exec=sched_lib.SequentialExec(
            clients=clients, frozen=frozen, ccfg=ccfg,
            class_emb=class_emb, local_steps=STEPS, batch_size=BATCH,
            lr=LR))
    _SETUPS[key] = out
    return out


def _trace(n=N_CLIENTS, step_mult=None, **kw):
    base = sched_lib.uniform_trace(n)
    fields = dict(availability=base.availability, speed=base.speed,
                  step_mult=base.step_mult if step_mult is None
                  else np.asarray(step_mult, np.int32))
    fields.update(kw)
    return sched_lib.AvailabilityTrace(**fields)


def _assert_tree_close(a, b, atol, msg=""):
    flat_b = dict((jax.tree_util.keystr(p), l) for p, l in
                  jax.tree_util.tree_leaves_with_path(b))
    for p, leaf in jax.tree_util.tree_leaves_with_path(a):
        np.testing.assert_allclose(
            np.asarray(leaf), np.asarray(flat_b[jax.tree_util.keystr(p)]),
            atol=atol, rtol=0, err_msg=f"{msg}{jax.tree_util.keystr(p)}")


@pytest.mark.parametrize("arm", ["fedclip", "tripleplay"])
def test_sync_partial_matches_sequential_oracle(arm):
    """A fused subset round (gather into staged pools, in-program
    aggregation over renormalized subset weights) must reproduce the
    sequential per-client loop restricted to the selected subset: final
    trainables, per-client loss/acc, uplink bytes."""
    s = _setup(arm)
    trace = _trace()
    mk = lambda ex: sched_lib.SyncPartialScheduler(
        executor=ex, trace=trace, local_steps=STEPS, clients_per_round=2)
    key = jax.random.PRNGKey(7)
    new_c, mc = mk(s["cohort_exec"]).step(s["global_tr"], 0, key)
    new_s, ms = mk(s["seq_exec"]).step(s["global_tr"], 0, key)
    assert list(mc["participation"]) == list(ms["participation"])
    np.testing.assert_allclose(mc["loss"], ms["loss"], atol=1e-3,
                               rtol=1e-4)
    np.testing.assert_allclose(mc["acc"], ms["acc"], atol=1e-5)
    assert int(mc["uplink_bytes"]) == int(ms["uplink_bytes"])
    _assert_tree_close(new_c, new_s, atol=5e-4, msg=f"{arm} ")


def test_sync_partial_at_K_N_reproduces_full_round_exactly():
    """The degenerate policy: K=N with a uniform trace selects the
    identity cohort with the full round's batch key, so the subset
    program (gather prefix + identical math) is bit-identical to PR 1's
    ``run_round``. SyncPartial at K=N exercises the gather program;
    FullSync short-circuits to the gather-free program — all three must
    agree bitwise."""
    s = _setup("fedclip")
    key = jax.random.PRNGKey(11)
    ref, mref = s["engine"].run_round(s["global_tr"], key)
    partial = sched_lib.SyncPartialScheduler(
        executor=s["cohort_exec"], trace=_trace(), local_steps=STEPS,
        clients_per_round=N_CLIENTS)
    full = sched_lib.FullSyncScheduler(
        executor=s["cohort_exec"], trace=_trace(), local_steps=STEPS)
    for sched in (partial, full):
        new, m = sched.step(s["global_tr"], 0, key)
        for (p, a), b in zip(jax.tree_util.tree_leaves_with_path(ref),
                             jax.tree.leaves(new)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{sched.name} {jax.tree_util.keystr(p)}")
        np.testing.assert_array_equal(mref["loss"], m["loss"])
        assert int(mref["uplink_bytes"]) == int(m["uplink_bytes"])
        assert list(m["participation"]) == list(range(N_CLIENTS))


def test_uplink_accounting_under_partial_participation():
    """Per-round uplink bytes must be exactly K x the per-client
    quantized payload (leading-axis-inert quantization), matching the
    sequential path's actual ``make_update`` payload sum."""
    s = _setup("tripleplay")
    trace = _trace()
    per_client = s["engine"].per_client_uplink_bytes(s["global_tr"])
    for k in (1, 2, 3):
        sched = sched_lib.SyncPartialScheduler(
            executor=s["cohort_exec"], trace=trace, local_steps=STEPS,
            clients_per_round=k)
        _, m = sched.step(s["global_tr"], 0, jax.random.PRNGKey(k))
        assert int(m["uplink_bytes"]) == k * per_client
        assert len(m["participation"]) == k


def test_async_virtual_time_is_bit_deterministic():
    """Two async runs with the same seed/trace must agree bitwise:
    participation order, staleness tags, virtual commit times, and the
    final global trainables."""
    s = _setup("fedclip")
    trace = sched_lib.skewed_trace(N_CLIENTS, seed=5)

    def run():
        sched = sched_lib.AsyncBufferedScheduler(
            executor=s["cohort_exec"], trace=trace, local_steps=STEPS,
            clients_per_round=1, staleness_beta=0.5, concurrency=2,
            client_n=[c.n for c in s["clients"]])
        tr = s["global_tr"]
        log = []
        for rnd in range(4):
            tr, m = sched.step(tr, rnd, jax.random.PRNGKey(rnd))
            log.append((list(m["participation"]), list(m["staleness"]),
                        m["vtime"]))
        return tr, log

    tr1, log1 = run()
    tr2, log2 = run()
    assert log1 == log2
    for a, b in zip(jax.tree.leaves(tr1), jax.tree.leaves(tr2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # staleness actually emerges: concurrency > buffer means some
    # committed updates trained against an older server version
    assert any(t > 0 for (_, taus, _) in log1 for t in taus)
    assert all(t >= 0 for (_, taus, _) in log1 for t in taus)


def test_async_rotates_through_idle_population():
    """Freed slots back-fill from the idle pool, so clients outside the
    initial concurrency draw rotate into training instead of being
    excluded for the whole run."""
    s = _setup("fedclip")
    trace = sched_lib.skewed_trace(N_CLIENTS, seed=2)
    sched = sched_lib.AsyncBufferedScheduler(
        executor=s["cohort_exec"], trace=trace, local_steps=STEPS,
        clients_per_round=1, staleness_beta=0.5, concurrency=2,
        client_n=[c.n for c in s["clients"]])
    tr = s["global_tr"]
    seen = set()
    for rnd in range(8):
        tr, m = sched.step(tr, rnd, jax.random.PRNGKey(rnd))
        seen.update(int(c) for c in m["participation"])
    assert seen == set(range(N_CLIENTS))


def test_engine_rejects_untraced_heterogeneity():
    """A scheduler carrying heterogeneous step counts over an engine
    staged homogeneous must fail loudly, not silently train the wrong
    number of steps."""
    s = _setup("fedclip")   # staged with every step_mult == 1
    sched = sched_lib.FullSyncScheduler(
        executor=s["cohort_exec"], trace=_trace(step_mult=[2, 1, 1]),
        local_steps=STEPS)
    with pytest.raises(ValueError,
                       match="staged homogeneous|outside \\[1,"):
        sched.step(s["global_tr"], 0, jax.random.PRNGKey(0))


def test_sequential_rejects_untraced_heterogeneity():
    """The sequential oracle mirrors the engine's loud failure: a step
    profile exceeding its staged batch-index layout must raise, never
    silently truncate (executor parity)."""
    s = _setup("fedclip")   # max_steps staged with every step_mult == 1
    sched = sched_lib.FullSyncScheduler(
        executor=s["seq_exec"], trace=_trace(step_mult=[2, 1, 1]),
        local_steps=STEPS)
    with pytest.raises(ValueError, match="exceed the staged maximum"):
        sched.step(s["global_tr"], 0, jax.random.PRNGKey(0))


def test_run_round_rejects_heterogeneous_engine():
    """``run_round`` is the unmasked homogeneous program; on an engine
    staged with step multipliers it must refuse rather than silently
    train every client the base step count."""
    s = _setup("fedclip", step_mult=[2, 1, 1])
    with pytest.raises(ValueError, match="homogeneous"):
        s["engine"].run_round(s["global_tr"], jax.random.PRNGKey(0))


def test_full_policy_rejects_clients_per_round():
    with pytest.raises(ValueError, match="meaningless"):
        sched_lib.make_scheduler(
            "full", executor=None, trace=_trace(), local_steps=STEPS,
            clients_per_round=2)


def test_staleness_weights_beta0_is_fedavg():
    m = np.array([10, 30, 60], np.float64)
    tau = np.array([0, 2, 5], np.float64)
    w0 = sched_lib.staleness_weights(m, tau, beta=0.0)
    np.testing.assert_allclose(w0, m / m.sum(), rtol=1e-6)
    # β>0 discounts stale updates: same mass, higher τ → lower weight
    w = sched_lib.staleness_weights([1, 1, 1], [0, 1, 3], beta=0.7)
    assert w[0] > w[1] > w[2]
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    with pytest.raises(ValueError):
        sched_lib.staleness_weights([0.0, 0.0], [0, 0], beta=0.5)


def test_async_beta0_commit_equals_fedavg_aggregate():
    """An async buffer commit at β=0 must equal plain sample-count
    FedAvg over the same buffered deltas (cohort and sequential commit
    paths agree with ``server.aggregate``)."""
    s = _setup("fedclip")
    cohort = sched_lib.Cohort(sel=np.array([0, 2], np.int32),
                              n_steps=np.full(2, STEPS, np.int32),
                              staleness=np.array([3, 1], np.int32))
    deltas, m = s["cohort_exec"].run_wave(
        s["global_tr"], cohort, jax.random.PRNGKey(3))
    masses = [s["clients"][0].n, s["clients"][2].n]
    w0 = sched_lib.staleness_weights(masses, cohort.staleness, beta=0.0)
    got = s["cohort_exec"].commit_buffer(s["global_tr"], w0, deltas)
    ref = server.aggregate(s["global_tr"], list(zip(masses, deltas)))
    _assert_tree_close(got, ref, atol=1e-6)


def test_heterogeneous_local_steps_parity():
    """Trace-assigned step multipliers: the fused program masks the tail
    of its fixed-length scan per client; the sequential oracle simply
    runs fewer steps. Both must agree."""
    mult = [2, 1, 1]
    s = _setup("fedclip", step_mult=mult)
    assert s["engine"].max_steps == STEPS * 2
    trace = _trace(step_mult=mult)
    mk = lambda ex: sched_lib.FullSyncScheduler(
        executor=ex, trace=trace, local_steps=STEPS)
    key = jax.random.PRNGKey(9)
    new_c, mc = mk(s["cohort_exec"]).step(s["global_tr"], 0, key)
    new_s, ms = mk(s["seq_exec"]).step(s["global_tr"], 0, key)
    np.testing.assert_allclose(mc["loss"], ms["loss"], atol=1e-3,
                               rtol=1e-4)
    _assert_tree_close(new_c, new_s, atol=5e-4, msg="het ")


def test_traces_deterministic_and_validated():
    t1 = sched_lib.skewed_trace(8, seed=3)
    t2 = sched_lib.skewed_trace(8, seed=3)
    np.testing.assert_array_equal(t1.availability, t2.availability)
    np.testing.assert_array_equal(t1.speed, t2.speed)
    assert t1.step_mult.min() >= 1 and \
        t1.step_mult.max() <= MAX_STEP_MULT
    np.testing.assert_allclose(t1.selection_probs().sum(), 1.0,
                               rtol=1e-12)
    assert sched_lib.resolve_trace(None, 4).name == "uniform"
    assert sched_lib.resolve_trace("skewed", 4, seed=1).n == 4
    assert sched_lib.resolve_trace("skewed", 64, seed=1).step_mult.max() \
        == 1
    assert sched_lib.resolve_trace("skewed-het", 64,
                                   seed=1).step_mult.max() > 1
    with pytest.raises(ValueError):
        sched_lib.resolve_trace(t1, 4)       # built for 8 clients
    with pytest.raises(ValueError):
        sched_lib.AvailabilityTrace(
            availability=np.ones(2), speed=np.ones(2),
            step_mult=np.array([1, MAX_STEP_MULT + 1]))


def test_aggregation_weight_guards():
    g = {"w": jnp.zeros((4,))}
    stacked = {"w": jnp.ones((2, 4))}
    ok = jnp.asarray([0.25, 0.75])
    out = server.aggregate_stacked(g, ok, stacked)
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0)
    with pytest.raises(ValueError):   # not normalized
        server.aggregate_stacked(g, jnp.asarray([1.0, 1.0]), stacked)
    with pytest.raises(ValueError):   # wrong shape
        server.aggregate_stacked(g, jnp.asarray([1.0]), stacked)
    with pytest.raises(ValueError):   # negative mass
        server.aggregate(g, [(-1.0, {"w": jnp.ones((4,))}),
                             (2.0, {"w": jnp.ones((4,))})])
    with pytest.raises(ValueError):   # zero total
        server.aggregate(g, [(0.0, {"w": jnp.ones((4,))})])


def test_simulator_history_columns_and_compile_split():
    """run_federated drives every policy through one scheduler path and
    records participation/staleness/vtime plus the one-time compile cost
    (round_time_s is steady-state)."""
    from repro.fl.simulator import FLConfig, run_federated
    h = run_federated(FLConfig(
        dataset="pacs", strategy="fedclip", n_clients=4, rounds=2,
        local_steps=3, n_per_class=12, batch_size=8, lr=3e-3,
        participation="sync-partial", clients_per_round=2,
        trace="skewed"))
    assert h.meta["participation"] == "sync-partial"
    assert h.meta["clients_per_round"] == 2
    assert h.meta["compile_time_s"] > 0
    assert len(h.participation) == 2 and \
        all(len(p) == 2 for p in h.participation)
    assert h.staleness == [[0, 0], [0, 0]]
    assert h.vtime == [1.0, 2.0]
    assert all(len(l) == 2 for l in h.client_loss)
    # steady-state rounds exclude the jit cost recorded in meta
    assert max(h.round_time_s) < h.meta["compile_time_s"]
