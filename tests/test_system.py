"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_full_tripleplay_pipeline_learns():
    """The paper's pipeline end-to-end: pretrained frozen CLIP + adapter
    + LoRA + GAN rebalancing + quantized aggregation, multiple rounds —
    server loss must improve and the uplink must stay compressed."""
    from repro.fl.simulator import FLConfig, run_federated
    h = run_federated(FLConfig(
        dataset="pacs", strategy="tripleplay", n_clients=3, rounds=4,
        local_steps=6, n_per_class=24, gan_steps=60, lr=3e-3))
    assert h.server_loss[-1] < h.server_loss[0]
    assert all(np.isfinite(v) for v in h.server_acc)
    # compressed uplink: int8-quantized trainables only
    assert h.uplink_bytes[0] < h.meta["trainable_params"] * 4 * 3 / 2


def test_federated_llm_round_on_assigned_arch():
    """launch/train.py path: one FL round of QLoRA fine-tuning on a
    reduced assigned backbone reduces the clients' LM loss."""
    from repro.configs import get_reduced
    from repro.launch.train import (aggregate, client_update,
                                    synthetic_token_stream)
    from repro.models import build_model
    cfg = get_reduced("yi-9b").replace(quant_bits=4, quant_mode="nf4",
                                       quant_block=64)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    frozen, tr = params["frozen"], params["trainable"]
    data = synthetic_token_stream(np.random.RandomState(0),
                                  cfg.vocab_size, 2, seq=48)
    losses = []
    for rnd in range(2):
        updates = []
        for c in range(2):
            d, _, loss, n_steps, n_samples = client_update(
                model, frozen, tr, data[c], steps=8, batch=8, lr=5e-3,
                comm_bits=8, seed=rnd * 10 + c)
            assert n_steps == 8 and n_samples == 64  # round ledger feed
            updates.append((len(data[c]), d))
            losses.append(loss)
        tr = aggregate(tr, updates)
    assert np.mean(losses[-2:]) < np.mean(losses[:2])


def test_serving_pipeline_deterministic():
    """Greedy decode twice from the same prefill gives identical tokens."""
    from repro.configs import get_reduced
    from repro.models import build_model
    cfg = get_reduced("h2o-danube-3-4b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (2, 16)), jnp.int32)

    def gen():
        logits, cache = model.prefill(params["frozen"],
                                      params["trainable"],
                                      {"tokens": toks}, max_len=24)
        t = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out = [t]
        for i in range(4):
            logits, cache = model.decode_step(
                params["frozen"], params["trainable"], cache, t,
                jnp.asarray(16 + i, jnp.int32))
            t = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out.append(t)
        return np.asarray(jnp.concatenate(out, 1))

    a, b = gen(), gen()
    assert (a == b).all()
