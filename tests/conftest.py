import sys

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # Offline container without hypothesis: install the deterministic
    # fallback before test modules import it (conftest loads first).
    from _hypothesis_stub import install
    install(sys.modules)


@pytest.fixture
def rng():
    return np.random.RandomState(0)
