"""Personalized-adapter serving plane (fl.serve): batched-vs-sequential
parity across mixed tenant families, quantized-at-rest round trips, LRU
eviction correctness under overflow traces, bucket-reuse compile counts,
and the virtual-time replay determinism contract.

The heavy fixture (one trained mixed-tenancy plane) is module-scoped:
every test reuses the same backing trees and builds cheap secondary
stores/engines over them instead of retraining.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import quant as qlib
from repro.fl import runtime as runtime_lib
from repro.fl import serve as serve_lib
from repro.fl.serve import engine as engine_lib
from repro.fl.serve import store as store_lib

N_USERS = 6


@pytest.fixture(scope="module")
def plane():
    return serve_lib.demo_plane(
        N_USERS, mixed=True, seed=0, quant_bits=8, max_batch=4,
        n_per_class=12)


def _engine_over(plane, *, quant_bits, max_entries=None, max_batch=4,
                 runtime=None):
    """A fresh store + engine over the fixture's trained backing —
    no retraining, independent runtime/ledger when asked."""
    rt = runtime if runtime is not None else runtime_lib.ProgramRuntime()
    store = store_lib.AdapterStore(
        plane["backing"], max_entries=max_entries or N_USERS,
        quant_bits=quant_bits, runtime=rt)
    eng = engine_lib.ServeEngine(
        frozen=plane["frozen"], ccfg=plane["ccfg"],
        class_emb=plane["class_emb"], store=store,
        cfg=engine_lib.ServeConfig(max_batch=max_batch))
    return eng


def _requests(plane, uids, *, seed=0):
    rs = np.random.RandomState(seed)
    pool = plane["images"]
    return [(int(u), pool[rs.randint(0, len(pool))]) for u in uids]


def _oracle(plane, requests):
    return engine_lib.serve_sequential(
        plane["frozen"], plane["ccfg"], plane["class_emb"],
        plane["backing"], requests)


# -- parity ------------------------------------------------------------

def test_mixed_tenant_parity_quantized(plane):
    # every tenant of both families in one stream; int8-at-rest logits
    # must track the fp32 sequential oracle
    reqs = _requests(plane, [0, 3, 1, 4, 2, 5, 0, 3], seed=1)
    out, info = plane["engine"].serve(reqs)
    ref = _oracle(plane, reqs)
    assert out.shape == ref.shape == (len(reqs), plane["n_classes"])
    assert np.max(np.abs(out - ref)) < 5e-2
    # the mixed flight really split by family (adapter-only + LoRA)
    assert info["groups"] > info["flights"]


def test_unquantized_store_is_tight(plane):
    # quant_bits=0 keeps the slabs fp32: the only difference from the
    # oracle is the S=1 closed-form head, which is exact reduction —
    # tolerance is fp noise
    eng = _engine_over(plane, quant_bits=0)
    reqs = _requests(plane, [5, 0, 2, 4], seed=2)
    out, _ = eng.serve(reqs)
    ref = _oracle(plane, reqs)
    assert np.max(np.abs(out - ref)) < 1e-4


def test_flight_wider_than_store_rejected(plane):
    with pytest.raises(ValueError, match="max_entries"):
        _engine_over(plane, quant_bits=8, max_entries=2, max_batch=4)


# -- quantized at rest -------------------------------------------------

def test_quantize_at_rest_roundtrip(plane):
    tr = jax.tree.map(jnp.asarray, plane["backing"][0])
    q8 = store_lib.quantize_at_rest(tr, bits=8)
    # eligible 2-D adapter mats became QTensors, biases stayed fp
    leaves = jax.tree.leaves(q8, is_leaf=lambda l: isinstance(
        l, qlib.QTensor))
    assert any(isinstance(l, qlib.QTensor) for l in leaves)
    assert all(l.ndim == 1 for l in leaves
               if not isinstance(l, qlib.QTensor))
    deq = qlib.dequantize_tree(q8, jnp.float32)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(tr),
                              jax.tree.leaves(deq)))
    assert err < 5e-2
    # bits=0 is identity (the fp store mode)
    q0 = store_lib.quantize_at_rest(tr, bits=0)
    assert all(not isinstance(l, qlib.QTensor)
               for l in jax.tree.leaves(
                   q0, is_leaf=lambda l: isinstance(l, qlib.QTensor)))
    # quantization shrinks the at-rest footprint
    assert qlib.tree_bytes(q8) < qlib.tree_bytes(q0)


def test_store_rejects_bad_config(plane):
    with pytest.raises(ValueError, match="max_entries"):
        store_lib.AdapterStore(plane["backing"], max_entries=0)
    with pytest.raises(ValueError, match="quant_bits"):
        store_lib.AdapterStore(plane["backing"], max_entries=2,
                               quant_bits=3)


# -- LRU eviction ------------------------------------------------------

def test_lru_eviction_under_overflow_stays_correct(plane):
    # capacity 3 over a 6-user population: the stream forces evictions;
    # every re-admission re-quantizes from backing, so answers still
    # match the oracle
    eng = _engine_over(plane, quant_bits=8, max_entries=3, max_batch=2)
    uids = [0, 1, 2, 3, 4, 5, 0, 1, 5, 5, 2, 0]
    reqs = _requests(plane, uids, seed=3)
    out, _ = eng.serve(reqs)
    st = eng.store.stats()
    assert st["evictions"] > 0
    assert st["resident"] <= 3
    assert st["hits"] + st["misses"] == len(reqs)
    # misses beyond capacity each evicted exactly one resident
    assert st["evictions"] == st["misses"] - st["resident"]
    ref = _oracle(plane, reqs)
    assert np.max(np.abs(out - ref)) < 5e-2


def test_lru_order_and_flight_safety(plane):
    eng = _engine_over(plane, quant_bits=8, max_entries=3, max_batch=3)
    s = eng.store
    for u in (0, 1, 2):
        s.fetch(u)
    assert s.resident() == (0, 1, 2)
    s.fetch(0)                       # hit: 0 becomes MRU
    assert s.resident() == (1, 2, 0)
    s.fetch(3)                       # evicts 1 (global LRU)
    assert 1 not in s.resident() and s.resident()[-1] == 3
    # one full-width flight of distinct users never self-evicts: all
    # three fetched users are resident afterwards
    for u in (4, 5, 0):
        s.fetch(u)
    assert set(s.resident()) == {4, 5, 0}


def test_unknown_uid_raises(plane):
    eng = _engine_over(plane, quant_bits=8)
    with pytest.raises(KeyError, match="no trained adapter"):
        eng.store.fetch(N_USERS + 7)


# -- compile reuse -----------------------------------------------------

def test_request_size_sweep_reuses_one_serve_compile(plane):
    # R in {2, 3, 4} with max_batch=4 all bucket to width 4: the sweep
    # must compile exactly ONE serve program (per family; we stay in
    # the adapter-only family) — a second compile means request-shape
    # bucketing regressed
    rt = runtime_lib.ProgramRuntime()
    eng = _engine_over(plane, quant_bits=8, max_batch=4, runtime=rt)
    fam0 = [0, 1, 2]                 # adapter-only tenants
    for r in (2, 3, 4):
        eng.serve(_requests(plane, fam0[:r] + fam0[:max(0, r - 3)],
                            seed=r))
    st = rt.stats()[engine_lib.SERVE_KIND]
    assert st["n_compiles"] == 1
    assert st["n_groups"] == 3
    assert st["n_requests"] == 2 + 3 + 4


def test_batched_plane_not_degenerate(plane):
    # the CI smoke's contract: dispatches (fused programs) must be
    # strictly fewer than requests answered
    eng = plane["engine"]
    assert eng.n_requests > 0
    assert eng.n_dispatches < eng.n_requests


# -- traces + replay ---------------------------------------------------

def test_zipf_trace_shape_and_determinism():
    a = serve_lib.zipf_request_trace(8, 40, seed=5, period=1.0,
                                     amplitude=0.5)
    b = serve_lib.zipf_request_trace(8, 40, seed=5, period=1.0,
                                     amplitude=0.5)
    assert np.array_equal(a.uid, b.uid)
    assert np.array_equal(a.t, b.t)
    assert a.n == 40 and a.concurrency() <= 8
    assert np.all(np.diff(a.t) >= 0)
    assert "diurnal" in a.name


def test_trace_json_roundtrip(tmp_path):
    tr = serve_lib.zipf_request_trace(5, 12, seed=9)
    p = tmp_path / "trace.json"
    serve_lib.save_request_trace(tr, p)
    back = serve_lib.load_request_trace(p)
    assert np.array_equal(tr.uid, back.uid)
    assert np.allclose(tr.t, back.t)
    assert back.n_users == 5 and back.name == tr.name


def test_trace_validation():
    with pytest.raises(ValueError, match="nondecreasing"):
        serve_lib.RequestTrace(uid=np.asarray([0, 1]),
                               t=np.asarray([1.0, 0.5]), n_users=2)
    with pytest.raises(ValueError, match="uids outside"):
        serve_lib.RequestTrace(uid=np.asarray([0, 7]),
                               t=np.asarray([0.0, 1.0]), n_users=2)


def test_replay_is_deterministic(plane):
    # identical backing + trace through two independent engines: the
    # virtual-clock schedule, latencies, and logits replay bitwise
    trace = serve_lib.zipf_request_trace(N_USERS, 18, seed=4,
                                         rate=300.0)
    images = serve_lib.request_images(plane, trace, seed=4)
    recs = []
    for _ in range(2):
        eng = _engine_over(plane, quant_bits=8, max_entries=4,
                           max_batch=4)
        recs.append(serve_lib.replay(eng, trace, images))
    a, b = recs
    assert a["n_flights"] == b["n_flights"]
    assert [f["n"] for f in a["flights"]] == \
        [f["n"] for f in b["flights"]]
    assert [f["bucket"] for f in a["flights"]] == \
        [f["bucket"] for f in b["flights"]]
    assert np.array_equal(a["lat_v"], b["lat_v"])
    assert np.array_equal(a["logits"], b["logits"])
    assert a["store"] == b["store"]
    # latency stats are consistent with the raw vector
    assert a["lat_v_p50"] == pytest.approx(
        float(np.percentile(a["lat_v"], 50)))
    # every request waits at least one service dispatch
    from repro.fl.serve.driver import SERVICE_C0
    assert a["lat_v"].min() >= SERVICE_C0


def test_replay_matches_oracle_and_counts_store(plane):
    trace = serve_lib.zipf_request_trace(N_USERS, 16, seed=6,
                                         rate=300.0)
    images = serve_lib.request_images(plane, trace, seed=6)
    eng = _engine_over(plane, quant_bits=8)
    rec = serve_lib.replay(eng, trace, images)
    ref = _oracle(plane, [(int(u), im)
                          for u, im in zip(trace.uid, images)])
    assert np.max(np.abs(rec["logits"] - ref)) < 5e-2
    st = rec["store"]
    assert st["hits"] + st["misses"] == trace.n
    assert st["misses"] == trace.concurrency()   # capacity = population
    assert 0.0 <= st["hit_rate"] <= 1.0


def test_replay_rejects_misaligned_images(plane):
    trace = serve_lib.zipf_request_trace(N_USERS, 4, seed=0)
    with pytest.raises(ValueError, match="align"):
        serve_lib.replay(plane["engine"], trace,
                         plane["images"][:2])


# -- launch/serve CLI --------------------------------------------------

def test_select_token_greedy_and_sampling():
    from repro.launch.serve import select_token
    logits = jnp.asarray([[0.0, 5.0, 1.0], [9.0, 0.0, 1.0]])
    tok = select_token(logits, greedy=True)
    assert tok.shape == (2, 1) and tok.dtype == jnp.int32
    assert tok[:, 0].tolist() == [1, 0]
    key = jax.random.PRNGKey(0)
    s1 = select_token(logits, greedy=False, temperature=0.5, key=key)
    s2 = select_token(logits, greedy=False, temperature=0.5, key=key)
    assert np.array_equal(s1, s2)          # deterministic in the key
    with pytest.raises(ValueError, match="PRNG key"):
        select_token(logits, greedy=False)
    with pytest.raises(ValueError, match="temperature"):
        select_token(logits, greedy=False, temperature=0.0, key=key)


def test_serve_parser_greedy_flag_is_live():
    from repro.launch.serve import build_parser
    ap = build_parser()
    assert ap.parse_args([]).greedy is True
    assert ap.parse_args(["--greedy"]).greedy is True
    # the regression: --no-greedy must actually flip it
    ns = ap.parse_args(["--no-greedy", "--temperature", "0.7"])
    assert ns.greedy is False and ns.temperature == 0.7
    assert ap.parse_args(["--adapters", "4"]).adapters == 4
