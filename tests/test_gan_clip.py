"""GAN (§III-B) and CLIP dual-encoder substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clip as clip_lib
from repro.core import gan as gan_lib
from repro.core import optim
from repro.data.synthetic import class_tokens, make_dataset


def test_gan_shapes_and_range(rng):
    cfg = gan_lib.GANConfig(n_classes=5)
    params = gan_lib.init_gan(jax.random.PRNGKey(0), cfg)
    labels = jnp.asarray(rng.randint(0, 5, 6), jnp.int32)
    imgs = gan_lib.synthesize(jax.random.PRNGKey(1), params["gen"], cfg,
                              labels)
    assert imgs.shape == (6, 32, 32, 3)
    assert float(imgs.min()) >= -1.0 and float(imgs.max()) <= 1.0


def test_gan_training_is_finite_and_learns(rng):
    cfg = gan_lib.GANConfig(n_classes=3, g_dim=16, d_dim=16)
    data = make_dataset("pacs", n_per_class=8, seed=0, longtail_gamma=1.0)
    imgs = jnp.asarray(data["images"][:48])
    labs = jnp.asarray(data["labels"][:48] % 3)
    params, metrics = gan_lib.train_gan(jax.random.PRNGKey(0), cfg, imgs,
                                        labs, steps=30, batch=16)
    assert np.isfinite(float(metrics["d_loss"]))
    assert np.isfinite(float(metrics["g_loss"]))
    # discriminator separates real samples from generator samples (the
    # boundary its min-max objective optimizes)
    fake = gan_lib.synthesize(jax.random.PRNGKey(5), params["gen"], cfg,
                              labs[:16])
    d_real = gan_lib.discriminate(params["disc"], cfg, imgs[:16],
                                  labs[:16])
    d_fake = gan_lib.discriminate(params["disc"], cfg, fake, labs[:16])
    assert float(d_real.mean()) > float(d_fake.mean())


def test_clip_contrastive_pretraining_descends():
    ccfg = clip_lib.CLIPConfig(vision_layers=1, text_layers=1, d_model=32,
                               d_ff=64, proj_dim=16)
    data = make_dataset("pacs", n_per_class=8, seed=0, longtail_gamma=1.0)
    imgs = jnp.asarray(data["images"][:32])
    toks = jnp.asarray(data["tokens"][:32])
    params = clip_lib.init_clip(jax.random.PRNGKey(0), ccfg)
    opt = optim.adam_init(params)

    @jax.jit
    def step(p, o):
        l, g = jax.value_and_grad(
            lambda p: clip_lib.contrastive_loss(p, ccfg, imgs, toks))(p)
        p, o = optim.adam_update(g, o, p, lr=1e-3)
        return p, o, l
    losses = []
    for _ in range(20):
        params, opt, l = step(params, opt)
        losses.append(float(l))
    assert losses[-1] < losses[0]


def test_zero_shot_logits_shape_and_scale():
    ccfg = clip_lib.CLIPConfig()
    params = clip_lib.init_clip(jax.random.PRNGKey(0), ccfg)
    img = jnp.zeros((4, 32, 32, 3))
    emb = clip_lib.image_embedding(params, ccfg, img)
    cls = jnp.asarray(np.random.RandomState(0).randn(7, ccfg.proj_dim),
                      jnp.float32)
    logits = clip_lib.zero_shot_logits(emb, cls, params["logit_scale"])
    assert logits.shape == (4, 7)
    assert np.isfinite(np.asarray(logits)).all()


def test_synthetic_dataset_longtail():
    d = make_dataset("pacs", n_per_class=40, seed=0, longtail_gamma=8.0)
    hist = np.bincount(d["labels"], minlength=7)
    assert hist[0] < hist[1:].min() / 2      # class 0 underrepresented
    bal = make_dataset("pacs", n_per_class=40, seed=0, longtail_gamma=1.0)
    hb = np.bincount(bal["labels"], minlength=7)
    assert hb.max() - hb.min() <= 1
    assert d["images"].shape[1:] == (32, 32, 3)
    assert np.abs(d["images"]).max() <= 1.0


def test_class_tokens_deterministic_and_distinct():
    from repro.data.synthetic import SPECS
    spec = SPECS["pacs"]
    t = class_tokens(spec, np.arange(7))
    assert len({tuple(r) for r in t}) == 7


def test_gan_training_int8_compute_is_finite_and_learns(rng):
    """conv_impl="gemm_int8" trains *with* quantized matmuls — the run
    must stay finite and still separate real from fake."""
    cfg = gan_lib.GANConfig(n_classes=3, g_dim=16, d_dim=16,
                            conv_impl="gemm_int8")
    data = make_dataset("pacs", n_per_class=8, seed=0, longtail_gamma=1.0)
    imgs = jnp.asarray(data["images"][:48])
    labs = jnp.asarray(data["labels"][:48] % 3)
    params, metrics = gan_lib.train_gan(jax.random.PRNGKey(0), cfg, imgs,
                                        labs, steps=30, batch=16)
    assert np.isfinite(float(metrics["d_loss"]))
    assert np.isfinite(float(metrics["g_loss"]))
    fake = gan_lib.synthesize(jax.random.PRNGKey(5), params["gen"], cfg,
                              labs[:16])
    assert bool(jnp.isfinite(fake).all())
    d_real = gan_lib.discriminate(params["disc"], cfg, imgs[:16],
                                  labs[:16])
    d_fake = gan_lib.discriminate(params["disc"], cfg, fake, labs[:16])
    assert float(d_real.mean()) > float(d_fake.mean())


def test_gan_conv_impl_unknown_rejected(rng):
    cfg = gan_lib.GANConfig(n_classes=3, conv_impl="nope")
    gen = gan_lib.init_gan(jax.random.PRNGKey(0), cfg)["gen"]
    labels = jnp.asarray(rng.randint(0, 3, 4), jnp.int32)
    with pytest.raises(ValueError, match="conv_impl"):
        gan_lib.synthesize(jax.random.PRNGKey(0), gen, cfg, labels)
