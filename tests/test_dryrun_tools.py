"""Dry-run tooling: HLO collective parser and roofline term math."""
import pytest

from repro.launch.dryrun import parse_collectives

HLO = """
  %ag = f32[16,4096]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %ar.1 = (bf16[128,64]{1,0}, bf16[128,64]{1,0}) all-reduce(%a, %b), replica_groups={{0,1,2,3}}, to_apply=%add
  %a2a = s8[2,24,7168]{2,1,0} all-to-all(%y), channel_id=3, replica_groups=[32,16]<=[512]
  %rs = f32[8,8]{1,0} reduce-scatter(%z), channel_id=4, replica_groups=[16,16]<=[256], dimensions={0}
  %cp = bf16[4,4]{1,0} collective-permute(%w), channel_id=5, source_target_pairs={{0,1}}
  %notacoll = f32[4,4]{1,0} add(%p, %q)
"""


def test_parse_collectives_kinds_and_bytes():
    st = parse_collectives(HLO)
    assert set(st) == {"all-gather", "all-reduce", "all-to-all",
                       "reduce-scatter", "collective-permute"}
    assert st["all-gather"]["bytes"] == 16 * 4096 * 4
    assert st["all-gather"]["gsize"] == 16
    assert st["all-reduce"]["bytes"] == 2 * 128 * 64 * 2
    assert st["all-reduce"]["gsize"] == 4
    assert st["all-to-all"]["bytes"] == 2 * 24 * 7168 * 1
    assert st["all-to-all"]["gsize"] == 16
    assert st["reduce-scatter"]["count"] == 1
    assert st["collective-permute"]["bytes"] == 4 * 4 * 2


def test_roofline_terms_math():
    from benchmarks.roofline import HBM_BW, PEAK_FLOPS, terms
    rec = {"n_devices": 256, "hlo_flops": 0.0, "hlo_bytes": 0.0,
           "hlo_flops_cal": PEAK_FLOPS, "hlo_bytes_cal": HBM_BW,
           "collectives_cal": {"all-gather": {"bytes": 50e9, "gsize": 16,
                                              "count": 1}},
           "collectives": {}, "params_active": 1_000_000,
           "global_batch": 2, "seq_len": 4, "kind": "train",
           "argument_bytes": 2**30, "output_bytes": 0, "temp_bytes": 0}
    t = terms(rec)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 1.0) < 1e-9
    assert abs(t["collective_s"] - 15 / 16) < 1e-6
    assert t["dominant"] == "compute"
    assert abs(t["model_flops"] - 6 * 1e6 * 8) < 1
    assert abs(t["hbm_gib"] - 1.0) < 1e-6


def test_moe_int8_dispatch_local_noop(rng):
    """moe_dispatch_bits only affects the distributed path; the local
    path (no mesh) is unchanged."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.models import moe as moe_lib
    cfg = get_reduced("qwen3-moe-235b-a22b").replace(capacity_factor=8.0)
    p = moe_lib.init_experts(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.randn(2, 4, cfg.d_model) * 0.2, jnp.float32)
    y0, _ = moe_lib.moe_ffn(p, x, cfg)
    y1, _ = moe_lib.moe_ffn(p, x, cfg.replace(moe_dispatch_bits=8))
    assert float(jnp.abs(y0 - y1).max()) == 0.0
