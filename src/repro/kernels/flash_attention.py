"""Pallas TPU flash attention (causal / sliding-window / bidirectional).

Grid (B, H, nq, nk) with the KV-block dimension minormost so the running
(m, l, acc) statistics live in VMEM scratch across the nk steps — the
standard TPU flash schedule: HBM→VMEM tiles of (bq×D) and (bk×D), softmax
statistics in registers/VMEM, one MXU matmul per (q-block, k-block) pair.
GQA is handled in the index map (kv head = h // G), so K/V tiles are
fetched once per query-head group.

TARGET: TPU (Mosaic). On this CPU container it is validated with
``interpret=True`` against kernels/ref.py (tests/test_kernels.py); real-TPU
deployments should keep D a multiple of 128 for MXU alignment (h2o-danube's
D=120 pads in the wrapper).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, bq, bk, nk, s_q, s_kv):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # (bq, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < s_kv
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=128, block_k=128, interpret=False):
    """q: (B, S, H, D); k, v: (B, Skv, Hkv, D) -> (B, S, H, D)."""
    B, S, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    bq = min(block_q, S)
    bk = min(block_k, Skv)
    Sp = -(-S // bq) * bq
    Skvp = -(-Skv // bk) * bk
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    if Skvp != Skv:
        k = jnp.pad(k, ((0, 0), (0, Skvp - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skvp - Skv), (0, 0), (0, 0)))
    nq, nk = Sp // bq, Skvp // bk
    grid = (B, H, nq, nk)
    scale = 1.0 / (D ** 0.5)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk, s_q=S,
                          s_kv=Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
