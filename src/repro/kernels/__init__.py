# Pallas TPU kernels (quant_matmul, blockwise_quant, flash_attention) with
# jnp oracles in ref.py and backend dispatch in ops.py.
