"""Pallas TPU blockwise absmax quantization (int8 / packed int4).

Used for communication compression of FL updates and KV-cache quantization:
one pass over the tensor computing per-(block × column) absmax scales and
the quantized payload. Blocks run along the leading (contraction) dim to
match quant_matmul's layout.

TARGET: TPU. Validated with interpret=True vs kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import QTensor


def _kernel(x_ref, q_ref, s_ref, *, bits):
    x = x_ref[...].astype(jnp.float32)              # (block, bn)
    absmax = jnp.maximum(jnp.abs(x).max(axis=0, keepdims=True), 1e-12)
    if bits == 8:
        scale = absmax / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        q_ref[0] = q
    else:
        scale = absmax / 7.0
        q = jnp.clip(jnp.round(x / scale), -8, 7).astype(jnp.int8)
        u = (q + 8).astype(jnp.uint8)
        q_ref[0] = (u[0::2] << 4) | u[1::2]
    s_ref[0] = scale


@functools.partial(jax.jit, static_argnames=("bits", "block", "block_n",
                                             "interpret"))
def blockwise_quant(x, *, bits=8, block=128, block_n=512,
                    interpret=False) -> QTensor:
    """x: (K, N) -> QTensor with blocks of ``block`` along K.

    Both dims pad to their tile: N to ``block_n`` (sliced back below)
    and K to a multiple of ``block`` with zero rows. Zero padding never
    perturbs a block's absmax scale (real rows dominate; an all-pad
    block hits the 1e-12 floor), so the result equals quantizing the
    zero-padded input exactly. The returned ``q``/``scales`` cover the
    padded K while ``orig_shape`` records the true K — ``dequantize``
    yields ``ceil(K/block)*block`` rows (zeros past K); callers slice
    ``[:K]``."""
    K, N = x.shape
    block = min(block, K)
    Kp = -(-K // block) * block
    if Kp != K:
        x = jnp.pad(x, ((0, Kp - K), (0, 0)))
    G = Kp // block
    bn = min(block_n, N)
    Np = -(-N // bn) * bn
    xp = jnp.pad(x, ((0, 0), (0, Np - N))) if Np != N else x
    rows = block // 2 if bits == 4 else block
    qdt = jnp.uint8 if bits == 4 else jnp.int8

    q, s = pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=(G, Np // bn),
        in_specs=[pl.BlockSpec((block, bn), lambda gi, ni: (gi, ni))],
        out_specs=[
            pl.BlockSpec((1, rows, bn), lambda gi, ni: (gi, 0, ni)),
            pl.BlockSpec((1, 1, bn), lambda gi, ni: (gi, 0, ni)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, rows, Np), qdt),
            jax.ShapeDtypeStruct((G, 1, Np), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    q = q[..., :N]
    s = s[..., :N]
    return QTensor(q=q, scales=s, bits=bits, mode="linear", block=block,
                   out_dtype=x.dtype, orig_shape=(K, N))
