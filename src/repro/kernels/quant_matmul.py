"""Pallas TPU fused dequant-matmul — the QLoRA backbone hot path (§III-C).

Computes y = x @ dequant(W_q) without ever materializing the dequantized
weight in HBM: int8 / packed-int4 / NF4 tiles stream HBM→VMEM, are
dequantized in-register, and feed the MXU directly. Quantization blocks
run along the contraction dim (multiples of 128 — DESIGN.md §5), so the
grid's minormost dimension walks the G quant groups with a f32 accumulator
tile in VMEM scratch.

TARGET: TPU. Validated with interpret=True vs kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import NF4_CODE, QTensor


def dequant_tile(q_ref, s_ref, code_ref, *, bits, mode):
    """Dequantize one (block[/2], bn) VMEM tile in-register: unpack int4
    pairs, map NF4 codes through the VMEM-resident codebook, apply the
    per-block absmax scale. Shared by ``quant_matmul`` and the fused
    LoRA kernel (``kernels.lora_matmul``) so both stream the identical
    quantized layout."""
    qv = q_ref[0]                                   # (block[/2], bn)
    if bits == 4:
        hi = (qv >> 4).astype(jnp.int8) - 8
        lo = (qv & 0xF).astype(jnp.int8) - 8
        vals = jnp.stack([hi, lo], axis=1).reshape(-1, qv.shape[-1])
    else:
        vals = qv
    if mode == "nf4":
        code = code_ref[0]                          # (16,) VMEM-resident
        w = jnp.take(code, (vals + 8).astype(jnp.int32))
    else:
        w = vals.astype(jnp.float32)
    return w * s_ref[0]                             # (block, bn) f32


def _kernel(x_ref, q_ref, s_ref, code_ref, o_ref, acc_ref, *, bits, mode,
            ng):
    gi = pl.program_id(2)

    @pl.when(gi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                  # (bm, block)
    w = dequant_tile(q_ref, s_ref, code_ref, bits=bits, mode=mode)
    acc_ref[...] += jax.lax.dot_general(
        x.astype(jnp.float32), w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(gi == ng - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def quant_matmul(x, qt: QTensor, *, block_m=256, block_n=256,
                 interpret=False):
    """x: (..., K) @ dequant(qt (K, N)) -> (..., N). ``qt`` may cover a
    K zero-padded to a block multiple (the odd-K ``blockwise_quant``
    contract); x zero-pads to match — the last block then contracts
    defined zeros instead of out-of-bounds reads."""
    *lead, K = x.shape
    M = 1
    for s in lead:
        M *= s
    x2 = x.reshape(M, K)
    Kq = qt.q.shape[0] * qt.block
    if Kq != K:
        if Kq < K or (Kq - K) >= qt.block:
            raise ValueError(
                f"quantized contraction dim {Kq} incompatible with "
                f"x's {K} (block {qt.block})")
        x2 = jnp.pad(x2, ((0, 0), (0, Kq - K)))
    G = qt.q.shape[0]
    N = qt.q.shape[-1]
    block = qt.block
    bm = min(block_m, max(8, M))
    bn = min(block_n, N)
    Mp = -(-M // bm) * bm
    Np = -(-N // bn) * bn
    if Mp != M:
        x2 = jnp.pad(x2, ((0, Mp - M), (0, 0)))
    qv, sv = qt.q, qt.scales
    if Np != N:
        qv = jnp.pad(qv, ((0, 0), (0, 0), (0, Np - N)))
        sv = jnp.pad(sv, ((0, 0), (0, 0), (0, Np - N)))
    rows = qv.shape[1]                     # block or block//2 (packed)
    grid = (Mp // bm, Np // bn, G)

    code = jnp.asarray(NF4_CODE).reshape(1, 16)
    out = pl.pallas_call(
        functools.partial(_kernel, bits=qt.bits, mode=qt.mode, ng=G),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, block), lambda mi, ni, gi: (mi, gi)),
            pl.BlockSpec((1, rows, bn), lambda mi, ni, gi: (gi, 0, ni)),
            pl.BlockSpec((1, 1, bn), lambda mi, ni, gi: (gi, 0, ni)),
            pl.BlockSpec((1, 16), lambda mi, ni, gi: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, gi: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x2, qv, sv, code)
    return out[:M, :N].reshape(*lead, N)
