"""Pure-jnp oracles for every Pallas kernel.

These are the *reference semantics* — kernels must match them via
assert_allclose in tests — and they double as the CPU execution path used
by the dry-run (Pallas lowers only on TPU; see DESIGN.md §6).

All three are memory-conscious implementations (the flash reference is
itself blocked) so that 32k-sequence dry-runs never materialize S×S scores.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import quant as qlib

NEG_INF = -1e30


# ------------------------------------------------------------------
# flash attention (causal / sliding-window / bidirectional), GQA-aware
# ------------------------------------------------------------------
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    q_chunk: int = 512, k_chunk: int = 512) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, S, Hkv, D) -> (B, S, H, D).

    Blocked softmax(QK^T)V with running (m, l, acc) statistics; never
    materializes more than a (q_chunk, k_chunk) score tile per head group.
    """
    B, S, H, D = q.shape
    Skv = k.shape[1]
    Hkv = k.shape[2]
    G = H // Hkv
    qc = min(q_chunk, S)
    kc = min(k_chunk, Skv)
    # pad to chunk multiples; padded keys are masked, padded queries sliced
    Sp = -(-S // qc) * qc
    Skvp = -(-Skv // kc) * kc
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    if Skvp != Skv:
        k = jnp.pad(k, ((0, 0), (0, Skvp - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skvp - Skv), (0, 0), (0, 0)))
    nq, nk = Sp // qc, Skvp // kc
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))

    qg = q.reshape(B, Sp, Hkv, G, D)

    def q_block(qi):
        qb = lax.dynamic_slice_in_dim(qg, qi * qc, qc, axis=1)
        qb = qb.astype(jnp.float32) * scale
        qpos = qi * qc + jnp.arange(qc)

        def k_step(carry, kj):
            m, l, acc = carry
            kb = lax.dynamic_slice_in_dim(k, kj * kc, kc, axis=1)
            vb = lax.dynamic_slice_in_dim(v, kj * kc, kc, axis=1)
            s = jnp.einsum("bqkgd,bpkd->bkgqp", qb, kb.astype(jnp.float32))
            kpos = kj * kc + jnp.arange(kc)
            mask = jnp.broadcast_to(kpos[None, :] < Skv, (qc, kc))
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqp,bpkd->bkgqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qc, D), jnp.float32)
        (m, l, acc), _ = lax.scan(k_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B, Hkv, G, qc, D)

    blocks = lax.map(q_block, jnp.arange(nq))           # (nq, B, Hkv, G, qc, D)
    out = jnp.moveaxis(blocks, 0, 3)                    # (B, Hkv, G, nq, qc, D)
    out = out.reshape(B, Hkv, G, Sp, D).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sp, H, D)[:, :S].astype(q.dtype)


def decode_attention_partial(q: jax.Array, k_cache: jax.Array,
                             v_cache: jax.Array, slot_pos: jax.Array):
    """Partial (m, l, acc) statistics for flash-decoding over a slice of
    the cache slots. Shapes as in ``decode_attention`` but with any slot
    count; combine partials with log-sum-exp (see kernels/ops.py)."""
    B, _, H, D = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bpkd->bkgp", qg, k_cache.astype(jnp.float32))
    s = jnp.where((slot_pos >= 0)[:, None, None, :], s, NEG_INF)
    m = s.max(-1)                                          # (B,Hkv,G)
    p = jnp.exp(s - m[..., None])
    p = jnp.where((slot_pos >= 0)[:, None, None, :], p, 0.0)
    l = p.sum(-1)
    acc = jnp.einsum("bkgp,bpkd->bkgd", p, v_cache.astype(jnp.float32))
    return m, l, acc


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     slot_pos: jax.Array) -> jax.Array:
    """Single-token attention against a (ring-)cache.

    q: (B, 1, H, D); caches: (B, Smax, Hkv, D); slot_pos: (B, Smax) int32
    absolute position stored in each slot, -1 for empty. Keys are stored
    already position-encoded, so only validity masking is needed.
    """
    B, _, H, D = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bpkd->bkgp", qg, k_cache.astype(jnp.float32))
    s = jnp.where((slot_pos >= 0)[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgp,bpkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ------------------------------------------------------------------
# selective scan (Mamba-1 recurrence) — naive sequential oracle
# ------------------------------------------------------------------
def selective_scan(dt, x, Bm, Cm, A, h0=None):
    """dt, x: (B, S, di); Bm, Cm: (B, S, N); A: (di, N).
    Returns (y (B, S, di), h_last (B, di, N)); h0 defaults to zeros."""
    B, S, di = x.shape
    N = A.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, di, N), jnp.float32)

    def step(h, t):
        a = jnp.exp(dt[:, t, :, None] * A)                 # (B, di, N)
        h = a * h + (dt[:, t] * x[:, t])[..., None] * Bm[:, t, None, :]
        y = jnp.einsum("ben,bn->be", h, Cm[:, t])
        return h, y

    h_last, ys = lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), h_last


# ------------------------------------------------------------------
# fused dequant-matmul (QLoRA backbone hot path)
# ------------------------------------------------------------------
def quant_matmul(x: jax.Array, qt: qlib.QTensor) -> jax.Array:
    """x: (..., K) @ dequant(qt): (K, N) -> (..., N).

    ``qt`` may cover a K zero-padded to a block multiple (the odd-K
    ``blockwise_quant`` contract); x's contraction dim zero-pads to
    match, which contracts exactly like slicing the pad rows off."""
    w = qlib.dequantize(qt, x.dtype)
    Kq, K = w.shape[-2], x.shape[-1]
    if Kq != K:
        if Kq < K or (Kq - K) >= qt.block:
            raise ValueError(
                f"quantized contraction dim {Kq} incompatible with "
                f"x's {K} (block {qt.block})")
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, Kq - K)])
    if w.ndim > 2:
        # stacked (per-client / per-layer) QTensor: contract pairwise
        # along the shared leading axes — the serve plane's vmapped
        # per-tenant slabs executed un-vmapped
        lead = w.shape[:-2]
        if x.shape[:len(lead)] != lead:
            raise ValueError(
                f"stacked quant_matmul needs matching lead dims: x "
                f"{x.shape} vs dequant(qt) {w.shape}")
        if x.ndim == w.ndim - 1:              # one row per stack entry
            return (x[..., None, :] @ w)[..., 0, :]
        return jnp.matmul(x, w)
    return jnp.einsum("...k,kn->...n", x, w)


# ------------------------------------------------------------------
# fused LoRA matmul (the QLoRA arm's whole linear layer)
# ------------------------------------------------------------------
def lora_matmul(x: jax.Array, w, a: jax.Array, b: jax.Array, *,
                scale: float) -> jax.Array:
    """``y = x @ W(+dequant) + scale·(x@A)@B`` with fp32 accumulation,
    cast back to ``x.dtype`` — the parity oracle and CPU execution path
    of the fused Pallas LoRA kernel (``kernels.lora_matmul``). ``w``
    may be a :class:`~repro.core.quant.QTensor` (odd-K pad contract as
    in :func:`quant_matmul`) or a dense matrix."""
    xf = x.astype(jnp.float32)
    if isinstance(w, qlib.QTensor):
        base = quant_matmul(xf, w)
    else:
        base = jnp.einsum("...k,kn->...n", xf, w.astype(jnp.float32))
    h = jnp.einsum("...k,kr->...r", xf, a.astype(jnp.float32))
    delta = jnp.einsum("...r,rn->...n", h, b.astype(jnp.float32))
    return (base + scale * delta).astype(x.dtype)


# ------------------------------------------------------------------
# blockwise quantization (communication compression / KV quant)
# ------------------------------------------------------------------
def blockwise_quant(x: jax.Array, *, bits: int = 8, block: int = 128,
                    mode: str = "linear") -> qlib.QTensor:
    """Same contract as the Pallas kernel, including odd K: a
    contraction dim not divisible by the block zero-pads up to the next
    block multiple (pad rows never perturb a block's absmax scale), the
    payload covers the padded K, and ``orig_shape`` records the true
    shape — callers slice dequantized rows ``[:K]``."""
    *lead, K, N = x.shape
    blk = min(block, K)
    Kp = -(-K // blk) * blk
    if Kp == K:
        return qlib.quantize(x, bits=bits, block=block, mode=mode)
    pad = [(0, 0)] * len(lead) + [(0, Kp - K), (0, 0)]
    qt = qlib.quantize(jnp.pad(x, pad), bits=bits, block=block,
                       mode=mode)
    return dataclasses.replace(qt, orig_shape=tuple(x.shape))
