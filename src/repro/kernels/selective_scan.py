"""Pallas TPU selective scan (Mamba-1 recurrence).

    h_t = exp(Δ_t ⊗ A) ∘ h_{t-1} + (Δ_t x_t) ⊗ B_t,   y_t = ⟨h_t, C_t⟩

Grid (B, d_inner/bd, S/chunk) with the time-chunk dimension minormost: the
(bd, N) state lives in VMEM scratch across chunk steps, each chunk streams
its (chunk, bd) Δ/x and (chunk, N) B/C tiles HBM→VMEM once, and the
recurrence runs serially in time but fully vectorized over the (bd, N)
state lanes — the VPU-shaped port of the fused CUDA scan (DESIGN.md §5).

TARGET: TPU. Validated with interpret=True vs kernels/ref.selective_scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, y_ref, h_out_ref, h_ref, *,
            chunk, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...]                                   # (bd, N)

    def step(t, h):
        dt_t = dt_ref[0, t, :]                       # (bd,)
        x_t = x_ref[0, t, :]
        b_t = b_ref[0, t, :]                         # (N,)
        c_t = c_ref[0, t, :]
        a = jnp.exp(dt_t[:, None] * A)               # (bd, N)
        h = a * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=-1).astype(
            y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == nc - 1)
    def _flush():
        h_out_ref[0, :, :] = h.astype(h_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "chunk",
                                             "interpret"))
def selective_scan(dt, x, Bm, Cm, A, *, block_d=256, chunk=128,
                   interpret=False):
    """dt, x: (B, S, di) f32; Bm, Cm: (B, S, N) f32; A: (di, N) f32.
    Returns (y (B, S, di) f32, h_last (B, di, N) f32), h0 = 0."""
    B, S, di = x.shape
    N = A.shape[-1]
    bd = min(block_d, di)
    L = min(chunk, S)
    assert di % bd == 0, (di, bd)
    Sp = -(-S // L) * L
    if Sp != S:  # identity padding: dt=0 -> a=1, b contribution 0
        padw = ((0, 0), (0, Sp - S), (0, 0))
        dt, x, Bm, Cm = (jnp.pad(t, padw) for t in (dt, x, Bm, Cm))
    nc = Sp // L
    grid = (B, di // bd, nc)

    y, h_last = pl.pallas_call(
        functools.partial(_kernel, chunk=L, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, L, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, L, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, L, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((bd, N), lambda b, d, c: (d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, bd, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(dt, x, Bm, Cm, A)
    return y[:, :S], h_last
