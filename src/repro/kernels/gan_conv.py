"""Gemm-based 4x4 / stride-2 conv kernels for the fleet-GAN engine.

XLA CPU lowers ``lax.conv_transpose`` through input dilation — three
quarters of the inner-product terms multiply inserted zeros, and the
data-gradient of a strided conv pays the same dilation tax — and lowers
a ``jax.vmap`` over per-client kernels to ``batch_group_count`` grouped
convolutions, which fall off the fast Eigen path entirely (measured ~4x
*slower* than the per-client loop on the 2-core container). Both facts
make the stacked cohort-axis GAN program (``fl.fleetgan``) unviable on
the conv primitives.

These kernels express the exact same linear maps as dense gemms over
phase-decomposed (sub-pixel) layouts:

- ``conv4x4_s2``: the input is split into its four stride-2 phases by a
  reshape, the 16 kernel taps become 16 cheaply shifted phase views
  concatenated on channels (im2col without strided slicing), and the
  conv is one ``(b*oh*ow, 16*ci) @ (16*ci, co)`` matmul. Elementwise
  identical sums to ``lax.conv_general_dilated`` (empirically bitwise
  on CPU), with a transpose that is pads/slices + one gemm — no
  dilation.
- ``convT4x4_s2``: lax semantics are ``out[2i+2-a, 2j+2-c] +=
  x[i,j] . w[a,c]``. For wide outputs, the four output phases are one
  fused gemm (phase kernels concatenated on the output axis) over four
  shifted input copies, interleaved by reshape. For narrow outputs
  (``co < 8``, e.g. the to-RGB layer, where the phase gemm degenerates
  to skinny-N / tiny-K matmuls) the contribution tensor
  ``x @ w (ci, 16co)`` is computed in one gemm and overlap-added into
  phases instead. Only the useful quarter of the FLOPs is computed.

Both are plain ``jnp`` programs, so autodiff yields gemm-based
transposes (the backward pass is where the conv primitives hurt most),
and a ``jax.vmap`` over a leading cohort axis of per-client kernels
lowers to batched gemms instead of grouped convolutions.

Shapes are NHWC with even spatial dims, kernels are HWIO ``(4, 4, ci,
co)``, stride 2, SAME padding — the only geometry the DCGAN in
``core.gan`` uses (32 -> 16 -> 8 -> 4 and back).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _phase_split(x):
    """(b, h, w, c) -> (b, 2, 2, h//2, w//2, c) stride-2 phase grid."""
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).transpose(0, 2, 4, 1,
                                                           3, 5)


# For output position i, a SAME-padded 4x4/stride-2 window covers input
# rows 2i-1 .. 2i+2: tap a lives in phase (a+1) % 2 at offset
# -1 / 0 / 0 / +1 — precomputed as tap index -> (phase, shift).
_TAP = {0: (1, -1), 1: (0, 0), 2: (1, 0), 3: (0, 1)}


def _im2col(x):
    """(b, h, w, ci) -> (b, h//2, w//2, 16*ci) patch matrix of the
    SAME-padded 4x4/stride-2 windows, tap-major (a, c, ci) to match
    ``w.reshape(16*ci, co)``."""
    b, h, ww, ci = x.shape
    oh, ow = h // 2, ww // 2
    ph = jnp.pad(_phase_split(x), ((0, 0), (0, 0), (0, 0), (1, 1),
                                   (1, 1), (0, 0)))
    taps = []
    for a in range(4):
        p, da = _TAP[a]
        for c in range(4):
            q, dc = _TAP[c]
            taps.append(lax.slice(
                ph, (0, p, q, 1 + da, 1 + dc, 0),
                (b, p + 1, q + 1, 1 + da + oh, 1 + dc + ow, ci)))
    return jnp.concatenate(taps, axis=-1).reshape(b, oh, ow, 16 * ci)


def _flip_T(w):
    """(4, 4, ci, co) -> spatially flipped, channel-transposed
    (4, 4, co, ci) — the kernel of the transposed linear map."""
    return w[::-1, ::-1].transpose(0, 1, 3, 2)


@jax.custom_vjp
def conv4x4_s2(x: jax.Array, w: jax.Array) -> jax.Array:
    """SAME, stride-2 correlation of ``x (b, h, w, ci)`` with ``w (4, 4,
    ci, co)`` -> ``(b, h//2, w//2, co)``; equals
    ``lax.conv_general_dilated`` with NHWC/HWIO layouts.

    Carries a hand-written VJP: autodiff through the im2col layout ops
    produces pathological pad/scatter chains on XLA CPU (measured ~3x
    the cost of the equivalent gemms), so the backward is expressed
    through the same gemm kernels — ``dx`` is the flipped
    ``convT4x4_s2``, ``dw`` one patch-matrix gemm.
    """
    b, h, ww, ci = x.shape
    kh, kw, wci, co = w.shape
    if (kh, kw) != (4, 4) or wci != ci or h % 2 or ww % 2:
        raise ValueError(f"conv4x4_s2 needs a 4x4 kernel on even dims, "
                         f"got x {x.shape} w {w.shape}")
    return _im2col(x) @ w.reshape(16 * ci, co)


def _conv_fwd(x, w):
    ci, co = w.shape[2], w.shape[3]
    cols = _im2col(x)
    # the patch matrix is the residual (it is what dw contracts
    # against); recomputing it in the backward costs more than carrying
    # it
    return cols @ w.reshape(16 * ci, co), (cols, w)


def _conv_bwd(res, g):
    cols, w = res
    ci, co = w.shape[2], w.shape[3]
    # dx[r] = sum_{i,a: 2i+a-1=r} g[i] . w[a]  ==  convT with the
    # flipped/transposed kernel (out[2i+2-a'] += g[i] . w[3-a'])
    dx = _convT(g, _flip_T(w))
    dw = (cols.reshape(-1, 16 * ci).T @ g.reshape(-1, co)
          ).reshape(4, 4, ci, co)
    return dx, dw


conv4x4_s2.defvjp(_conv_fwd, _conv_bwd)


def _convT_phase(x, w, co):
    """convT as one gemm over shifted copies: the four output-phase
    kernels concatenated on the output axis."""
    b, h, ww, ci = x.shape
    H, W = h + 1, ww + 1
    xs = jnp.concatenate(
        [jnp.pad(x, ((0, 0), (s, 1 - s), (t, 1 - t), (0, 0)))
         for s in (0, 1) for t in (0, 1)], axis=-1)
    wt = jnp.concatenate([
        jnp.concatenate([w[3 - (p + 2 * s), 3 - (q + 2 * t)]
                         for s in (0, 1) for t in (0, 1)], axis=0)
        for p in (0, 1) for q in (0, 1)], axis=1)     # (4ci, 4co)
    g = (xs @ wt).reshape(b, H, W, 2, 2, co)
    return g.transpose(0, 1, 3, 2, 4, 5).reshape(b, 2 * H, 2 * W, co)


def _convT_contrib(x, w, co):
    """convT via the contribution tensor ``x @ w (ci, 16co)`` (one gemm
    with a healthy contraction dim even when ``co`` is tiny) overlap-
    added into output phases."""
    b, h, ww, ci = x.shape
    H, W = h + 1, ww + 1
    contrib = (x @ w.transpose(2, 0, 1, 3).reshape(ci, 16 * co)
               ).reshape(b, h, ww, 4, 4, co)
    phases = []
    for p in (0, 1):
        for q in (0, 1):
            acc = 0
            for s in (0, 1):
                for t in (0, 1):
                    acc = acc + jnp.pad(
                        contrib[:, :, :, 3 - (p + 2 * s),
                                3 - (q + 2 * t), :],
                        ((0, 0), (s, 1 - s), (t, 1 - t), (0, 0)))
            phases.append(acc)
    g = jnp.stack(phases, axis=3).reshape(b, H, W, 2, 2, co)
    return g.transpose(0, 1, 3, 2, 4, 5).reshape(b, 2 * H, 2 * W, co)


def _convT(x, w):
    """Raw convT forward (no vjp wrapping; also the ``dx`` kernel of
    ``conv4x4_s2``)."""
    b, h, ww, ci = x.shape
    co = w.shape[3]
    form = _convT_contrib if co < 8 else _convT_phase
    g = form(x, w, co)
    return g[:, 1:2 * h + 1, 1:2 * ww + 1, :]


def _im2col_T(g):
    """Patch matrix of the *transposed* map: for ``g (b, 2h, 2w, co)``
    returns ``(b, h, w, 16*co)`` whose tap-(a, c) block is
    ``g_pad[2i+2-a, 2j+2-c]`` — the strided gather the convT weight
    gradient contracts against."""
    b, H2, W2, co = g.shape
    h, w = H2 // 2, W2 // 2
    ph = _phase_split(jnp.pad(g, ((0, 0), (1, 1), (1, 1), (0, 0))))
    taps = []
    # tap a gathers rows 2i+3-a of the padded grid: phase (3-a) % 2,
    # phase-row offset (3-a) // 2
    for a in range(4):
        p, s = (3 - a) % 2, (3 - a) // 2
        for c in range(4):
            q, t = (3 - c) % 2, (3 - c) // 2
            taps.append(lax.slice(
                ph, (0, p, q, s, t, 0),
                (b, p + 1, q + 1, s + h, t + w, co)))
    return jnp.concatenate(taps, axis=-1).reshape(b, h, w, 16 * co)


@jax.custom_vjp
def convT4x4_s2(x: jax.Array, w: jax.Array) -> jax.Array:
    """SAME, stride-2 transposed convolution of ``x (b, h, w, ci)`` with
    ``w (4, 4, ci, co)`` -> ``(b, 2h, 2w, co)``; equals
    ``lax.conv_transpose`` (``transpose_kernel=False``) with NHWC/HWIO
    layouts up to gemm re-association (~1 ulp).

    Hand-written VJP, like ``conv4x4_s2``: ``dx`` is the flipped
    stride-2 conv, ``dw`` one transposed-patch gemm — all expressed
    through the same gemm kernels instead of autodiff's pad/scatter
    chains.
    """
    b, h, ww, ci = x.shape
    kh, kw, wci, co = w.shape
    if (kh, kw) != (4, 4) or wci != ci:
        raise ValueError(f"convT4x4_s2 needs a 4x4 kernel, got x "
                         f"{x.shape} w {w.shape}")
    return _convT(x, w)


def _convT_fwd(x, w):
    return convT4x4_s2(x, w), (x, w)


def _convT_bwd(res, g):
    x, w = res
    ci, co = w.shape[2], w.shape[3]
    # dx[i] = sum_a g[2i+2-a] . w[a]  ==  stride-2 conv of g with the
    # flipped/transposed kernel
    dx = _im2col(g) @ _flip_T(w).reshape(16 * co, ci)
    # dw[a] = sum_i x[i] (x) g[2i+2-a]
    dw = (x.reshape(-1, ci).T @ _im2col_T(g).reshape(-1, 16 * co)
          ).reshape(ci, 4, 4, co).transpose(1, 2, 0, 3)
    return dx, dw


convT4x4_s2.defvjp(_convT_fwd, _convT_bwd)


# ---------------------------------------------------------------------
# int8 quantized-compute variants (GANConfig.conv_impl="gemm_int8"):
# the *same* phase-decomposed gemm forms, but every matmul quantizes
# both operands blockwise to int8 along the contraction dim, multiplies
# in int8->int32, and accumulates the scaled block partials in fp32 —
# training *with* quantized matmuls (QA-LoRA-style quantized compute),
# not merely quantized uplink. Gradients flow straight-through: the
# custom VJPs express dx/dw through the identical quantized gemms over
# the true cotangents (the round-to-int8 step itself has zero gradient
# almost everywhere, as usual for quantization-aware training).
# ---------------------------------------------------------------------
INT8_BLOCK = 64


def _q8_rows(x, blk):
    """(M, K) -> int8 codes (M, G, blk) + f32 absmax scales (M, G),
    blockwise along the contraction dim (zero-padded to a block
    multiple; pad columns quantize to exact zeros)."""
    M, K = x.shape
    Kp = -(-K // blk) * blk
    if Kp != K:
        x = jnp.pad(x, ((0, 0), (0, Kp - K)))
    xg = x.reshape(M, Kp // blk, blk)
    s = jnp.max(jnp.abs(xg), axis=-1) / 127.0
    safe = jnp.where(s == 0, 1.0, s)
    q = jnp.clip(jnp.round(xg / safe[..., None]), -127, 127)
    return q.astype(jnp.int8), s


def quant_gemm_int8(x: jax.Array, w: jax.Array,
                    blk: int = INT8_BLOCK) -> jax.Array:
    """Quantized-compute ``x (M, K) @ w (K, N) -> (M, N) f32``: both
    operands blockwise-int8 along K (per-row × per-column absmax
    scales), int8×int8→int32 block products, fp32 accumulation of the
    scaled partials. A ``lax.scan`` over the K-blocks bounds live
    memory to one (M, N) accumulator."""
    M, K = x.shape
    if w.shape[0] != K:
        raise ValueError(f"contraction mismatch: x {x.shape} w {w.shape}")
    N = w.shape[1]
    b = min(blk, K)
    qx, sx = _q8_rows(x.astype(jnp.float32), b)       # (M, G, b), (M, G)
    qw, sw = _q8_rows(w.astype(jnp.float32).T, b)     # (N, G, b), (N, G)

    def step(acc, g):
        p = lax.dot_general(qx[:, g], qw[:, g],
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.int32)
        return acc + p.astype(jnp.float32) * sx[:, g, None] * \
            sw[None, :, g], None

    acc, _ = lax.scan(step, jnp.zeros((M, N), jnp.float32),
                      jnp.arange(qx.shape[1]))
    return acc


def _convT_q8(x, w):
    """``_convT`` with the inner gemm quantized (int8 compute)."""
    b, h, ww, ci = x.shape
    co = w.shape[3]
    H, W = h + 1, ww + 1
    if co < 8:
        contrib = quant_gemm_int8(
            x.reshape(-1, ci),
            w.transpose(2, 0, 1, 3).reshape(ci, 16 * co)
        ).reshape(b, h, ww, 4, 4, co)
        phases = []
        for p in (0, 1):
            for q in (0, 1):
                acc = 0
                for s in (0, 1):
                    for t in (0, 1):
                        acc = acc + jnp.pad(
                            contrib[:, :, :, 3 - (p + 2 * s),
                                    3 - (q + 2 * t), :],
                            ((0, 0), (s, 1 - s), (t, 1 - t), (0, 0)))
                phases.append(acc)
        g = jnp.stack(phases, axis=3).reshape(b, H, W, 2, 2, co)
    else:
        xs = jnp.concatenate(
            [jnp.pad(x, ((0, 0), (s, 1 - s), (t, 1 - t), (0, 0)))
             for s in (0, 1) for t in (0, 1)], axis=-1)
        wt = jnp.concatenate([
            jnp.concatenate([w[3 - (p + 2 * s), 3 - (q + 2 * t)]
                             for s in (0, 1) for t in (0, 1)], axis=0)
            for p in (0, 1) for q in (0, 1)], axis=1)   # (4ci, 4co)
        g = quant_gemm_int8(xs.reshape(-1, 4 * ci), wt) \
            .reshape(b, H, W, 2, 2, co)
    g = g.transpose(0, 1, 3, 2, 4, 5).reshape(b, 2 * H, 2 * W, co)
    return g[:, 1:2 * h + 1, 1:2 * ww + 1, :]


@jax.custom_vjp
def conv4x4_s2_int8(x: jax.Array, w: jax.Array) -> jax.Array:
    """``conv4x4_s2`` with the patch-matrix gemm in int8 quantized
    compute (fp32 accumulation). Same shapes/geometry contract."""
    b, h, ww, ci = x.shape
    kh, kw, wci, co = w.shape
    if (kh, kw) != (4, 4) or wci != ci or h % 2 or ww % 2:
        raise ValueError(f"conv4x4_s2_int8 needs a 4x4 kernel on even "
                         f"dims, got x {x.shape} w {w.shape}")
    cols = _im2col(x)
    return quant_gemm_int8(cols.reshape(-1, 16 * ci),
                           w.reshape(16 * ci, co)) \
        .reshape(b, h // 2, ww // 2, co).astype(x.dtype)


def _conv_i8_fwd(x, w):
    return conv4x4_s2_int8(x, w), (x, w)


def _conv_i8_bwd(res, g):
    x, w = res
    ci, co = w.shape[2], w.shape[3]
    dx = _convT_q8(g, _flip_T(w)).astype(x.dtype)
    cols = _im2col(x)
    dw = quant_gemm_int8(cols.reshape(-1, 16 * ci).T,
                         g.reshape(-1, co).astype(jnp.float32)) \
        .reshape(4, 4, ci, co).astype(w.dtype)
    return dx, dw


conv4x4_s2_int8.defvjp(_conv_i8_fwd, _conv_i8_bwd)


@jax.custom_vjp
def convT4x4_s2_int8(x: jax.Array, w: jax.Array) -> jax.Array:
    """``convT4x4_s2`` with the phase/contribution gemm in int8
    quantized compute (fp32 accumulation)."""
    b, h, ww, ci = x.shape
    kh, kw, wci, co = w.shape
    if (kh, kw) != (4, 4) or wci != ci:
        raise ValueError(f"convT4x4_s2_int8 needs a 4x4 kernel, got x "
                         f"{x.shape} w {w.shape}")
    return _convT_q8(x, w).astype(x.dtype)


def _convT_i8_fwd(x, w):
    return convT4x4_s2_int8(x, w), (x, w)


def _convT_i8_bwd(res, g):
    x, w = res
    ci, co = w.shape[2], w.shape[3]
    dx = quant_gemm_int8(_im2col(g).reshape(-1, 16 * co),
                         _flip_T(w).reshape(16 * co, ci)) \
        .reshape(x.shape).astype(x.dtype)
    dw = quant_gemm_int8(x.reshape(-1, ci).T.astype(jnp.float32),
                         _im2col_T(g).reshape(-1, 16 * co)) \
        .reshape(ci, 4, 4, co).transpose(1, 2, 0, 3).astype(w.dtype)
    return dx, dw


convT4x4_s2_int8.defvjp(_convT_i8_fwd, _convT_i8_bwd)
