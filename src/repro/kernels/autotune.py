"""Block-shape autotuning for the Pallas kernels.

The fused kernels (``quant_matmul``, ``lora_matmul``) take static
``block_m``/``block_n`` tile shapes; the right choice depends on the
backend and the problem geometry. This harness sweeps a candidate list
once per ``(backend, kernel, shape-bucket)`` and caches the winner —
keyed like the :class:`~repro.fl.runtime.ProgramRuntime` executable
cache (kind + static config + a bucketed argument-shape signature), in
process *and* persisted as JSON (``REPRO_AUTOTUNE_CACHE``, default
``~/.cache/repro/autotune.json``) so later processes start warm.

Contract (pinned by tests/test_kernels.py and the CI smoke):

- ``lookup`` never sweeps — it returns the cached winner or the
  default, so hot paths pay a dict probe, not a compile.
- ``sweep`` on a cached key is a pure hit: no timing, no compiles, no
  ledger charge — a repeated sweep adds *zero* compiles to the runtime
  ledger.
- sweep wall-clock (compiles + timing runs) is charged to the compile
  ledger (``ProgramRuntime.charge``) under ``autotune_<kernel>``, so
  ``History.meta``-style accounting sees tuning cost exactly where it
  sees compile cost.

The M (row) dimension buckets to powers of two (the same bucketing the
cohort runtime applies to widths) so a ragged row-count sweep shares
one tuning entry; K/N/bits/mode are exact — they change the kernel's
inner tiling, not just its trip count.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax

# candidate (block_m, block_n) tiles per kernel — small, curated lists:
# the sweep cost is real compile time, charged to the ledger
CANDIDATES: Dict[str, Tuple[Tuple[int, int], ...]] = {
    "quant_matmul": ((64, 128), (128, 128), (128, 256), (256, 256)),
    "lora_matmul": ((64, 128), (128, 128), (128, 256), (256, 256)),
}
DEFAULT_BLOCKS: Tuple[int, int] = (256, 256)

_CACHE: Dict[str, Tuple[int, int]] = {}
_LOADED: set = set()


def cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"))


def _pow2_bucket(n: int) -> int:
    return 1 << (max(1, int(n)) - 1).bit_length()


def key_for(kernel: str, M: int, K: int, N: int, *, bits: int = 0,
            mode: str = "", backend: Optional[str] = None) -> str:
    """Cache key: backend + kernel + bucketed shape signature (the
    in-process analogue of the ProgramRuntime ``(kind, static_key,
    arg-sig)`` tuple, flattened to a JSON-safe string)."""
    backend = backend or jax.default_backend()
    return "/".join((backend, kernel, f"M{_pow2_bucket(M)}", f"K{K}",
                     f"N{N}", f"b{bits}{mode}"))


def _load(path: str) -> None:
    if path in _LOADED:
        return
    _LOADED.add(path)
    try:
        with open(path) as f:
            disk = json.load(f)
    except (OSError, ValueError):
        return
    for k, v in disk.items():
        _CACHE.setdefault(k, (int(v[0]), int(v[1])))


def _save(path: str) -> None:
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({k: list(v) for k, v in sorted(_CACHE.items())},
                      f, indent=1)
    except OSError:
        pass                      # persistence is best-effort


def clear(*, in_process_only: bool = True) -> None:
    """Drop the in-process cache (tests); the JSON file is left alone
    unless ``in_process_only=False``."""
    _CACHE.clear()
    _LOADED.clear()
    if not in_process_only:
        try:
            os.remove(cache_path())
        except OSError:
            pass


def lookup(kernel: str, M: int, K: int, N: int, *, bits: int = 0,
           mode: str = "", default: Tuple[int, int] = DEFAULT_BLOCKS,
           path: Optional[str] = None) -> Tuple[int, int]:
    """Cached winner for this shape bucket, or ``default``. Never
    sweeps, never compiles — safe on the hot dispatch path."""
    path = path or cache_path()
    _load(path)
    return _CACHE.get(key_for(kernel, M, K, N, bits=bits, mode=mode),
                      default)


@dataclass
class SweepResult:
    key: str
    best: Tuple[int, int]
    swept: bool              # False = cache hit (zero new compiles)
    n_candidates: int
    time_s: float
    timings: Dict[str, float]


def sweep(kernel: str, build: Callable[[int, int], Callable[[], object]],
          M: int, K: int, N: int, *, bits: int = 0, mode: str = "",
          candidates: Optional[Sequence[Tuple[int, int]]] = None,
          runtime=None, path: Optional[str] = None,
          iters: int = 2) -> SweepResult:
    """Time ``build(block_m, block_n)()`` over the candidate tiles and
    cache the fastest for this ``(backend, kernel, shape-bucket)`` key.

    ``build`` returns a zero-arg thunk running the kernel at that tile
    (closing over its operands); the first call per candidate pays the
    compile, then ``iters`` calls are timed. A key already cached (in
    process or in the JSON file) returns immediately — all-hits, zero
    compiles, zero ledger charge. Otherwise total sweep wall-clock is
    charged to ``runtime``'s compile ledger as ``autotune_<kernel>``.
    """
    path = path or cache_path()
    _load(path)
    key = key_for(kernel, M, K, N, bits=bits, mode=mode)
    hit = _CACHE.get(key)
    if hit is not None:
        return SweepResult(key=key, best=hit, swept=False,
                           n_candidates=0, time_s=0.0, timings={})
    cands = tuple(candidates if candidates is not None
                  else CANDIDATES.get(kernel, (DEFAULT_BLOCKS,)))
    if not cands:
        raise ValueError(f"empty candidate list for {kernel}")
    t_sweep0 = time.perf_counter()
    timings: Dict[str, float] = {}
    best, best_t = None, float("inf")
    for bm, bn in cands:
        fn = build(int(bm), int(bn))
        out = fn()                                   # compile + warm
        jax.block_until_ready(jax.tree.leaves(out))
        t0 = time.perf_counter()
        for _ in range(max(1, iters)):
            out = fn()
        jax.block_until_ready(jax.tree.leaves(out))
        dt = (time.perf_counter() - t0) / max(1, iters)
        timings[f"{bm}x{bn}"] = dt
        if dt < best_t:
            best, best_t = (int(bm), int(bn)), dt
    total = time.perf_counter() - t_sweep0
    _CACHE[key] = best
    _save(path)
    if runtime is not None:
        runtime.charge(f"autotune_{kernel}", total, n=len(cands))
    return SweepResult(key=key, best=best, swept=True,
                       n_candidates=len(cands), time_s=total,
                       timings=timings)
