"""jit-ready wrappers around the attention / quantization hot spots.

Dispatch:
- Pallas TPU kernels when running on TPU (or interpret mode when forced);
- under a production mesh Runtime, an explicit ``shard_map`` distribution
  (batch → dp axes, query heads padded to the ``model`` axis, KV expanded
  per local head; decode uses flash-decoding log-sum-exp combination over
  the slot-sharded cache) — relying on GSPMD propagation through the
  blocked-softmax scan replicates K/V across the batch axis, which is
  exactly the failure the explicit mapping removes;
- plain jnp reference otherwise (unit tests, CPU examples).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core import quant as qlib
from repro.kernels import ref
from repro.models import runtime as rt_lib

_FORCE = os.environ.get("REPRO_PALLAS", "")  # "interpret" | "tpu" | ""


def _use_pallas() -> bool:
    return _FORCE in ("interpret", "tpu") or jax.default_backend() == "tpu"


def _interpret() -> bool:
    return _FORCE == "interpret" or jax.default_backend() != "tpu"


def _kernel_flash(q, k, v, *, causal, window, q_chunk=512, k_chunk=512):
    if _use_pallas():
        from repro.kernels import flash_attention as fk
        return fk.flash_attention(q, k, v, causal=causal, window=window,
                                  interpret=_interpret())
    return ref.flash_attention(q, k, v, causal=causal, window=window,
                               q_chunk=q_chunk, k_chunk=k_chunk)


def flash_attention(q, k, v, *, causal=True, window=None,
                    q_chunk=512, k_chunk=512):
    rt = rt_lib.get_runtime()
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    if rt is None:
        return _kernel_flash(q, k, v, causal=causal, window=window,
                             q_chunk=q_chunk, k_chunk=k_chunk)
    mesh, m, dp = rt.mesh, rt.tp_size, rt.dp_axes
    dp_sz = rt.dp_size
    if B % dp_sz:
        dp, dp_sz = (), 1
    G = H // Hkv
    Hp = -(-H // m) * m
    if Hp != H:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Hp - H), (0, 0)))
    Hl = Hp // m

    def local(q_l, k_l, v_l):
        r = lax.axis_index(rt.tp_axis)
        gids = r * Hl + jnp.arange(Hl)
        kv_ids = jnp.clip(gids, 0, H - 1) // G
        k_e = jnp.take(k_l, kv_ids, axis=2)
        v_e = jnp.take(v_l, kv_ids, axis=2)
        return _kernel_flash(q_l, k_e, v_e, causal=causal, window=window,
                             q_chunk=q_chunk, k_chunk=k_chunk)

    out = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp or None, None, rt.tp_axis, None),
                  P(dp or None, None, None, None),
                  P(dp or None, None, None, None)),
        out_specs=P(dp or None, None, rt.tp_axis, None),
        check_vma=False)(q, k, v)
    return out[:, :, :H]


def decode_attention(q, k_cache, v_cache, slot_pos):
    rt = rt_lib.get_runtime()
    B, _, H, D = q.shape
    M = k_cache.shape[1]
    if rt is None or M % rt.tp_size:
        return ref.decode_attention(q, k_cache, v_cache, slot_pos)
    mesh, dp = rt.mesh, rt.dp_axes
    if B % rt.dp_size:
        dp = ()

    def local(q_l, k_l, v_l, sp_l):
        mi, li, acci = ref.decode_attention_partial(q_l, k_l, v_l, sp_l)
        mg = lax.pmax(mi, rt.tp_axis)
        corr = jnp.exp(mi - mg)
        lg = lax.psum(li * corr, rt.tp_axis)
        accg = lax.psum(acci * corr[..., None], rt.tp_axis)
        out = accg / jnp.maximum(lg, 1e-30)[..., None]
        Bl = q_l.shape[0]
        return out.reshape(Bl, 1, H, D).astype(q_l.dtype)

    return compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp or None, None, None, None),
                  P(dp or None, rt.tp_axis, None, None),
                  P(dp or None, rt.tp_axis, None, None),
                  P(None, rt.tp_axis)),
        out_specs=P(dp or None, None, None, None),
        check_vma=False)(q, k_cache, v_cache, slot_pos)


def selective_scan(dt, x, Bm, Cm, A):
    """Mamba-1 recurrence: Pallas on TPU, chunked associative scan on CPU
    (models/ssm.py calls this from inside its shard_map body)."""
    if _use_pallas():
        from repro.kernels import selective_scan as sk
        return sk.selective_scan(dt, x, Bm, Cm, A,
                                 interpret=_interpret())
    return None  # caller falls back to its chunked associative scan


def quant_matmul(x, qt: qlib.QTensor):
    # qt.q.ndim == 3 means a plain 2-D weight: (G, block[/2], N)
    if _use_pallas() and qt.q.ndim == 3:
        from repro.kernels import quant_matmul as qk
        return qk.quant_matmul(x, qt, interpret=_interpret())
    return ref.quant_matmul(x, qt)


def blockwise_quant(x, *, bits=8, block=128, mode="linear"):
    if _use_pallas() and x.ndim == 2 and mode != "nf4":
        from repro.kernels import blockwise_quant as bk
        return bk.blockwise_quant(x, bits=bits, block=block,
                                  interpret=_interpret())
    return ref.blockwise_quant(x, bits=bits, block=block, mode=mode)
