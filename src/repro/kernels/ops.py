"""jit-ready wrappers around the attention / quantization hot spots.

Dispatch:
- Pallas TPU kernels when running on TPU (or interpret mode when forced);
- under a production mesh Runtime, an explicit ``shard_map`` distribution
  (batch → dp axes, query heads padded to the ``model`` axis, KV expanded
  per local head; decode uses flash-decoding log-sum-exp combination over
  the slot-sharded cache) — relying on GSPMD propagation through the
  blocked-softmax scan replicates K/V across the batch axis, which is
  exactly the failure the explicit mapping removes;
- plain jnp reference otherwise (unit tests, CPU examples).
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core import quant as qlib
from repro.kernels import autotune, ref
from repro.models import runtime as rt_lib

_FORCE = os.environ.get("REPRO_PALLAS", "")  # "interpret" | "tpu" | ""


def _use_pallas() -> bool:
    return _FORCE in ("interpret", "tpu") or jax.default_backend() == "tpu"


def _interpret() -> bool:
    return _FORCE == "interpret" or jax.default_backend() != "tpu"


# -- trace-time path counters ------------------------------------------
# Incremented when a dispatch wrapper *traces* (once per compile, not per
# step), so CI can assert which implementation a program actually took —
# the "no silent fallback" guard: reset, build the program, then check
# e.g. KERNEL_TRACES["lora_linear_fused"] > 0.
KERNEL_TRACES: dict = {}


def trace_count(name: str, n: int = 1) -> None:
    KERNEL_TRACES[name] = KERNEL_TRACES.get(name, 0) + int(n)


def reset_kernel_traces() -> None:
    KERNEL_TRACES.clear()


def _kernel_flash(q, k, v, *, causal, window, q_chunk=512, k_chunk=512):
    if _use_pallas():
        from repro.kernels import flash_attention as fk
        return fk.flash_attention(q, k, v, causal=causal, window=window,
                                  interpret=_interpret())
    return ref.flash_attention(q, k, v, causal=causal, window=window,
                               q_chunk=q_chunk, k_chunk=k_chunk)


def flash_attention(q, k, v, *, causal=True, window=None,
                    q_chunk=512, k_chunk=512):
    rt = rt_lib.get_runtime()
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    if rt is None:
        return _kernel_flash(q, k, v, causal=causal, window=window,
                             q_chunk=q_chunk, k_chunk=k_chunk)
    mesh, m, dp = rt.mesh, rt.tp_size, rt.dp_axes
    dp_sz = rt.dp_size
    if B % dp_sz:
        dp, dp_sz = (), 1
    G = H // Hkv
    Hp = -(-H // m) * m
    if Hp != H:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Hp - H), (0, 0)))
    Hl = Hp // m

    def local(q_l, k_l, v_l):
        r = lax.axis_index(rt.tp_axis)
        gids = r * Hl + jnp.arange(Hl)
        kv_ids = jnp.clip(gids, 0, H - 1) // G
        k_e = jnp.take(k_l, kv_ids, axis=2)
        v_e = jnp.take(v_l, kv_ids, axis=2)
        return _kernel_flash(q_l, k_e, v_e, causal=causal, window=window,
                             q_chunk=q_chunk, k_chunk=k_chunk)

    out = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp or None, None, rt.tp_axis, None),
                  P(dp or None, None, None, None),
                  P(dp or None, None, None, None)),
        out_specs=P(dp or None, None, rt.tp_axis, None),
        check_vma=False)(q, k, v)
    return out[:, :, :H]


def decode_attention(q, k_cache, v_cache, slot_pos):
    rt = rt_lib.get_runtime()
    B, _, H, D = q.shape
    M = k_cache.shape[1]
    if rt is None or M % rt.tp_size:
        return ref.decode_attention(q, k_cache, v_cache, slot_pos)
    mesh, dp = rt.mesh, rt.dp_axes
    if B % rt.dp_size:
        dp = ()

    def local(q_l, k_l, v_l, sp_l):
        mi, li, acci = ref.decode_attention_partial(q_l, k_l, v_l, sp_l)
        mg = lax.pmax(mi, rt.tp_axis)
        corr = jnp.exp(mi - mg)
        lg = lax.psum(li * corr, rt.tp_axis)
        accg = lax.psum(acci * corr[..., None], rt.tp_axis)
        out = accg / jnp.maximum(lg, 1e-30)[..., None]
        Bl = q_l.shape[0]
        return out.reshape(Bl, 1, H, D).astype(q_l.dtype)

    return compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp or None, None, None, None),
                  P(dp or None, rt.tp_axis, None, None),
                  P(dp or None, rt.tp_axis, None, None),
                  P(None, rt.tp_axis)),
        out_specs=P(dp or None, None, None, None),
        check_vma=False)(q, k_cache, v_cache, slot_pos)


def selective_scan(dt, x, Bm, Cm, A):
    """Mamba-1 recurrence: Pallas on TPU, chunked associative scan on CPU
    (models/ssm.py calls this from inside its shard_map body)."""
    if _use_pallas():
        from repro.kernels import selective_scan as sk
        return sk.selective_scan(dt, x, Bm, Cm, A,
                                 interpret=_interpret())
    return None  # caller falls back to its chunked associative scan


def quant_matmul(x, qt: qlib.QTensor):
    # qt.q.ndim == 3 means a plain 2-D weight: (G, block[/2], N)
    if _use_pallas():
        from repro.kernels import quant_matmul as qk
        if qt.q.ndim == 3:
            K, N = x.shape[-1], qt.q.shape[-1]
            M = 1
            for s in x.shape[:-1]:
                M *= s
            bm, bn = autotune.lookup("quant_matmul", M, K, N,
                                     bits=qt.bits, mode=qt.mode)
            trace_count("quant_matmul_pallas")
            return qk.quant_matmul(x, qt, block_m=bm, block_n=bn,
                                   interpret=_interpret())
        if qt.q.ndim == 4:
            # stacked (per-client) QTensor — the serve plane's vmapped
            # per-tenant slabs: vmap the Pallas kernel over the stack
            # axis of both operands (x: (T, [M,] K); qt.q: (T, G, ·, N))
            if x.shape[0] != qt.q.shape[0]:
                raise ValueError(
                    f"stacked quant_matmul needs matching stack dims: "
                    f"x {x.shape} vs qt.q {qt.q.shape}")
            trace_count("quant_matmul_pallas_stacked")
            fn = partial(qk.quant_matmul, interpret=_interpret())
            return jax.vmap(fn)(x, qt)
        # >1 stack axis has no Pallas mapping yet; with Pallas forced a
        # silent ref fallback would hide exactly the regression the CI
        # guards look for, so report it loudly instead.
        raise NotImplementedError(
            f"quant_matmul: no Pallas path for qt.q.ndim={qt.q.ndim} "
            "(>1 stack axis); flatten the stack axes or unset "
            "REPRO_PALLAS to take kernels.ref explicitly")
    trace_count("quant_matmul_ref")
    return ref.quant_matmul(x, qt)


# -- fused LoRA matmul (the QLoRA arm's whole linear layer) ------------
def _lora_fwd_impl(scale, x, w, a, b):
    if isinstance(w, qlib.QTensor) and _use_pallas() and w.q.ndim == 3:
        from repro.kernels import lora_matmul as lk
        K, N = x.shape[-1], w.q.shape[-1]
        M = 1
        for s in x.shape[:-1]:
            M *= s
        bm, bn = autotune.lookup("lora_matmul", M, K, N, bits=w.bits,
                                 mode=w.mode)
        trace_count("lora_matmul_pallas")
        return lk.lora_matmul(x, w, a, b, scale=scale, block_m=bm,
                              block_n=bn, interpret=_interpret())
    trace_count("lora_matmul_ref")
    return ref.lora_matmul(x, w, a, b, scale=scale)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _lora_mm(scale, x, w, a, b):
    return _lora_fwd_impl(scale, x, w, a, b)


def _lora_fwd(scale, x, w, a, b):
    if isinstance(w, qlib.QTensor) and \
            not (_use_pallas() and w.q.ndim == 3):
        # ref path: the forward materializes the dequantized weight
        # anyway, so save it as a residual — the backward's Wᵀ gemm then
        # reuses it instead of re-dequantizing (exactly what autodiff of
        # the einsum chain would do)
        trace_count("lora_matmul_ref")
        wd = qlib.dequantize(w, jnp.float32)[:x.shape[-1]]
        return ref.lora_matmul(x, wd, a, b, scale=scale), \
            (x, w, a, b, wd)
    return _lora_fwd_impl(scale, x, w, a, b), (x, w, a, b, None)


def _lora_bwd(scale, res, g):
    x, w, a, b, wd = res
    K = x.shape[-1]
    x2 = x.reshape(-1, K).astype(jnp.float32)
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    gb = g2 @ bf.T                                   # (M, r)
    if isinstance(w, qlib.QTensor):
        if wd is not None:
            dxw = g2 @ wd.T                          # (M, K) exactly
        elif _use_pallas() and w.q.ndim == 3:
            from repro.kernels import lora_matmul as lk
            dxw = lk.quant_matmul_t(g2, w,
                                    interpret=_interpret())[:, :K]
        else:
            dxw = (g2 @ qlib.dequantize(w, jnp.float32).T)[:, :K]
        # the quantized payload is not differentiable: int8/uint8 codes
        # take a float0 cotangent, the f32 scales a symbolic zero
        import numpy as np
        dw = dataclasses.replace(
            w, q=np.zeros(w.q.shape, jax.dtypes.float0),
            scales=jnp.zeros_like(w.scales))
    else:
        wf = w.astype(jnp.float32)
        dxw = g2 @ wf.T
        dw = (x2.T @ g2).astype(w.dtype)
    dx = (dxw + scale * gb @ af.T).reshape(x.shape).astype(x.dtype)
    da = (scale * (x2.T @ gb)).astype(a.dtype)
    db = (scale * ((x2 @ af).T @ g2)).astype(b.dtype)
    return dx, dw, da, db


_lora_mm.defvjp(_lora_fwd, _lora_bwd)


def lora_matmul(x, w, a, b, *, scale: float):
    """``y = x @ W + scale·(x@A)@B`` as ONE op with fp32 accumulation
    and a custom VJP (dx through Wᵀ + BᵀAᵀ, dA/dB through the same
    tiled gemms). ``w`` may be a QTensor — streamed quantized through
    the fused Pallas kernel on TPU/interpret, ``kernels.ref`` (also
    fp32-fused) elsewhere — or a dense matrix."""
    return _lora_mm(float(scale), x, w, a, b)


def blockwise_quant(x, *, bits=8, block=128, mode="linear"):
    if _use_pallas() and x.ndim == 2 and mode != "nf4":
        from repro.kernels import blockwise_quant as bk
        return bk.blockwise_quant(x, bits=bits, block=block,
                                  interpret=_interpret())
    return ref.blockwise_quant(x, bits=bits, block=block, mode=mode)
