"""Pallas TPU fused LoRA matmul — the QLoRA arm's whole linear layer
in one kernel (§III-C).

Computes ``y = x @ dequant(W_q) + scale * (x @ A) @ B`` without ever
materializing the dequantized weight: the quantized tiles stream
HBM→VMEM and are dequantized in-register exactly as in
``kernels.quant_matmul`` (the shared ``dequant_tile``), while the LoRA
factors ride the same grid — A is blocked along the contraction dim by
the quant groups (an ``(bm, r)`` f32 VMEM scratch accumulates ``x @ A``
alongside the main ``(bm, bn)`` accumulator), and B joins at the final
group with one tiny ``(r, bn)`` gemm before the flush. All accumulation
is fp32.

``quant_matmul_t`` is the backward-pass companion: ``g @ dequant(W)ᵀ``
through the same streamed tiles (grid minormost over the N blocks, the
output tile indexed by quant group), which is the ``dx``-through-Wᵀ
gemm of the custom VJP in ``kernels.ops.lora_matmul``.

TARGET: TPU. Validated with interpret=True vs ``kernels/ref.py``
(``ref.lora_matmul`` — also the CPU execution path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import NF4_CODE, QTensor
from repro.kernels.quant_matmul import dequant_tile


def _lora_kernel(x_ref, q_ref, s_ref, code_ref, a_ref, b_ref, o_ref,
                 acc_ref, h_ref, *, bits, mode, ng, scale):
    gi = pl.program_id(2)

    @pl.when(gi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...].astype(jnp.float32)              # (bm, block)
    w = dequant_tile(q_ref, s_ref, code_ref, bits=bits, mode=mode)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    h_ref[...] += jax.lax.dot_general(              # (bm, r) += x @ A_g
        x, a_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(gi == ng - 1)
    def _flush():
        delta = jax.lax.dot_general(                # (bm, bn) = h @ B
            h_ref[...], b_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * delta).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_m",
                                             "block_n", "interpret"))
def lora_matmul(x, qt: QTensor, a, b, *, scale: float, block_m=256,
                block_n=256, interpret=False):
    """``x (..., K) @ dequant(qt (K, N)) + scale·(x@A)@B -> (..., N)``
    in one kernel. ``qt`` may cover a K zero-padded to a block multiple
    (the odd-K ``blockwise_quant`` contract) — x and A zero-pad rows to
    match, which contracts identically. ``a``: (K, r); ``b``: (r, N)."""
    *lead, K = x.shape
    M = 1
    for s in lead:
        M *= s
    x2 = x.reshape(M, K)
    Kq = qt.q.shape[0] * qt.block
    if Kq != K:
        if Kq < K or (Kq - K) >= qt.block:
            raise ValueError(
                f"quantized contraction dim {Kq} incompatible with "
                f"x's {K} (block {qt.block})")
        x2 = jnp.pad(x2, ((0, 0), (0, Kq - K)))
    if a.shape[0] != K:
        raise ValueError(f"LoRA A rows {a.shape[0]} != contraction {K}")
    a2 = jnp.pad(a, ((0, Kq - K), (0, 0))) if Kq != K else a
    G = qt.q.shape[0]
    N = qt.q.shape[-1]
    r = a.shape[-1]
    block = qt.block
    bm = min(block_m, max(8, M))
    bn = min(block_n, N)
    Mp = -(-M // bm) * bm
    Np = -(-N // bn) * bn
    if Mp != M:
        x2 = jnp.pad(x2, ((0, Mp - M), (0, 0)))
    qv, sv, b2 = qt.q, qt.scales, b
    if Np != N:
        qv = jnp.pad(qv, ((0, 0), (0, 0), (0, Np - N)))
        sv = jnp.pad(sv, ((0, 0), (0, 0), (0, Np - N)))
        b2 = jnp.pad(b, ((0, 0), (0, Np - N)))
    rows = qv.shape[1]                     # block or block//2 (packed)
    grid = (Mp // bm, Np // bn, G)

    code = jnp.asarray(NF4_CODE).reshape(1, 16)
    out = pl.pallas_call(
        functools.partial(_lora_kernel, bits=qt.bits, mode=qt.mode,
                          ng=G, scale=float(scale)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, block), lambda mi, ni, gi: (mi, gi)),
            pl.BlockSpec((1, rows, bn), lambda mi, ni, gi: (gi, 0, ni)),
            pl.BlockSpec((1, 1, bn), lambda mi, ni, gi: (gi, 0, ni)),
            pl.BlockSpec((1, 16), lambda mi, ni, gi: (0, 0)),
            pl.BlockSpec((block, r), lambda mi, ni, gi: (gi, 0)),
            pl.BlockSpec((r, bn), lambda mi, ni, gi: (0, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, gi: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, r), jnp.float32)],
        interpret=interpret,
    )(x2, qv, sv, code, a2, b2)
    return out[:M, :N].reshape(*lead, N)


def _t_kernel(g_ref, q_ref, s_ref, code_ref, o_ref, acc_ref, *, bits,
              mode, nn):
    ni = pl.program_id(2)

    @pl.when(ni == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)              # (bm, bn)
    w = dequant_tile(q_ref, s_ref, code_ref, bits=bits, mode=mode)
    acc_ref[...] += jax.lax.dot_general(            # (bm, block) += g @ wᵀ
        g, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ni == nn - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def quant_matmul_t(g, qt: QTensor, *, block_m=256, block_n=256,
                   interpret=False):
    """``g (..., N) @ dequant(qt (K, N))ᵀ -> (..., Kq)`` — the
    transposed contraction of ``quant_matmul``, streaming the identical
    quantized tiles (the dx gemm of the fused LoRA VJP). The output
    covers the padded Kq; callers slice ``[..., :K]``."""
    *lead, N = g.shape
    M = 1
    for s in lead:
        M *= s
    g2 = g.reshape(M, N)
    if N != qt.q.shape[-1]:
        raise ValueError(
            f"contraction dim {N} != quantized N {qt.q.shape[-1]}")
    G = qt.q.shape[0]
    block = qt.block
    Kq = G * block
    bm = min(block_m, max(8, M))
    bn = min(block_n, N)
    Mp = -(-M // bm) * bm
    Np = -(-N // bn) * bn
    if Mp != M:
        g2 = jnp.pad(g2, ((0, Mp - M), (0, 0)))
    qv, sv = qt.q, qt.scales
    if Np != N:
        # pad columns with zero *scales*: padded columns then dequantize
        # to exact zeros and contract inertly
        qv = jnp.pad(qv, ((0, 0), (0, 0), (0, Np - N)))
        sv = jnp.pad(sv, ((0, 0), (0, 0), (0, Np - N)))
        g2 = jnp.pad(g2, ((0, 0), (0, Np - N)))
    rows = qv.shape[1]
    grid = (Mp // bm, G, Np // bn)

    code = jnp.asarray(NF4_CODE).reshape(1, 16)
    out = pl.pallas_call(
        functools.partial(_t_kernel, bits=qt.bits, mode=qt.mode,
                          nn=Np // bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda mi, gi, ni: (mi, ni)),
            pl.BlockSpec((1, rows, bn), lambda mi, gi, ni: (gi, 0, ni)),
            pl.BlockSpec((1, 1, bn), lambda mi, gi, ni: (gi, 0, ni)),
            pl.BlockSpec((1, 16), lambda mi, gi, ni: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, block), lambda mi, gi, ni: (mi, gi)),
        out_shape=jax.ShapeDtypeStruct((Mp, Kq), g.dtype),
        scratch_shapes=[pltpu.VMEM((bm, block), jnp.float32)],
        interpret=interpret,
    )(g2, qv, sv, code)
    return out[:M].reshape(*lead, Kq)
