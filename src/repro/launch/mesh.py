"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis only
carries data/client parallelism and the FL aggregation all-reduce, so the
slow DCN link between pods moves only compressed adapter/LoRA bytes
(TriplePlay's communication story — DESIGN.md §4).

A function, not a module constant: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)}. "
            "Set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (dryrun.py does this).")
    try:
        from jax.sharding import AxisType
        axis_types = (AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types, devices=devices)
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes, devices=devices)


def make_debug_mesh(shape=(1, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CPU multi-device tests (8 fake devices)."""
    n = 1
    for s in shape:
        n *= s
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes, (AxisType.Auto,) * len(axes),
                             devices=jax.devices()[:n])
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_data_mesh(n_shards: int = 0):
    """Data-parallel-only mesh ``(data=n_shards,)`` over the host's
    devices — the mesh the cohort/fleet-GAN engines shard their stacked
    cohort axis over when there is no model parallelism in play
    (mesh-scale benchmarks, forced-8-device CI smokes). ``n_shards=0``
    takes every visible device."""
    devices = jax.devices()
    n = n_shards or len(devices)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for a (data={n}) mesh; have "
            f"{len(devices)}. Set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "before importing jax.")
    try:
        from jax.sharding import AxisType
        return jax.make_mesh((n,), ("data",), (AxisType.Auto,),
                             devices=devices[:n])
    except (ImportError, TypeError):
        return jax.make_mesh((n,), ("data",), devices=devices[:n])


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def cohort_sharding(mesh, ndim: int):
    """NamedSharding splitting a leading cohort (client) axis across the
    mesh's data-parallel axes, everything else replicated. The cohort
    engine device_puts its staged pools / stacked trainables with this so
    a single jitted round spreads clients over the mesh (pjit partitions
    the vmapped local-training program along the cohort axis)."""
    from jax.sharding import NamedSharding, PartitionSpec
    dp = dp_axes(mesh)
    return NamedSharding(
        mesh, PartitionSpec(dp if dp else None, *([None] * (ndim - 1))))


def replicated_sharding(mesh):
    """Fully-replicated NamedSharding on ``mesh``. The cohort engine
    device_puts the global trainables with this before a sharded round:
    a round's OUTPUT trainables come back mesh-replicated, so without
    canonicalizing the first (host-resident) input the sharding-aware
    runtime cache would compile the same round twice — once for the
    host placement, once for the steady-state chained placement."""
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec())


def cohort_axis_size(mesh) -> int:
    """Number of mesh shards along the cohort (data-parallel) axes."""
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n
