"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against the production mesh with ShapeDtypeStruct stand-ins
(no allocation), and record memory / FLOP / collective statistics for
EXPERIMENTS.md §Dry-run and the roofline analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.jsonl
"""
# The placeholder-device flag must be set before jax initializes devices —
# keep these as the very first executable lines (per the dry-run contract).
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, get_config
from repro.core import compat
from repro.core import optim
from repro.launch import shardings as sh
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import build_model
from repro.models import runtime as rt_lib

# long_500k needs sub-quadratic attention (see DESIGN.md §4): run for the
# SSM / hybrid / SWA architectures, skip for pure full-attention archs.
LONG_OK = {"falcon-mamba-7b", "recurrentgemma-2b", "h2o-danube-3-4b"}

_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
             "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
             "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
             "c128": 16}
_COLL_RE = re.compile(
    r"=\s*(\(?[^)]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_BRACES_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective traffic by op kind, from the partitioned HLO.

    Bytes are the HLO *output* buffer sizes per op; the roofline applies
    op-specific ring factors (see benchmarks/roofline.py)."""
    stats: dict = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes):
            if dt not in _DT_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DT_BYTES[dt]
        g = _GROUP_RE.search(line)
        if g:
            gsize = int(g.group(2))
        else:
            g2 = _GROUP_BRACES_RE.search(line)
            gsize = len(g2.group(1).split(",")) if g2 else 0
        e = stats.setdefault(kind, {"count": 0, "bytes": 0, "gsize": 0})
        e["count"] += 1
        e["bytes"] += nbytes
        e["gsize"] = max(e["gsize"], gsize)
    return stats


def lower_step(arch: str, shape_name: str, *, multi_pod: bool,
               quant_bits: int = 0, quant_mode: str = "linear",
               seq_shard: bool = True, remat: bool = True,
               kv_quant: int = 0, grad_accum: int = 1,
               trainable_dtype: str = "", extra_cfg=None,
               cfg_override=None):
    """Returns (lowered, model, cfg, mesh) for one combination."""
    shape = INPUT_SHAPES[shape_name]
    cfg = cfg_override or get_config(arch)
    if quant_bits and not cfg.quant_bits:
        cfg = cfg.replace(quant_bits=quant_bits, quant_mode=quant_mode)
    if kv_quant and not cfg.kv_quant_bits:
        cfg = cfg.replace(kv_quant_bits=kv_quant)
    if grad_accum > 1:
        cfg = cfg.replace(grad_accum=grad_accum)
    if trainable_dtype:
        cfg = cfg.replace(trainable_dtype=trainable_dtype)
    if extra_cfg:
        cfg = cfg.replace(**extra_cfg)
    cfg = cfg.replace(seq_shard=seq_shard, remat=remat)
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_axes(mesh)
    rt = rt_lib.Runtime(mesh=mesh, dp_axes=dp, tp_axis="model")
    model = build_model(cfg)

    with rt_lib.runtime(rt), mesh:
        specs = model.param_specs()
        pspec = sh.param_specs_tree(cfg, specs, mesh)
        psh = sh.to_shardings(mesh, pspec)
        batch = model.input_specs(shape)
        if shape.kind == "train":
            bsh = sh.to_shardings(
                mesh, sh.batch_specs_tree(cfg, batch, mesh, dp))
            opt = optim.adam_specs(specs["trainable"])
            osh = jax.tree.map(
                lambda _: jax.NamedSharding(mesh, P()), opt)

            def fn(frozen, trainable, opt_state, b):
                return model.train_step(frozen, trainable, opt_state, b)

            lowered = jax.jit(fn, in_shardings=(
                psh["frozen"], psh["trainable"], osh, bsh)).lower(
                    specs["frozen"], specs["trainable"], opt, batch)
        elif shape.kind == "prefill":
            bsh = sh.to_shardings(
                mesh, sh.batch_specs_tree(cfg, batch, mesh, dp))

            def fn(frozen, trainable, b):
                return model.prefill(frozen, trainable, b)

            lowered = jax.jit(fn, in_shardings=(
                psh["frozen"], psh["trainable"], bsh)).lower(
                    specs["frozen"], specs["trainable"], batch)
        else:  # decode
            cache = batch["cache"]
            csh = sh.to_shardings(
                mesh, sh.cache_specs_tree(cfg, cache, mesh, dp))
            tsh = sh.to_shardings(
                mesh, sh.batch_specs_tree(
                    cfg, {"tokens": batch["tokens"]}, mesh, dp))["tokens"]

            def fn(frozen, trainable, cache, tokens, pos):
                return model.decode_step(frozen, trainable, cache, tokens,
                                         pos)

            lowered = jax.jit(fn, in_shardings=(
                psh["frozen"], psh["trainable"], csh, tsh,
                jax.NamedSharding(mesh, P()))).lower(
                    specs["frozen"], specs["trainable"], cache,
                    batch["tokens"], batch["pos"])
    return lowered, model, cfg, mesh


def calibrated_costs(arch: str, shape_name: str, *, multi_pod: bool,
                     quant_bits: int = 0, quant_mode: str = "linear",
                     seq_shard: bool = True, remat: bool = True,
                     kv_quant: int = 0, grad_accum: int = 1,
                     trainable_dtype: str = "", extra_cfg=None) -> dict:
    """True per-step cost estimates.

    XLA's cost_analysis counts each while-loop body ONCE regardless of trip
    count, so the scanned/blocked production graphs undercount FLOPs by
    ~n_layers×. Calibration lowers two small variants with the layer stack
    UNROLLED and every inner loop removed (single-tile attention, one-chunk
    recurrent scans, batched expert einsum — cfg.calibrate), then
    extrapolates linearly in depth:  cost(L) = c1 + (c2 - c1)·(reps - 1).
    """
    base = get_config(arch)
    if quant_bits:
        base = base.replace(quant_bits=quant_bits, quant_mode=quant_mode)
    if kv_quant:
        base = base.replace(kv_quant_bits=kv_quant)
    if grad_accum > 1:
        base = base.replace(grad_accum=grad_accum)
    if trainable_dtype:
        base = base.replace(trainable_dtype=trainable_dtype)
    if extra_cfg:
        base = base.replace(**extra_cfg)
    pat = len(base.attn_pattern)
    reps_true = (base.n_layers - base.first_k_dense) / pat

    def one(reps):
        # grad_accum adds a microbatch scan (another uncounted loop), and
        # an A-way accumulated step costs ~= the single-shot step, so
        # calibration always runs accum=1.
        cfg = base.replace(
            n_layers=base.first_k_dense + reps * pat,
            encoder_layers=(reps * pat if base.encoder_layers else 0),
            unroll_layers=True, calibrate=True, grad_accum=1)
        lowered, *_ = lower_step(
            arch, shape_name, multi_pod=multi_pod, seq_shard=seq_shard,
            remat=remat, cfg_override=cfg)
        compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        coll = parse_collectives(compiled.as_text())
        return (float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)), coll)

    f1, b1, c1 = one(1)
    f2, b2, c2 = one(2)
    ex = lambda a, b: a + (b - a) * (reps_true - 1)
    coll = {}
    for kind in set(c1) | set(c2):
        e1 = c1.get(kind, {"count": 0, "bytes": 0, "gsize": 0})
        e2 = c2.get(kind, {"count": 0, "bytes": 0, "gsize": 0})
        coll[kind] = {
            "count": int(round(ex(e1["count"], e2["count"]))),
            "bytes": float(ex(e1["bytes"], e2["bytes"])),
            "gsize": max(e1["gsize"], e2["gsize"]),
        }
    return {"hlo_flops_cal": ex(f1, f2), "hlo_bytes_cal": ex(b1, b2),
            "collectives_cal": coll}


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            quant_bits: int = 0, quant_mode: str = "linear",
            seq_shard: bool = True, remat: bool = True,
            kv_quant: int = 0, grad_accum: int = 1,
            trainable_dtype: str = "", extra_cfg=None,
            verbose: bool = True, calibrate: bool = True) -> dict:
    t0 = time.time()
    lowered, model, cfg, mesh = lower_step(
        arch, shape_name, multi_pod=multi_pod, quant_bits=quant_bits,
        quant_mode=quant_mode, seq_shard=seq_shard, remat=remat,
        kv_quant=kv_quant, grad_accum=grad_accum,
        trainable_dtype=trainable_dtype, extra_cfg=extra_cfg)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    shape = INPUT_SHAPES[shape_name]
    n_active = cfg.param_count(active_only=True)
    n_total = cfg.param_count()
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "quant_bits": quant_bits, "quant_mode": quant_mode,
        "seq_shard": seq_shard, "remat": remat,
        "kv_quant": kv_quant, "grad_accum": grad_accum,
        "trainable_dtype": trainable_dtype or "float32",
        "extra_cfg": extra_cfg or {},
        "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "hlo_flops": float(cost.get("flops", 0.0)),
        "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "code_bytes": int(mem.generated_code_size_in_bytes),
        "collectives": coll,
        "params_total": n_total, "params_active": n_active,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    if calibrate:
        try:
            rec.update(calibrated_costs(
                arch, shape_name, multi_pod=multi_pod,
                quant_bits=quant_bits, quant_mode=quant_mode,
                seq_shard=seq_shard, remat=remat, kv_quant=kv_quant,
                grad_accum=grad_accum, trainable_dtype=trainable_dtype,
                extra_cfg=extra_cfg))
        except Exception as e:  # noqa: BLE001
            rec["calibration_error"] = repr(e)[:300]
    if verbose:
        print(f"== {arch} × {shape_name} × {rec['mesh']}"
              f"{' q' + str(quant_bits) if quant_bits else ''} ==")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}"
              f"GiB out={mem.output_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB (per device)")
        print(f"  cost_analysis: flops={rec['hlo_flops']:.3e} "
              f"bytes={rec['hlo_bytes']:.3e} (per device, loop bodies 1x)")
        if "hlo_flops_cal" in rec:
            print(f"  calibrated:   flops={rec['hlo_flops_cal']:.3e} "
                  f"bytes={rec['hlo_bytes_cal']:.3e} (per device)")
        print(f"  collectives: " + (", ".join(
            f"{k}:{v['count']}x {v['bytes']/2**20:.1f}MiB"
            for k, v in coll.items()) or "none"))
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s",
              flush=True)
    return rec


def fed_agg_dryrun(arch: str, *, multi_pod: bool = True,
                   comm_bits: int = 8) -> dict:
    """Lower + compile the federated aggregation step at production scale:
    every (pod, data) slice holds one client's (optionally quantized)
    LoRA+adapter delta; the server average is a weighted psum over the
    client axes — cross-pod DCN carries only these compressed bytes,
    which is TriplePlay's communication claim (paper Eq. w_final).
    """
    from jax.sharding import NamedSharding
    from repro.core.quant import qtensor_specs

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_axes(mesh)
    n_clients = 1
    for a in dp:
        n_clients *= mesh.shape[a]
    model = build_model(cfg)
    tr = model.param_specs()["trainable"]

    def stack(s):
        # per-client quantization of ≥2-D leaves (blocks along the leaf's
        # own contraction dim; the client dim is a lead dim)
        if comm_bits and len(s.shape) >= 2 and \
                int(np.prod(s.shape)) >= 256:
            return qtensor_specs((n_clients, *s.shape), jnp.float32,
                                 bits=comm_bits, block=64)
        return jax.ShapeDtypeStruct((n_clients, *s.shape), jnp.float32)

    stacked = jax.tree.map(stack, tr)
    weights = jax.ShapeDtypeStruct((n_clients,), jnp.float32)

    from repro.core.quant import QTensor, dequantize

    def leaf_weighted(l, w):
        d = dequantize(l, jnp.float32) if isinstance(l, QTensor) else l
        return jnp.einsum("c...,c->...", d.astype(jnp.float32),
                          w / jnp.sum(w))

    def fed_agg_psum(deltas, w):
        """GSPMD reduction over the client-sharded dim. NOTE: XLA must
        dequantize before it can sum -> the all-reduce moves f32 bytes
        regardless of the payload dtype (measured; see EXPERIMENTS §Perf
        FL-level) — quantized FL aggregation needs a gather schedule."""
        return jax.tree.map(lambda l: leaf_weighted(l, w), deltas,
                            is_leaf=lambda l: isinstance(l, QTensor))

    def fed_agg_gather(deltas, w):
        """shard_map: all-gather the (int8) payloads over the client axes
        — compressed bytes on the wire — then dequantize + weighted-sum
        locally (what a real FL server/hierarchical aggregator does)."""
        def local(d_loc, w_full):
            g = jax.tree.map(
                lambda l: jax.lax.all_gather(l, dp, axis=0, tiled=True),
                d_loc)
            return jax.tree.map(lambda l: leaf_weighted(l, w_full), g,
                                is_leaf=lambda l: isinstance(l, QTensor))
        in_specs = (jax.tree.map(
            lambda l: P(dp) if not isinstance(l, QTensor) else
            QTensor(q=P(dp), scales=P(dp), bits=l.bits, mode=l.mode,
                    block=l.block, out_dtype=l.out_dtype,
                    orig_shape=l.orig_shape),
            stacked, is_leaf=lambda l: isinstance(l, QTensor)), P())
        out_specs = jax.tree.map(
            lambda l: P(), jax.eval_shape(
                lambda d, w: fed_agg_psum(d, w), stacked, weights))
        return compat.shard_map(local, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)(
                                 deltas, w)

    def fed_agg_hier(deltas, w):
        """Hierarchical: weighted f32 psum within each pod (fast ICI),
        then int8 re-quantized exchange ACROSS pods only — the scarce
        DCN link carries compressed bytes. Requires the multi-pod mesh."""
        def local(d_loc, w_full):
            r_pod = jax.lax.axis_index("pod")
            r_data = jax.lax.axis_index("data")
            cid = r_pod * mesh.shape["data"] + r_data
            wi = jnp.take(w_full, cid)

            def one(l):
                d = dequantize(l, jnp.float32)[0] if isinstance(
                    l, QTensor) else l.astype(jnp.float32)[0]
                pod_sum = jax.lax.psum(d * wi, "data")     # ICI, f32
                flat = pod_sum.reshape(-1)
                pad = (-flat.size) % 64
                flat = jnp.pad(flat, (0, pad)).reshape(-1, 64)
                s = jnp.maximum(jnp.abs(flat).max(-1, keepdims=True),
                                1e-12) / 127.0
                q = jnp.clip(jnp.round(flat / s), -127,
                             127).astype(jnp.int8)
                qg = jax.lax.all_gather(q, "pod")          # DCN, int8
                sg = jax.lax.all_gather(s, "pod")
                tot = (qg.astype(jnp.float32) * sg).sum(0)
                return tot.reshape(-1)[:pod_sum.size].reshape(
                    pod_sum.shape) / jnp.sum(w_full)
            return jax.tree.map(one, d_loc,
                                is_leaf=lambda l: isinstance(l, QTensor))
        in_specs = (jax.tree.map(
            lambda l: P(dp) if not isinstance(l, QTensor) else
            QTensor(q=P(dp), scales=P(dp), bits=l.bits, mode=l.mode,
                    block=l.block, out_dtype=l.out_dtype,
                    orig_shape=l.orig_shape),
            stacked, is_leaf=lambda l: isinstance(l, QTensor)), P())
        out_specs = jax.tree.map(
            lambda l: P(), jax.eval_shape(fed_agg_psum, stacked, weights))
        return compat.shard_map(local, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)(
                                 deltas, w)

    dsh = jax.tree.map(
        lambda _: NamedSharding(mesh, P(dp)), stacked,
        is_leaf=lambda l: isinstance(l, jax.ShapeDtypeStruct))
    out = {"arch": arch, "comm_bits": comm_bits, "n_clients": n_clients}
    schedules = [("psum", fed_agg_psum), ("gather", fed_agg_gather)]
    if multi_pod:
        schedules.append(("hierarchical", fed_agg_hier))
    with mesh:
        for sched, fn in schedules:
            lowered = jax.jit(fn, in_shardings=(
                dsh, NamedSharding(mesh, P()))).lower(stacked, weights)
            compiled = lowered.compile()
            coll = parse_collectives(compiled.as_text())
            total = sum(v["bytes"] for v in coll.values())
            # cross-pod (DCN) share: collectives whose groups span pods
            pod_sz = mesh.shape.get("pod", 1)
            cross = sum(v["bytes"] for v in coll.values()
                        if v.get("gsize", 0) in (pod_sz, n_clients)
                        and pod_sz > 1)
            out[f"collective_bytes_{sched}"] = total
            out[f"cross_pod_bytes_{sched}"] = cross
            print(f"fed-agg {arch} "
                  f"({'2x16x16' if multi_pod else '16x16'}, "
                  f"{n_clients} clients, comm_bits={comm_bits}, "
                  f"{sched}): wire={total/2**20:.1f}MiB/device "
                  f"cross-pod={cross/2**20:.1f}MiB")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--quant", type=int, default=0, choices=[0, 4, 8])
    ap.add_argument("--quant-mode", default="linear",
                    choices=["linear", "nf4"])
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--kv-quant", type=int, default=0, choices=[0, 8])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--all", action="store_true",
                    help="full sweep: every arch × shape")
    ap.add_argument("--fed-agg", action="store_true",
                    help="lower the federated aggregation step instead")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    if args.fed_agg:
        archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
        for arch in archs:
            for bits in (0, args.quant or 8):
                rec = fed_agg_dryrun(
                    arch, multi_pod=args.mesh != "single", comm_bits=bits)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
        return

    archs = list(ARCHS) if args.arch == "all" or args.all else \
        args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" or args.all else \
        args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records, failures = [], []
    for arch in archs:
        for shape in shapes:
            if shape == "long_500k" and arch not in LONG_OK:
                print(f"-- skip {arch} × long_500k (full attention; "
                      "see DESIGN.md §4)", flush=True)
                continue
            for mp in meshes:
                try:
                    rec = run_one(arch, shape, multi_pod=mp,
                                  quant_bits=args.quant,
                                  quant_mode=args.quant_mode,
                                  seq_shard=not args.no_seq_shard,
                                  remat=not args.no_remat,
                                  kv_quant=args.kv_quant,
                                  grad_accum=args.grad_accum)
                    records.append(rec)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps(rec) + "\n")
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)[:500]))
                    print(f"!! FAIL {arch} × {shape} × "
                          f"{'multi' if mp else 'single'}: {e!r}"[:600],
                          flush=True)
    print(f"\n{len(records)} ok, {len(failures)} failed")
    for f in failures:
        print("  FAIL", f)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
