"""Sharding rules: param/input/cache PartitionSpecs for the production mesh.

Megatron-style tensor parallelism over ``model`` with contraction-dim
fallback when a head/vocab dim doesn't divide (llava's 56 heads), FSDP-style
2-D sharding for MoE experts (E→model, last dim→data — must match
``moe.expert_partition_specs`` so jit arguments arrive exactly where the
shard_map expects them), sequence/slot sharding for long caches, and
replication for everything small (LoRA, adapter, norms, router — the
trainable set TriplePlay communicates).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.quant import QTensor

REPLICATED_FRAGMENTS = (
    "lora", "adapter", "ln", "norm", "router", "dt_bias", "a_log",
    "d_skip", "lam", "bias", "slot_pos")


def _div(n: int, m: int) -> bool:
    return n % m == 0


def _base_rule(cfg: ModelConfig, name: str, shape, m: int) -> P:
    """PartitionSpec for the *logical* (unquantized) 2-D weight."""
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    if name in ("embed",):
        V, d = shape
        if _div(V, m):
            return P("model", None)
        return P(None, "model") if _div(d, m) else P()
    if name in ("head",):
        d, V = shape
        if _div(V, m):
            return P(None, "model")
        return P("model", None) if _div(d, m) else P()
    if name in ("pos_embed", "enc_pos"):
        return P(None, "model") if _div(shape[-1], m) else P()
    if name in ("wq", "cwq"):
        return P(None, "model") if _div(H, m) else \
            (P("model", None) if _div(shape[0], m) else P())
    if name in ("wk", "wv", "cwk", "cwv"):
        return P(None, "model") if _div(Hkv, m) else P()  # kv small: replicate
    if name in ("wo", "cwo"):
        return P("model", None) if _div(H, m) else \
            (P(None, "model") if _div(shape[-1], m) else P())
    if name in ("wu", "wg", "w1"):
        return P(None, "model") if _div(shape[-1], m) else P()
    if name in ("wd", "w2"):
        return P("model", None) if _div(shape[0], m) else P()
    # fallback: shard the largest divisible dim
    dims = [None] * len(shape)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if _div(shape[i], m):
            dims[i] = "model"
            break
    return P(*dims)


def _lift_qtensor(spec: P, q_leaf, m: int) -> P:
    """Map a 2-D weight spec (K, N) onto QTensor storage (…, G, B, N).
    The contraction-dim sharding lands on the quant-group dim G when G
    divides the mesh; otherwise fall back to sharding N (GSPMD reshards
    the matmul accordingly — a storage-layout decision, not semantics)."""
    ndim = len(q_leaf.shape)
    lead = ndim - 3
    G, N = q_leaf.shape[lead], q_leaf.shape[-1]
    sK = spec[0] if len(spec) > 0 else None
    sN = spec[1] if len(spec) > 1 else None
    dims = [None] * ndim
    if sK is not None and G % m == 0:
        dims[lead] = sK
    elif sK is not None and sN is None and N % m == 0:
        dims[-1] = sK          # fall back: shard the output dim instead
    if sN is not None and N % m == 0:
        dims[-1] = sN
    return P(*dims)


def _recurrent_rules(cfg: ModelConfig, m: int):
    """Exact-name specs for Mamba / RG-LRU leaves — these MUST match the
    shard_map in_specs inside models/ssm.py and models/rglru.py."""
    from repro.models.rglru import GATE_BLOCKS, rglru_partition_specs
    from repro.models.ssm import mamba_partition_specs
    rules = {}
    if cfg.family == "ssm" and cfg.d_inner % m == 0:
        rules.update(mamba_partition_specs(cfg, "model"))
    if cfg.family == "hybrid":
        w = cfg.lru_width or cfg.d_model
        if w % m == 0 and GATE_BLOCKS % m == 0:
            rules.update(rglru_partition_specs(cfg, "model"))
    return rules


def param_specs_tree(cfg: ModelConfig, params: Any, mesh: Mesh):
    """PartitionSpec tree for a (possibly quantized, possibly stacked)
    param tree. Works on real arrays or ShapeDtypeStructs."""
    m = mesh.shape["model"]
    recurrent = _recurrent_rules(cfg, m)

    def is_leaf(x):
        return isinstance(x, QTensor)

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=is_leaf)
    out = []
    for path, leaf in flat:
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        keys = [str(k) for k in keys]
        pstr = "/".join(keys).lower()
        name = next((k for k in reversed(keys)
                     if not k.isdigit() and k not in ("q", "scales", "a", "b")),
                    keys[-1] if keys else "")
        # recurrent-block leaves: module-owned specs (match shard_map)
        if name in recurrent and "lora" not in pstr:
            base = recurrent[name]
            if isinstance(leaf, QTensor):
                if len(base) == 2:
                    out.append(QTensor(
                        q=_lift_qtensor(base, leaf.q, m),
                        scales=_lift_qtensor(base, leaf.scales, m),
                        bits=leaf.bits, mode=leaf.mode, block=leaf.block,
                        out_dtype=leaf.out_dtype,
                        orig_shape=leaf.orig_shape))
                else:
                    out.append(jax.tree.map(lambda _: P(), leaf))
                continue
            pad = len(leaf.shape) - len(base)
            out.append(P(*([None] * pad), *base))
            continue
        # trainable / tiny leaves: replicated
        if any(f in pstr for f in REPLICATED_FRAGMENTS):
            if isinstance(leaf, QTensor):
                out.append(jax.tree.map(lambda _: P(), leaf))
                continue
            out.append(P())
            continue
        # MoE experts: E -> model, last dim -> data (matches shard_map specs)
        if "moe" in pstr and name in ("wg", "wu", "wd"):
            def espec(l):
                dims = [None] * len(l.shape)
                dims[1] = "model"   # (L, E, ...) stacked
                dims[-1] = "data"
                return P(*dims)
            if isinstance(leaf, QTensor):
                out.append(QTensor(q=espec(leaf.q), scales=espec(leaf.scales),
                                   bits=leaf.bits, mode=leaf.mode,
                                   block=leaf.block, out_dtype=leaf.out_dtype,
                                   orig_shape=leaf.orig_shape))
                continue
            out.append(espec(leaf))
            continue
        # stacked layers carry a leading L dim -> rule applies to the rest
        if isinstance(leaf, QTensor):
            base_shape = leaf.orig_shape[-2:]
            spec = _base_rule(cfg, name, base_shape, m)
            out.append(QTensor(
                q=_lift_qtensor(spec, leaf.q, m),
                scales=_lift_qtensor(spec, leaf.scales, m),
                bits=leaf.bits, mode=leaf.mode, block=leaf.block,
                out_dtype=leaf.out_dtype, orig_shape=leaf.orig_shape))
            continue
        shape = leaf.shape
        if len(shape) == 0 or min(shape) == 0:
            out.append(P())
            continue
        stacked = name not in ("embed", "head", "pos_embed", "enc_pos") and \
            len(shape) >= 3
        core = shape[1:] if stacked else shape
        if len(core) == 1:
            spec = P("model") if _div(core[0], m) and core[0] >= m and \
                name not in REPLICATED_FRAGMENTS else P()
        else:
            spec = _base_rule(cfg, name, core[-2:], m)
            if len(core) > 2:
                spec = P(*([None] * (len(core) - 2)), *spec)
        if stacked:
            spec = P(None, *spec)
        out.append(spec)
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_specs_tree(cfg: ModelConfig, batch: Any, mesh: Mesh, dp):
    """Input batch PartitionSpecs: batch dim over dp axes."""
    def spec(x):
        if len(x.shape) == 0:
            return P()
        B = x.shape[0]
        dp_sz = 1
        for a in dp:
            dp_sz *= mesh.shape[a]
        lead = dp if _div(B, dp_sz) else None
        return P(lead, *([None] * (len(x.shape) - 1)))
    return jax.tree.map(spec, batch)


def cache_specs_tree(cfg: ModelConfig, cache: Any, mesh: Mesh, dp):
    """KV/state cache PartitionSpecs: batch -> dp, slot/seq dim -> model."""
    m = mesh.shape["model"]
    dp_sz = 1
    for a in dp:
        dp_sz *= mesh.shape[a]

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in flat:
        keys = [str(getattr(k, "key", "")) for k in path]
        name = keys[-1]
        sh = leaf.shape
        if name == "slot_pos":
            M = sh[-1]
            lead = [None] * (len(sh) - 1)
            out.append(P(*lead, "model" if _div(M, m) else None))
            continue
        if "adapter" in keys:           # (B, M, h, dh)
            B, M = sh[0], sh[1]
            out.append(P(dp if _div(B, dp_sz) else None,
                         "model" if _div(M, m) else None, None, None))
            continue
        if name in ("k", "v", "k_scale", "v_scale"):  # (L, B, M, Hkv, D|1)
            B, M = sh[1], sh[2]
            out.append(P(None, dp if _div(B, dp_sz) else None,
                         "model" if _div(M, m) else None, None, None))
            continue
        if name == "h" and len(sh) == 4:      # ssm state (L, B, di, N)
            out.append(P(None, dp if _div(sh[1], dp_sz) else None,
                         "model" if _div(sh[2], m) else None, None))
            continue
        if name == "h" and len(sh) == 3:      # lru state (L, B, w)
            out.append(P(None, dp if _div(sh[1], dp_sz) else None,
                         "model" if _div(sh[2], m) else None))
            continue
        if name == "conv":              # (L, B, K-1, width)
            out.append(P(None, dp if _div(sh[1], dp_sz) else None, None,
                         "model" if _div(sh[-1], m) else None))
            continue
        out.append(P(*([None] * len(sh))))
    return jax.tree_util.tree_unflatten(treedef, out)


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda l: isinstance(l, P))
