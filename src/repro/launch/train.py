"""Federated training driver.

Runs TriplePlay federated fine-tuning of an assigned backbone: every FL
client holds a frozen (optionally NF4/int4-quantized) copy of the model and
trains only LoRA + adapter on its local token stream; each round the
quantized client deltas are weighted-averaged into the global trainables.

On this CPU container the driver runs REDUCED configs end-to-end (real
training); on hardware the same code paths run the full configs under the
production mesh (the dry-run proves those lower/compile — launch/dryrun.py).

Also exposes ``fed_round_spec`` — the aggregation step as a lowerable
program: local train step + psum of the (compressed) update over the
('pod','data') client axes, which is the cross-pod traffic TriplePlay
minimizes (DESIGN.md §4).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --rounds 3 \
      --clients 4 --local-steps 2 --quant 4
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core import optim
from repro.core.quant import dequantize_tree, quantize_tree, tree_bytes
from repro.models import build_model


def synthetic_token_stream(rng, vocab, n_clients, docs_per_client=64,
                           seq=128):
    """Per-client token corpora with client-specific n-gram statistics
    (non-IID: each client favours a different token sub-range)."""
    out = []
    for c in range(n_clients):
        lo = (c * vocab) // (2 * n_clients)
        hi = lo + vocab // 2
        toks = rng.randint(lo, hi, (docs_per_client, seq + 1))
        # inject structure: repeat bigrams so there is something to learn
        toks[:, 2::2] = toks[:, 1:-1:2]
        out.append(toks.astype(np.int32))
    return out


def local_steps_for(n_docs: int, *, base_steps: int, batch: int,
                    epochs: float = 0.0) -> int:
    """Per-client local step count — the cohort engine's epoch
    accounting (``Client.local_steps_for`` scales the configured steps
    by the client's compute profile) applied to the LLM token stream:
    ``epochs`` E > 0 sizes the round so the client covers its corpus E
    times at this batch size, so a data-rich client runs (and is
    *ledgered for*) proportionally more steps; E == 0 keeps the flat
    ``base_steps``."""
    if epochs <= 0:
        return int(base_steps)
    return max(1, -(-int(round(epochs * n_docs)) // int(batch)))


def client_update(model, frozen, global_tr, data, *, steps, batch, lr,
                  comm_bits, seed):
    """One client's local round; returns ``(delta, uplink_bytes, loss,
    n_steps, n_samples)`` — the step/sample counts feed the round
    ledger so multi-epoch local training is never under-counted."""
    rng = np.random.RandomState(seed)
    tr = global_tr
    opt = optim.adam_init(tr)
    loss = 0.0
    step_fn = jax.jit(lambda f, t, o, b: model.train_step(f, t, o, b,
                                                          lr=lr))
    for _ in range(steps):
        idx = rng.randint(0, len(data), batch)
        toks = jnp.asarray(data[idx])
        b = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "mask": jnp.ones(toks[:, 1:].shape, jnp.float32)}
        tr, opt, m = step_fn(frozen, tr, opt, b)
        loss = float(m["loss"])
    delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), tr,
                         global_tr)
    if comm_bits:
        delta = quantize_tree(delta, bits=comm_bits, block=64,
                              min_size=256, skip_names=("slot",))
    return delta, tree_bytes(delta), loss, int(steps), int(steps * batch)


def aggregate(global_tr, updates):
    total = sum(m for m, _ in updates)
    acc = None
    for m, d in updates:
        dd = dequantize_tree(d, jnp.float32)
        w = m / total
        acc = jax.tree.map(lambda x: w * x, dd) if acc is None else \
            jax.tree.map(lambda a, x: a + w * x, acc, dd)
    return jax.tree.map(lambda g, a: (g.astype(jnp.float32) + a).astype(
        g.dtype), global_tr, acc)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-epochs", type=float, default=0.0,
                    help="size each client's round to cover its corpus "
                         "this many times (cohort-engine epoch "
                         "accounting); 0 = flat --local-steps")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--quant", type=int, default=4, choices=[0, 4, 8],
                    help="backbone quantization bits (QLoRA)")
    ap.add_argument("--comm-bits", type=int, default=8, choices=[0, 4, 8])
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (non-reduced) architecture")
    ap.add_argument("--ckpt", default="",
                    help="checkpoint path; saves the FL server state every "
                         "round and resumes from it if present")
    args = ap.parse_args()

    cfg = (get_config if args.full_config else get_reduced)(args.arch)
    if args.quant:
        cfg = cfg.replace(quant_bits=args.quant, quant_mode="nf4",
                          quant_block=64)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    frozen, global_tr = params["frozen"], params["trainable"]
    frozen_bytes = tree_bytes(frozen)
    print(f"arch={cfg.name} family={cfg.family} "
          f"backbone={frozen_bytes/2**20:.1f}MiB "
          f"(quant_bits={cfg.quant_bits}) trainable="
          f"{tree_bytes(global_tr)/2**20:.2f}MiB")

    rng = np.random.RandomState(0)
    data = synthetic_token_stream(rng, cfg.vocab_size, args.clients,
                                  seq=args.seq)
    start_round = 0
    if args.ckpt and os.path.exists(args.ckpt):
        from repro.ckpt import restore_fl_state
        global_tr, _, start_round, _ = restore_fl_state(
            args.ckpt, like_trainable=global_tr)
        print(f"resumed from {args.ckpt} at round {start_round}")
    total_steps = total_samples = total_uplink = 0
    for rnd in range(start_round, args.rounds):
        t0 = time.time()
        updates, losses, payload = [], [], 0
        rnd_steps = rnd_samples = 0
        for c in range(args.clients):
            steps_c = local_steps_for(len(data[c]),
                                      base_steps=args.local_steps,
                                      batch=args.batch,
                                      epochs=args.local_epochs)
            d, nbytes, loss, n_steps, n_samples = client_update(
                model, frozen, global_tr, data[c], steps=steps_c,
                batch=args.batch, lr=args.lr, comm_bits=args.comm_bits,
                seed=rnd * 100 + c)
            updates.append((len(data[c]), d))
            losses.append(loss)
            payload += nbytes
            rnd_steps += n_steps
            rnd_samples += n_samples
        global_tr = aggregate(global_tr, updates)
        total_steps += rnd_steps
        total_samples += rnd_samples
        total_uplink += payload
        if args.ckpt:
            from repro.ckpt import save_fl_state
            save_fl_state(args.ckpt, round_idx=rnd + 1,
                          global_trainable=global_tr,
                          client_sizes=[len(d) for d in data])
        epochs_covered = rnd_samples / max(1, sum(len(d) for d in data))
        print(f"round {rnd}: mean client loss={np.mean(losses):.4f} "
              f"uplink={payload/2**20:.2f}MiB "
              f"local_steps={rnd_steps} epochs={epochs_covered:.2f} "
              f"({time.time()-t0:.1f}s)", flush=True)
    print(f"done: total_local_steps={total_steps} "
          f"total_samples={total_samples} "
          f"total_uplink={total_uplink/2**20:.2f}MiB")


if __name__ == "__main__":
    main()
