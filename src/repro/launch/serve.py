"""Batched serving driver: prefill a prompt batch, then autoregressive
decode against the ring KV/state cache — the serve_step the decode-shape
dry-runs lower at production scale.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-3-4b \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--quant", type=int, default=0, choices=[0, 4, 8])
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args()

    cfg = (get_config if args.full_config else get_reduced)(args.arch)
    if args.quant:
        cfg = cfg.replace(quant_bits=args.quant, quant_mode="nf4",
                          quant_block=64)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    frozen, tr = params["frozen"], params["trainable"]

    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G + (cfg.n_patches if cfg.family == "vlm" else 0)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (B, P)), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.randn(B, cfg.n_patches, cfg.d_model) * 0.02, jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.n_frames, cfg.d_model) * 0.02, jnp.float32)

    prefill = jax.jit(lambda f, t, b: model.prefill(f, t, b,
                                                    max_len=max_len))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = jax.block_until_ready(prefill(frozen, tr, batch))
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    pos0 = P + (cfg.n_patches if cfg.family == "vlm" else 0)
    t0 = time.time()
    for i in range(G - 1):
        logits, cache = decode(frozen, tr, cache, tok,
                               jnp.asarray(pos0 + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    toks = np.asarray(jnp.concatenate(out, 1))
    print(f"arch={cfg.name} batch={B} prompt={P} gen={G}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.1f} ms total, "
          f"{B*(G-1)/max(t_decode,1e-9):.0f} tok/s")
    print("sample token ids:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
