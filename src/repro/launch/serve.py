"""Batched serving driver: prefill a prompt batch, then autoregressive
decode against the ring KV/state cache — the serve_step the decode-shape
dry-runs lower at production scale.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-3-4b \
      --batch 4 --prompt-len 64 --gen 32
  PYTHONPATH=src python -m repro.launch.serve --adapters 8 --requests 48

``--adapters N`` switches into the personalized-adapter serving plane
(:mod:`repro.fl.serve`): train N per-user adapter trees, replay a
Zipf/diurnal request trace through the multi-tenant batched engine, and
print virtual-latency percentiles plus cache/compile ledgers.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import build_model


def select_token(logits, *, greedy: bool, temperature: float = 1.0,
                 key=None):
    """One decode-step token choice over ``logits (B, V)``: argmax when
    ``greedy``, else temperature-scaled categorical sampling (requires a
    PRNG ``key``). Returns ``(B, 1) int32``."""
    if greedy:
        tok = jnp.argmax(logits, -1)
    else:
        if key is None:
            raise ValueError("sampling needs a PRNG key")
        if temperature <= 0:
            raise ValueError("temperature must be > 0 when sampling")
        tok = jax.random.categorical(key, logits / temperature, axis=-1)
    return tok[:, None].astype(jnp.int32)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--quant", type=int, default=0, choices=[0, 4, 8])
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--greedy", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="argmax decode (default); --no-greedy samples")
    ap.add_argument("--temperature", type=float, default=1.0,
                    help="sampling temperature (with --no-greedy)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--adapters", type=int, default=0, metavar="N",
                    help="serve N personalized adapter tenants instead "
                         "of the token-decode path")
    ap.add_argument("--requests", type=int, default=64,
                    help="trace length for --adapters mode")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="serve flight cap for --adapters mode")
    ap.add_argument("--cache-entries", type=int, default=0,
                    help="adapter-cache capacity (0 = full population)")
    return ap


def run_adapter_mode(args) -> None:
    from repro.fl import serve as serve_lib

    n = args.adapters
    cap = args.cache_entries or None
    plane = serve_lib.demo_plane(
        n, mixed=n >= 2, seed=args.seed, quant_bits=args.quant or 8,
        max_entries=cap, max_batch=args.max_batch)
    trace = serve_lib.zipf_request_trace(
        n, args.requests, seed=args.seed, rate=200.0, period=1.0,
        amplitude=0.5)
    images = serve_lib.request_images(plane, trace, seed=args.seed)
    rec = serve_lib.replay(plane["engine"], trace, images)
    st = plane["store"].stats()
    print(f"adapters={n} requests={rec['n_requests']} "
          f"concurrency={rec['concurrency']} trace={rec['trace']}")
    print(f"flights={rec['n_flights']} "
          f"lat_v p50={rec['lat_v_p50']*1e3:.2f}ms "
          f"p99={rec['lat_v_p99']*1e3:.2f}ms "
          f"throughput={rec['throughput_v']:.0f} req/vs")
    print(f"cache: hits={st['hits']} misses={st['misses']} "
          f"evictions={st['evictions']} "
          f"hit_rate={rec['store']['hit_rate']:.2f} "
          f"bytes_at_rest={plane['store'].bytes_at_rest()}")
    for kind, row in sorted(plane["runtime"].stats().items()):
        print(f"ledger {kind}: {row}")


def main():
    args = build_parser().parse_args()
    if args.adapters:
        run_adapter_mode(args)
        return

    cfg = (get_config if args.full_config else get_reduced)(args.arch)
    if args.quant:
        cfg = cfg.replace(quant_bits=args.quant, quant_mode="nf4",
                          quant_block=64)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    frozen, tr = params["frozen"], params["trainable"]

    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G + (cfg.n_patches if cfg.family == "vlm" else 0)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (B, P)), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.randn(B, cfg.n_patches, cfg.d_model) * 0.02, jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.n_frames, cfg.d_model) * 0.02, jnp.float32)

    prefill = jax.jit(lambda f, t, b: model.prefill(f, t, b,
                                                    max_len=max_len))
    decode = jax.jit(model.decode_step)
    key = jax.random.PRNGKey(args.seed)

    t0 = time.time()
    logits, cache = jax.block_until_ready(prefill(frozen, tr, batch))
    t_prefill = time.time() - t0
    key, k = jax.random.split(key)
    tok = select_token(logits, greedy=args.greedy,
                       temperature=args.temperature, key=k)
    out = [tok]
    pos0 = P + (cfg.n_patches if cfg.family == "vlm" else 0)
    t0 = time.time()
    for i in range(G - 1):
        logits, cache = decode(frozen, tr, cache, tok,
                               jnp.asarray(pos0 + i, jnp.int32))
        key, k = jax.random.split(key)
        tok = select_token(logits, greedy=args.greedy,
                           temperature=args.temperature, key=k)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    toks = np.asarray(jnp.concatenate(out, 1))
    mode = "greedy" if args.greedy else \
        f"sample(T={args.temperature:g})"
    print(f"arch={cfg.name} batch={B} prompt={P} gen={G} mode={mode}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.1f} ms total, "
          f"{B*(G-1)/max(t_decode,1e-9):.0f} tok/s")
    print("sample token ids:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
