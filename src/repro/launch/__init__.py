# Launchers: mesh construction, sharding rules, the multi-pod dry-run,
# and the FL training / serving drivers.
