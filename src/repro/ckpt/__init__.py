from repro.ckpt.checkpoint import (load_checkpoint, restore_fl_state,
                                   save_checkpoint, save_fl_state)  # noqa
