"""Checkpointing: param/optimizer/FL-round state to disk and back.

Pure-numpy .npz container (no orbax offline) with a JSON manifest:
- arbitrary pytrees of jax/np arrays, including quantized ``QTensor``
  leaves (their payload/scales/metadata round-trip exactly — a QLoRA
  backbone checkpoint stays int4/NF4 on disk);
- atomic writes (tmp + rename), integrity check via per-leaf shapes;
- FL server state = round counter + global trainables + per-client sample
  counts, so a federated run resumes mid-protocol.

Sharded arrays are pulled to host before saving (checkpoints are taken
from the replicated trainable set in FL — the backbone is frozen and
reproducible from seed+quantization, but can be checkpointed too).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import numpy as np

from repro.core.quant import QTensor

_SEP = "/"
_QMETA_KEYS = ("bits", "mode", "block", "orig_shape")


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    arrays: Dict[str, np.ndarray] = {}
    meta: Dict[str, Any] = {"qtensors": {}, "dtypes": {}}
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda l: isinstance(l, QTensor))
    meta["treedef"] = str(treedef)
    paths = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        paths.append(key)
        if isinstance(leaf, QTensor):
            arrays[key + ".q"] = np.asarray(leaf.q)
            arrays[key + ".scales"] = np.asarray(leaf.scales)
            meta["qtensors"][key] = {
                "bits": leaf.bits, "mode": leaf.mode, "block": leaf.block,
                "orig_shape": list(leaf.orig_shape),
                "out_dtype": np.dtype(leaf.out_dtype).name}
        else:
            a = np.asarray(leaf)
            arrays[key] = a
            meta["dtypes"][key] = a.dtype.name
    meta["paths"] = paths
    return arrays, meta


def save_checkpoint(path: str, tree, *, extra: dict | None = None) -> None:
    """Atomically write ``tree`` (+ JSON-serializable ``extra``) to
    ``path`` (a .npz file; a sibling .json holds the manifest)."""
    arrays, meta = _flatten(tree)
    if extra:
        meta["extra"] = extra
    d = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    mtmp = path + ".json.tmp"
    with open(mtmp, "w") as f:
        json.dump(meta, f)
    os.replace(mtmp, path + ".json")


def load_checkpoint(path: str, like) -> Tuple[Any, dict]:
    """Restore a tree with the same structure as ``like``.
    Returns (tree, extra)."""
    with open(path + ".json") as f:
        meta = json.load(f)
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        like, is_leaf=lambda l: isinstance(l, QTensor))
    out = []
    for path_keys, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path_keys)
        if isinstance(leaf, QTensor):
            qm = meta["qtensors"][key]
            out.append(QTensor(
                q=jax.numpy.asarray(data[key + ".q"]),
                scales=jax.numpy.asarray(data[key + ".scales"]),
                bits=qm["bits"], mode=qm["mode"], block=qm["block"],
                out_dtype=np.dtype(qm["out_dtype"]),
                orig_shape=tuple(qm["orig_shape"])))
        else:
            a = data[key]
            want = getattr(leaf, "shape", None)
            if want is not None and tuple(a.shape) != tuple(want):
                raise ValueError(
                    f"checkpoint leaf {key}: shape {a.shape} != {want}")
            out.append(jax.numpy.asarray(a))
    return (jax.tree_util.tree_unflatten(treedef, out),
            meta.get("extra", {}))


# ------------------------------------------------------------- FL state
def save_fl_state(path: str, *, round_idx: int, global_trainable,
                  client_sizes, opt_state=None) -> None:
    tree = {"trainable": global_trainable}
    if opt_state is not None:
        tree["opt"] = opt_state
    save_checkpoint(path, tree, extra={
        "round": int(round_idx),
        "client_sizes": [int(c) for c in client_sizes]})


def restore_fl_state(path: str, *, like_trainable, like_opt=None):
    like = {"trainable": like_trainable}
    if like_opt is not None:
        like["opt"] = like_opt
    tree, extra = load_checkpoint(path, like)
    return (tree["trainable"], tree.get("opt"), int(extra["round"]),
            extra["client_sizes"])
