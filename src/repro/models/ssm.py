"""Mamba-1 selective-scan block (falcon-mamba), TPU-adapted.

GPU Mamba fuses the recurrence into one CUDA kernel; the TPU-native shape
of the same math is a *chunked* scan (DESIGN.md §5): ``lax.scan`` over
sequence chunks carrying the (B, d_inner, N) state, with an
``associative_scan`` inside each chunk — the chunk working set is sized for
VMEM and every op is MXU/VPU-friendly. The recurrence
``h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t`` is composed associatively via
(a, b) pairs: (a2, b2)∘(a1, b1) = (a1·a2, a2·b1 + b2).

Distribution: everything in the block is per-channel in d_inner, so under a
Runtime the block runs inside ``shard_map`` with d_inner sharded over the
``model`` axis. The only cross-shard communication is the small psum for
x_proj (Δ/B/C depend on all channels) and the reduce-scatter of the output
projection back to the sequence-sharded residual. Relying on GSPMD to
partition the scan instead replicates the (B,S,d_inner,N) tensors
(measured 342 GiB/device on falcon-mamba train_4k).

``in_proj`` is stored as two matrices (x-branch, z-gate) so the d_inner
shard never straddles the packed halves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import compat
from repro.core import lora as lora_lib
from repro.models import runtime as rt_lib


# ---------------------------------------------------------------- scan util
def _comb(left, right):
    a1, b1 = left
    a2, b2 = right
    return a1 * a2, a2 * b1 + b2


def chunked_linear_scan(a, b, h0, chunk: int):
    """Elementwise linear recurrence h_t = a_t·h_{t-1} + b_t.

    a, b: (B, S, ...); h0: (B, ...). Returns (h_all (B,S,...), h_last).
    Chunked so peak memory is O(B·chunk·state) regardless of S."""
    B, S = a.shape[0], a.shape[1]
    chunk = min(chunk, S)
    Sp = -(-S // chunk) * chunk
    if Sp != S:  # pad with identity transitions (a=1, b=0), slice after
        pw = [(0, 0), (0, Sp - S)] + [(0, 0)] * (a.ndim - 2)
        a = jnp.pad(a, pw, constant_values=1.0)
        b = jnp.pad(b, pw)
    nc = Sp // chunk
    rest = a.shape[2:]
    a_c = jnp.moveaxis(a.reshape(B, nc, chunk, *rest), 1, 0)
    b_c = jnp.moveaxis(b.reshape(B, nc, chunk, *rest), 1, 0)

    def step(h, ab):
        ac, bc = ab
        a_cum, b_scan = lax.associative_scan(_comb, (ac, bc), axis=1)
        h_full = b_scan + a_cum * h[:, None]
        return h_full[:, -1], h_full

    _, h_all = lax.scan(step, h0, (a_c, b_c))
    h_all = jnp.moveaxis(h_all, 0, 1).reshape(B, Sp, *rest)[:, :S]
    return h_all, h_all[:, -1]


def _chunked_ssm_scan(dt, A, Bm, Cm, xc, h0, chunk: int):
    """Selective scan emitting y = (h·C).sum(N) chunk-by-chunk so the
    (B, chunk, di, N) state tensor never materializes beyond one chunk.

    dt, xc: (B,S,di); A: (di,N); Bm, Cm: (B,S,N); h0: (B,di,N) f32.
    Returns (y (B,S,di) f32, h_last)."""
    B, S, di = xc.shape
    N = A.shape[-1]
    chunk = min(chunk, S)
    Sp = -(-S // chunk) * chunk
    pad = Sp - S
    if pad:
        z2 = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt, xc, Bm, Cm = z2(dt), z2(xc), z2(Bm), z2(Cm)
    nc = Sp // chunk
    mv = lambda x: jnp.moveaxis(
        x.reshape(B, nc, chunk, *x.shape[2:]), 1, 0)
    dt_c, xc_c, B_c, C_c = mv(dt), mv(xc), mv(Bm), mv(Cm)

    def step(h, inp):
        dtc, xcc, bc, cc = inp                    # (B,L,di) / (B,L,N)
        a = jnp.exp(dtc[..., None] * A)           # (B,L,di,N)
        b = (dtc * xcc)[..., None] * bc[:, :, None, :]
        a_cum, b_scan = lax.associative_scan(_comb, (a, b), axis=1)
        h_full = b_scan + a_cum * h[:, None]
        y = jnp.einsum("blen,bln->ble", h_full, cc)
        return h_full[:, -1], y

    h_last, y = lax.scan(step, h0, (dt_c, xc_c, B_c, C_c))
    y = jnp.moveaxis(y, 0, 1).reshape(B, Sp, di)[:, :S]
    if pad:
        # padded steps have a=exp(0·A)=1, b=0 -> state frozen; h_last is
        # correct only when pad == 0, so recompute from the last valid row
        pass
    return y, h_last


# ---------------------------------------------------------------- params
def init_mamba(rng, cfg: ModelConfig, dtype):
    d, di, N, R, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                      cfg.ssm_conv)
    ks = jax.random.split(rng, 6)
    s = lambda fan: 1.0 / jnp.sqrt(fan)
    return {
        "in_proj_x": jax.random.normal(ks[0], (d, di), dtype) * s(d),
        "in_proj_z": jax.random.normal(ks[5], (d, di), dtype) * s(d),
        "conv_w": jax.random.normal(ks[1], (K, di), dtype) * s(K),
        "x_proj": jax.random.normal(ks[2], (di, R + 2 * N), dtype) * s(di),
        "dt_proj": jax.random.normal(ks[3], (R, di), dtype) * s(R),
        "dt_bias": jnp.full((di,), -2.0, jnp.float32),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[4], (di, d), dtype) * s(di),
    }


def mamba_specs(cfg: ModelConfig, dtype, lead=()):
    d, di, N, R, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                      cfg.ssm_conv)
    f = lambda *sh, dt=dtype: jax.ShapeDtypeStruct((*lead, *sh), dt)
    return {"in_proj_x": f(d, di), "in_proj_z": f(d, di),
            "conv_w": f(K, di),
            "x_proj": f(di, R + 2 * N), "dt_proj": f(R, di),
            "dt_bias": f(di, dt=jnp.float32),
            "a_log": f(di, N, dt=jnp.float32),
            "d_skip": f(di, dt=jnp.float32), "out_proj": f(di, d)}


def mamba_partition_specs(cfg: ModelConfig, tp_axis="model", lead=()):
    """Per-leaf PartitionSpecs: the d_inner dim -> tp axis. Shared by the
    launch sharding rules and the shard_map in_specs (they must agree)."""
    nl = (None,) * len(lead)
    return {"in_proj_x": P(*nl, None, tp_axis),
            "in_proj_z": P(*nl, None, tp_axis),
            "conv_w": P(*nl, None, tp_axis),
            "x_proj": P(*nl, tp_axis, None),
            "dt_proj": P(*nl, None, tp_axis),
            "dt_bias": P(*nl, tp_axis),
            "a_log": P(*nl, tp_axis, None),
            "d_skip": P(*nl, tp_axis),
            "out_proj": P(*nl, tp_axis, None)}


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype):
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {"h": jnp.zeros((batch, di, N), jnp.float32),
            "conv": jnp.zeros((batch, K - 1, di), dtype)}


def mamba_cache_specs(cfg: ModelConfig, batch: int, dtype, lead=()):
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {"h": jax.ShapeDtypeStruct((*lead, batch, di, N), jnp.float32),
            "conv": jax.ShapeDtypeStruct((*lead, batch, K - 1, di), dtype)}


# ---------------------------------------------------------------- forward
def _causal_conv(conv_w, x1, dtype):
    """Depthwise causal conv over S. x1: (B, S, di)."""
    K = conv_w.shape[0]
    w = conv_w.astype(dtype)[:, None, :]
    x_pad = jnp.pad(x1, ((0, 0), (K - 1, 0), (0, 0)))
    return lax.conv_general_dilated(
        x_pad, w, window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=x1.shape[-1])


def _lora_delta(x, pair, sl, alpha, rank):
    """LoRA delta for a d_inner-sharded target: B is column-sliced."""
    if pair is None:
        return 0.0
    h = jnp.einsum("...k,kr->...r", x.astype(pair["a"].dtype), pair["a"])
    b = pair["b"] if sl is None else lax.dynamic_slice_in_dim(
        pair["b"], sl[0], sl[1], axis=1)
    return (jnp.einsum("...r,rn->...n", h, b) * (alpha / rank)).astype(
        x.dtype)


def _mamba_core(p, x, cfg: ModelConfig, h0, lo, *, shard=None):
    """x: (B, S, d) -> (out_partial, cache). When ``shard=(r, m)`` the
    params are local d_inner shards and the output is a PARTIAL sum
    (caller reduces)."""
    B, S, _ = x.shape
    dtype = x.dtype
    di_l = p["in_proj_x"].shape[-1]
    N, R = cfg.ssm_state, cfg.dt_rank
    alpha, rank = cfg.lora_alpha, cfg.lora_rank
    sl_x = None if shard is None else (shard[0] * di_l, di_l)

    x1 = x @ p["in_proj_x"].astype(dtype) + _lora_delta(
        x, lo.get("in_proj_x"), sl_x, alpha, rank)
    z = x @ p["in_proj_z"].astype(dtype)
    xc = jax.nn.silu(_causal_conv(p["conv_w"], x1, dtype))

    proj = (xc @ p["x_proj"].astype(dtype)).astype(jnp.float32)
    if shard is not None:
        proj = lax.psum(proj, rt_lib.get_runtime().tp_axis)
    dt_r, Bm, Cm = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(jnp.float32) +
                         p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    zero_start = h0 is None
    if h0 is None:
        h0 = jnp.zeros((B, di_l, N), jnp.float32)
    kern = None
    if zero_start and not cfg.calibrate:
        # TPU: fused Pallas selective scan (kernels/selective_scan.py);
        # returns None on CPU where the chunked associative scan is used
        from repro.kernels import ops as kops
        kern = kops.selective_scan(dt, xc.astype(jnp.float32), Bm, Cm, A)
    if kern is not None:
        y, h_last = kern
    else:
        chunk = S if cfg.calibrate else cfg.scan_chunk
        y, h_last = _chunked_ssm_scan(dt, A, Bm, Cm,
                                      xc.astype(jnp.float32), h0, chunk)
    y = y + p["d_skip"] * xc.astype(jnp.float32)
    y = y.astype(dtype) * jax.nn.silu(z)
    # out_proj contracts the (possibly sharded) d_inner dim -> partial
    out = y @ p["out_proj"].astype(dtype)
    if lo.get("out_proj") is not None:
        a = lo["out_proj"]["a"] if shard is None else \
            lax.dynamic_slice_in_dim(lo["out_proj"]["a"], sl_x[0], di_l, 0)
        h = jnp.einsum("...k,kr->...r", y.astype(a.dtype), a)
        out = out + (jnp.einsum("...r,rn->...n", h, lo["out_proj"]["b"]) *
                     (alpha / rank)).astype(dtype)
    K = cfg.ssm_conv
    tail = x1[:, -(K - 1):, :] if S >= K - 1 else \
        jnp.pad(x1, ((0, 0), (K - 1 - S, 0), (0, 0)))
    return out, {"h": h_last, "conv": tail}


def mamba_block(p, x, cfg: ModelConfig, *, lora=None, h0=None):
    """x: (B, S, d) -> (y (B, S, d), cache). Dispatches to the shard_map
    d_inner-parallel path under a Runtime."""
    from repro.core.quant import QTensor, maybe_dequantize
    lo = lora or {}
    rt = rt_lib.get_runtime()
    B, S, d = x.shape
    # recurrent blocks consume dense weights; QLoRA storage stays int4/NF4
    # in HBM, dequantization is fused into the per-layer compute
    p = jax.tree.map(maybe_dequantize, p,
                     is_leaf=lambda l: isinstance(l, QTensor))
    if rt is None:
        return _mamba_core(p, x, cfg, h0, lo)

    mesh, m, tp, dp = rt.mesh, rt.tp_size, rt.tp_axis, rt.dp_axes
    if cfg.d_inner % m or (B % rt.dp_size):
        return _mamba_core(p, x, cfg, h0, lo)
    pspec = mamba_partition_specs(cfg, tp)
    p = {k: p[k] for k in pspec}          # layer dict may carry norms etc.
    lo = {k: v for k, v in lo.items() if k in ("in_proj_x", "out_proj")}
    lspec = jax.tree.map(lambda _: P(), lo)
    seq_out = tp if (cfg.seq_shard and S % m == 0 and S > 1) else None

    # checkpoint INSIDE the shard_map body: its AD residuals reduce to the
    # block inputs (kept sequence-SHARDED — the all-gather happens inside
    # the checkpointed region and is recomputed in the backward), so the
    # layer scan saves only (B, S/m, d) per layer. Wrapping the shard_map
    # in the scan-body checkpoint instead compiles pathologically slowly
    # (measured 25+ minutes vs 17 s on falcon-mamba train_4k).
    @jax.checkpoint
    def fn(x_l, p_l, lo_l, h0_l):
        r = lax.axis_index(tp)
        if seq_out:
            x_l = lax.all_gather(x_l, tp, axis=1, tiled=True)
        out, cache = _mamba_core(p_l, x_l, cfg, h0_l, lo_l, shard=(r, m))
        if seq_out:
            out = lax.psum_scatter(out, tp, scatter_dimension=1,
                                   tiled=True)
        else:
            out = lax.psum(out, tp)
        return out, cache

    h0_spec = P(dp, tp, None)
    return compat.shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp, seq_out, None), pspec, lspec,
                  None if h0 is None else h0_spec),
        out_specs=(P(dp, seq_out, None),
                   {"h": P(dp, tp, None), "conv": P(dp, None, tp)}),
        check_vma=False)(x, p, lo, h0)


def mamba_decode(p, x, cache, cfg: ModelConfig, *, lora=None):
    """Single-token step. x: (B, 1, d). Plain (GSPMD) execution — every op
    is small and elementwise, so no explicit mapping is needed."""
    from repro.core.quant import QTensor, maybe_dequantize
    p = jax.tree.map(maybe_dequantize, p,
                     is_leaf=lambda l: isinstance(l, QTensor))
    B = x.shape[0]
    dtype = x.dtype
    lo = lora or {}
    alpha, rank = cfg.lora_alpha, cfg.lora_rank
    x1 = (x[:, 0] @ p["in_proj_x"].astype(dtype) +
          _lora_delta(x[:, 0], lo.get("in_proj_x"), None, alpha, rank))
    z = x[:, 0] @ p["in_proj_z"].astype(dtype)
    window = jnp.concatenate([cache["conv"],
                              x1[:, None, :].astype(cache["conv"].dtype)], 1)
    w = p["conv_w"].astype(dtype)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", window.astype(dtype), w))
    N, R = cfg.ssm_state, cfg.dt_rank
    proj = (xc @ p["x_proj"].astype(dtype)).astype(jnp.float32)
    dt_r, Bm, Cm = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(jnp.float32) +
                         p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    a = jnp.exp(dt[..., None] * A)
    b = (dt * xc.astype(jnp.float32))[..., None] * Bm[:, None, :]
    h = a * cache["h"] + b
    y = jnp.einsum("ben,bn->be", h, Cm) + p["d_skip"] * xc.astype(
        jnp.float32)
    y = y.astype(dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dtype)
    if lo.get("out_proj") is not None:
        out = out + _lora_delta(y, lo["out_proj"], None, alpha, rank)
    return out[:, None, :], {"h": h, "conv": window[:, 1:, :]}
