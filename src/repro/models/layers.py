"""Shared transformer building blocks: norms, RoPE, GQA attention
(train/prefill/decode with ring-buffer sliding-window caches), MLPs.

All weights may be ``QTensor`` (quantized backbone — paper §III-C); every
projection optionally carries a LoRA pair. Weights are bias-free
(llama-convention; a deviation for starcoder2/whisper, noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import lora as lora_lib
from repro.core.quant import QTensor
from repro.kernels import ops as kops
from repro.configs.base import ModelConfig


# ------------------------------------------------------------------ norms
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))
            ).astype(x.dtype)


# ------------------------------------------------------------------ rope
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (S,) or scalar."""
    B, S, H, D = x.shape
    half = D // 2
    freq = jnp.exp(-jnp.log(theta) *
                   jnp.arange(half, dtype=jnp.float32) / half)
    ang = jnp.asarray(positions, jnp.float32).reshape(-1)[:, None] * freq
    cos, sin = jnp.cos(ang), jnp.sin(ang)          # (S, half)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(
        jnp.float32)
    cos = cos[None, :, None, :]
    sin = sin[None, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ linear
def linear(x, w, lo=None, *, cfg: ModelConfig):
    return lora_lib.linear(x, w, lo, alpha=cfg.lora_alpha,
                           rank=cfg.lora_rank)


# ------------------------------------------------------------------ attention
def init_attention(rng, cfg: ModelConfig, dtype, *, cross: bool = False):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(rng, 4)
    s = lambda fan_in: 1.0 / jnp.sqrt(fan_in)
    pre = "c" if cross else ""
    return {
        pre + "wq": jax.random.normal(ks[0], (d, qd), dtype) * s(d),
        pre + "wk": jax.random.normal(ks[1], (d, kvd), dtype) * s(d),
        pre + "wv": jax.random.normal(ks[2], (d, kvd), dtype) * s(d),
        pre + "wo": jax.random.normal(ks[3], (qd, d), dtype) * s(qd),
    }


def attention_specs(cfg: ModelConfig, dtype, *, cross: bool = False,
                    lead=()):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    f = lambda *sh: jax.ShapeDtypeStruct((*lead, *sh), dtype)
    pre = "c" if cross else ""
    return {pre + "wq": f(d, qd), pre + "wk": f(d, kvd),
            pre + "wv": f(d, kvd), pre + "wo": f(qd, d)}


def attention(p, x, positions, cfg: ModelConfig, *, lora=None,
              causal=True, window=None, kv_x=None, use_rope=True,
              prefix=""):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    B, S, _ = x.shape
    lo = lora or {}
    g = lambda n: lo.get(prefix + n)
    q = linear(x, p[prefix + "wq"], g("wq"), cfg=cfg)
    src = kv_x if kv_x is not None else x
    k = linear(src, p[prefix + "wk"], g("wk"), cfg=cfg)
    v = linear(src, p[prefix + "wv"], g("wv"), cfg=cfg)
    Skv = src.shape[1]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    if use_rope and kv_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if cfg.calibrate:  # single-tile attention: exact FLOP accounting
        out = kops.flash_attention(q, k, v, causal=causal, window=window,
                                   q_chunk=S, k_chunk=Skv)
    else:
        out = kops.flash_attention(q, k, v, causal=causal, window=window)
    out = out.reshape(B, S, cfg.q_dim)
    y = linear(out, p[prefix + "wo"], g("wo"), cfg=cfg)
    return y, (k, v)


def ring_from_full(k, v, M: int, *, kv_quant: bool = False):
    """Convert full prefill K/V (B, S, H, D) into a ring cache of M slots.

    Slot s holds the largest position p < S with p % M == s (i.e. the last
    min(S, M) tokens laid out ring-consistently); slots with no such p are
    empty (slot_pos = -1), so decoding can continue at position S with
    ``slot = pos % M`` for both full (M >= max context) and sliding-window
    (M = window) caches."""
    S = k.shape[1]
    s = jnp.arange(M, dtype=jnp.int32)
    p = s + ((S - 1 - s) // M) * M
    valid = s < S
    slot_pos = jnp.where(valid, p, -1).astype(jnp.int32)
    if M != S:
        idx = jnp.clip(p, 0, S - 1)
        k = jnp.take(k, idx, axis=1)
        v = jnp.take(v, idx, axis=1)
    out = {"slot_pos": slot_pos}
    kq, ks = quant_kv(k, kv_quant)
    vq, vs = quant_kv(v, kv_quant)
    out["k"], out["v"] = kq, vq
    if kv_quant:
        out["k_scale"], out["v_scale"] = ks, vs
    return out


def _kv_dtype(cfg: ModelConfig, dtype):
    return jnp.int8 if cfg.kv_quant_bits == 8 else dtype


def quant_kv(x, enabled: bool):
    """Per-(token, head) absmax int8 quantization of K/V rows.
    x: (..., D) -> (int8 payload, f32 scale (..., 1))."""
    if not enabled:
        return x, None
    xf = x.astype(jnp.float32)
    s = jnp.maximum(jnp.abs(xf).max(-1, keepdims=True), 1e-12) / 127.0
    return jnp.clip(jnp.round(xf / s), -127, 127).astype(jnp.int8), s


def dequant_kv(x, scale, dtype):
    if scale is None:
        return x.astype(dtype)
    return (x.astype(jnp.float32) * scale).astype(dtype)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Ring-buffer KV cache for one layer. ``max_len`` = window for SWA.
    With cfg.kv_quant_bits == 8 the cache stores int8 rows + f32 scales
    (paper-aligned quantization applied to serving state — §Perf)."""
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    c = {"k": jnp.zeros(shape, _kv_dtype(cfg, dtype)),
         "v": jnp.zeros(shape, _kv_dtype(cfg, dtype)),
         "slot_pos": jnp.full((max_len,), -1, jnp.int32)}
    if cfg.kv_quant_bits == 8:
        c["k_scale"] = jnp.zeros((*shape[:3], 1), jnp.float32)
        c["v_scale"] = jnp.zeros((*shape[:3], 1), jnp.float32)
    return c


def kv_cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype,
                   lead=()):
    shape = (*lead, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    c = {"k": jax.ShapeDtypeStruct(shape, _kv_dtype(cfg, dtype)),
         "v": jax.ShapeDtypeStruct(shape, _kv_dtype(cfg, dtype)),
         "slot_pos": jax.ShapeDtypeStruct((*lead, max_len), jnp.int32)}
    if cfg.kv_quant_bits == 8:
        c["k_scale"] = jax.ShapeDtypeStruct((*shape[:-1], 1), jnp.float32)
        c["v_scale"] = jax.ShapeDtypeStruct((*shape[:-1], 1), jnp.float32)
    return c


def attention_decode(p, x, pos, cache, cfg: ModelConfig, *, lora=None,
                     use_rope=True, prefix="", update_cache=True):
    """One-token attention against a ring cache.

    x: (B, 1, d); pos: scalar int32 absolute position.
    Keys are stored already RoPE'd, so lookups need no re-rotation.
    """
    B = x.shape[0]
    lo = lora or {}
    g = lambda n: lo.get(prefix + n)
    q = linear(x, p[prefix + "wq"], g("wq"), cfg=cfg).reshape(
        B, 1, cfg.n_heads, cfg.head_dim)
    if use_rope:
        q = rope(q, pos, cfg.rope_theta)
    if update_cache:
        k = linear(x, p[prefix + "wk"], g("wk"), cfg=cfg).reshape(
            B, 1, cfg.n_kv_heads, cfg.head_dim)
        v = linear(x, p[prefix + "wv"], g("wv"), cfg=cfg).reshape(
            B, 1, cfg.n_kv_heads, cfg.head_dim)
        if use_rope:
            k = rope(k, pos, cfg.rope_theta)
        quant = cfg.kv_quant_bits == 8 and "k_scale" in cache
        kq, ks = quant_kv(k, quant)
        vq, vs = quant_kv(v, quant)
        max_len = cache["k"].shape[1]
        slot = (pos % max_len).astype(jnp.int32)
        upd = lambda buf, val: lax.dynamic_update_slice_in_dim(
            buf, val.astype(buf.dtype), slot, axis=1)
        new = {
            "k": upd(cache["k"], kq), "v": upd(cache["v"], vq),
            "slot_pos": lax.dynamic_update_slice_in_dim(
                cache["slot_pos"], pos[None].astype(jnp.int32), slot,
                axis=0),
        }
        if quant:
            new["k_scale"] = upd(cache["k_scale"], ks)
            new["v_scale"] = upd(cache["v_scale"], vs)
        cache = new
    out = kops.decode_attention(
        q, dequant_kv(cache["k"], cache.get("k_scale"), x.dtype),
        dequant_kv(cache["v"], cache.get("v_scale"), x.dtype),
        cache["slot_pos"][None])
    y = linear(out.reshape(B, 1, cfg.q_dim), p[prefix + "wo"], g("wo"),
               cfg=cfg)
    return y, cache


# ------------------------------------------------------------------ mlp
def init_mlp(rng, d: int, ff: int, kind: str, dtype):
    ks = jax.random.split(rng, 3)
    s = lambda fan_in: 1.0 / jnp.sqrt(fan_in)
    p = {"wu": jax.random.normal(ks[0], (d, ff), dtype) * s(d),
         "wd": jax.random.normal(ks[1], (ff, d), dtype) * s(ff)}
    if kind == "swiglu":
        p["wg"] = jax.random.normal(ks[2], (d, ff), dtype) * s(d)
    return p


def mlp_specs(d: int, ff: int, kind: str, dtype, lead=()):
    f = lambda *sh: jax.ShapeDtypeStruct((*lead, *sh), dtype)
    p = {"wu": f(d, ff), "wd": f(ff, d)}
    if kind == "swiglu":
        p["wg"] = f(d, ff)
    return p


def mlp(p, x, cfg: ModelConfig, *, lora=None, kind=None):
    kind = kind or cfg.mlp
    lo = lora or {}
    if kind == "swiglu":
        h = jax.nn.silu(linear(x, p["wg"], lo.get("wg"), cfg=cfg)) * \
            linear(x, p["wu"], lo.get("wu"), cfg=cfg)
    else:
        h = jax.nn.gelu(linear(x, p["wu"], lo.get("wu"), cfg=cfg))
    return linear(h, p["wd"], lo.get("wd"), cfg=cfg)
