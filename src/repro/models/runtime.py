"""Runtime distribution context.

Model code is mesh-agnostic; when a mesh context is installed (by the
launcher / dry-run), layers that have an *explicit* distributed
implementation (MoE expert-parallel all-to-all, sequence-parallel residual
constraints) use it. Without a context (unit tests, CPU examples)
everything runs as plain local jnp.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class Runtime:
    mesh: Mesh
    dp_axes: Tuple[str, ...] = ("data",)   # client/data-parallel axes
    tp_axis: str = "model"                 # tensor/expert-parallel axis

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= int(self.mesh.shape[a])
        return n

    @property
    def tp_size(self) -> int:
        return int(self.mesh.shape[self.tp_axis])


_CURRENT: list = [None]


def set_runtime(rt: Optional[Runtime]) -> None:
    _CURRENT[0] = rt


def get_runtime() -> Optional[Runtime]:
    return _CURRENT[0]


@contextlib.contextmanager
def runtime(rt: Optional[Runtime]):
    prev = _CURRENT[0]
    _CURRENT[0] = rt
    try:
        yield
    finally:
        _CURRENT[0] = prev


def constrain(x, *spec):
    """with_sharding_constraint if a runtime mesh is installed, else no-op."""
    rt = get_runtime()
    if rt is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rt.mesh, P(*spec)))
