"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Recurrence (per channel): a_t = exp(c · log σ(Λ) · r_t),
h_t = a_t h_{t-1} + sqrt(1 − a_t²) · (i_t ⊙ x_t), with learned recurrence
gate r_t and input gate i_t. The gate matrices are *block-diagonal*
(Griffin's design — one block per head) which makes them shard-local.

Distribution mirrors the Mamba block: under a Runtime the block runs in
``shard_map`` with lru_width sharded over ``model``; the only cross-shard
communication is the output-projection reduce(-scatter). Shares the chunked
associative scan with the Mamba block (TPU-native; DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import compat
from repro.core import lora as lora_lib
from repro.models import runtime as rt_lib
from repro.models.ssm import chunked_linear_scan, _causal_conv, _lora_delta

_C = 8.0
GATE_BLOCKS = 16  # block-diagonal gate heads (w % 16 == 0 for all configs)


def init_rglru(rng, cfg: ModelConfig, dtype):
    d, w, K = cfg.d_model, cfg.lru_width or cfg.d_model, cfg.ssm_conv
    gb = GATE_BLOCKS
    wb = w // gb
    ks = jax.random.split(rng, 6)
    s = lambda fan: 1.0 / jnp.sqrt(fan)
    return {
        "wx": jax.random.normal(ks[0], (d, w), dtype) * s(d),
        "wy": jax.random.normal(ks[1], (d, w), dtype) * s(d),
        "conv_w": jax.random.normal(ks[2], (K, w), dtype) * s(K),
        "w_rg": jax.random.normal(ks[3], (gb, wb, wb), dtype) * s(wb),
        "w_ig": jax.random.normal(ks[4], (gb, wb, wb), dtype) * s(wb),
        "lam": jnp.full((w,), 2.0, jnp.float32),      # σ(Λ) ≈ 0.88
        "out_proj": jax.random.normal(ks[5], (w, d), dtype) * s(w),
    }


def rglru_specs(cfg: ModelConfig, dtype, lead=()):
    d, w, K = cfg.d_model, cfg.lru_width or cfg.d_model, cfg.ssm_conv
    gb = GATE_BLOCKS
    wb = w // gb
    f = lambda *sh, dt=dtype: jax.ShapeDtypeStruct((*lead, *sh), dt)
    return {"wx": f(d, w), "wy": f(d, w), "conv_w": f(K, w),
            "w_rg": f(gb, wb, wb), "w_ig": f(gb, wb, wb),
            "lam": f(w, dt=jnp.float32), "out_proj": f(w, d)}


def rglru_partition_specs(cfg: ModelConfig, tp_axis="model", lead=()):
    nl = (None,) * len(lead)
    return {"wx": P(*nl, None, tp_axis), "wy": P(*nl, None, tp_axis),
            "conv_w": P(*nl, None, tp_axis),
            "w_rg": P(*nl, tp_axis, None, None),
            "w_ig": P(*nl, tp_axis, None, None),
            "lam": P(*nl, tp_axis), "out_proj": P(*nl, tp_axis, None)}


def rglru_cache_init(cfg: ModelConfig, batch: int, dtype):
    w, K = cfg.lru_width or cfg.d_model, cfg.ssm_conv
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, K - 1, w), dtype)}


def rglru_cache_specs(cfg: ModelConfig, batch: int, dtype, lead=()):
    w, K = cfg.lru_width or cfg.d_model, cfg.ssm_conv
    return {"h": jax.ShapeDtypeStruct((*lead, batch, w), jnp.float32),
            "conv": jax.ShapeDtypeStruct((*lead, batch, K - 1, w), dtype)}


def _block_gate(wm, x32):
    """Block-diagonal matmul: x (..., gb·wb) × wm (gb, wb, wb)."""
    gb, wb, _ = wm.shape
    xs = x32.reshape(*x32.shape[:-1], gb, wb)
    return jnp.einsum("...gw,gwv->...gv", xs,
                      wm.astype(jnp.float32)).reshape(x32.shape)


def _gates(p, xc):
    """(a_t, b_t) for the recurrence, from post-conv activations (f32)."""
    x32 = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_gate(p["w_rg"], x32))
    i = jax.nn.sigmoid(_block_gate(p["w_ig"], x32))
    log_a = _C * r * jax.nn.log_sigmoid(p["lam"])
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x32)
    return a, b


def _rglru_core(p, x, cfg: ModelConfig, h0, lo, *, shard=None):
    """Returns (out_partial, cache); out needs reduction when sharded."""
    B, S, _ = x.shape
    dtype = x.dtype
    alpha, rank = cfg.lora_alpha, cfg.lora_rank
    w_l = p["wx"].shape[-1]
    sl = None if shard is None else (shard[0] * w_l, w_l)
    gate = jax.nn.gelu(x @ p["wy"].astype(dtype) +
                       _lora_delta(x, lo.get("wy"), sl, alpha, rank))
    val = x @ p["wx"].astype(dtype) + _lora_delta(
        x, lo.get("wx"), sl, alpha, rank)
    xc = _causal_conv(p["conv_w"], val, dtype)
    a, b = _gates(p, xc)
    if h0 is None:
        h0 = jnp.zeros((B, w_l), jnp.float32)
    chunk = S if cfg.calibrate else cfg.scan_chunk
    h_all, h_last = chunked_linear_scan(a, b, h0, chunk)
    y = h_all.astype(dtype) * gate
    out = y @ p["out_proj"].astype(dtype)
    if lo.get("out_proj") is not None:
        aL = lo["out_proj"]["a"] if shard is None else \
            lax.dynamic_slice_in_dim(lo["out_proj"]["a"], sl[0], w_l, 0)
        hL = jnp.einsum("...k,kr->...r", y.astype(aL.dtype), aL)
        out = out + (jnp.einsum("...r,rn->...n", hL, lo["out_proj"]["b"]) *
                     (alpha / rank)).astype(dtype)
    K = cfg.ssm_conv
    tail = val[:, -(K - 1):, :] if S >= K - 1 else \
        jnp.pad(val, ((0, 0), (K - 1 - S, 0), (0, 0)))
    return out, {"h": h_last, "conv": tail}


def rglru_block(p, x, cfg: ModelConfig, *, lora=None, h0=None):
    """x: (B, S, d) -> (y (B, S, d), cache)."""
    from repro.core.quant import QTensor, maybe_dequantize
    lo = lora or {}
    rt = rt_lib.get_runtime()
    B, S, _ = x.shape
    w = cfg.lru_width or cfg.d_model
    p = jax.tree.map(maybe_dequantize, p,
                     is_leaf=lambda l: isinstance(l, QTensor))
    if rt is None:
        return _rglru_core(p, x, cfg, h0, lo)
    mesh, m, tp, dp = rt.mesh, rt.tp_size, rt.tp_axis, rt.dp_axes
    if w % m or GATE_BLOCKS % m or (B % rt.dp_size):
        return _rglru_core(p, x, cfg, h0, lo)
    pspec = rglru_partition_specs(cfg, tp)
    p = {k: p[k] for k in pspec}          # layer dict may carry attn/mlp
    lo = {k: v for k, v in lo.items() if k in ("wx", "wy", "out_proj")}
    lspec = jax.tree.map(lambda _: P(), lo)
    seq_out = tp if (cfg.seq_shard and S % m == 0 and S > 1) else None

    @jax.checkpoint  # see models/ssm.py — remat inside the shard_map body
    def fn(x_l, p_l, lo_l, h0_l):
        r = lax.axis_index(tp)
        if seq_out:
            x_l = lax.all_gather(x_l, tp, axis=1, tiled=True)
        out, cache = _rglru_core(p_l, x_l, cfg, h0_l, lo_l, shard=(r, m))
        if seq_out:
            out = lax.psum_scatter(out, tp, scatter_dimension=1, tiled=True)
        else:
            out = lax.psum(out, tp)
        return out, cache

    return compat.shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp, seq_out, None), pspec, lspec,
                  None if h0 is None else P(dp, tp)),
        out_specs=(P(dp, seq_out, None),
                   {"h": P(dp, tp), "conv": P(dp, None, tp)}),
        check_vma=False)(x, p, lo, h0)


def rglru_decode(p, x, cache, cfg: ModelConfig, *, lora=None):
    """Single-token step. x: (B, 1, d). GSPMD execution (all ops small)."""
    from repro.core.quant import QTensor, maybe_dequantize
    p = jax.tree.map(maybe_dequantize, p,
                     is_leaf=lambda l: isinstance(l, QTensor))
    dtype = x.dtype
    lo = lora or {}
    alpha, rank = cfg.lora_alpha, cfg.lora_rank
    gate = jax.nn.gelu(x[:, 0] @ p["wy"].astype(dtype) +
                       _lora_delta(x[:, 0], lo.get("wy"), None, alpha, rank))
    val = x[:, 0] @ p["wx"].astype(dtype) + _lora_delta(
        x[:, 0], lo.get("wx"), None, alpha, rank)
    window = jnp.concatenate(
        [cache["conv"], val[:, None, :].astype(cache["conv"].dtype)], 1)
    xc = jnp.einsum("bkd,kd->bd", window.astype(dtype),
                    p["conv_w"].astype(dtype))
    a, b = _gates(p, xc)
    h = a * cache["h"] + b
    y = h.astype(dtype) * gate
    out = y @ p["out_proj"].astype(dtype)
    if lo.get("out_proj") is not None:
        out = out + _lora_delta(y, lo["out_proj"], None, alpha, rank)
    return out[:, None, :], {"h": h, "conv": window[:, 1:, :]}
