"""Unified model facade for every assigned architecture family.

A ``Model`` exposes:
  init_params(rng)      -> {"frozen": ..., "trainable": {"lora", "adapter"}}
  param_specs()         -> same pytree of ShapeDtypeStructs (dry-run)
  forward(...)          -> logits, aux             (train shapes)
  train_step(...)       -> TriplePlay local client step (LoRA+adapter only)
  prefill(...)          -> last-token logits, KV/state cache
  decode_step(...)      -> one-token logits, updated cache
  init_cache/cache_specs, input_specs

The frozen backbone may be quantized (cfg.quant_bits ∈ {0, 8, 4} with
linear or NF4 blocks); only LoRA pairs and the paper's attention adapter
are trainable — exactly TriplePlay's client-side configuration.

Layers are stacked and ``lax.scan``ned (hybrid RG-LRU/attention patterns
use a per-layer flag + ``lax.cond``) so HLO size and compile time are O(1)
in depth. ``cfg.first_k_dense`` layers (kimi-k2) are unrolled before the
scanned MoE stack.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ATTN, RGLRU, SSM, InputShape, ModelConfig
from repro.core import adapter as adapter_lib
from repro.core import losses, optim
from repro.core import lora as lora_lib
from repro.core.quant import quantize_tree, quantize_tree_specs
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models import runtime as rt_lib

KIND_ID = {ATTN: 0, SSM: 1, RGLRU: 2}


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _dp(cfg):
    rt = rt_lib.get_runtime()
    return rt.dp_axes if rt else ("data",)


def _seq_axis(cfg, S):
    rt = rt_lib.get_runtime()
    if rt is None or not cfg.seq_shard or S <= 1 or S % rt.tp_size:
        return None
    return rt.tp_axis


# ================================================================ params
def _lora_targets(cfg: ModelConfig) -> Dict[str, tuple]:
    d, qd, kvd, ff = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.d_ff
    t: Dict[str, tuple] = {}
    fam = cfg.family
    if fam != "ssm":
        t.update(wq=(d, qd), wk=(d, kvd), wv=(d, kvd), wo=(qd, d))
    if fam in ("dense", "vlm", "encdec"):
        t.update(wu=(d, ff), wd=(ff, d))
        if cfg.mlp == "swiglu":
            t["wg"] = (d, ff)
    if fam == "encdec":
        t.update(cwq=(d, qd), cwk=(d, kvd), cwv=(d, kvd), cwo=(qd, d))
    if fam == "ssm":
        t.update(in_proj_x=(d, cfg.d_inner), out_proj=(cfg.d_inner, d))
    if fam == "hybrid":
        w = cfg.lru_width or d
        t.update(wx=(d, w), wy=(d, w), out_proj=(w, d))
    return t


def _init_lora_layer(cfg, rng):
    t = _lora_targets(cfg)
    ks = jax.random.split(rng, len(t))
    tdt = jnp.dtype(cfg.trainable_dtype)
    return {n: lora_lib.init_pair(k, kk, nn, cfg.lora_rank, dtype=tdt)
            for (n, (kk, nn)), k in zip(sorted(t.items()), ks)}


def _lora_layer_specs(cfg, lead=()):
    t = _lora_targets(cfg)
    tdt = jnp.dtype(cfg.trainable_dtype)
    return {n: lora_lib.pair_specs(kk, nn, cfg.lora_rank, dtype=tdt,
                                   lead=lead)
            for n, (kk, nn) in sorted(t.items())}


def _init_layer(cfg: ModelConfig, rng, dtype, *, dense_ff: int = 0,
                encoder: bool = False):
    """One backbone layer of the arch family (dense variant if dense_ff)."""
    fam = cfg.family
    ks = jax.random.split(rng, 8)
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), jnp.float32)}
    if fam == "ssm":
        p.update(ssm_lib.init_mamba(ks[0], cfg, dtype))
        return p
    p.update(L.init_attention(ks[0], cfg, dtype))
    p["ln2"] = jnp.zeros((d,), jnp.float32)
    if encoder:
        p.update(L.init_mlp(ks[1], d, cfg.d_ff, cfg.mlp, dtype))
        return p
    if fam == "encdec":
        p["lnc"] = jnp.zeros((d,), jnp.float32)
        p.update(L.init_attention(ks[2], cfg, dtype, cross=True))
        p.update(L.init_mlp(ks[1], d, cfg.d_ff, cfg.mlp, dtype))
        return p
    if fam == "hybrid":
        p.update(rglru_lib.init_rglru(ks[3], cfg, dtype))
        p.update(L.init_mlp(ks[1], d, cfg.d_ff, cfg.mlp, dtype))
        return p
    if fam == "moe" and not dense_ff:
        p["moe"] = moe_lib.init_experts(ks[4], cfg, dtype)
        if cfg.n_shared_experts:
            p["shared"] = L.init_mlp(
                ks[5], d, cfg.d_ff * cfg.n_shared_experts, "swiglu", dtype)
        return p
    ff = dense_ff or cfg.d_ff
    kind = "swiglu" if fam == "moe" else cfg.mlp
    p.update(L.init_mlp(ks[1], d, ff, kind, dtype))
    return p


def _layer_specs(cfg: ModelConfig, dtype, lead=(), *, dense_ff: int = 0,
                 encoder: bool = False):
    fam = cfg.family
    d = cfg.d_model
    f1 = jax.ShapeDtypeStruct((*lead, d), jnp.float32)
    p: Dict[str, Any] = {"ln1": f1}
    if fam == "ssm":
        p.update(ssm_lib.mamba_specs(cfg, dtype, lead))
        return p
    p.update(L.attention_specs(cfg, dtype, lead=lead))
    p["ln2"] = f1
    if encoder:
        p.update(L.mlp_specs(d, cfg.d_ff, cfg.mlp, dtype, lead))
        return p
    if fam == "encdec":
        p["lnc"] = f1
        p.update(L.attention_specs(cfg, dtype, cross=True, lead=lead))
        p.update(L.mlp_specs(d, cfg.d_ff, cfg.mlp, dtype, lead))
        return p
    if fam == "hybrid":
        p.update(rglru_lib.rglru_specs(cfg, dtype, lead))
        p.update(L.mlp_specs(d, cfg.d_ff, cfg.mlp, dtype, lead))
        return p
    if fam == "moe" and not dense_ff:
        p["moe"] = moe_lib.expert_specs(cfg, dtype, lead)
        if cfg.n_shared_experts:
            p["shared"] = L.mlp_specs(
                d, cfg.d_ff * cfg.n_shared_experts, "swiglu", dtype, lead)
        return p
    ff = dense_ff or cfg.d_ff
    kind = "swiglu" if fam == "moe" else cfg.mlp
    p.update(L.mlp_specs(d, ff, kind, dtype, lead))
    return p


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.n_scanned = cfg.n_layers - cfg.first_k_dense
        self.kinds = np.array(
            [KIND_ID[k] for k in cfg.layer_kinds()[cfg.first_k_dense:]],
            np.int32)
        self.hybrid = cfg.family == "hybrid"

    # ---------------------------------------------------------- params
    def init_params(self, rng):
        cfg = self.cfg
        dt = _dtype(cfg)
        k_emb, k_head, k_lay, k_dense, k_enc, k_lora, k_ad, k_pos = \
            jax.random.split(rng, 8)
        frozen: Dict[str, Any] = {
            "embed": jax.random.normal(
                k_emb, (cfg.vocab_size, cfg.d_model), dt) * 0.02,
            "head": jax.random.normal(
                k_head, (cfg.d_model, cfg.vocab_size), dt) /
            jnp.sqrt(jnp.asarray(cfg.d_model, dt)),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if not cfg.use_rope:
            frozen["pos_embed"] = jax.random.normal(
                k_pos, (cfg.max_pos, cfg.d_model), dt) * 0.02
        frozen["layers"] = jax.vmap(
            lambda k: _init_layer(cfg, k, dt))(
                jax.random.split(k_lay, self.n_scanned))
        if cfg.first_k_dense:
            frozen["dense_layers"] = [
                _init_layer(cfg, k, dt, dense_ff=cfg.dense_d_ff)
                for k in jax.random.split(k_dense, cfg.first_k_dense)]
        if cfg.encoder_layers:
            frozen["enc_layers"] = jax.vmap(
                lambda k: _init_layer(cfg, k, dt, encoder=True))(
                    jax.random.split(k_enc, cfg.encoder_layers))
            frozen["enc_pos"] = jax.random.normal(
                jax.random.fold_in(k_enc, 1),
                (cfg.n_frames, cfg.d_model), dt) * 0.02
            frozen["enc_final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.quant_bits:
            for key in ("layers", "dense_layers", "enc_layers"):
                if key in frozen:
                    frozen[key] = quantize_tree(
                        frozen[key], bits=cfg.quant_bits,
                        block=cfg.quant_block, mode=cfg.quant_mode)
        tdt = jnp.dtype(cfg.trainable_dtype)
        trainable: Dict[str, Any] = {
            "lora": jax.vmap(lambda k: _init_lora_layer(cfg, k))(
                jax.random.split(k_lora, self.n_scanned)),
            "adapter": adapter_lib.init(
                k_ad, cfg.d_model, n_heads=cfg.adapter_heads,
                d_ff=cfg.adapter_d_ff, dtype=tdt),
        }
        if cfg.first_k_dense:
            trainable["dense_lora"] = [
                _init_lora_layer(cfg, k)
                for k in jax.random.split(jax.random.fold_in(k_lora, 1),
                                          cfg.first_k_dense)]
        if cfg.encoder_layers:
            trainable["enc_lora"] = jax.vmap(
                lambda k: _enc_lora_init(cfg, k))(
                    jax.random.split(jax.random.fold_in(k_lora, 2),
                                     cfg.encoder_layers))
        return {"frozen": frozen, "trainable": trainable}

    def param_specs(self):
        cfg = self.cfg
        dt = _dtype(cfg)
        S = lambda *sh, d=dt: jax.ShapeDtypeStruct(sh, d)
        frozen: Dict[str, Any] = {
            "embed": S(cfg.vocab_size, cfg.d_model),
            "head": S(cfg.d_model, cfg.vocab_size),
            "final_norm": S(cfg.d_model, d=jnp.float32),
        }
        if not cfg.use_rope:
            frozen["pos_embed"] = S(cfg.max_pos, cfg.d_model)
        frozen["layers"] = _layer_specs(cfg, dt, lead=(self.n_scanned,))
        if cfg.first_k_dense:
            frozen["dense_layers"] = [
                _layer_specs(cfg, dt, dense_ff=cfg.dense_d_ff)
                for _ in range(cfg.first_k_dense)]
        if cfg.encoder_layers:
            frozen["enc_layers"] = _layer_specs(
                cfg, dt, lead=(cfg.encoder_layers,), encoder=True)
            frozen["enc_pos"] = S(cfg.n_frames, cfg.d_model)
            frozen["enc_final_norm"] = S(cfg.d_model, d=jnp.float32)
        if cfg.quant_bits:
            for key in ("layers", "dense_layers", "enc_layers"):
                if key in frozen:
                    frozen[key] = quantize_tree_specs(
                        frozen[key], bits=cfg.quant_bits,
                        block=cfg.quant_block, mode=cfg.quant_mode)
        tdt = jnp.dtype(cfg.trainable_dtype)
        trainable: Dict[str, Any] = {
            "lora": _lora_layer_specs(cfg, lead=(self.n_scanned,)),
            "adapter": adapter_lib.specs(
                cfg.d_model, d_ff=cfg.adapter_d_ff, dtype=tdt),
        }
        if cfg.first_k_dense:
            trainable["dense_lora"] = [
                _lora_layer_specs(cfg) for _ in range(cfg.first_k_dense)]
        if cfg.encoder_layers:
            trainable["enc_lora"] = _enc_lora_specs(
                cfg, lead=(cfg.encoder_layers,))
        return {"frozen": frozen, "trainable": trainable}

    # ---------------------------------------------------------- encoder
    def _encode(self, frozen, trainable, frames):
        cfg = self.cfg
        x = frames.astype(_dtype(cfg)) + frozen["enc_pos"][None]
        positions = jnp.arange(x.shape[1])

        def body(x, inp):
            p, lo = inp
            h, _ = L.attention(p, L.rms_norm(x, p["ln1"]), positions, cfg,
                               lora=lo, causal=False, use_rope=False)
            x = x + h
            x = x + L.mlp(p, L.rms_norm(x, p["ln2"]), cfg, lora=lo)
            return x, None

        xs = (frozen["enc_layers"], trainable["enc_lora"])
        if cfg.unroll_layers:
            for i in range(cfg.encoder_layers):
                x, _ = body(x, jax.tree.map(lambda l: l[i], xs))
            return L.rms_norm(x, frozen["enc_final_norm"])
        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = lax.scan(body, x, xs)
        return L.rms_norm(x, frozen["enc_final_norm"])

    # ---------------------------------------------------------- blocks
    def _block(self, p, lo, x, positions, enc_out, mode, cache=None,
               pos=None, cache_len=None, kind=0):
        """One layer. mode: 'train' | 'prefill' | 'decode'.
        Returns (x, cache_entry, aux)."""
        cfg = self.cfg
        fam = cfg.family
        decode = mode == "decode"
        aux = jnp.zeros((), jnp.float32)

        def attn_part(x):
            xin = L.rms_norm(x, p["ln1"])
            if decode:
                h, kv = L.attention_decode(
                    p, xin, pos, cache["kv"], cfg, lora=lo,
                    use_rope=cfg.use_rope)
            else:
                h, (k, v) = L.attention(
                    p, xin, positions, cfg, lora=lo, causal=True,
                    window=cfg.window, use_rope=cfg.use_rope)
                kv = L.ring_from_full(
                    k, v, cache_len, kv_quant=cfg.kv_quant_bits == 8) \
                    if mode == "prefill" else None
            return x + h, kv

        def lru_part(x):
            xin = L.rms_norm(x, p["ln1"])
            if decode:
                h, st = rglru_lib.rglru_decode(p, xin, cache["lru"], cfg,
                                               lora=lo)
            else:
                h, st = rglru_lib.rglru_block(p, xin, cfg, lora=lo)
                st = st if mode == "prefill" else None
            return x + h, st

        if fam == "ssm":
            xin = L.rms_norm(x, p["ln1"])
            if decode:
                h, st = ssm_lib.mamba_decode(p, xin, cache["ssm"], cfg,
                                             lora=lo)
            else:
                h, st = ssm_lib.mamba_block(p, xin, cfg, lora=lo)
            return x + h, {"ssm": st}, aux

        if fam == "hybrid":
            B = x.shape[0]
            M = cache["kv"]["k"].shape[1] if decode else cache_len
            # hybrid layers skip the outer scan-body remat (see _stack);
            # attention/MLP get their own checkpoints here, the RG-LRU
            # block checkpoints inside its shard_map body
            inner_remat = jax.checkpoint if (cfg.remat and mode == "train") \
                else (lambda f: f)

            def attn_branch(x):
                xa, kv = inner_remat(attn_part)(x)
                dummy = _dummy_lru(cfg, B, _dtype(cfg)) \
                    if mode != "train" else None
                return xa, {"kv": kv, "lru": dummy} if mode != "train" \
                    else {"kv": None, "lru": None}

            def lru_branch(x):
                xl, st = lru_part(x)
                dummy = _dummy_kv(cfg, B, M, _dtype(cfg)) \
                    if mode != "train" else None
                return xl, {"kv": dummy, "lru": st} if mode != "train" \
                    else {"kv": None, "lru": None}

            x, entry = lax.cond(kind == KIND_ID[ATTN], attn_branch,
                                lru_branch, x)
            mlp_fn = inner_remat(
                lambda h: L.mlp(p, L.rms_norm(h, p["ln2"]), cfg, lora=lo))
            x = x + mlp_fn(x)
            return x, entry, aux

        # attention families: dense / moe / vlm / encdec
        x, kv = attn_part(x)
        entry = {"kv": kv}
        if fam == "encdec":
            xin = L.rms_norm(x, p["lnc"])
            if decode:
                h, _ = L.attention_decode(
                    p, xin, pos, cache["ckv"], cfg, lora=lo, prefix="c",
                    use_rope=False, update_cache=False)
                entry["ckv"] = cache["ckv"]
            else:
                h, (ck, cv) = L.attention(
                    p, xin, positions, cfg, lora=lo, prefix="c",
                    causal=False, kv_x=enc_out, use_rope=False)
                entry["ckv"] = {"k": ck, "v": cv,
                                "slot_pos": jnp.arange(ck.shape[1],
                                                       dtype=jnp.int32)} \
                    if mode == "prefill" else None
            x = x + h
        if fam == "moe" and "moe" in p:
            y, aux = moe_lib.moe_ffn(p["moe"], L.rms_norm(x, p["ln2"]), cfg)
            if cfg.n_shared_experts:
                y = y + L.mlp(p["shared"], L.rms_norm(x, p["ln2"]), cfg,
                              kind="swiglu")
            x = x + y
        else:
            kind_mlp = "swiglu" if fam == "moe" else cfg.mlp
            x = x + L.mlp(p, L.rms_norm(x, p["ln2"]), cfg, lora=lo,
                          kind=kind_mlp)
        return x, entry, aux

    # ---------------------------------------------------------- forward
    def _embed_inputs(self, frozen, batch, mode):
        cfg = self.cfg
        dt = _dtype(cfg)
        tokens = batch["tokens"]
        x = jnp.take(frozen["embed"], tokens, axis=0).astype(dt)
        if cfg.family == "vlm" and "image_embeds" in batch:
            img = batch["image_embeds"].astype(dt)
            x = jnp.concatenate([img, x], axis=1)
        S = x.shape[1]
        if mode == "decode":
            positions = None
        else:
            positions = jnp.arange(S)
            if not cfg.use_rope:
                x = x + jnp.take(frozen["pos_embed"],
                                 jnp.minimum(positions, cfg.max_pos - 1),
                                 axis=0)[None]
        return x, positions

    def _stack(self, frozen, trainable, x, positions, enc_out, mode,
               cache=None, pos=None, cache_len=None):
        cfg = self.cfg
        dp = _dp(cfg)
        seq_ax = _seq_axis(cfg, x.shape[1])
        kinds = jnp.asarray(self.kinds)

        # unrolled first-k-dense layers (kimi-k2)
        new_dense_cache = []
        for i in range(cfg.first_k_dense):
            c = None if cache is None else \
                jax.tree.map(lambda l: l[i], cache["dense"])
            x, entry, _ = self._block(
                frozen["dense_layers"][i], trainable["dense_lora"][i], x,
                positions, enc_out, mode, cache=c, pos=pos,
                cache_len=cache_len)
            new_dense_cache.append(entry)
            x = rt_lib.constrain(x, dp, seq_ax, None)

        def body(carry, inp):
            x, aux = carry
            p, lo, kind, c = inp
            x, entry, a = self._block(p, lo, x, positions, enc_out, mode,
                                      cache=c, pos=pos,
                                      cache_len=cache_len, kind=kind)
            x = rt_lib.constrain(x, dp, seq_ax, None)
            return (x, aux + a), entry

        # scan-body remat — except for recurrent families, where wrapping
        # the shard_map'd chunked scan in jax.checkpoint compiles
        # pathologically slowly (25+ min vs 17 s); those blocks checkpoint
        # inside their shard_map bodies instead (models/ssm.py).
        if cfg.remat and mode == "train" and \
                cfg.family not in ("ssm", "hybrid"):
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)

        scan_cache = None if cache is None else cache["scan"]
        xs = (frozen["layers"], trainable["lora"], kinds, scan_cache)
        if cfg.unroll_layers:  # dry-run cost calibration: no while loop
            carry = (x, jnp.zeros((), jnp.float32))
            entries_list = []
            for i in range(self.n_scanned):
                xi = jax.tree.map(lambda l: l[i], xs)
                carry, e = body(carry, xi)
                entries_list.append(e)
            x, aux = carry
            entries = None
            if entries_list and entries_list[0] is not None and \
                    jax.tree.leaves(entries_list[0]):
                entries = jax.tree.map(lambda *ls: jnp.stack(ls),
                                       *entries_list)
        else:
            (x, aux), entries = lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), xs)
        new_cache = None
        if mode in ("prefill", "decode"):
            new_cache = {"scan": entries}
            if cfg.first_k_dense:
                new_cache["dense"] = jax.tree.map(
                    lambda *ls: jnp.stack(ls), *new_dense_cache) \
                    if len(new_dense_cache) > 1 else jax.tree.map(
                        lambda l: l[None], new_dense_cache[0])
        return x, aux, new_cache

    def forward(self, frozen, trainable, batch):
        """Training-shape forward. Returns (logits, moe aux loss)."""
        cfg = self.cfg
        enc_out = None
        if cfg.family == "encdec":
            enc_out = self._encode(frozen, trainable, batch["frames"])
        x, positions = self._embed_inputs(frozen, batch, "train")
        x, aux, _ = self._stack(frozen, trainable, x, positions, enc_out,
                                "train")
        x = L.rms_norm(x, frozen["final_norm"])
        x = adapter_lib.apply(trainable["adapter"], x,
                              n_heads=cfg.adapter_heads, causal=True)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            frozen["head"].astype(x.dtype))
        # keep logits vocab-replicated / seq-sharded so the CE gather and
        # logsumexp stay local (a vocab-sharded CE gather all-gathers the
        # full (B,S,V) logits — measured 16 GiB/device on yi-9b train_4k)
        logits = rt_lib.constrain(logits, _dp(cfg),
                                  _seq_axis(cfg, logits.shape[1]), None)
        return logits, aux

    # ---------------------------------------------------------- training
    def loss_fn(self, frozen, trainable, batch):
        logits, aux = self.forward(frozen, trainable, batch)
        mask = batch.get("mask")
        ce = losses.cross_entropy(logits, batch["labels"], mask)
        return ce + 0.01 * aux, {"ce": ce, "aux": aux}

    def train_step(self, frozen, trainable, opt_state, batch, *,
                   lr=1e-4):
        """One TriplePlay local client step: grads w.r.t. LoRA+adapter only.
        cfg.grad_accum > 1 scans microbatches and accumulates grads (the
        §Perf memory lever for the big-batch training shapes)."""
        A = self.cfg.grad_accum
        if A > 1:
            def micro(carry, mb):
                (loss, parts), g = jax.value_and_grad(
                    lambda tr: self.loss_fn(frozen, tr, mb),
                    has_aux=True)(trainable)
                acc, losses = carry
                acc = jax.tree.map(lambda a, b: a + b / A, acc, g)
                return (acc, losses + loss / A), None
            mbs = jax.tree.map(
                lambda l: l.reshape(A, l.shape[0] // A, *l.shape[1:]),
                batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), trainable)
            (grads, loss), _ = lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), mbs)
            parts = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        else:
            (loss, parts), grads = jax.value_and_grad(
                lambda tr: self.loss_fn(frozen, tr, batch), has_aux=True)(
                    trainable)
        trainable, opt_state = optim.adam_update(
            grads, opt_state, trainable, lr=lr, grad_clip=1.0)
        metrics = {"loss": loss, **parts,
                   "grad_norm": optim.global_norm(grads)}
        return trainable, opt_state, metrics

    # ---------------------------------------------------------- serving
    def effective_cache_len(self, context_len: int) -> int:
        if self.cfg.window:
            return min(context_len, self.cfg.window)
        return context_len

    def prefill(self, frozen, trainable, batch, max_len: int | None = None):
        """Returns (last-token logits (B, V), cache).

        ``max_len`` sizes the emitted cache (defaults to the prompt length);
        pass the serving context length so subsequent ``decode_step`` calls
        have room (sliding-window archs cap at the window regardless)."""
        cfg = self.cfg
        enc_out = None
        if cfg.family == "encdec":
            enc_out = self._encode(frozen, trainable, batch["frames"])
        x, positions = self._embed_inputs(frozen, batch, "prefill")
        M = self.effective_cache_len(max_len or x.shape[1])
        x, aux, cache = self._stack(frozen, trainable, x, positions,
                                    enc_out, "prefill", cache_len=M)
        x = L.rms_norm(x, frozen["final_norm"])
        x, acache = adapter_lib.prefill(
            trainable["adapter"], x,
            min(max_len or x.shape[1], cfg.adapter_window),
            n_heads=cfg.adapter_heads)
        cache["adapter"] = acache
        logits = jnp.einsum("bsd,dv->bsv", x,
                            frozen["head"].astype(x.dtype))[:, 0]
        return logits, cache

    def decode_step(self, frozen, trainable, cache, tokens, pos):
        """tokens: (B, 1); pos: scalar int32. Returns (logits (B, V), cache)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        x = jnp.take(frozen["embed"], tokens, axis=0).astype(dt)
        if not cfg.use_rope:
            x = x + jnp.take(frozen["pos_embed"],
                             jnp.minimum(pos, cfg.max_pos - 1),
                             axis=0)[None, None]
        acache = cache["adapter"]
        x, _, cache = self._stack(frozen, trainable, x, None, None,
                                  "decode", cache=cache, pos=pos)
        x = L.rms_norm(x, frozen["final_norm"])
        x, acache = adapter_lib.decode(trainable["adapter"], x, acache,
                                       pos, n_heads=cfg.adapter_heads)
        cache["adapter"] = acache
        logits = jnp.einsum("bsd,dv->bsv", x,
                            frozen["head"].astype(x.dtype))[:, 0]
        return logits, cache

    # ---------------------------------------------------------- caches
    def _entry_specs(self, batch, M, dt, init=False):
        """Per-layer cache entry (spec or zeros)."""
        cfg = self.cfg
        fam = cfg.family
        mk = (lambda tree: jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype) if s.dtype != jnp.int32
            else jnp.full(s.shape, -1, jnp.int32), tree)) if init else \
            (lambda tree: tree)
        if fam == "ssm":
            return {"ssm": mk(ssm_lib.mamba_cache_specs(cfg, batch, dt))}
        kv = mk(L.kv_cache_specs(cfg, batch, M, dt))
        if fam == "hybrid":
            return {"kv": kv,
                    "lru": mk(rglru_lib.rglru_cache_specs(cfg, batch, dt))}
        entry = {"kv": kv}
        if fam == "encdec":
            ck = L.kv_cache_specs(cfg, batch, cfg.n_frames, dt)
            entry["ckv"] = mk(ck)
        return entry

    def cache_specs(self, batch: int, context_len: int):
        cfg = self.cfg
        dt = _dtype(cfg)
        M = self.effective_cache_len(context_len)
        one = self._entry_specs(batch, M, dt)
        stack = lambda tree, n: jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), tree)
        out = {"scan": stack(one, self.n_scanned)}
        if cfg.first_k_dense:
            out["dense"] = stack(one, cfg.first_k_dense)
        out["adapter"] = adapter_lib.cache_specs(
            cfg.d_model, batch, min(context_len, cfg.adapter_window), dt,
            n_heads=cfg.adapter_heads)
        return out

    def init_cache(self, batch: int, context_len: int):
        cfg = self.cfg
        dt = _dtype(cfg)
        M = self.effective_cache_len(context_len)
        one = self._entry_specs(batch, M, dt, init=True)
        stack = lambda tree, n: jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n, *a.shape)).copy(), tree)
        out = {"scan": stack(one, self.n_scanned)}
        if cfg.first_k_dense:
            out["dense"] = stack(one, cfg.first_k_dense)
        aspec = adapter_lib.cache_specs(
            cfg.d_model, batch, min(context_len, cfg.adapter_window), dt,
            n_heads=cfg.adapter_heads)
        out["adapter"] = jax.tree.map(
            lambda s: jnp.full(s.shape, -1, jnp.int32)
            if s.dtype == jnp.int32 else jnp.zeros(s.shape, s.dtype), aspec)
        return out

    # ---------------------------------------------------------- inputs
    def input_specs(self, shape: InputShape) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a step."""
        cfg = self.cfg
        dt = _dtype(cfg)
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            S_text = S - cfg.n_patches if cfg.family == "vlm" else S
            specs = {"tokens": jax.ShapeDtypeStruct((B, S_text), i32),
                     "labels": jax.ShapeDtypeStruct((B, S), i32),
                     "mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
            if cfg.family == "vlm":
                specs["image_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_patches, cfg.d_model), dt)
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_frames, cfg.d_model), dt)
            return specs
        if shape.kind == "prefill":
            S_text = S - cfg.n_patches if cfg.family == "vlm" else S
            specs = {"tokens": jax.ShapeDtypeStruct((B, S_text), i32)}
            if cfg.family == "vlm":
                specs["image_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_patches, cfg.d_model), dt)
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_frames, cfg.d_model), dt)
            return specs
        # decode
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32),
                "cache": self.cache_specs(B, S)}


# ---------------------------------------------------------------- helpers
def _enc_lora_init(cfg, rng):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    t = dict(wq=(d, qd), wk=(d, kvd), wv=(d, kvd), wo=(qd, d))
    ks = jax.random.split(rng, len(t))
    tdt = jnp.dtype(cfg.trainable_dtype)
    return {n: lora_lib.init_pair(k, kk, nn, cfg.lora_rank, dtype=tdt)
            for (n, (kk, nn)), k in zip(sorted(t.items()), ks)}


def _enc_lora_specs(cfg, lead=()):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    t = dict(wq=(d, qd), wk=(d, kvd), wv=(d, kvd), wo=(qd, d))
    tdt = jnp.dtype(cfg.trainable_dtype)
    return {n: lora_lib.pair_specs(kk, nn, cfg.lora_rank, dtype=tdt,
                                   lead=lead)
            for n, (kk, nn) in sorted(t.items())}


def _dummy_kv(cfg, B, M, dt):
    sh = (B, M, cfg.n_kv_heads, cfg.head_dim)
    kdt = jnp.int8 if cfg.kv_quant_bits == 8 else dt
    c = {"k": jnp.zeros(sh, kdt), "v": jnp.zeros(sh, kdt),
         "slot_pos": jnp.full((M,), -1, jnp.int32)}
    if cfg.kv_quant_bits == 8:
        c["k_scale"] = jnp.zeros((*sh[:3], 1), jnp.float32)
        c["v_scale"] = jnp.zeros((*sh[:3], 1), jnp.float32)
    return c


def _dummy_lru(cfg, B, dt):
    w, K = cfg.lru_width or cfg.d_model, cfg.ssm_conv
    return {"h": jnp.zeros((B, w), jnp.float32),
            "conv": jnp.zeros((B, K - 1, w), dt)}


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
