"""Token-choice top-k MoE with explicit expert parallelism.

Two execution paths with identical routing semantics:

- **local** (no Runtime installed — unit tests, CPU examples): capacity-
  bounded gather/scatter dispatch into an (E, C, d) buffer, batched expert
  einsum, combine.
- **distributed** (under the production mesh): ``shard_map`` over
  ``(pod, data, model)``. Tokens are sharded over (dp × model); each rank
  builds an (E, C_e, d) send buffer, an ``all_to_all`` over the ``model``
  axis delivers token slots to their expert's owner, expert weights are
  2-D sharded (E→model, last-dim→data, FSDP-style) and all-gathered
  per-expert inside a scan, and a reverse ``all_to_all`` returns outputs.
  This is the collective pattern a real expert-parallel deployment uses,
  and the all-to-all / all-gather bytes it emits are what §Roofline reads.

Experts are part of the *frozen, quantizable* backbone (TriplePlay trains
only LoRA/adapter), so no optimizer state or weight gradients exist for
them — the backward pass only transports activation gradients through the
collectives (their transposes are themselves collectives).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import compat
from repro.core.quant import QTensor, dequantize
from repro.models import runtime as rt_lib


# ------------------------------------------------------------------ params
def init_experts(rng, cfg: ModelConfig, dtype):
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 4)
    s = lambda fan: 1.0 / jnp.sqrt(fan)
    return {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * s(d),
        "wg": jax.random.normal(ks[1], (E, d, ff), dtype) * s(d),
        "wu": jax.random.normal(ks[2], (E, d, ff), dtype) * s(d),
        "wd": jax.random.normal(ks[3], (E, ff, d), dtype) * s(ff),
    }


def expert_specs(cfg: ModelConfig, dtype, lead=()):
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.d_ff
    f = lambda *sh: jax.ShapeDtypeStruct((*lead, *sh), dtype)
    return {"router": jax.ShapeDtypeStruct((*lead, d, E), jnp.float32),
            "wg": f(E, d, ff), "wu": f(E, d, ff), "wd": f(E, ff, d)}


def expert_partition_specs(params, tp_axis="model", fsdp_axis="data",
                           lead_scanned=True):
    """PartitionSpec tree for the (possibly quantized) expert params.
    Uniform rule: E dim -> tp axis, last dim -> fsdp axis; router replicated.
    ``lead_scanned``: params carry a leading (L,) stacked-layer dim."""
    def spec(path, leaf):
        name = path[-1] if isinstance(path[-1], str) else str(path[-1])
        nlead = 1 if lead_scanned else 0
        if "router" in str(path):
            return P(*([None] * leaf.ndim))
        dims = [None] * leaf.ndim
        dims[nlead] = tp_axis          # E dim
        dims[-1] = fsdp_axis           # d or ff — uniformly gatherable
        return P(*dims)
    return _tree_map_with_name(spec, params)


def _tree_map_with_name(fn, tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda l: isinstance(l, jax.ShapeDtypeStruct))
    out = [fn(tuple(str(k) for k in path), leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------------------------ routing
def _route(router_w, x2d, cfg: ModelConfig):
    """x2d: (T, d) -> (gates (T,k), ids (T,k)) with renormalized gates."""
    logits = x2d.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # auxiliary load-balance statistics (Switch-style)
    density = jnp.mean(jax.nn.one_hot(ids[:, 0], cfg.n_experts), axis=0)
    p_mean = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(density * p_mean)
    return gates, ids, aux


def _slot_assignment(ids_flat: jax.Array, E: int, C: int):
    """Capacity-bounded slot for every token-copy.

    Returns (order, sorted_ids, slot, keep): sorting token-copies by expert
    id, ``slot`` is the position within the expert's segment; copies with
    slot >= C are dropped (their gate contribution becomes zero)."""
    order = jnp.argsort(ids_flat, stable=True)
    sorted_ids = ids_flat[order]
    seg_start = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
    slot = jnp.arange(ids_flat.size, dtype=jnp.int32) - seg_start
    keep = slot < C
    return order, sorted_ids, slot, keep


def _expert_mlp(x_e, wg_e, wu_e, wd_e, dtype):
    h = jax.nn.silu(x_e @ wg_e.astype(dtype)) * (x_e @ wu_e.astype(dtype))
    return h @ wd_e.astype(dtype)


def _deq(w, dtype):
    return dequantize(w, dtype) if isinstance(w, QTensor) else w


# ------------------------------------------------------------------ local
def _moe_local(p, x2d, cfg: ModelConfig):
    T, d = x2d.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    C = max(1, math.ceil(T * k * cfg.capacity_factor / E))
    gates, ids, aux = _route(p["router"], x2d, cfg)
    order, sorted_ids, slot, keep = _slot_assignment(ids.reshape(-1), E, C)
    vals = x2d[order // k]
    buf = jnp.zeros((E, C, d), x2d.dtype).at[
        sorted_ids, jnp.where(keep, slot, C)].set(vals, mode="drop")
    wg, wu, wd = (_deq(p[n], x2d.dtype) for n in ("wg", "wu", "wd"))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
        jnp.einsum("ecd,edf->ecf", buf, wu)
    out = jnp.einsum("ecf,efd->ecd", h, wd)
    y_sorted = out.at[sorted_ids, jnp.where(keep, slot, C)].get(
        mode="fill", fill_value=0)
    y_copies = jnp.zeros_like(y_sorted).at[order].set(
        y_sorted * keep[:, None].astype(y_sorted.dtype))
    y = (y_copies.reshape(T, k, d) *
         gates[..., None].astype(y_copies.dtype)).sum(axis=1)
    return y, aux


# ------------------------------------------------------------------ dist
def _q8_rows(x):
    """Per-row absmax int8 quantization (for low-precision dispatch)."""
    s = jnp.maximum(jnp.abs(x.astype(jnp.float32)).max(-1, keepdims=True),
                    1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127,
                 127).astype(jnp.int8)
    return q, s


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _a2a_q8(x, tp_axis):
    """int8 all_to_all: per-row absmax quantize, exchange payload+scales,
    dequantize. Both directions (activations fwd, cotangents bwd) ride the
    wire in int8 — the DeepSeek-V3 low-precision-dispatch pattern."""
    q, s = _q8_rows(x)
    q = lax.all_to_all(q, tp_axis, split_axis=0, concat_axis=0, tiled=True)
    s = lax.all_to_all(s, tp_axis, split_axis=0, concat_axis=0, tiled=True)
    return (q.astype(jnp.float32) * s).astype(x.dtype)


def _a2a_q8_fwd(x, tp_axis):
    return _a2a_q8(x, tp_axis), None


def _a2a_q8_bwd(tp_axis, _, g):
    # all_to_all (split=concat, tiled) is its own transpose
    return (_a2a_q8(g, tp_axis),)


_a2a_q8.defvjp(_a2a_q8_fwd, _a2a_q8_bwd)


def _a2a_maybe_q8(x, tp_axis, enabled, dtype):
    """all_to_all with optional int8 payload + f32 per-row scales."""
    if not enabled:
        return lax.all_to_all(x, tp_axis, split_axis=0, concat_axis=0,
                              tiled=True)
    return _a2a_q8(x, tp_axis).astype(dtype)


def _moe_dist_body(x_loc, p, cfg: ModelConfig, m: int, tp_axis: str,
                   fsdp_axis: str):
    """Runs per-device inside shard_map. x_loc: (T_ls, d) local token slice.
    p: expert params with local shards (E/m experts × last-dim/fsdp)."""
    T_ls, d = x_loc.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    E_l = E // m
    C = max(1, math.ceil(T_ls * k * cfg.capacity_factor / E))
    dtype = x_loc.dtype

    gates, ids, aux = _route(p["router"], x_loc, cfg)  # router replicated
    order, sorted_ids, slot, keep = _slot_assignment(ids.reshape(-1), E, C)
    vals = x_loc[order // k]
    send = jnp.zeros((E, C, d), dtype).at[
        sorted_ids, jnp.where(keep, slot, C)].set(vals, mode="drop")

    # exchange slots with expert owners: (m, E_l, C, d) transpose-a2a
    q8 = cfg.moe_dispatch_bits == 8
    send = send.reshape(m, E_l, C, d)
    recv = _a2a_maybe_q8(send, tp_axis, q8, dtype)       # (m_src, E_l, C, d)
    toks = recv.transpose(1, 0, 2, 3).reshape(E_l, m * C, d)

    # per-expert FSDP: gather this expert's full weights over `fsdp_axis`
    gather = lambda w: jax.tree.map(
        lambda l: lax.all_gather(l, fsdp_axis, axis=l.ndim - 1,
                                 tiled=True), w)
    if cfg.calibrate:
        # batched expert einsum (no scan) for exact FLOP accounting
        wg_f = _deq(gather(p["wg"]), dtype)
        wu_f = _deq(gather(p["wu"]), dtype)
        wd_f = _deq(gather(p["wd"]), dtype)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", toks, wg_f)) * \
            jnp.einsum("ecd,edf->ecf", toks, wu_f)
        y_experts = jnp.einsum("ecf,efd->ecd", h, wd_f)
    else:
        def body(_, inp):
            x_e, wg_e, wu_e, wd_e = inp
            wg_f = _deq(gather(wg_e), dtype)
            wu_f = _deq(gather(wu_e), dtype)
            wd_f = _deq(gather(wd_e), dtype)
            return None, _expert_mlp(x_e, wg_f, wu_f, wd_f, dtype)

        xs = (toks, p["wg"], p["wu"], p["wd"])
        _, y_experts = lax.scan(body, None, xs)          # (E_l, m*C, d)

    y_back = y_experts.reshape(E_l, m, C, d).transpose(1, 0, 2, 3)
    y_home = _a2a_maybe_q8(y_back, tp_axis, q8, dtype)   # (m, E_l, C, d)
    y_buf = y_home.reshape(E, C, d)
    y_sorted = y_buf.at[sorted_ids, jnp.where(keep, slot, C)].get(
        mode="fill", fill_value=0)
    y_copies = jnp.zeros_like(y_sorted).at[order].set(
        y_sorted * keep[:, None].astype(dtype))
    y = (y_copies.reshape(T_ls, k, d) *
         gates[..., None].astype(dtype)).sum(axis=1)
    return y, aux


def moe_ffn(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (y (B, S, d), aux load-balance loss).

    Dispatches to the shard_map expert-parallel path when a Runtime is
    installed, else to the local path."""
    B, S, d = x.shape
    rt = rt_lib.get_runtime()
    if rt is None:
        y, aux = _moe_local(p, x.reshape(B * S, d), cfg)
        return y.reshape(B, S, d), aux

    mesh = rt.mesh
    m = rt.tp_size
    dp = rt.dp_axes
    tp, fsdp = rt.tp_axis, "data"
    pspecs = expert_partition_specs(p, tp_axis=tp, fsdp_axis=fsdp,
                                    lead_scanned=False)
    seq_shardable = S > 1 and S % m == 0

    all_axes = tuple(dp) + (tp,)

    if seq_shardable:
        def fn(x_in, p_in):
            x_loc = x_in.reshape(-1, d)
            y, aux = _moe_dist_body(x_loc, p_in, cfg, m, tp, fsdp)
            return y.reshape(x_in.shape), lax.pmean(aux, all_axes)
        return compat.shard_map(
            fn, mesh=mesh,
            in_specs=(P(dp, tp, None), pspecs),
            out_specs=(P(dp, tp, None), P()),
            check_vma=False)(x, p)

    # decode path: S == 1 -> split the batch over the tp axis inside
    def fn(x_in, p_in):
        Bl = x_in.shape[0]
        t = max(1, -(-Bl // m))
        r = lax.axis_index(tp)
        x_pad = jnp.pad(x_in.reshape(Bl, d), ((0, m * t - Bl), (0, 0)))
        x_loc = lax.dynamic_slice_in_dim(x_pad, r * t, t, axis=0)
        y_loc, aux = _moe_dist_body(x_loc, p_in, cfg, m, tp, fsdp)
        y_all = lax.all_gather(y_loc, tp, axis=0, tiled=True)[:Bl]
        return y_all.reshape(x_in.shape), lax.pmean(aux, all_axes)
    return compat.shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp, None, None), pspecs),
        out_specs=(P(dp, None, None), P()),
        check_vma=False)(x, p)
