"""Batched cohort execution engine: vmap/scan-fused federated rounds.

The sequential simulator runs each round as a Python loop over clients
with one jitted step per local batch — O(n_clients * local_steps) device
dispatches plus a host->device transfer per step. But with a frozen,
shared backbone and a tiny trainable tree, every client's local training
is the *same program over different data and trainable state*, which is
exactly the shape ``jax.vmap`` (over the cohort) + ``jax.lax.scan`` (over
local steps) compile into one fused device program.

This engine therefore executes an entire federated round — local Adam
training for every selected client, delta computation, per-client uplink
quantization, and weighted FedAvg aggregation — as **one jitted,
buffer-donated call**:

 - client trainables are stacked along a leading cohort axis (every
   client starts a round from the global trainables, so the stack is a
   broadcast);
 - each client's (GAN-rebalanced) data pool is staged on device once,
   zero-padded to a fixed shape (n_clients, P, ...) so shapes never
   recompile — and staging hoists every trainable-independent prefix of
   the forward to a one-time cost: pools are stored as pooled backbone
   features (adapter-only arms) or embedded patch tokens (LoRA arms),
   so local steps never re-run frozen computation the sequential
   interpreter redoes per batch;
 - per-step batch indices are drawn with ``jax.random`` in one small
   dedicated dispatch per round on replicated inputs (padding rows are
   never sampled: indices live in [0, pool_len)) and fed to the fused
   round as data, keeping the draw independent of the mesh layout;
 - uplink compression reuses the exact blockwise layout of the
   sequential path (quantization blocks run along trailing dims, so the
   stacked quantization is elementwise-identical to quantizing each
   client's delta separately);
 - with a mesh, the staged cohort arrays (and the per-round cohort-axis
   inputs) are sharded over the data-parallel axes
   (``launch.mesh.cohort_sharding``) and pjit splits the vmapped round
   across devices; aggregation then runs hierarchically
   (``server.aggregate_tree``): each shard reduces its own cohort rows
   to a partial weighted sum + partial mass, and only the small
   (shards, ...) partials cross the mesh in the global reduce.

The sequential ``Client.local_train`` path stays alive as the reference
oracle; ``round_indices`` reproduces the engine's sample sequence so
parity tests can drive both paths with identical batches.

Partial participation (``fl.sched``) builds on the same staging: the
pools of *all* clients stay device-resident, and a subset round is the
same fused program prefixed with a gather — ``pool_staged[sel]`` — so
selecting a different subset each round never re-uploads data.
``run_subset_round`` aggregates in-program (sync-partial); ``run_wave``
stops before aggregation and returns the stacked quantized deltas,
which the async scheduler buffers on the host and commits with
staleness-discounted weights. Heterogeneous per-client local-step
counts (availability traces) run inside the same fixed-length scan via
the ``active`` mask of ``optim.adam_scan`` — a masked step is a bitwise
no-op on (params, opt state).

Every fused program compiles and executes through the shared
:class:`repro.fl.runtime.ProgramRuntime` (AOT ``lower().compile()``,
one cache, per-kind compile accounting), and subset/wave cohort widths
are padded to power-of-two buckets (``runtime.bucket_width``): a
selection of K clients runs at width ``B >= K`` with pad rows that
gather client 0's staged pool, receive zero-filled batch indices (the
true K rows keep the exact ``round_indices`` sample stream — indices
are drawn outside the program at the true width), and carry zero
aggregation weight, so padding never leaks into sampling, aggregation,
or uplink accounting while a K-sweep compiles O(log N) programs instead
of O(N). On a mesh the bucket additionally rounds up to a shard
multiple (``bucket_width(..., shards=...)``) so the bucketed cohort
axis always splits evenly over the data-parallel shards. K=N never pads
(``bucket_width(N, N) == N``), keeping the degenerate full-sync case
bit-identical to the gather-free full round.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clip as clip_lib
from repro.core import lora as lora_lib
from repro.core import losses, optim, quant
from repro.core.quant import tree_bytes
from repro.data.synthetic import stage_client_pools
from repro.fl import client as client_lib
from repro.fl import runtime as runtime_lib
from repro.fl import server
from repro.fl import strategies as strategies_lib
from repro.fl.strategies import Strategy
from repro.launch import mesh as mesh_lib


@dataclass(frozen=True)
class CohortConfig:
    """Static round-execution parameters (baked into the jitted round)."""
    strategy: Strategy
    local_steps: int
    batch_size: int
    lr: float
    mesh: Any = None          # optional Mesh: shard cohort over dp axes
    donate: bool = True       # donate the global-trainable buffers
    # stage the masked (heterogeneous-step) programs even when every
    # client's trace multiplier is 1 — the chaos layer cuts step counts
    # per client at dispatch time, which is just a heterogeneous step
    # profile the engine must be staged to honor
    force_het: bool = False


def encode_rows(frozen, ccfg, *, use_lora: bool, rows, runtime=None,
                chunk: int = 512):
    """Encode ``(n, H, W, ch)`` image rows through the
    trainable-independent prefix of the forward — the whole frozen
    backbone (pooled features) for adapter-only arms, the patch
    embedding (tokens) for LoRA arms — in fixed-size chunks through the
    shared program runtime. Full chunks run at ``chunk`` rows; the
    ragged tail pads to its power-of-two bucket, so any row count
    reuses O(log chunk) compiles while the pad waste stays below the
    tail itself (never a full chunk)."""
    runtime = runtime or runtime_lib.ProgramRuntime()
    n = rows.shape[0]
    flat = jnp.asarray(rows)

    def build():
        if use_lora:
            return lambda fz, x: clip_lib.embed_patches(fz, ccfg, x)
        return lambda fz, x: clip_lib.encode_image(fz, ccfg, x)

    def encode(piece):
        args = (frozen, piece)
        return runtime.compile("stage_encode", build, args,
                               static_key=(ccfg, use_lora))(*args)

    out = [encode(flat[i:i + chunk])
           for i in range(0, n - n % chunk, chunk)]
    tail = n % chunk
    if tail:
        ck = runtime_lib.bucket_rows(tail, chunk)
        out.append(encode(runtime_lib.pad_leading(
            flat[n - tail:], ck))[:tail])
    return jnp.concatenate(out) if len(out) != 1 else out[0][:n]


def stage_encoded_pools(frozen, ccfg, *, use_lora: bool, imgs, put=None,
                        chunk: int = 512, runtime=None):
    """Encode padded client pools ``(C, P, H, W, ch)`` via
    :func:`encode_rows` and reshape back to the cohort layout.

    This is the single staging pipeline for every pool that enters the
    cohort engine: raw client data and the fleet-GAN rebalancing sets
    (``fl.fleetgan``) flow through it identically, so GAN-augmented
    pools cost one staging pass like any other pool."""
    put = jnp.asarray if put is None else put
    C, P = imgs.shape[:2]
    staged = encode_rows(
        frozen, ccfg, use_lora=use_lora,
        rows=jnp.asarray(imgs).reshape(C * P, *imgs.shape[2:]),
        runtime=runtime, chunk=chunk)
    return put(staged.reshape(C, P, *staged.shape[1:]))


def sample_batch_indices(key, lens, steps: int, batch: int):
    """(n_clients, steps, batch) pool indices, client i's in
    [0, lens[i]). The engine draws these in a dedicated small dispatch on
    *replicated* inputs — never inside the sharded round program, where
    non-partitionable threefry would make the draw depend on the mesh
    layout — so ``round_indices`` (the eager form driving the sequential
    oracle) reproduces the engine's batches exactly on any mesh."""
    keys = jax.random.split(key, lens.shape[0])
    return jax.vmap(
        lambda k, n: jax.random.randint(k, (steps, batch), 0, n))(
            keys, lens)


def round_indices(key, lens, steps: int, batch: int) -> np.ndarray:
    """Host-side view of one round's per-client batch indices. For subset
    rounds pass ``lens[sel]`` (and the engine's ``max_steps``) — the
    fused program and the sequential oracle then see identical batches."""
    return np.asarray(sample_batch_indices(
        key, jnp.asarray(lens, jnp.int32), steps, batch))


def client_logits(frozen, ccfg, trainable, x, class_emb, *,
                  use_lora: bool):
    """One client's forward from its *staged* input to zero-shot class
    logits: ``x`` is the hoisted trainable-independent prefix output —
    pooled backbone features for adapter-only arms, embedded patch
    tokens for LoRA arms (see :func:`encode_rows`).

    This is the single stacked-adapter apply path: the cohort training
    loss vmaps it over the cohort axis, and the serving plane
    (``fl.serve``) vmaps it over the request axis (its quantized-at-rest
    store swaps in a ``quant_matmul`` head that tests pin against this
    definition), so train-time and serve-time personalization share one
    forward."""
    feat = clip_lib.encode_tokens(frozen, ccfg, x,
                                  lora=trainable.get("lora")) \
        if use_lora else x
    return client_lib.head_logits(frozen, trainable, feat, class_emb)


def slice_client_delta(stacked_delta, i: int):
    """Extract client ``i``'s delta from a stacked (possibly quantized)
    delta tree. QTensor leaves are re-wrapped with per-client metadata so
    slices taken from waves of different widths share one treedef (the
    async scheduler stacks buffered slices across waves) and
    ``tree_bytes`` reports the true per-client uplink payload."""
    def f(l):
        if isinstance(l, quant.QTensor):
            return quant.QTensor(
                q=l.q[i], scales=l.scales[i], bits=l.bits, mode=l.mode,
                block=l.block, out_dtype=l.out_dtype,
                orig_shape=tuple(l.orig_shape[1:]))
        return l[i]
    return jax.tree.map(f, stacked_delta,
                        is_leaf=lambda l: isinstance(l, quant.QTensor))


def comm_quantize_stacked(delta, strategy: Strategy):
    """Uplink-quantize a stacked delta tree (leading cohort axis) with
    semantics identical to each client quantizing its own delta:
    eligibility and block choice use the *per-client* leaf shape, and the
    blockwise absmax runs along trailing dims only, so the leading axis
    is inert."""
    if not strategy.comm_bits:
        return delta
    flat, treedef = jax.tree_util.tree_flatten_with_path(delta)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(k) for k in path)
        per_client = leaf.shape[1:]
        if not quant._quantizable(pstr, per_client, leaf.dtype,
                                  strategies_lib.COMM_MIN_SIZE,
                                  strategies_lib.COMM_SKIP):
            out.append(leaf)
            continue
        b = quant._pick_block(per_client[-2], strategies_lib.COMM_BLOCK)
        bits, mode = strategy.comm_bits, "linear"
        if b % 2:
            bits, mode = 8, "linear"
        out.append(quant.quantize(leaf, bits=bits, block=b, mode=mode))
    return jax.tree_util.tree_unflatten(treedef, out)


class CohortEngine:
    """One-dispatch-per-round federated executor.

    Built once per simulation from the instantiated clients; ``run_round``
    then advances the global trainables with a single jitted call
    returning per-client last-step loss/acc.
    """

    def __init__(self, *, frozen, ccfg, class_emb,
                 clients: Sequence[client_lib.Client], cfg: CohortConfig,
                 runtime=None, gan_job=None):
        self.cfg = cfg
        self.runtime = runtime if runtime is not None else \
            runtime_lib.ProgramRuntime()
        self.n_clients = len(clients)
        empty = [c.cid for c in clients if len(c.pool()[1]) == 0]
        if empty:
            raise ValueError(
                f"clients {empty} have empty pools; federated rounds "
                "(sequential or cohort) need every participant to hold "
                "data — drop them from the cohort")
        if gan_job is not None and cfg.mesh is not None:
            # the pending-GAN overlap path scatters into the staged
            # buffer with a plain .at[] update; keep the sharded layout
            # on the simple resolve-first path
            gan_job.resolve()
            gan_job = None
        if gan_job is not None:
            # Overlap fleet-GAN prep with pool staging: the GAN job's
            # rebalancing-set *sizes and labels* are host-known at launch
            # (rebalance_labels is a label histogram), so the padded pool
            # layout, lens, and labels are final now — only the
            # synthesized image contents are still computing on device.
            # Stage the raw rows immediately (the zero rows reserved for
            # the synthetic images are overwritten in feature space once
            # the job resolves below).
            pools = []
            for i, c in enumerate(clients):
                nd = gan_job.need.get(i, np.zeros((0,), np.int32))
                pools.append((
                    np.concatenate([
                        np.asarray(c.images, np.float32),
                        np.zeros((len(nd), *c.images.shape[1:]),
                                 np.float32)]),
                    np.concatenate([np.asarray(c.labels, np.int32),
                                    nd])))
        else:
            pools = [c.pool() for c in clients]
        imgs, labs, lens = stage_client_pools(pools)
        self.client_n = np.asarray([c.n for c in clients], np.float32)
        weights = self.client_n / self.client_n.sum()
        # trace-assigned compute heterogeneity: client i runs
        # local_steps * step_mult[i] steps; the fused program scans the
        # static max and masks the tail per client.
        self.step_mult = np.asarray(
            [c.local_steps_for(1) for c in clients], np.int32)
        if self.step_mult.max() > strategies_lib.MAX_STEP_MULT:
            raise ValueError(
                f"client step multipliers {self.step_mult.max()} exceed "
                f"strategies.MAX_STEP_MULT={strategies_lib.MAX_STEP_MULT}"
                " — the fused scan length must stay bounded")
        self.max_steps = cfg.local_steps * int(self.step_mult.max())
        self._het = bool(self.step_mult.max() > 1 or cfg.force_het)

        if cfg.mesh is not None:
            shards = mesh_lib.cohort_axis_size(cfg.mesh)
            if self.n_clients % shards:
                raise ValueError(
                    f"cohort of {self.n_clients} clients not divisible by "
                    f"the mesh's {shards} data-parallel shards")
            put = lambda x: jax.device_put(
                x, mesh_lib.cohort_sharding(cfg.mesh, np.ndim(x)))
        else:
            shards = 1
            put = jnp.asarray
        # cohort-axis shard count: subset/wave widths bucket to shard
        # multiples (runtime.bucket_width(shards=...)) and the
        # in-program FedAvg runs hierarchically (shard-local partial
        # sums -> global reduce) so the full stacked delta is never
        # reduced on one device
        self.shards = shards
        self._put = put
        self._rep = mesh_lib.replicated_sharding(cfg.mesh) \
            if cfg.mesh is not None else None

        # Hoist every trainable-independent prefix of the forward out of
        # the training loop — staging the pool once per engine makes this
        # a one-time cost instead of a per-step one:
        #  - no LoRA: the whole frozen backbone; the pool is stored as
        #    pooled features (C, P, d) and local steps train only the
        #    adapter head;
        #  - with LoRA: the patch embedding (+cls+pos), which LoRA never
        #    touches; the pool is stored as embedded tokens
        #    (C, P, S, d).
        # GAN-rebalanced pools (fl.fleetgan) arrive here already
        # augmented via Client.pool() and stage like any other pool.
        self.pool_staged = stage_encoded_pools(
            frozen, ccfg, use_lora=cfg.strategy.use_lora, imgs=imgs,
            put=put, runtime=self.runtime)
        self.pool_labs = put(labs)
        # lens stays replicated: it feeds the dedicated host-side batch
        # index draw (sample_batch_indices), never the sharded round
        self.lens = jnp.asarray(lens, jnp.int32)
        self.weights = put(weights.astype(np.float32))
        self.frozen = frozen
        self.class_emb = class_emb
        self.ccfg = ccfg
        self._uplink_per_client: Optional[int] = None
        # programs the engine closes over self.cfg/self.ccfg for: the
        # runtime cache key must carry those statics so engines sharing
        # one runtime (benchmark sweeps) never collide. The LoRA matmul
        # routing (fused op vs legacy einsum chain, REPRO_LORA_FUSED) is
        # read at trace time inside core.lora.linear, so it is a static
        # of the traced program too — without it a bench flipping the
        # env var between engines would hit a stale executable compiled
        # for the other path
        self._static_key = (cfg.strategy, ccfg, cfg.local_steps,
                            cfg.batch_size, cfg.lr, self._het,
                            self.max_steps, cfg.mesh,
                            lora_lib._fused_enabled())
        if gan_job is not None:
            self._merge_gan_features(gan_job, clients)

    def _merge_gan_features(self, gan_job, clients):
        """Land a pending fleet-GAN job into the already-staged pools:
        resolve the job (blocks on the GAN device work that overlapped
        staging), encode the synthesized rows through the same staging
        program, and scatter them into their reserved slots. One staging
        pipeline, two passes over disjoint rows."""
        gan_job.resolve()
        # chaos: clients that dropped between launch and resolve never
        # delivered their synthesized rows, but the padded pool layout
        # (fixed at launch) reserved slots for them — shrink their lens
        # back to the raw pool so the zero-feature reserved rows are
        # never sampled (lens is the sampling bound, so this is exact)
        dropped = [i for i in sorted(getattr(gan_job, "dropped", ()))
                   if len(gan_job.need.get(i, ())) > 0]
        if dropped:
            raw = jnp.asarray([clients[i].n for i in dropped], jnp.int32)
            self.lens = self.lens.at[jnp.asarray(dropped)].set(raw)
        aug = [(i, c.aug_images) for i, c in enumerate(clients)
               if c.aug_images is not None and len(c.aug_images)]
        if not aug:
            return
        rows = np.concatenate([a for _, a in aug]).astype(np.float32)
        feats = encode_rows(
            self.frozen, self.ccfg, use_lora=self.cfg.strategy.use_lora,
            rows=rows, runtime=self.runtime)
        ci = np.concatenate([np.full(len(a), i, np.int32)
                             for i, a in aug])
        # synthetic rows sit right after client i's raw rows (the pool
        # layout Client.pool() produces)
        ri = np.concatenate([clients[i].n + np.arange(len(a))
                             for i, a in aug]).astype(np.int32)
        self.pool_staged = self.pool_staged.at[
            jnp.asarray(ci), jnp.asarray(ri)].set(feats)

    def _sample_idx(self, key, lens, steps: int):
        """Per-round batch indices through the runtime cache (kind
        ``sample_idx`` — one tiny program per distinct selection
        width)."""
        batch = self.cfg.batch_size

        def build():
            return lambda k, l: sample_batch_indices(k, l, steps, batch)

        args = (key, lens)
        return self.runtime.run(
            "sample_idx", build, args,
            static_key=(steps, batch))

    # -- uplink accounting --------------------------------------------
    def per_client_uplink_bytes(self, global_tr) -> int:
        """One client's (quantized) delta payload. Shape-only (no device
        work), computed once via the spec path of the quantizer; exact
        for every participant because quantization is leading-axis-inert
        and all clients share the trainable shapes."""
        if self._uplink_per_client is None:
            specs = jax.tree.map(
                lambda g: jax.ShapeDtypeStruct(g.shape, jnp.float32),
                global_tr)
            if self.cfg.strategy.comm_bits:
                specs = quant.quantize_tree_specs(
                    specs, bits=self.cfg.strategy.comm_bits,
                    block=strategies_lib.COMM_BLOCK,
                    min_size=strategies_lib.COMM_MIN_SIZE,
                    skip_names=strategies_lib.COMM_SKIP)
            self._uplink_per_client = tree_bytes(specs)
        return self._uplink_per_client

    def uplink_bytes(self, global_tr) -> int:
        """Full-cohort round uplink: n_clients x per-client delta size."""
        return self.n_clients * self.per_client_uplink_bytes(global_tr)

    # -- the fused round ----------------------------------------------
    def _local_train(self, frozen, class_emb, tr, staged, labs, ix,
                     n_steps=None):
        """One client's local training (vmapped over the cohort axis),
        shared by the full, subset, and wave programs. ``n_steps`` (a
        traced scalar) masks the tail of the fixed-length scan for
        heterogeneous step counts; ``None`` keeps the unmasked PR 1
        program byte-for-byte."""
        lr = self.cfg.lr
        ccfg = self.ccfg
        use_lora = self.cfg.strategy.use_lora
        opt = optim.adam_init(tr)

        def grad_fn(t, ixt):
            bx, by = staged[ixt], labs[ixt]

            def loss_fn(tt):
                logits = client_logits(frozen, ccfg, tt, bx, class_emb,
                                       use_lora=use_lora)
                return (losses.cross_entropy(logits, by),
                        losses.accuracy(logits, by))

            (loss, acc), g = jax.value_and_grad(
                loss_fn, has_aux=True)(t)
            return g, (loss, acc)

        active = None if n_steps is None else \
            optim.step_mask(n_steps, ix.shape[0])
        tr, opt, (ls, accs) = optim.adam_scan(
            grad_fn, tr, opt, ix, lr=lr, grad_clip=1.0, active=active)
        if n_steps is None:
            return tr, ls[-1], accs[-1]
        return tr, jnp.take(ls, n_steps - 1), jnp.take(accs, n_steps - 1)

    def _train_cohort(self, global_tr, staged, labs, idx, n_steps,
                      frozen, class_emb):
        """Broadcast the global trainables over the cohort, train every
        client, and return (stacked quantized deltas, loss, acc)."""
        C = idx.shape[0]
        cohort_tr = jax.tree.map(
            lambda g: jnp.broadcast_to(g[None], (C,) + g.shape),
            global_tr)
        if n_steps is None:
            after, loss, acc = jax.vmap(
                lambda tr, s, l, ix: self._local_train(
                    frozen, class_emb, tr, s, l, ix))(
                cohort_tr, staged, labs, idx)
        else:
            after, loss, acc = jax.vmap(
                lambda tr, s, l, ix, n: self._local_train(
                    frozen, class_emb, tr, s, l, ix, n))(
                cohort_tr, staged, labs, idx, n_steps)
        delta = jax.tree.map(
            lambda a, g: (a - g[None]).astype(jnp.float32),
            after, global_tr)
        return comm_quantize_stacked(delta, self.cfg.strategy), loss, acc

    def _aggregate(self, global_tr, weights, delta):
        """In-program FedAvg. Unsharded engines keep the flat
        ``aggregate_stacked`` reduction bit-for-bit (the K=N == full
        round identity depends on it); mesh engines aggregate
        hierarchically — each shard reduces its own cohort rows to a
        partial sum + partial mass and only the (shards, ...) partials
        cross the mesh — so the stacked delta is never reduced on one
        device. Tree == flat within fp tolerance (re-association),
        pinned by the hypothesis property in tests/test_runtime.py."""
        if self.shards > 1:
            return server.aggregate_tree(global_tr, weights, delta,
                                         n_shards=self.shards)
        return server.aggregate_stacked(global_tr, weights, delta)

    def _build_round(self):
        def round_fn(global_tr, idx, pool_staged, pool_labs, weights,
                     frozen, class_emb):
            delta, loss, acc = self._train_cohort(
                global_tr, pool_staged, pool_labs, idx, None, frozen,
                class_emb)
            new_global = self._aggregate(global_tr, weights, delta)
            return new_global, loss, acc

        return round_fn

    def _build_subset_round(self):
        """Sync-partial round at a fixed (bucketed) cohort width: gather
        the selected clients' already-staged pools (no re-upload, one
        compile per width bucket), train, quantize, and aggregate
        in-program with the host-normalized subset weights (zero for pad
        rows)."""
        het = self._het

        def round_fn(global_tr, sel, n_steps, idx, pool_staged,
                     pool_labs, weights, frozen, class_emb):
            staged = jnp.take(pool_staged, sel, axis=0)
            labs = jnp.take(pool_labs, sel, axis=0)
            delta, loss, acc = self._train_cohort(
                global_tr, staged, labs, idx, n_steps if het else None,
                frozen, class_emb)
            new_global = self._aggregate(global_tr, weights, delta)
            return new_global, loss, acc

        return round_fn

    def _build_wave(self):
        """Async wave: identical local training, but the program stops
        before aggregation and returns the stacked quantized deltas — the
        scheduler buffers them on the host and commits with
        staleness-discounted weights later. No donation: the caller's
        global trainables stay alive for the commit."""
        het = self._het

        def wave_fn(global_tr, sel, n_steps, idx, pool_staged,
                    pool_labs, frozen, class_emb):
            staged = jnp.take(pool_staged, sel, axis=0)
            labs = jnp.take(pool_labs, sel, axis=0)
            return self._train_cohort(
                global_tr, staged, labs, idx, n_steps if het else None,
                frozen, class_emb)

        return wave_fn

    def _canon_global(self, global_tr):
        """Pin the global trainables to the canonical mesh-replicated
        placement before a sharded dispatch. A sharded round's OUTPUT
        trainables come back replicated over the mesh, so without this
        the warmup round (host-resident inputs) and every chained round
        (replicated inputs) would compile separate executables under
        the sharding-aware runtime cache keys; device_put is a no-op
        once the placement already matches."""
        if self._rep is None:
            return global_tr
        return jax.tree.map(lambda g: jax.device_put(g, self._rep),
                            global_tr)

    def _donate(self):
        return (0,) if self.cfg.donate else ()

    def _bucket_inputs(self, sel_dev, n_steps, idx, B: int):
        """Pad the cohort-axis inputs of a width-K selection to the
        width-B bucket: pad rows gather client 0's staged pool, sample
        index 0 every step, and run the minimum step count — all of it
        thrown away (zero aggregation weight, metrics sliced to K).
        The true rows' arrays are untouched: indices were drawn at the
        true K *before* padding, so the sample stream is exactly the
        unbucketed one."""
        return (runtime_lib.pad_leading(sel_dev, B),
                runtime_lib.pad_leading(n_steps, B, fill=1),
                runtime_lib.pad_leading(idx, B))

    def _subset_inputs(self, sel, key, n_steps=None):
        sel = np.asarray(sel, np.int32)
        order = np.argsort(sel, kind="stable")
        sel = sel[order]
        if len(np.unique(sel)) != len(sel) or sel.min() < 0 or \
                sel.max() >= self.n_clients:
            raise ValueError(f"invalid client subset {sel}")
        if n_steps is None:
            n_steps = self.cfg.local_steps * self.step_mult[sel]
        else:
            # caller-supplied (scheduler trace) step counts, reordered
            # with the selection sort — they are the single source of
            # truth, so a profile the staged program cannot honor fails
            # loudly instead of silently training different counts
            n_steps = np.asarray(n_steps, np.int32)[order]
            if n_steps.shape != sel.shape:
                raise ValueError(
                    f"n_steps shape {n_steps.shape} != sel {sel.shape}")
            if n_steps.min() < 1 or n_steps.max() > self.max_steps:
                raise ValueError(
                    f"n_steps {n_steps} outside [1, {self.max_steps}] "
                    "(engine staged with max step multiplier "
                    f"{int(self.step_mult.max())})")
            if not self._het and np.any(n_steps != self.cfg.local_steps):
                raise ValueError(
                    "engine was staged homogeneous (every client "
                    "step_mult==1) but the scheduler requested "
                    f"heterogeneous step counts {n_steps}; set "
                    "Client.step_mult before building the engine")
        sel_dev = jnp.asarray(sel)
        lens_sel = jnp.take(self.lens, sel_dev)
        # indices are drawn at the TRUE selection width, before any
        # bucket padding — threefry draws are not shape-stable, so the
        # pad must never touch the sample stream (round_indices stays
        # the oracle for the real rows)
        idx = self._sample_idx(key, lens_sel, self.max_steps)
        return sel, sel_dev, jnp.asarray(n_steps, jnp.int32), idx

    def run_subset_round(self, global_tr, sel, key, n_steps=None):
        """Sync-partial round over client positions ``sel`` (treated as a
        set; canonicalized to sorted order so selection is
        permutation-invariant and K=N reproduces the full round).
        Aggregation weights are the selected clients' sample counts,
        renormalized over the subset — padding rows of the width bucket
        carry weight zero. ``n_steps`` optionally overrides the
        per-client step counts (aligned with ``sel``'s order)."""
        sel, sel_dev, n_steps, idx = self._subset_inputs(sel, key,
                                                         n_steps)
        K = len(sel)
        B = runtime_lib.bucket_width(K, self.n_clients,
                                     shards=self.shards)
        weights = np.zeros(B, np.float32)
        weights[:K] = self.client_n[sel] / self.client_n[sel].sum()
        server.check_weights(weights, B)
        if B > K:
            sel_dev, n_steps, idx = self._bucket_inputs(
                sel_dev, n_steps, idx, B)
        weights = self._put(weights)
        if self.cfg.mesh is not None:
            sel_dev, n_steps, idx = (self._put(sel_dev),
                                     self._put(n_steps), self._put(idx))
            global_tr = self._canon_global(global_tr)
        uplink = K * self.per_client_uplink_bytes(global_tr)
        args = (global_tr, sel_dev, n_steps, idx, self.pool_staged,
                self.pool_labs, weights, self.frozen, self.class_emb)
        new_tr, loss, acc = self.runtime.run(
            "subset_round", self._build_subset_round, args,
            static_key=self._static_key,
            donate_argnums=self._donate())
        # metrics stay device-resident (sliced to the true K in-graph):
        # the caller decides when to materialize — the pipelined round
        # loop defers them to its bulk ring flush
        return new_tr, {
            "loss": loss[:K], "acc": acc[:K],
            "uplink_bytes": uplink,
            "sel": sel}

    def run_wave(self, global_tr, sel, key, n_steps=None):
        """Train client positions ``sel`` from ``global_tr`` without
        committing: returns (stacked quantized delta tree, metrics).
        Slice per-client updates out with ``slice_client_delta`` — the
        true clients occupy rows [0, K) of the width bucket; pad rows
        are never sliced or committed."""
        sel, sel_dev, n_steps, idx = self._subset_inputs(sel, key,
                                                         n_steps)
        K = len(sel)
        B = runtime_lib.bucket_width(K, self.n_clients,
                                     shards=self.shards)
        if B > K:
            sel_dev, n_steps, idx = self._bucket_inputs(
                sel_dev, n_steps, idx, B)
        if self.cfg.mesh is not None:
            sel_dev, n_steps, idx = (self._put(sel_dev),
                                     self._put(n_steps), self._put(idx))
            global_tr = self._canon_global(global_tr)
        args = (global_tr, sel_dev, n_steps, idx, self.pool_staged,
                self.pool_labs, self.frozen, self.class_emb)
        delta, loss, acc = self.runtime.run(
            "wave_round", self._build_wave, args,
            static_key=self._static_key)
        return delta, {
            "loss": loss[:K], "acc": acc[:K],
            "uplink_bytes": K * self.per_client_uplink_bytes(global_tr),
            "sel": sel}

    def run_round(self, global_tr, key):
        """Advance one full-cohort federated round. Returns
        (new_global_trainables, metrics) where metrics carries per-client
        last-step loss/acc and the round's uplink byte count."""
        if self._het:
            raise ValueError(
                "run_round is the homogeneous (unmasked) full-cohort "
                f"program, but clients carry step_mult {self.step_mult}"
                " — use run_subset_round(sel=arange(n_clients)) so the "
                "masked scan honors the heterogeneous step counts")
        uplink = self.uplink_bytes(global_tr)
        idx = self._sample_idx(key, self.lens, self.cfg.local_steps)
        if self.cfg.mesh is not None:
            idx = self._put(idx)
            global_tr = self._canon_global(global_tr)
        args = (global_tr, idx, self.pool_staged, self.pool_labs,
                self.weights, self.frozen, self.class_emb)
        new_tr, loss, acc = self.runtime.run(
            "full_round", self._build_round, args,
            static_key=self._static_key,
            donate_argnums=self._donate())
        return new_tr, {"loss": loss, "acc": acc,
                        "uplink_bytes": uplink}
