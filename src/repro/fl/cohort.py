"""Batched cohort execution engine: vmap/scan-fused federated rounds.

The sequential simulator runs each round as a Python loop over clients
with one jitted step per local batch — O(n_clients * local_steps) device
dispatches plus a host->device transfer per step. But with a frozen,
shared backbone and a tiny trainable tree, every client's local training
is the *same program over different data and trainable state*, which is
exactly the shape ``jax.vmap`` (over the cohort) + ``jax.lax.scan`` (over
local steps) compile into one fused device program.

This engine therefore executes an entire federated round — local Adam
training for every selected client, delta computation, per-client uplink
quantization, and weighted FedAvg aggregation — as **one jitted,
buffer-donated call**:

 - client trainables are stacked along a leading cohort axis (every
   client starts a round from the global trainables, so the stack is a
   broadcast);
 - each client's (GAN-rebalanced) data pool is staged on device once,
   zero-padded to a fixed shape (n_clients, P, ...) so shapes never
   recompile — and staging hoists every trainable-independent prefix of
   the forward to a one-time cost: pools are stored as pooled backbone
   features (adapter-only arms) or embedded patch tokens (LoRA arms),
   so local steps never re-run frozen computation the sequential
   interpreter redoes per batch;
 - per-step batch indices are drawn with ``jax.random`` in one small
   dedicated dispatch per round on replicated inputs (padding rows are
   never sampled: indices live in [0, pool_len)) and fed to the fused
   round as data, keeping the draw independent of the mesh layout;
 - uplink compression reuses the exact blockwise layout of the
   sequential path (quantization blocks run along trailing dims, so the
   stacked quantization is elementwise-identical to quantizing each
   client's delta separately);
 - with a mesh, the staged cohort arrays are sharded over the
   data-parallel axes (``launch.mesh.cohort_sharding``) and pjit splits
   the vmapped round across devices.

The sequential ``Client.local_train`` path stays alive as the reference
oracle; ``round_indices`` reproduces the engine's sample sequence so
parity tests can drive both paths with identical batches.

Partial participation (``fl.sched``) builds on the same staging: the
pools of *all* clients stay device-resident, and a subset round is the
same fused program prefixed with a gather — ``pool_staged[sel]`` for a
fixed cohort width K, so selecting a different subset each round never
re-uploads data or recompiles. ``run_subset_round`` aggregates in-program
(sync-partial); ``run_wave`` stops before aggregation and returns the
stacked quantized deltas, which the async scheduler buffers on the host
and commits with staleness-discounted weights. Heterogeneous per-client
local-step counts (availability traces) run inside the same fixed-length
scan via the ``active`` mask of ``optim.adam_scan`` — a masked step is a
bitwise no-op on (params, opt state).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clip as clip_lib
from repro.core import losses, optim, quant
from repro.core.quant import tree_bytes
from repro.data.synthetic import stage_client_pools
from repro.fl import client as client_lib
from repro.fl import server
from repro.fl import strategies as strategies_lib
from repro.fl.strategies import Strategy
from repro.launch import mesh as mesh_lib


@dataclass(frozen=True)
class CohortConfig:
    """Static round-execution parameters (baked into the jitted round)."""
    strategy: Strategy
    local_steps: int
    batch_size: int
    lr: float
    mesh: Any = None          # optional Mesh: shard cohort over dp axes
    donate: bool = True       # donate the global-trainable buffers


def stage_encoded_pools(frozen, ccfg, *, use_lora: bool, imgs, put=None,
                        chunk: int = 512):
    """Encode padded client pools ``(C, P, H, W, ch)`` through the
    trainable-independent prefix of the forward — the whole frozen
    backbone (pooled features) for adapter-only arms, the patch
    embedding (tokens) for LoRA arms — in fixed-size chunks, one jitted
    program reused across chunks.

    This is the single staging pipeline for every pool that enters the
    cohort engine: raw client data and the fleet-GAN rebalancing sets
    (``fl.fleetgan``) flow through it identically, so GAN-augmented
    pools cost one staging pass like any other pool."""
    put = jnp.asarray if put is None else put
    C, P = imgs.shape[:2]
    flat = jnp.asarray(imgs.reshape(C * P, *imgs.shape[2:]))
    stage = jax.jit(
        (lambda x: clip_lib.embed_patches(frozen, ccfg, x))
        if use_lora else
        (lambda x: clip_lib.encode_image(frozen, ccfg, x)))
    staged = jnp.concatenate(
        [stage(flat[i:i + chunk]) for i in range(0, C * P, chunk)])
    return put(staged.reshape(C, P, *staged.shape[1:]))


def sample_batch_indices(key, lens, steps: int, batch: int):
    """(n_clients, steps, batch) pool indices, client i's in
    [0, lens[i]). The engine draws these in a dedicated small dispatch on
    *replicated* inputs — never inside the sharded round program, where
    non-partitionable threefry would make the draw depend on the mesh
    layout — so ``round_indices`` (the eager form driving the sequential
    oracle) reproduces the engine's batches exactly on any mesh."""
    keys = jax.random.split(key, lens.shape[0])
    return jax.vmap(
        lambda k, n: jax.random.randint(k, (steps, batch), 0, n))(
            keys, lens)


def round_indices(key, lens, steps: int, batch: int) -> np.ndarray:
    """Host-side view of one round's per-client batch indices. For subset
    rounds pass ``lens[sel]`` (and the engine's ``max_steps``) — the
    fused program and the sequential oracle then see identical batches."""
    return np.asarray(sample_batch_indices(
        key, jnp.asarray(lens, jnp.int32), steps, batch))


def slice_client_delta(stacked_delta, i: int):
    """Extract client ``i``'s delta from a stacked (possibly quantized)
    delta tree. QTensor leaves are re-wrapped with per-client metadata so
    slices taken from waves of different widths share one treedef (the
    async scheduler stacks buffered slices across waves) and
    ``tree_bytes`` reports the true per-client uplink payload."""
    def f(l):
        if isinstance(l, quant.QTensor):
            return quant.QTensor(
                q=l.q[i], scales=l.scales[i], bits=l.bits, mode=l.mode,
                block=l.block, out_dtype=l.out_dtype,
                orig_shape=tuple(l.orig_shape[1:]))
        return l[i]
    return jax.tree.map(f, stacked_delta,
                        is_leaf=lambda l: isinstance(l, quant.QTensor))


def comm_quantize_stacked(delta, strategy: Strategy):
    """Uplink-quantize a stacked delta tree (leading cohort axis) with
    semantics identical to each client quantizing its own delta:
    eligibility and block choice use the *per-client* leaf shape, and the
    blockwise absmax runs along trailing dims only, so the leading axis
    is inert."""
    if not strategy.comm_bits:
        return delta
    flat, treedef = jax.tree_util.tree_flatten_with_path(delta)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(k) for k in path)
        per_client = leaf.shape[1:]
        if not quant._quantizable(pstr, per_client, leaf.dtype,
                                  strategies_lib.COMM_MIN_SIZE,
                                  strategies_lib.COMM_SKIP):
            out.append(leaf)
            continue
        b = quant._pick_block(per_client[-2], strategies_lib.COMM_BLOCK)
        bits, mode = strategy.comm_bits, "linear"
        if b % 2:
            bits, mode = 8, "linear"
        out.append(quant.quantize(leaf, bits=bits, block=b, mode=mode))
    return jax.tree_util.tree_unflatten(treedef, out)


class CohortEngine:
    """One-dispatch-per-round federated executor.

    Built once per simulation from the instantiated clients; ``run_round``
    then advances the global trainables with a single jitted call
    returning per-client last-step loss/acc.
    """

    def __init__(self, *, frozen, ccfg, class_emb,
                 clients: Sequence[client_lib.Client], cfg: CohortConfig):
        self.cfg = cfg
        self.n_clients = len(clients)
        empty = [c.cid for c in clients if len(c.pool()[1]) == 0]
        if empty:
            raise ValueError(
                f"clients {empty} have empty pools; federated rounds "
                "(sequential or cohort) need every participant to hold "
                "data — drop them from the cohort")
        imgs, labs, lens = stage_client_pools([c.pool() for c in clients])
        self.client_n = np.asarray([c.n for c in clients], np.float32)
        weights = self.client_n / self.client_n.sum()
        # trace-assigned compute heterogeneity: client i runs
        # local_steps * step_mult[i] steps; the fused program scans the
        # static max and masks the tail per client.
        self.step_mult = np.asarray(
            [c.local_steps_for(1) for c in clients], np.int32)
        if self.step_mult.max() > strategies_lib.MAX_STEP_MULT:
            raise ValueError(
                f"client step multipliers {self.step_mult.max()} exceed "
                f"strategies.MAX_STEP_MULT={strategies_lib.MAX_STEP_MULT}"
                " — the fused scan length must stay bounded")
        self.max_steps = cfg.local_steps * int(self.step_mult.max())
        self._het = bool(self.step_mult.max() > 1)

        if cfg.mesh is not None:
            shards = mesh_lib.cohort_axis_size(cfg.mesh)
            if self.n_clients % shards:
                raise ValueError(
                    f"cohort of {self.n_clients} clients not divisible by "
                    f"the mesh's {shards} data-parallel shards")
            put = lambda x: jax.device_put(
                x, mesh_lib.cohort_sharding(cfg.mesh, np.ndim(x)))
        else:
            put = jnp.asarray

        # Hoist every trainable-independent prefix of the forward out of
        # the training loop — staging the pool once per engine makes this
        # a one-time cost instead of a per-step one:
        #  - no LoRA: the whole frozen backbone; the pool is stored as
        #    pooled features (C, P, d) and local steps train only the
        #    adapter head;
        #  - with LoRA: the patch embedding (+cls+pos), which LoRA never
        #    touches; the pool is stored as embedded tokens
        #    (C, P, S, d).
        # GAN-rebalanced pools (fl.fleetgan) arrive here already
        # augmented via Client.pool() and stage like any other pool.
        self.pool_staged = stage_encoded_pools(
            frozen, ccfg, use_lora=cfg.strategy.use_lora, imgs=imgs,
            put=put)
        self.pool_labs = put(labs)
        self.lens = jnp.asarray(lens, jnp.int32)
        self.weights = jnp.asarray(weights, jnp.float32)
        self.frozen = frozen
        self.class_emb = class_emb
        self.ccfg = ccfg
        self._uplink_per_client: Optional[int] = None
        self._sample = jax.jit(sample_batch_indices,
                               static_argnums=(2, 3))
        self._round = self._build_round()
        self._subset_rounds = {}   # K -> jitted train+aggregate program
        self._wave_rounds = {}     # K -> jitted train-only wave program

    # -- uplink accounting --------------------------------------------
    def per_client_uplink_bytes(self, global_tr) -> int:
        """One client's (quantized) delta payload. Shape-only (no device
        work), computed once via the spec path of the quantizer; exact
        for every participant because quantization is leading-axis-inert
        and all clients share the trainable shapes."""
        if self._uplink_per_client is None:
            specs = jax.tree.map(
                lambda g: jax.ShapeDtypeStruct(g.shape, jnp.float32),
                global_tr)
            if self.cfg.strategy.comm_bits:
                specs = quant.quantize_tree_specs(
                    specs, bits=self.cfg.strategy.comm_bits,
                    block=strategies_lib.COMM_BLOCK,
                    min_size=strategies_lib.COMM_MIN_SIZE,
                    skip_names=strategies_lib.COMM_SKIP)
            self._uplink_per_client = tree_bytes(specs)
        return self._uplink_per_client

    def uplink_bytes(self, global_tr) -> int:
        """Full-cohort round uplink: n_clients x per-client delta size."""
        return self.n_clients * self.per_client_uplink_bytes(global_tr)

    # -- the fused round ----------------------------------------------
    def _local_train(self, frozen, class_emb, tr, staged, labs, ix,
                     n_steps=None):
        """One client's local training (vmapped over the cohort axis),
        shared by the full, subset, and wave programs. ``n_steps`` (a
        traced scalar) masks the tail of the fixed-length scan for
        heterogeneous step counts; ``None`` keeps the unmasked PR 1
        program byte-for-byte."""
        lr = self.cfg.lr
        ccfg = self.ccfg
        use_lora = self.cfg.strategy.use_lora
        opt = optim.adam_init(tr)

        def grad_fn(t, ixt):
            bx, by = staged[ixt], labs[ixt]

            def loss_fn(tt):
                feat = clip_lib.encode_tokens(
                    frozen, ccfg, bx, lora=tt.get("lora")) \
                    if use_lora else bx
                logits = client_lib.head_logits(
                    frozen, tt, feat, class_emb)
                return (losses.cross_entropy(logits, by),
                        losses.accuracy(logits, by))

            (loss, acc), g = jax.value_and_grad(
                loss_fn, has_aux=True)(t)
            return g, (loss, acc)

        active = None if n_steps is None else \
            jnp.arange(ix.shape[0]) < n_steps
        tr, opt, (ls, accs) = optim.adam_scan(
            grad_fn, tr, opt, ix, lr=lr, grad_clip=1.0, active=active)
        if n_steps is None:
            return tr, ls[-1], accs[-1]
        return tr, jnp.take(ls, n_steps - 1), jnp.take(accs, n_steps - 1)

    def _train_cohort(self, global_tr, staged, labs, idx, n_steps,
                      frozen, class_emb):
        """Broadcast the global trainables over the cohort, train every
        client, and return (stacked quantized deltas, loss, acc)."""
        C = idx.shape[0]
        cohort_tr = jax.tree.map(
            lambda g: jnp.broadcast_to(g[None], (C,) + g.shape),
            global_tr)
        if n_steps is None:
            after, loss, acc = jax.vmap(
                lambda tr, s, l, ix: self._local_train(
                    frozen, class_emb, tr, s, l, ix))(
                cohort_tr, staged, labs, idx)
        else:
            after, loss, acc = jax.vmap(
                lambda tr, s, l, ix, n: self._local_train(
                    frozen, class_emb, tr, s, l, ix, n))(
                cohort_tr, staged, labs, idx, n_steps)
        delta = jax.tree.map(
            lambda a, g: (a - g[None]).astype(jnp.float32),
            after, global_tr)
        return comm_quantize_stacked(delta, self.cfg.strategy), loss, acc

    def _build_round(self):
        def round_fn(global_tr, idx, pool_staged, pool_labs, weights,
                     frozen, class_emb):
            delta, loss, acc = self._train_cohort(
                global_tr, pool_staged, pool_labs, idx, None, frozen,
                class_emb)
            new_global = server.aggregate_stacked(global_tr, weights,
                                                  delta)
            return new_global, loss, acc

        donate = (0,) if self.cfg.donate else ()
        return jax.jit(round_fn, donate_argnums=donate)

    def _build_subset_round(self):
        """Sync-partial round at fixed cohort width K: gather the
        selected clients' already-staged pools (no re-upload, one compile
        per K), train, quantize, and aggregate in-program with the
        host-normalized subset weights."""
        het = self._het

        def round_fn(global_tr, sel, n_steps, idx, pool_staged,
                     pool_labs, weights, frozen, class_emb):
            staged = jnp.take(pool_staged, sel, axis=0)
            labs = jnp.take(pool_labs, sel, axis=0)
            delta, loss, acc = self._train_cohort(
                global_tr, staged, labs, idx, n_steps if het else None,
                frozen, class_emb)
            new_global = server.aggregate_stacked(global_tr, weights,
                                                  delta)
            return new_global, loss, acc

        donate = (0,) if self.cfg.donate else ()
        return jax.jit(round_fn, donate_argnums=donate)

    def _build_wave(self):
        """Async wave: identical local training, but the program stops
        before aggregation and returns the stacked quantized deltas — the
        scheduler buffers them on the host and commits with
        staleness-discounted weights later. No donation: the caller's
        global trainables stay alive for the commit."""
        het = self._het

        def wave_fn(global_tr, sel, n_steps, idx, pool_staged,
                    pool_labs, frozen, class_emb):
            staged = jnp.take(pool_staged, sel, axis=0)
            labs = jnp.take(pool_labs, sel, axis=0)
            return self._train_cohort(
                global_tr, staged, labs, idx, n_steps if het else None,
                frozen, class_emb)

        return jax.jit(wave_fn)

    def _subset_inputs(self, sel, key, n_steps=None):
        sel = np.asarray(sel, np.int32)
        order = np.argsort(sel, kind="stable")
        sel = sel[order]
        if len(np.unique(sel)) != len(sel) or sel.min() < 0 or \
                sel.max() >= self.n_clients:
            raise ValueError(f"invalid client subset {sel}")
        if n_steps is None:
            n_steps = self.cfg.local_steps * self.step_mult[sel]
        else:
            # caller-supplied (scheduler trace) step counts, reordered
            # with the selection sort — they are the single source of
            # truth, so a profile the staged program cannot honor fails
            # loudly instead of silently training different counts
            n_steps = np.asarray(n_steps, np.int32)[order]
            if n_steps.shape != sel.shape:
                raise ValueError(
                    f"n_steps shape {n_steps.shape} != sel {sel.shape}")
            if n_steps.min() < 1 or n_steps.max() > self.max_steps:
                raise ValueError(
                    f"n_steps {n_steps} outside [1, {self.max_steps}] "
                    "(engine staged with max step multiplier "
                    f"{int(self.step_mult.max())})")
            if not self._het and np.any(n_steps != self.cfg.local_steps):
                raise ValueError(
                    "engine was staged homogeneous (every client "
                    "step_mult==1) but the scheduler requested "
                    f"heterogeneous step counts {n_steps}; set "
                    "Client.step_mult before building the engine")
        sel_dev = jnp.asarray(sel)
        lens_sel = jnp.take(self.lens, sel_dev)
        idx = self._sample(key, lens_sel, self.max_steps,
                           self.cfg.batch_size)
        return sel, sel_dev, jnp.asarray(n_steps, jnp.int32), idx

    def run_subset_round(self, global_tr, sel, key, n_steps=None):
        """Sync-partial round over client positions ``sel`` (treated as a
        set; canonicalized to sorted order so selection is
        permutation-invariant and K=N reproduces the full round).
        Aggregation weights are the selected clients' sample counts,
        renormalized over the subset. ``n_steps`` optionally overrides
        the per-client step counts (aligned with ``sel``'s order)."""
        sel, sel_dev, n_steps, idx = self._subset_inputs(sel, key,
                                                         n_steps)
        K = len(sel)
        weights = self.client_n[sel] / self.client_n[sel].sum()
        weights = jnp.asarray(weights, jnp.float32)
        server.check_weights(weights, K)
        if K not in self._subset_rounds:
            self._subset_rounds[K] = self._build_subset_round()
        new_tr, loss, acc = self._subset_rounds[K](
            global_tr, sel_dev, n_steps, idx, self.pool_staged,
            self.pool_labs, weights, self.frozen, self.class_emb)
        return new_tr, {
            "loss": np.asarray(loss), "acc": np.asarray(acc),
            "uplink_bytes": K * self.per_client_uplink_bytes(global_tr),
            "sel": sel}

    def run_wave(self, global_tr, sel, key, n_steps=None):
        """Train client positions ``sel`` from ``global_tr`` without
        committing: returns (stacked quantized delta tree, metrics).
        Slice per-client updates out with ``slice_client_delta``."""
        sel, sel_dev, n_steps, idx = self._subset_inputs(sel, key,
                                                         n_steps)
        K = len(sel)
        if K not in self._wave_rounds:
            self._wave_rounds[K] = self._build_wave()
        delta, loss, acc = self._wave_rounds[K](
            global_tr, sel_dev, n_steps, idx, self.pool_staged,
            self.pool_labs, self.frozen, self.class_emb)
        return delta, {
            "loss": np.asarray(loss), "acc": np.asarray(acc),
            "uplink_bytes": K * self.per_client_uplink_bytes(global_tr),
            "sel": sel}

    def run_round(self, global_tr, key):
        """Advance one full-cohort federated round. Returns
        (new_global_trainables, metrics) where metrics carries per-client
        last-step loss/acc and the round's uplink byte count."""
        if self._het:
            raise ValueError(
                "run_round is the homogeneous (unmasked) full-cohort "
                f"program, but clients carry step_mult {self.step_mult}"
                " — use run_subset_round(sel=arange(n_clients)) so the "
                "masked scan honors the heterogeneous step counts")
        uplink = self.uplink_bytes(global_tr)
        idx = self._sample(key, self.lens, self.cfg.local_steps,
                           self.cfg.batch_size)
        new_tr, loss, acc = self._round(
            global_tr, idx, self.pool_staged, self.pool_labs,
            self.weights, self.frozen, self.class_emb)
        return new_tr, {"loss": np.asarray(loss),
                        "acc": np.asarray(acc),
                        "uplink_bytes": uplink}
