"""Batched cohort execution engine: vmap/scan-fused federated rounds.

The sequential simulator runs each round as a Python loop over clients
with one jitted step per local batch — O(n_clients * local_steps) device
dispatches plus a host->device transfer per step. But with a frozen,
shared backbone and a tiny trainable tree, every client's local training
is the *same program over different data and trainable state*, which is
exactly the shape ``jax.vmap`` (over the cohort) + ``jax.lax.scan`` (over
local steps) compile into one fused device program.

This engine therefore executes an entire federated round — local Adam
training for every selected client, delta computation, per-client uplink
quantization, and weighted FedAvg aggregation — as **one jitted,
buffer-donated call**:

 - client trainables are stacked along a leading cohort axis (every
   client starts a round from the global trainables, so the stack is a
   broadcast);
 - each client's (GAN-rebalanced) data pool is staged on device once,
   zero-padded to a fixed shape (n_clients, P, ...) so shapes never
   recompile — and staging hoists every trainable-independent prefix of
   the forward to a one-time cost: pools are stored as pooled backbone
   features (adapter-only arms) or embedded patch tokens (LoRA arms),
   so local steps never re-run frozen computation the sequential
   interpreter redoes per batch;
 - per-step batch indices are drawn with ``jax.random`` in one small
   dedicated dispatch per round on replicated inputs (padding rows are
   never sampled: indices live in [0, pool_len)) and fed to the fused
   round as data, keeping the draw independent of the mesh layout;
 - uplink compression reuses the exact blockwise layout of the
   sequential path (quantization blocks run along trailing dims, so the
   stacked quantization is elementwise-identical to quantizing each
   client's delta separately);
 - with a mesh, the staged cohort arrays are sharded over the
   data-parallel axes (``launch.mesh.cohort_sharding``) and pjit splits
   the vmapped round across devices.

The sequential ``Client.local_train`` path stays alive as the reference
oracle; ``round_indices`` reproduces the engine's sample sequence so
parity tests can drive both paths with identical batches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clip as clip_lib
from repro.core import losses, optim, quant
from repro.core.quant import tree_bytes
from repro.data.synthetic import stage_client_pools
from repro.fl import client as client_lib
from repro.fl import server
from repro.fl import strategies as strategies_lib
from repro.fl.strategies import Strategy
from repro.launch import mesh as mesh_lib


@dataclass(frozen=True)
class CohortConfig:
    """Static round-execution parameters (baked into the jitted round)."""
    strategy: Strategy
    local_steps: int
    batch_size: int
    lr: float
    mesh: Any = None          # optional Mesh: shard cohort over dp axes
    donate: bool = True       # donate the global-trainable buffers


def sample_batch_indices(key, lens, steps: int, batch: int):
    """(n_clients, steps, batch) pool indices, client i's in
    [0, lens[i]). The engine draws these in a dedicated small dispatch on
    *replicated* inputs — never inside the sharded round program, where
    non-partitionable threefry would make the draw depend on the mesh
    layout — so ``round_indices`` (the eager form driving the sequential
    oracle) reproduces the engine's batches exactly on any mesh."""
    keys = jax.random.split(key, lens.shape[0])
    return jax.vmap(
        lambda k, n: jax.random.randint(k, (steps, batch), 0, n))(
            keys, lens)


def round_indices(key, lens, steps: int, batch: int) -> np.ndarray:
    """Host-side view of one round's per-client batch indices."""
    return np.asarray(sample_batch_indices(
        key, jnp.asarray(lens, jnp.int32), steps, batch))


def comm_quantize_stacked(delta, strategy: Strategy):
    """Uplink-quantize a stacked delta tree (leading cohort axis) with
    semantics identical to each client quantizing its own delta:
    eligibility and block choice use the *per-client* leaf shape, and the
    blockwise absmax runs along trailing dims only, so the leading axis
    is inert."""
    if not strategy.comm_bits:
        return delta
    flat, treedef = jax.tree_util.tree_flatten_with_path(delta)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(k) for k in path)
        per_client = leaf.shape[1:]
        if not quant._quantizable(pstr, per_client, leaf.dtype,
                                  strategies_lib.COMM_MIN_SIZE,
                                  strategies_lib.COMM_SKIP):
            out.append(leaf)
            continue
        b = quant._pick_block(per_client[-2], strategies_lib.COMM_BLOCK)
        bits, mode = strategy.comm_bits, "linear"
        if b % 2:
            bits, mode = 8, "linear"
        out.append(quant.quantize(leaf, bits=bits, block=b, mode=mode))
    return jax.tree_util.tree_unflatten(treedef, out)


class CohortEngine:
    """One-dispatch-per-round federated executor.

    Built once per simulation from the instantiated clients; ``run_round``
    then advances the global trainables with a single jitted call
    returning per-client last-step loss/acc.
    """

    def __init__(self, *, frozen, ccfg, class_emb,
                 clients: Sequence[client_lib.Client], cfg: CohortConfig):
        self.cfg = cfg
        self.n_clients = len(clients)
        empty = [c.cid for c in clients if len(c.pool()[1]) == 0]
        if empty:
            raise ValueError(
                f"clients {empty} have empty pools; federated rounds "
                "(sequential or cohort) need every participant to hold "
                "data — drop them from the cohort")
        imgs, labs, lens = stage_client_pools([c.pool() for c in clients])
        weights = np.asarray([c.n for c in clients], np.float32)
        weights = weights / weights.sum()

        if cfg.mesh is not None:
            shards = mesh_lib.cohort_axis_size(cfg.mesh)
            if self.n_clients % shards:
                raise ValueError(
                    f"cohort of {self.n_clients} clients not divisible by "
                    f"the mesh's {shards} data-parallel shards")
            put = lambda x: jax.device_put(
                x, mesh_lib.cohort_sharding(cfg.mesh, np.ndim(x)))
        else:
            put = jnp.asarray

        # Hoist every trainable-independent prefix of the forward out of
        # the training loop — staging the pool once per engine makes this
        # a one-time cost instead of a per-step one:
        #  - no LoRA: the whole frozen backbone; the pool is stored as
        #    pooled features (C, P, d) and local steps train only the
        #    adapter head;
        #  - with LoRA: the patch embedding (+cls+pos), which LoRA never
        #    touches; the pool is stored as embedded tokens
        #    (C, P, S, d).
        C, P = labs.shape
        flat_imgs = jnp.asarray(imgs.reshape(C * P, *imgs.shape[2:]))
        stage = jax.jit(
            (lambda x: clip_lib.embed_patches(frozen, ccfg, x))
            if cfg.strategy.use_lora else
            (lambda x: clip_lib.encode_image(frozen, ccfg, x)))
        staged = jnp.concatenate(
            [stage(flat_imgs[i:i + 512])
             for i in range(0, C * P, 512)])
        self.pool_staged = put(staged.reshape(C, P, *staged.shape[1:]))
        self.pool_labs = put(labs)
        self.lens = jnp.asarray(lens, jnp.int32)
        self.weights = jnp.asarray(weights, jnp.float32)
        self.frozen = frozen
        self.class_emb = class_emb
        self.ccfg = ccfg
        self._uplink_bytes: Optional[int] = None
        self._sample = jax.jit(sample_batch_indices,
                               static_argnums=(2, 3))
        self._round = self._build_round()

    # -- uplink accounting --------------------------------------------
    def uplink_bytes(self, global_tr) -> int:
        """Per-round total uplink payload: n_clients x the (quantized)
        per-client delta size. Shape-only (no device work), computed
        once via the spec path of the quantizer."""
        if self._uplink_bytes is None:
            specs = jax.tree.map(
                lambda g: jax.ShapeDtypeStruct(g.shape, jnp.float32),
                global_tr)
            if self.cfg.strategy.comm_bits:
                specs = quant.quantize_tree_specs(
                    specs, bits=self.cfg.strategy.comm_bits,
                    block=strategies_lib.COMM_BLOCK,
                    min_size=strategies_lib.COMM_MIN_SIZE,
                    skip_names=strategies_lib.COMM_SKIP)
            self._uplink_bytes = self.n_clients * tree_bytes(specs)
        return self._uplink_bytes

    # -- the fused round ----------------------------------------------
    def _build_round(self):
        steps = self.cfg.local_steps
        batch = self.cfg.batch_size
        lr = self.cfg.lr
        strategy = self.cfg.strategy
        ccfg = self.ccfg

        use_lora = strategy.use_lora

        def round_fn(global_tr, idx, pool_staged, pool_labs, weights,
                     frozen, class_emb):
            C = idx.shape[0]
            cohort_tr = jax.tree.map(
                lambda g: jnp.broadcast_to(g[None], (C,) + g.shape),
                global_tr)

            def local(tr, staged, labs, ix):
                opt = optim.adam_init(tr)

                def grad_fn(t, ixt):
                    bx, by = staged[ixt], labs[ixt]

                    def loss_fn(tt):
                        feat = clip_lib.encode_tokens(
                            frozen, ccfg, bx, lora=tt.get("lora")) \
                            if use_lora else bx
                        logits = client_lib.head_logits(
                            frozen, tt, feat, class_emb)
                        return (losses.cross_entropy(logits, by),
                                losses.accuracy(logits, by))

                    (loss, acc), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(t)
                    return g, (loss, acc)

                tr, opt, (ls, accs) = optim.adam_scan(
                    grad_fn, tr, opt, ix, lr=lr, grad_clip=1.0)
                return tr, ls[-1], accs[-1]

            after, loss, acc = jax.vmap(local)(
                cohort_tr, pool_staged, pool_labs, idx)
            delta = jax.tree.map(
                lambda a, g: (a - g[None]).astype(jnp.float32),
                after, global_tr)
            delta = comm_quantize_stacked(delta, strategy)
            new_global = server.aggregate_stacked(global_tr, weights,
                                                  delta)
            return new_global, loss, acc

        donate = (0,) if self.cfg.donate else ()
        return jax.jit(round_fn, donate_argnums=donate)

    def run_round(self, global_tr, key):
        """Advance one federated round. Returns (new_global_trainables,
        metrics) where metrics carries per-client last-step loss/acc and
        the round's uplink byte count."""
        uplink = self.uplink_bytes(global_tr)
        idx = self._sample(key, self.lens, self.cfg.local_steps,
                           self.cfg.batch_size)
        new_tr, loss, acc = self._round(
            global_tr, idx, self.pool_staged, self.pool_labs,
            self.weights, self.frozen, self.class_emb)
        return new_tr, {"loss": np.asarray(loss),
                        "acc": np.asarray(acc),
                        "uplink_bytes": uplink}
