"""Client data partitioning: Dirichlet non-IID + domain skew.

``dirichlet_partition`` is the standard non-IID benchmark protocol
(labels ~ Dir(alpha) per client); ``domain_partition`` assigns each client
a dominant domain (PACS-style heterogeneity). Both preserve every sample
exactly once (tested by property tests).
"""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    client_idx: List[list] = [[] for _ in range(n_clients)]
    for c, idx in enumerate(idx_by_class):
        if len(idx) == 0:
            continue
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for i, part in enumerate(np.split(idx, cuts)):
            client_idx[i].extend(part.tolist())
    out = []
    for i in range(n_clients):
        a = np.asarray(sorted(client_idx[i]), np.int64)
        out.append(a)
    return out


def domain_partition(domains: np.ndarray, n_clients: int,
                     skew: float = 0.8, seed: int = 0) -> List[np.ndarray]:
    """Each client draws ``skew`` of its data from one dominant domain."""
    rng = np.random.RandomState(seed)
    n_dom = int(domains.max()) + 1
    pools = [list(np.where(domains == d)[0]) for d in range(n_dom)]
    for p in pools:
        rng.shuffle(p)
    n = len(domains)
    per = n // n_clients
    available = set(range(n))
    out = []
    for i in range(n_clients):
        dom = i % n_dom
        want_dom = int(per * skew)
        sel = []
        while pools[dom] and len(sel) < want_dom:
            j = pools[dom].pop()
            if j in available:
                sel.append(j)
                available.discard(j)
        rest = sorted(available)
        rng.shuffle(rest)
        for j in rest[:per - len(sel)]:
            sel.append(j)
            available.discard(j)
        out.append(np.asarray(sorted(sel), np.int64))
    return out


def class_histogram(labels: np.ndarray, idx: np.ndarray,
                    n_classes: int) -> np.ndarray:
    return np.bincount(labels[idx], minlength=n_classes)
