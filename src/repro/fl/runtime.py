"""Bucketed program runtime: one compile cache under every fused engine.

Every fused program in the FL stack — full/subset/wave cohort rounds
(``fl.cohort``), the batch-index sampler, pool staging, and the
fleet-GAN train/synthesis programs (``fl.fleetgan``) — compiles and
executes through one :class:`ProgramRuntime`. The runtime owns three
things the engines used to re-implement ad hoc:

**AOT compilation + accounting.** Programs are compiled ahead of time
(``jax.jit(fn, donate_argnums=...).lower(*args).compile()``) and the
resulting executables are cached by ``(kind, static config, donation
signature, argument shapes/dtypes/shardings)`` and then *called
directly*, so the
executable cache is the execution path (no separate jit call-path cache
to re-warm). Wall-clock spent compiling is charged per ``kind`` on cache
misses only; ``stats()``/``n_compiles``/``compile_time_s`` give the
unified breakdown that ``History.meta`` reports instead of the three
ad-hoc timers the engines used to keep.

**Shape bucketing.** A shape-diverse workload must not pay one compile
per shape variant:

- *Cohort widths* (:func:`bucket_width`): a subset round or async wave
  over K of N clients runs at width ``B = min(N, max(4, next_pow2(K)))``
  — padded rows gather a valid client's staged pool but carry **zero
  aggregation weight** (the in-program FedAvg weight vector is
  renormalized over the true selection with zeros in the pad tail), pad
  batch indices are drawn *outside* the program at the true K (threefry
  draws are not shape-stable, so padding must never touch the sample
  stream) and zero-filled, and per-client metrics are sliced back to the
  true K on the host. A participation sweep over K ∈ {2,…,N} therefore
  compiles O(log N) programs instead of O(N), and padding never leaks
  into sampling, aggregation, or uplink-byte accounting.
- *Batch buckets with mean-correction* (``gan.train_step_bucketed``):
  GAN minibatch losses are batch means, so the fleet engine pads every
  client's minibatch to one shared bucket and computes **masked means**
  (``sum(per_row * mask) / n_true`` — the batch-mean loss rescaled by
  true-batch/padded-batch), which zeroes every padded row's gradient
  contribution exactly; all batch-size groups then share one train
  compile. Per-step noise is pre-drawn at the true batch shape
  (``gan.gan_z_stream``) so the RNG stream stays bitwise the sequential
  one.
- *Row buckets* (:func:`bucket_rows`): chunked staging / synthesis row
  counts pad to power-of-two buckets so ragged tails reuse a compile.

**Non-blocking dispatch.** ``dispatch()`` returns a :class:`Handle`
wrapping the executable's output arrays without forcing a host sync —
under JAX's asynchronous dispatch the program runs while the caller
stages other work; ``Handle.result()`` blocks and materializes. The
fleet-GAN synthesis dispatch uses this directly, and
``fleetgan.FleetGANJob`` (launch/resolve) is the engine-level form of
the same pattern: the simulator launches GAN prep, the cohort engine
stages the CLIP pools while those programs run, then resolves. Since
the pipelined round loop (PR 10) the handle is dependency-tracked:
``dispatch()`` accepts other handles as arguments (their outputs are
consumed without materializing), and a dispatch that *donates* buffers
registers a donation hazard on them — any later runtime call consuming
a donated-in-flight buffer raises loudly instead of reading freed
memory, until the donating handle materializes (after which JAX's own
deleted-array error still fires).

**Host-sync tracing.** Every intentional materialization point in the
stack — ``Handle.result()``, ``ProgramRuntime.sync()``, the
simulator's metric-ring flushes — counts into the module-level
``SYNC_TRACES`` ledger (the ``KERNEL_TRACES`` pattern), so tests and
the CI smoke can assert a pipelined steady-state round performs zero
host syncs rather than silently degenerating to the serial loop.
"""
from __future__ import annotations

import time
import weakref
from collections import OrderedDict
from typing import Any, Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

# Host-sync trace ledger (the KERNEL_TRACES pattern from kernels.ops):
# counts *intentional materialization points* by tag, incremented at
# the moment the host blocks on device results. Pipelined-mode tests
# reset it, run R rounds, and assert the steady-state tags stayed 0.
SYNC_TRACES: Dict[str, int] = {}


def sync_count(tag: str, n: int = 1) -> None:
    """Charge ``n`` host-sync events to ``tag`` in ``SYNC_TRACES``."""
    SYNC_TRACES[tag] = SYNC_TRACES.get(tag, 0) + int(n)


def reset_sync_traces() -> None:
    SYNC_TRACES.clear()

# Cohort-width buckets below this floor are not worth separate programs:
# a width-4 program over a width-2 selection wastes two masked rows of a
# cheap round, while halving the number of compiles a K-sweep pays.
MIN_COHORT_BUCKET = 4


def pow2_ceil(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    if n < 1:
        raise ValueError(f"pow2_ceil needs n >= 1, got {n}")
    return 1 << (int(n) - 1).bit_length()


def shard_multiple(n: int, shards: int) -> int:
    """Smallest multiple of ``shards`` >= n."""
    if shards < 1:
        raise ValueError(f"shard_multiple needs shards >= 1, got {shards}")
    return -(-int(n) // int(shards)) * int(shards)


def bucket_width(k: int, n: int, *, min_bucket: int = MIN_COHORT_BUCKET,
                 shards: int = 1) -> int:
    """Cohort-axis bucket for a selection of ``k`` out of ``n`` clients:
    the next power of two (floored at ``min_bucket``), clamped to ``n``.
    ``k == n`` always maps to ``n`` itself, so full-cohort selections
    never pad — the K=N subset round stays bit-identical to the
    gather-free full round.

    ``shards`` composes the bucket with mesh sharding of the cohort
    axis: the width rounds up to a shard multiple (still clamped to
    ``n``) so every data-parallel shard holds the same number of rows.
    The extra rows follow the existing pad contract — they gather a
    valid client's staged pool but carry zero aggregation weight and
    exactly-zero gradient/partial-sum contribution — so a sharded
    selection never needs its own padding rule. A mesh-sharded
    population must already satisfy ``n % shards == 0`` (the cohort
    engine enforces it), which keeps the K=N clamp a shard multiple
    too."""
    if not 1 <= k <= n:
        raise ValueError(f"selection width {k} out of range for {n}")
    if shards > 1 and n % shards:
        raise ValueError(
            f"population {n} not divisible by {shards} mesh shards — "
            "the staged cohort axis cannot shard evenly")
    if k >= n:
        return n
    b = min(n, max(min_bucket, pow2_ceil(k)))
    if shards > 1:
        b = min(n, shard_multiple(b, shards))
    return b


def bucket_rows(n: int, cap: int) -> int:
    """Row-count bucket for chunked row-wise programs (staging encode,
    GAN synthesis): the next power of two, clamped to ``cap``."""
    if n < 1:
        raise ValueError(f"bucket_rows needs n >= 1, got {n}")
    return min(int(cap), pow2_ceil(n))


def pad_leading(arr, width: int, fill=0):
    """Zero-(or ``fill``-)pad ``arr`` along axis 0 to ``width`` rows."""
    n = arr.shape[0]
    if n == width:
        return arr
    if n > width:
        raise ValueError(f"cannot pad {n} rows down to {width}")
    pad = jnp.full((width - n,) + tuple(arr.shape[1:]), fill, arr.dtype)
    return jnp.concatenate([arr, pad])


class Handle:
    """Dependency-tracked, non-blocking view of a dispatched program's
    outputs. The wrapped arrays are live as soon as the dispatch returns
    (JAX async dispatch); ``result()`` blocks until the computation
    finishes, counts the sync in ``SYNC_TRACES`` (tags ``handle_wait``
    and ``handle_wait:<kind>``), clears any donation hazards this
    dispatch registered, and returns the output tree.

    ``deps`` records the handles whose outputs fed this dispatch
    (``ProgramRuntime.dispatch`` unwraps handle arguments), so a
    pipeline's dataflow is inspectable without materializing anything.
    A handle whose dispatch *donated* input buffers blocks reuse of
    those buffers — the owning runtime raises on any later call that
    consumes them — until ``result()`` materializes the outputs."""

    __slots__ = ("kind", "deps", "_out", "_done", "_runtime",
                 "_hazard_ids")

    def __init__(self, out, *, kind: str = "anon", deps: Tuple = (),
                 runtime=None, hazard_ids: Tuple[int, ...] = ()):
        self.kind = kind
        self.deps = tuple(deps)
        self._out = out
        self._done = False
        self._runtime = runtime
        self._hazard_ids = tuple(hazard_ids)

    @property
    def done(self) -> bool:
        """True once ``result()`` has materialized the outputs."""
        return self._done

    def result(self):
        if not self._done:
            sync_count("handle_wait")
            sync_count(f"handle_wait:{self.kind}")
            jax.block_until_ready(jax.tree.leaves(self._out))
            self._done = True
            if self._runtime is not None and self._hazard_ids:
                self._runtime._clear_hazards(self._hazard_ids)
        return self._out

    @property
    def out(self):
        """The (possibly still-computing) output tree."""
        return self._out


class ProgramRuntime:
    """One AOT-compile cache + accounting ledger for a family of fused
    programs. Engines share a runtime (the simulator builds one per run
    and threads it through the cohort engine and the fleet-GAN engine)
    so ``History.meta`` reports a single unified compile breakdown, and
    identical programs built by different engines (e.g. a benchmark
    sweeping cohort widths over one staged population) share compiles.

    ``max_entries`` bounds the executable cache with LRU eviction (0 =
    unbounded, the default): long chaos sweeps touch many width/step-
    profile buckets, and without a bound every one stays pinned for the
    process lifetime. An evicted program recompiles (and recharges the
    ledger) on next use; eviction counts land per kind in ``stats()``
    (``n_evicted``) and in total via ``n_evictions``, so a sweep whose
    bound is set too tight shows up in the compile ledger instead of as
    silent thrash.
    """

    def __init__(self, max_entries: int = 0):
        if max_entries < 0:
            raise ValueError(f"max_entries={max_entries} must be >= 0 "
                             "(0 disables eviction)")
        self.max_entries = int(max_entries)
        self._exes: "OrderedDict[Tuple, Any]" = OrderedDict()
        self._kinds: Dict[str, Dict[str, float]] = {}
        # donation hazards: id(leaf) -> (weakref to the donated leaf,
        # donating program kind). Registered by dispatch() on donated
        # argument leaves, cleared when the donating handle materializes
        # or the leaf is garbage-collected (dead refs are pruned lazily).
        self._hazards: Dict[int, Tuple[Any, str]] = {}

    # -- cache ---------------------------------------------------------
    @staticmethod
    def _shard_sig(leaf) -> Tuple:
        """Sharding identity of one argument leaf. AOT executables bake
        their input shardings in at ``lower()`` time, so a sharded and
        an unsharded program over identical shapes are *different
        programs* and must never collide in the cache. Plain host
        arrays and single-device placements (the overwhelmingly common
        case) all map to ``()`` so the pre-mesh cache behavior — and
        its compile counts — are unchanged; only genuinely
        mesh-partitioned inputs (NamedSharding, or anything spanning
        more than one device) contribute a key."""
        s = getattr(leaf, "sharding", None)
        if s is None:
            return ()
        try:
            from jax.sharding import NamedSharding
            if isinstance(s, NamedSharding):
                mesh = s.mesh
                return (tuple(mesh.axis_names),
                        tuple(int(mesh.shape[a]) for a in mesh.axis_names),
                        str(s.spec))
            if len(s.device_set) > 1:
                return (str(s),)
        except Exception:
            return ()
        return ()

    @classmethod
    def _sig(cls, args) -> Tuple:
        return tuple(
            (tuple(getattr(l, "shape", ())),
             str(getattr(l, "dtype", type(l).__name__)),
             cls._shard_sig(l))
            for l in jax.tree.leaves(args))

    def compile(self, kind: str, build: Callable[[], Callable], args,
                *, static_key: Tuple = (),
                donate_argnums: Sequence[int] = ()):
        """Return the compiled executable for ``build()`` at ``args``'
        shapes, compiling (and charging wall-clock to ``kind``) only on a
        cache miss. ``static_key`` must capture everything the program
        closes over that is not visible in the argument shapes."""
        donate = tuple(donate_argnums)
        key = (kind, static_key, donate, self._sig(args))
        exe = self._exes.get(key)
        if exe is None:
            t0 = time.perf_counter()
            exe = jax.jit(build(), donate_argnums=donate) \
                .lower(*args).compile()
            dt = time.perf_counter() - t0
            self._exes[key] = exe
            k = self._kinds.setdefault(
                kind, {"n_compiles": 0, "compile_time_s": 0.0})
            k["n_compiles"] += 1
            k["compile_time_s"] += dt
            while self.max_entries and \
                    len(self._exes) > self.max_entries:
                old_key, _ = self._exes.popitem(last=False)
                ok = self._kinds.setdefault(
                    old_key[0],
                    {"n_compiles": 0, "compile_time_s": 0.0})
                ok["n_evicted"] = int(ok.get("n_evicted", 0)) + 1
        else:
            self._exes.move_to_end(key)
        return exe

    # -- donation hazards ----------------------------------------------
    def _prune_hazards(self) -> None:
        dead = [i for i, (ref, _) in self._hazards.items()
                if ref() is None]
        for i in dead:
            del self._hazards[i]

    def _clear_hazards(self, ids: Sequence[int]) -> None:
        for i in ids:
            self._hazards.pop(i, None)

    def _check_hazards(self, args, kind: str) -> None:
        """Raise loudly if any argument leaf was donated by a dispatch
        that has not materialized yet — consuming it would read a buffer
        the backend may already have aliased for the donor's outputs."""
        if not self._hazards:
            return
        for leaf in jax.tree.leaves(args):
            ent = self._hazards.get(id(leaf))
            if ent is not None and ent[0]() is leaf:
                raise RuntimeError(
                    f"donation hazard: program {kind!r} consumes a "
                    f"buffer donated to in-flight program {ent[1]!r}; "
                    "materialize that handle (Handle.result()) before "
                    "reusing its donated inputs")

    def _register_hazards(self, args, donate, kind: str):
        ids = []
        for i in donate:
            for leaf in jax.tree.leaves(args[i]):
                try:
                    ref = weakref.ref(leaf)
                except TypeError:
                    continue
                self._hazards[id(leaf)] = (ref, kind)
                ids.append(id(leaf))
        return tuple(ids)

    def run(self, kind: str, build, args, **kw):
        """Compile-or-hit, then execute without forcing a host sync —
        the handle-free form of ``dispatch`` (same hazard checks and
        donation tracking), returning the raw output tree."""
        return self.dispatch(kind, build, args, **kw).out

    def count(self, kind: str, counter: str, n: int = 1) -> None:
        """Charge ``n`` to an auxiliary per-kind counter in the same
        ledger the compile accounting lives in — the serving plane's
        adapter cache reports hits/misses/evictions this way, so
        ``stats()`` (and therefore ``History.meta``) stays the one place
        every runtime-level count is read from."""
        k = self._kinds.setdefault(
            kind, {"n_compiles": 0, "compile_time_s": 0.0})
        k[counter] = int(k.get(counter, 0)) + int(n)

    def charge(self, kind: str, seconds: float, n: int = 1) -> None:
        """Charge ``seconds`` of compile-class wall-clock (and ``n``
        compile events) to ``kind`` directly — the kernel autotuner
        (``kernels.autotune``) books its block-shape sweep time here, so
        tuning cost appears in the same ``stats()`` breakdown as AOT
        compile cost instead of in a side ledger."""
        k = self._kinds.setdefault(
            kind, {"n_compiles": 0, "compile_time_s": 0.0})
        k["n_compiles"] += int(n)
        k["compile_time_s"] += float(seconds)

    def dispatch(self, kind: str, build, args, **kw) -> Handle:
        """Compile-or-hit, then execute without forcing a host sync,
        returning a dependency-tracked :class:`Handle`. Top-level
        positional arguments may themselves be handles — their output
        trees are consumed in place (no materialization) and recorded
        as dependencies. Donated argument buffers are registered as
        hazards until the returned handle materializes."""
        deps = tuple(a for a in args if isinstance(a, Handle))
        if deps:
            args = tuple(a.out if isinstance(a, Handle) else a
                         for a in args)
        self._prune_hazards()
        self._check_hazards(args, kind)
        out = self.compile(kind, build, args, **kw)(*args)
        donate = tuple(kw.get("donate_argnums", ()))
        hazard_ids = self._register_hazards(args, donate, kind) \
            if donate else ()
        return Handle(out, kind=kind, deps=deps, runtime=self,
                      hazard_ids=hazard_ids)

    def sync(self, tree, tag: str = "sync"):
        """Materialize a pytree of device arrays in bulk, charging one
        host-sync event to ``tag`` — the counted form every deliberate
        blocking point in the pipelined loop goes through. Non-array
        leaves pass through untouched."""
        sync_count(tag)
        jax.block_until_ready([
            l for l in jax.tree.leaves(tree)
            if hasattr(l, "block_until_ready")])
        return tree

    def clear(self):
        """Drop every cached executable and reset the accounting — used
        by long-lived shape sweeps to bound memory and by benchmarks to
        force a cold compile measurement."""
        self._exes.clear()
        self._kinds.clear()

    # -- accounting ----------------------------------------------------
    def stats(self) -> Dict[str, Dict[str, float]]:
        """Per-kind ``{"n_compiles", "compile_time_s"}`` breakdown."""
        return {k: dict(v) for k, v in self._kinds.items()}

    @property
    def n_compiles(self) -> int:
        return sum(int(v["n_compiles"]) for v in self._kinds.values())

    @property
    def compile_time_s(self) -> float:
        return sum(v["compile_time_s"] for v in self._kinds.values())

    @property
    def n_evictions(self) -> int:
        """Total LRU evictions (0 while the cache is unbounded)."""
        return sum(int(v.get("n_evicted", 0))
                   for v in self._kinds.values())

    def subtotal(self, prefix: str) -> Tuple[int, float]:
        """(n_compiles, compile_time_s) summed over kinds matching
        ``prefix`` — e.g. ``subtotal("gan_")`` for the GAN engine's share
        of the one cache."""
        n, t = 0, 0.0
        for k, v in self._kinds.items():
            if k.startswith(prefix):
                n += int(v["n_compiles"])
                t += v["compile_time_s"]
        return n, t
