"""Fleet-GAN engine: cohort-wide long-tail rebalancing as fused programs.

The paper's third "play" — client-side conditional-GAN over-sampling of
tail classes (§III-B) — ran as the pre-cohort-engine pattern: a Python
loop over clients, each client a Python loop of per-step ``train_step``
dispatches, so tripleplay setup cost ``n_clients x gan_steps`` device
round-trips while local training ran as one fused program. This module
trains every client's GAN through ``gan.gan_scan`` (one ``lax.scan``
over GAN steps, donated params + Adam states) under a ``jax.vmap`` over
a stacked cohort axis, then synthesizes every client's rebalancing set
in one more stacked dispatch.

Layout and masking:

- Per-client pools are padded to one fixed shape per group
  (``stage_client_pools``); batch indices are drawn in ``[0, n_i)``
  (``gan.gan_batch_indices``) so padded rows carry zero sampling
  probability — the same masked-sampling discipline as ``fl.cohort``.
- Clients below ``strategies.GAN_MIN_POOL`` ride inside the stacked
  program with an all-False ``active`` mask: every one of their steps is
  a bitwise no-op on params + both Adam states (the het-local-steps
  masking of the scheduler PRs), and no GAN fields are written back.
- The GAN minibatch is ``strategies.gan_batch_size(n)`` — ``min(64,
  n)``-ish, *data-dependent*. A batch cannot be padded without changing
  the per-step math (losses are means over the batch), so clients are
  grouped by batch size and each group is one fused compile. Real
  (non-degenerate) partitions have few distinct sizes; the common
  all-``n >= 64`` case is a single compile.

RNG compatibility: client ``i`` consumes exactly the
``fold_in(rng, strategies.GAN_RNG_OFFSET + i)`` stream of the
sequential ``Client.prepare_gan`` path (``gan.gan_key_stream``), so the
sequential loop stays alive as the parity oracle: init params, batch
indices, and synthesis z-draws match it bitwise; trained params match
up to gemm-kernel re-association (``kernels.gan_conv`` — XLA fusion is
not bitwise-stable across loop->scan/vmap restructuring even on
identical primitives, same caveat as ``test_adam_scan_matches_loop``).

Compile cost is measured separately from steady-state execution
(AOT ``lower().compile()`` timing, cached across calls), mirroring the
``History.meta["compile_time_s"]`` hygiene of the round scheduler.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gan as gan_lib
from repro.core import optim
from repro.data.synthetic import stage_client_pools
from repro.fl import strategies as strategies_lib

_EXEC_CACHE: Dict = {}


def clear_cache():
    """Drop the compiled-executable cache. The cache is keyed by program
    kind + argument geometry and never evicts, so long-lived processes
    sweeping many distinct population shapes (benchmarks, shape sweeps)
    can use this to bound memory — and to force a cold
    ``compile_time_s`` measurement."""
    _EXEC_CACHE.clear()


@dataclass
class FleetGANReport:
    """What one fleet prep did: population split, fused-program groups
    (batch size -> cohort width), and the compile/steady-state timing
    split."""
    n_clients: int
    n_eligible: int
    n_synth: int = 0
    groups: List[Tuple[int, int]] = field(default_factory=list)
    compile_time_s: float = 0.0
    prep_time_s: float = 0.0
    d_loss: Dict[int, float] = field(default_factory=dict)
    g_loss: Dict[int, float] = field(default_factory=dict)


def _compiled(kind, build, args, record):
    """AOT-compile ``build()`` for ``args``' shapes (cached), charging
    wall-clock to ``record.compile_time_s`` only on a cache miss."""
    key = (kind,) + tuple(
        (tuple(l.shape), str(l.dtype)) for l in jax.tree.leaves(args))
    if key not in _EXEC_CACHE:
        t0 = time.perf_counter()
        _EXEC_CACHE[key] = build().lower(*args).compile()
        record.compile_time_s += time.perf_counter() - t0
    return _EXEC_CACHE[key]


def _keystream_fn(steps):
    return jax.jit(jax.vmap(lambda r: gan_lib.gan_key_stream(r, steps)))


def _indices_fn(batch):
    return jax.jit(jax.vmap(
        lambda kb, n: gan_lib.gan_batch_indices(kb, n, batch)))


def _init_fn(cfg):
    def one(k0):
        params = gan_lib.init_gan(k0, cfg)
        opt = {"gen": optim.adam_init(params["gen"]),
               "disc": optim.adam_init(params["disc"])}
        return params, opt
    return jax.jit(jax.vmap(one))


def _train_fn(cfg):
    def one(params, opt, imgs, labs, idx, kss, active):
        return gan_lib.gan_scan(params, opt, cfg, imgs, labs, idx, kss,
                                active=active)
    return jax.jit(jax.vmap(one), donate_argnums=(0, 1))


def _synth_fn(cfg):
    return jax.jit(jax.vmap(
        lambda gen, z, labs: gan_lib.generate(gen, cfg, z, labs)))


def prepare_gan_fleet(clients: Sequence, keys: Sequence, *, steps: int,
                      conv_impl: str = "gemm") -> FleetGANReport:
    """Train + synthesize every eligible client's GAN as stacked fused
    programs and write ``gan_cfg``/``gan_params``/``aug_images``/
    ``aug_labels`` back onto the clients — the fleet equivalent of

        for i, c in enumerate(clients):
            if c.n >= strategies.GAN_MIN_POOL:
                c.prepare_gan(keys[i], steps=steps)

    ``keys[i]`` is client i's GAN key (the simulator passes
    ``fold_in(rng, GAN_RNG_OFFSET + i)``). Ineligible clients ride the
    smallest-batch group fully masked (bitwise no-op steps) and keep
    their GAN fields unset. Returns a :class:`FleetGANReport`.
    """
    t_total = time.perf_counter()
    rep = FleetGANReport(n_clients=len(clients), n_eligible=0)
    if not clients:
        return rep
    if len(keys) != len(clients):
        # jnp indexing clamps out-of-bounds rows, so a short keys list
        # would silently reuse the last key — break parity loudly
        raise ValueError(
            f"need one GAN key per client (ineligible ones included): "
            f"got {len(keys)} keys for {len(clients)} clients")
    n_classes = clients[0].n_classes
    if any(c.n_classes != n_classes for c in clients):
        raise ValueError("fleet-GAN cohort must share one class space")
    if any(c.n == 0 for c in clients):
        raise ValueError("fleet-GAN cohort contains empty clients — "
                         "drop them before GAN prep (simulator does)")
    cfg = gan_lib.GANConfig(n_classes=n_classes, conv_impl=conv_impl)
    eligible = [c.n >= strategies_lib.GAN_MIN_POOL for c in clients]
    rep.n_eligible = int(sum(eligible))
    if rep.n_eligible == 0:       # empty-after-filter: nothing to train
        rep.prep_time_s = time.perf_counter() - t_total
        return rep

    # one dispatch: every client's full RNG stream (bitwise the
    # sequential split sequence)
    keys_arr = jnp.stack([jnp.asarray(k) for k in keys])
    ks_exec = _compiled(("keys", steps), lambda: _keystream_fn(steps),
                        (keys_arr,), rep)
    k0s, kbs, kss = ks_exec(keys_arr)

    # group by GAN batch size (the one unpaddable shape); ineligible
    # clients ride the smallest group, fully masked
    groups: Dict[int, List[int]] = {}
    for i, c in enumerate(clients):
        if eligible[i]:
            groups.setdefault(
                strategies_lib.gan_batch_size(c.n), []).append(i)
    small = min(groups)
    for i, c in enumerate(clients):
        if not eligible[i]:
            groups[small].append(i)

    stacked_gen: Dict[int, dict] = {}   # client pos -> generator params
    for batch in sorted(groups):
        pos = groups[batch]
        pos_dev = jnp.asarray(pos)
        pool_i, pool_l, lens = stage_client_pools(
            [(clients[i].images, clients[i].labels) for i in pos])
        iargs = (kbs[pos_dev], jnp.asarray(lens))
        idx_exec = _compiled(("idx", batch),
                             lambda: _indices_fn(batch), iargs, rep)
        idx = idx_exec(*iargs)
        k0s_g = k0s[pos_dev]
        init_exec = _compiled(("init", cfg), lambda: _init_fn(cfg),
                              (k0s_g,), rep)
        params, opt = init_exec(k0s_g)
        active = jnp.asarray(
            np.repeat([[eligible[i]] for i in pos], steps, axis=1))
        targs = (params, opt, jnp.asarray(pool_i), jnp.asarray(pool_l),
                 idx, kss[pos_dev], active)
        train_exec = _compiled(("train", cfg), lambda: _train_fn(cfg),
                               targs, rep)
        params, opt, ms = train_exec(*targs)
        rep.groups.append((batch, len(pos)))
        d_l, g_l = np.asarray(ms["d_loss"]), np.asarray(ms["g_loss"])
        for j, i in enumerate(pos):
            if eligible[i]:
                stacked_gen[i] = jax.tree.map(lambda l: l[j], params)
                rep.d_loss[i] = float(d_l[j, -1])
                rep.g_loss[i] = float(g_l[j, -1])

    # synthesis: per-client z drawn eagerly at the exact sequential
    # shape (threefry draws are not prefix-stable under padding), then
    # one stacked generate over the cohort
    synth = []                     # (pos, need, z)
    for i, c in enumerate(clients):
        if not eligible[i]:
            continue
        c.gan_cfg = cfg
        c.gan_params = stacked_gen[i]
        need = gan_lib.rebalance_labels(c.labels, n_classes)
        if len(need) == 0:
            c.aug_images = np.zeros((0, *c.images.shape[1:]), np.float32)
            c.aug_labels = np.zeros((0,), np.int32)
            continue
        z = jax.random.normal(jax.random.fold_in(keys_arr[i], 1),
                              (len(need), cfg.z_dim))
        synth.append((i, need, z))
    if synth:
        M = max(len(need) for _, need, _ in synth)
        z_pad = jnp.stack([
            jnp.pad(z, ((0, M - z.shape[0]), (0, 0)))
            for _, _, z in synth])
        lab_pad = jnp.asarray(np.stack([
            np.pad(need, (0, M - len(need))) for _, need, _ in synth]))
        gens = jax.tree.map(
            lambda *ls: jnp.stack(ls),
            *[stacked_gen[i]["gen"] for i, _, _ in synth])
        sargs = (gens, z_pad, lab_pad)
        synth_exec = _compiled(("synth", cfg), lambda: _synth_fn(cfg),
                               sargs, rep)
        imgs = np.asarray(synth_exec(*sargs), np.float32)
        for row, (i, need, _) in enumerate(synth):
            clients[i].aug_images = imgs[row, :len(need)]
            clients[i].aug_labels = need
            rep.n_synth += len(need)
    rep.prep_time_s = (time.perf_counter() - t_total
                       ) - rep.compile_time_s
    return rep
