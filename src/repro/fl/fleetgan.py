"""Fleet-GAN engine: cohort-wide long-tail rebalancing as fused programs.

The paper's third "play" — client-side conditional-GAN over-sampling of
tail classes (§III-B) — ran as the pre-cohort-engine pattern: a Python
loop over clients, each client a Python loop of per-step ``train_step``
dispatches. This module trains every client's GAN through
``gan.gan_scan_bucketed`` (one ``lax.scan`` over GAN steps, donated
params + Adam states) under a single ``jax.vmap`` over the whole stacked
cohort, then synthesizes every client's rebalancing set in one more
stacked dispatch — **one train compile and one synthesis compile for the
entire fleet**, regardless of how many distinct GAN batch sizes the
population carries.

Layout, masking, and the batch bucket:

- Per-client pools are padded to one fixed shape
  (``stage_client_pools``); batch indices are drawn in ``[0, n_i)``
  (``gan.gan_batch_indices``) so padded rows carry zero sampling
  probability — the same masked-sampling discipline as ``fl.cohort``.
- Clients below ``strategies.GAN_MIN_POOL`` ride inside the stacked
  program with an all-False ``active`` mask: every one of their steps is
  a bitwise no-op on params + both Adam states, and no GAN fields are
  written back.
- The GAN minibatch is ``strategies.gan_batch_size(n)`` — data-dependent
  and historically the one unpaddable shape (losses are batch means).
  The bucketed runtime pads every client's minibatch to the cohort-wide
  bucket ``B = max_i gan_batch_size(n_i)`` and corrects the means:
  ``gan.train_step_bucketed`` computes every batch-mean loss as the
  masked mean ``sum(per_row * mask) / n_true`` (the padded-batch mean
  rescaled by true-batch/padded-batch), which zeroes each padded row's
  gradient contribution exactly. Per-step noise is pre-drawn at the TRUE
  batch shape (``gan.gan_z_stream``) and zero-padded, because threefry
  draws are not shape-stable under padding.
- With ``FleetGANConfig.mesh`` the stacked cohort axis shards over the
  mesh's data-parallel axes: the cohort width pads up to a shard
  multiple with rider rows masked exactly like ineligible clients, and
  every key/index/z draw stays host-side at the TRUE width — so
  trained params and synthesized images are mesh-invariant (parity
  pinned in ``tests/test_distributed.py``).

RNG compatibility: client ``i`` consumes exactly the
``fold_in(rng, strategies.GAN_RNG_OFFSET + i)`` stream of the
sequential ``Client.prepare_gan`` path (``gan.gan_key_stream``), so the
sequential loop stays alive as the parity oracle: init params, batch
indices, per-step noise, and synthesis z-draws match it bitwise;
trained params match up to gemm-kernel re-association plus the
mean-correction's reduction reordering (``kernels.gan_conv`` — XLA
fusion is not bitwise-stable across loop->scan/vmap restructuring even
on identical primitives, same caveat as ``test_adam_scan_matches_loop``).

Execution is two-phase so GAN prep can overlap CLIP pool staging
(``fl.cohort`` accepts a pending job): :func:`launch_gan_fleet`
dispatches every device program through the shared
:class:`repro.fl.runtime.ProgramRuntime` without forcing a host sync
and returns a :class:`FleetGANJob`; ``job.resolve()`` materializes the
results onto the clients. :func:`prepare_gan_fleet` is the blocking
composition of the two. Compile cost is charged to the runtime's
``gan_*`` kinds (AOT ``lower().compile()`` timing, cached) and reported
via ``FleetGANReport.compile_time_s`` — the
``History.meta["gan_compile_time_s"]`` share of the one cache.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gan as gan_lib
from repro.core import optim
from repro.data.synthetic import stage_client_pools
from repro.fl import runtime as runtime_lib
from repro.fl import strategies as strategies_lib
from repro.launch import mesh as mesh_lib

# module-level default so standalone callers (tests, benchmarks) share
# executables across calls; the simulator threads its per-run runtime
# through instead so History.meta reports one unified cache
_DEFAULT_RUNTIME = runtime_lib.ProgramRuntime()


@dataclass(frozen=True)
class FleetGANConfig:
    """Fleet-engine execution knobs.

    ``conv_impl`` — conv lowering for every stacked GAN program:
    ``"gemm"`` (default, the phase-decomposed gemm kernels),
    ``"gemm_int8"`` (same gemm forms with blockwise-int8 quantized
    compute + fp32 accumulation — trains *with* quantized matmuls,
    §IV's resource knob beyond uplink quantization), or ``"lax"``
    (the conv primitives; slow on CPU, see kernels/gan_conv.py).

    ``bucket_batches`` — True (default) pads every client's GAN
    minibatch to the cohort-wide bucket so all batch-size groups share
    **one** train compile (plus the mean-correction arithmetic).
    False opts out: each distinct batch-size group trains through the
    *exact* :func:`gan.gan_scan` (in-program noise — bitwise the
    sequential RNG stream, no mask arithmetic), paying one train
    compile per group. The opt-out is for latency-critical single-shot
    prep: when a population is trained once and its batch-size groups
    are few, per-group programs are smaller and can compile+run faster
    than the one bucketed program padded to the cohort max.

    ``mesh`` — optional Mesh: the stacked GAN cohort axis (params, both
    Adam states, pools, pre-drawn index/noise streams, and the
    synthesis batch) is sharded over the mesh's data-parallel axes
    (``launch.mesh.cohort_sharding``), after padding the cohort width
    up to a shard multiple with rows that ride exactly like ineligible
    clients: all-False ``active`` mask (bitwise no-op steps), zero
    index/noise fills, never written back. All RNG stays host-side at
    the TRUE cohort width, so every key/index/z stream is bitwise the
    unsharded (and sequential) one on any mesh. Requires
    ``bucket_batches=True`` — the per-group exact path scatters trained
    groups back with ``.at[]`` updates, which would force resharding
    round-trips per group.
    """
    conv_impl: str = "gemm"
    bucket_batches: bool = True
    mesh: Any = None


def default_runtime() -> runtime_lib.ProgramRuntime:
    """The module-level runtime standalone calls compile through —
    benchmarks read its ledger (``stats()``/``subtotal("gan_")``) after
    a prep that wasn't given an explicit runtime."""
    return _DEFAULT_RUNTIME


def clear_cache():
    """Drop the default runtime's compiled-executable cache. The cache
    is keyed by program kind + argument geometry and never evicts, so
    long-lived processes sweeping many distinct population shapes
    (benchmarks, shape sweeps) can use this to bound memory — and to
    force a cold ``compile_time_s`` measurement."""
    _DEFAULT_RUNTIME.clear()


@dataclass
class FleetGANReport:
    """What one fleet prep did: population split, the fused train
    program's (batch bucket -> cohort width) group, and the
    compile/steady-state timing split."""
    n_clients: int
    n_eligible: int
    n_synth: int = 0
    n_dropped: int = 0   # eligible clients lost between launch/resolve
    groups: List[Tuple[int, int]] = field(default_factory=list)
    compile_time_s: float = 0.0
    prep_time_s: float = 0.0
    d_loss: Dict[int, float] = field(default_factory=dict)
    g_loss: Dict[int, float] = field(default_factory=dict)


def _keystream_build(steps):
    return lambda ks: jax.vmap(
        lambda r: gan_lib.gan_key_stream(r, steps))(ks)


def _indices_build(batch):
    return lambda kb, n: jax.vmap(
        lambda k, m: gan_lib.gan_batch_indices(k, m, batch))(kb, n)


def _zstream_build(batch, z_dim):
    return lambda ks: jax.vmap(
        lambda k: gan_lib.gan_z_stream(k, batch, z_dim))(ks)


def _init_build(cfg):
    def one(k0):
        params = gan_lib.init_gan(k0, cfg)
        opt = {"gen": optim.adam_init(params["gen"]),
               "disc": optim.adam_init(params["disc"])}
        return params, opt

    return lambda k0s: jax.vmap(one)(k0s)


def _train_build(cfg):
    def one(params, opt, imgs, labs, idx, z, z2, n_true, active):
        return gan_lib.gan_scan_bucketed(
            params, opt, cfg, imgs, labs, idx, z, z2, n_true,
            active=active)

    return lambda *a: jax.vmap(one)(*a)


def _train_exact_build(cfg):
    """Per-group exact program (``FleetGANConfig.bucket_batches=False``):
    plain :func:`gan.gan_scan` at the group's true batch size —
    in-program noise, so the RNG stream is *bitwise* the sequential
    ``train_gan`` one, with no mean-correction arithmetic."""
    def one(params, opt, imgs, labs, idx, ks):
        return gan_lib.gan_scan(params, opt, cfg, imgs, labs, idx, ks)

    return lambda *a: jax.vmap(one)(*a)


def _synth_build(cfg):
    return lambda gens, z, labs: jax.vmap(
        lambda g, zz, ll: gan_lib.generate(g, cfg, zz, ll))(
            gens, z, labs)


@dataclass
class FleetGANJob:
    """A launched (possibly still-computing) fleet-GAN prep. ``need``
    maps client position -> rebalancing labels (host-known at launch, so
    the cohort engine can lay out padded pools before the synthesized
    images exist); ``resolve()`` blocks on the device work, writes
    ``gan_cfg``/``gan_params``/``aug_images``/``aug_labels`` back onto
    the clients, and finalizes the report."""
    report: FleetGANReport
    need: Dict[int, np.ndarray]
    _clients: Sequence = ()
    _cfg: Optional[gan_lib.GANConfig] = None
    _runtime: Optional[runtime_lib.ProgramRuntime] = None
    _gan_snapshot: Tuple[int, float] = (0, 0.0)
    _launch_wall_s: float = 0.0
    _params: Optional[dict] = None          # stacked trained params
    _ms: Optional[dict] = None              # stacked per-step metrics
    _eligible: Sequence[bool] = ()
    _synth: Sequence = ()                   # [(pos, need, synth row)]
    _synth_handle: Optional[runtime_lib.Handle] = None
    _resolved: bool = False
    _dropped: set = field(default_factory=set)

    @property
    def resolved(self) -> bool:
        return self._resolved

    @property
    def dropped(self) -> frozenset:
        return frozenset(self._dropped)

    def mark_dropped(self, positions) -> None:
        """Chaos hook: client positions that dropped between launch and
        resolve. Their device work already ran (the stacked programs are
        in flight), but nothing is written back — no GAN params, no
        synthesized rebalancing rows — exactly as if the client had
        vanished before uploading. The cohort engine shrinks their
        reserved pool slots (``_merge_gan_features``), and the
        sequential oracle simply skips ``prepare_gan`` for them, so both
        executors see the same post-drop pools."""
        if self._resolved:
            raise RuntimeError(
                "cannot drop clients from an already-resolved fleet-GAN "
                "job — mark dropouts between launch and resolve")
        self._dropped.update(int(p) for p in positions)

    def resolve(self) -> FleetGANReport:
        if self._resolved:
            return self.report
        t0 = time.perf_counter()
        rep = self.report
        if self._params is not None:
            d_l = np.asarray(self._ms["d_loss"])
            g_l = np.asarray(self._ms["g_loss"])
            rep.n_dropped = sum(
                1 for i in self._dropped
                if 0 <= i < len(self._clients) and self._eligible[i])
            for i, c in enumerate(self._clients):
                if not self._eligible[i] or i in self._dropped:
                    continue
                c.gan_cfg = self._cfg
                c.gan_params = jax.tree.map(lambda l: l[i], self._params)
                rep.d_loss[i] = float(d_l[i, -1])
                rep.g_loss[i] = float(g_l[i, -1])
                nd = self.need[i]
                if len(nd) == 0:
                    c.aug_images = np.zeros(
                        (0, *c.images.shape[1:]), np.float32)
                    c.aug_labels = np.zeros((0,), np.int32)
        if self._synth:
            imgs = np.asarray(self._synth_handle.result(), np.float32)
            for pos, nd, row in self._synth:
                if pos in self._dropped:
                    continue      # synthesized, never delivered
                self._clients[pos].aug_images = imgs[row, :len(nd)]
                self._clients[pos].aug_labels = nd
                rep.n_synth += len(nd)
        if self._runtime is not None:
            n0, t0c = self._gan_snapshot
            n1, t1c = self._runtime.subtotal("gan_")
            rep.compile_time_s = t1c - t0c
        rep.prep_time_s = (self._launch_wall_s +
                           (time.perf_counter() - t0) -
                           rep.compile_time_s)
        # per-client results now live on the clients; drop the stacked
        # fleet buffers (params + both Adam moment trees, per-step
        # metrics, padded synth images) so they don't stay pinned on
        # device for the rest of the run
        self._params = self._ms = self._synth_handle = None
        self._resolved = True
        return rep


def launch_gan_fleet(clients: Sequence, keys: Sequence, *, steps: int,
                     conv_impl: str = "gemm",
                     fleet_cfg: Optional[FleetGANConfig] = None,
                     runtime: Optional[runtime_lib.ProgramRuntime] = None
                     ) -> FleetGANJob:
    """Dispatch the whole fleet's GAN training + synthesis as two fused
    programs through the shared runtime, without forcing a host sync —
    the caller can stage other device work (CLIP pool encoding) while
    the GANs train, then ``job.resolve()``. ``keys[i]`` is client i's
    GAN key (the simulator passes ``fold_in(rng, GAN_RNG_OFFSET + i)``).
    ``fleet_cfg`` overrides the execution knobs (and its ``conv_impl``
    wins over the legacy keyword when given).
    """
    t_launch = time.perf_counter()
    if fleet_cfg is not None:
        conv_impl = fleet_cfg.conv_impl
    bucketed = fleet_cfg.bucket_batches if fleet_cfg is not None else True
    mesh = fleet_cfg.mesh if fleet_cfg is not None else None
    if mesh is not None and not bucketed:
        raise ValueError(
            "mesh-sharded fleet-GAN requires bucket_batches=True — the "
            "per-group exact path scatters trained groups back with "
            ".at[] row updates, which would reshard per group")
    shards = mesh_lib.cohort_axis_size(mesh) if mesh is not None else 1
    put = (lambda x: jax.device_put(
        x, mesh_lib.cohort_sharding(mesh, jnp.ndim(x)))) \
        if mesh is not None else (lambda x: x)
    rt = runtime if runtime is not None else _DEFAULT_RUNTIME
    rep = FleetGANReport(n_clients=len(clients), n_eligible=0)
    job = FleetGANJob(report=rep, need={}, _clients=clients, _runtime=rt,
                      _gan_snapshot=rt.subtotal("gan_"))
    if not clients:
        job._launch_wall_s = time.perf_counter() - t_launch
        return job
    if len(keys) != len(clients):
        # jnp indexing clamps out-of-bounds rows, so a short keys list
        # would silently reuse the last key — break parity loudly
        raise ValueError(
            f"need one GAN key per client (ineligible ones included): "
            f"got {len(keys)} keys for {len(clients)} clients")
    n_classes = clients[0].n_classes
    if any(c.n_classes != n_classes for c in clients):
        raise ValueError("fleet-GAN cohort must share one class space")
    if any(c.n == 0 for c in clients):
        raise ValueError("fleet-GAN cohort contains empty clients — "
                         "drop them before GAN prep (simulator does)")
    cfg = gan_lib.GANConfig(n_classes=n_classes, conv_impl=conv_impl)
    job._cfg = cfg
    eligible = [c.n >= strategies_lib.GAN_MIN_POOL for c in clients]
    job._eligible = eligible
    rep.n_eligible = int(sum(eligible))
    if rep.n_eligible == 0:       # empty-after-filter: nothing to train
        job._launch_wall_s = time.perf_counter() - t_launch
        return job
    for i, c in enumerate(clients):
        job.need[i] = gan_lib.rebalance_labels(c.labels, n_classes) \
            if eligible[i] else np.zeros((0,), np.int32)

    C = len(clients)
    # one dispatch: every client's full RNG stream (bitwise the
    # sequential split sequence)
    keys_arr = jnp.stack([jnp.asarray(k) for k in keys])
    k0s, kbs, kss = rt.compile(
        "gan_keys", lambda: _keystream_build(steps), (keys_arr,),
        static_key=(steps,))(keys_arr)

    # the one shared batch bucket: every client's minibatch pads to the
    # cohort max; true batch sizes drive the in-program mean correction
    n_b = np.asarray([strategies_lib.gan_batch_size(c.n)
                      for c in clients], np.int32)
    B = int(n_b[np.asarray(eligible)].max())
    pool_i, pool_l, lens = stage_client_pools(
        [(c.images, c.labels) for c in clients])

    # mesh: pad the stacked cohort width up to a shard multiple. Pad
    # rows ride exactly like ineligible clients — all-False active
    # mask, zero index/noise fills, never written back — and duplicate
    # client 0's keys (already drawn at the TRUE width above; threefry
    # is not shape-stable, so every key/index/z draw happens before
    # this pad and is bitwise the unsharded stream on any mesh).
    Cp = runtime_lib.shard_multiple(C, shards)
    if Cp > C:
        tile = lambda a: jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (Cp - C,) + a.shape[1:])])
        k0s, kbs, kss = tile(k0s), tile(kbs), tile(kss)
        pool_i = np.concatenate([pool_i, np.zeros(
            (Cp - C, *np.shape(pool_i)[1:]), np.asarray(pool_i).dtype)])
        pool_l = np.concatenate([pool_l, np.zeros(
            (Cp - C, *np.shape(pool_l)[1:]), np.asarray(pool_l).dtype)])
        n_b = np.concatenate([n_b, np.full(Cp - C, B, np.int32)])

    by_batch: Dict[int, List[int]] = {}
    for i in range(C):
        if eligible[i]:
            by_batch.setdefault(int(n_b[i]), []).append(i)

    k0s = put(k0s)
    params, opt = rt.compile("gan_init", lambda: _init_build(cfg),
                             (k0s,), static_key=(cfg,))(k0s)

    if bucketed:
        # per-distinct-batch-size pre-draws at the TRUE shape (threefry
        # is not shape-stable), each group padded on its minibatch axis
        # to the bucket, then assembled into the (C, steps, B[, z_dim])
        # stacks with one concatenate + row permutation. Ineligible
        # clients' steps are fully masked no-ops, so their draws stay
        # zero.
        parts_idx, parts_z, parts_z2, order = [], [], [], []
        for batch, pos in sorted(by_batch.items()):
            pos_dev = jnp.asarray(pos)
            iargs = (kbs[pos_dev], jnp.asarray(lens)[pos_dev])
            idx_g = rt.compile("gan_idx", lambda: _indices_build(batch),
                               iargs, static_key=(batch,))(*iargs)
            zargs = (kss[pos_dev],)
            z_g, z2_g = rt.compile(
                "gan_z", lambda: _zstream_build(batch, cfg.z_dim),
                zargs, static_key=(batch, cfg.z_dim))(*zargs)
            bpad = ((0, 0), (0, 0), (0, B - batch))
            parts_idx.append(jnp.pad(idx_g, bpad))
            parts_z.append(jnp.pad(z_g, bpad + ((0, 0),)))
            parts_z2.append(jnp.pad(z2_g, bpad + ((0, 0),)))
            order.extend(pos)
        # mesh pad rows (positions C..Cp) join the ineligible riders:
        # zero draws, all-False active, masked bitwise no-op steps
        inelig = [i for i in range(Cp) if i >= C or not eligible[i]]
        if inelig:
            parts_idx.append(
                jnp.zeros((len(inelig), steps, B), jnp.int32))
            parts_z.append(
                jnp.zeros((len(inelig), steps, B, cfg.z_dim)))
            parts_z2.append(
                jnp.zeros((len(inelig), steps, B, cfg.z_dim)))
            order.extend(inelig)
        perm = jnp.asarray(np.argsort(np.asarray(order)))
        idx_all = jnp.concatenate(parts_idx)[perm]
        z_all = jnp.concatenate(parts_z)[perm]
        z2_all = jnp.concatenate(parts_z2)[perm]

        active = jnp.asarray(np.repeat(
            [[bool(e)] for e in eligible] + [[False]] * (Cp - C),
            steps, axis=1))
        targs = (params, opt, jnp.asarray(pool_i), jnp.asarray(pool_l),
                 idx_all, z_all, z2_all, jnp.asarray(n_b), active)
        if mesh is not None:
            targs = tuple(jax.tree.map(put, t) for t in targs)
        params, opt, ms = rt.compile(
            "gan_train", lambda: _train_build(cfg), targs,
            static_key=(cfg,), donate_argnums=(0, 1))(*targs)
        job._params, job._ms = params, ms
        rep.groups.append((B, C))
    else:
        # FleetGANConfig.bucket_batches=False: each batch-size group
        # trains through the exact per-group gan_scan (one compile per
        # group). Ineligible clients are simply left out — they keep
        # their init params (never written back) instead of riding the
        # program masked.
        pool_i_d, pool_l_d = jnp.asarray(pool_i), jnp.asarray(pool_l)
        d_l = np.zeros((C, steps), np.float32)
        g_l = np.zeros((C, steps), np.float32)
        for batch, pos in sorted(by_batch.items()):
            pos_dev = jnp.asarray(pos)
            iargs = (kbs[pos_dev], jnp.asarray(lens)[pos_dev])
            idx_g = rt.compile("gan_idx", lambda: _indices_build(batch),
                               iargs, static_key=(batch,))(*iargs)
            gp = jax.tree.map(lambda l: l[pos_dev], params)
            go = jax.tree.map(lambda l: l[pos_dev], opt)
            targs = (gp, go, pool_i_d[pos_dev], pool_l_d[pos_dev],
                     idx_g, kss[pos_dev])
            gp, go, ms = rt.compile(
                "gan_train", lambda: _train_exact_build(cfg), targs,
                static_key=(cfg, "exact"),
                donate_argnums=(0, 1))(*targs)
            params = jax.tree.map(
                lambda l, g: l.at[pos_dev].set(g), params, gp)
            d_l[pos] = np.asarray(ms["d_loss"])
            g_l[pos] = np.asarray(ms["g_loss"])
            rep.groups.append((batch, len(pos)))
        job._params = params
        job._ms = {"d_loss": d_l, "g_loss": g_l}

    # synthesis: per-client z drawn eagerly at the exact sequential
    # shape (threefry draws are not prefix-stable under padding), then
    # one stacked generate over the cohort, row count bucketed to a
    # power of two so nearby populations share the compile
    synth = []                     # (pos, need, z)
    for i, c in enumerate(clients):
        if not eligible[i] or len(job.need[i]) == 0:
            continue
        nd = job.need[i]
        z = jax.random.normal(jax.random.fold_in(keys_arr[i], 1),
                              (len(nd), cfg.z_dim))
        synth.append((i, nd, z))
    if synth:
        M = runtime_lib.pow2_ceil(max(len(nd) for _, nd, _ in synth))
        z_pad = jnp.stack([
            jnp.pad(z, ((0, M - z.shape[0]), (0, 0)))
            for _, _, z in synth])
        lab_pad = jnp.asarray(np.stack([
            np.pad(nd, (0, M - len(nd))) for _, nd, _ in synth]))
        row_src = [i for i, _, _ in synth]
        # mesh: pad the synthesis cohort axis to a shard multiple at
        # the END (true rows keep their positions for resolve()); pad
        # rows generate from client 0's trained params on zero z/labels
        # and are never delivered
        Sp = runtime_lib.shard_multiple(len(synth), shards)
        if Sp > len(synth):
            extra = Sp - len(synth)
            z_pad = jnp.concatenate(
                [z_pad, jnp.zeros((extra, M, cfg.z_dim))])
            lab_pad = jnp.concatenate(
                [lab_pad, jnp.zeros((extra, M), lab_pad.dtype)])
            row_src = row_src + [row_src[0]] * extra
        rows = jnp.asarray(row_src)
        gens = jax.tree.map(lambda l: l[rows], params["gen"])
        sargs = (gens, z_pad, lab_pad)
        if mesh is not None:
            sargs = tuple(jax.tree.map(put, t) for t in sargs)
        job._synth_handle = rt.dispatch(
            "gan_synth", lambda: _synth_build(cfg), sargs,
            static_key=(cfg,))
        job._synth = [(i, nd, row) for row, (i, nd, _) in
                      enumerate(synth)]
    job._launch_wall_s = time.perf_counter() - t_launch
    return job


def prepare_gan_fleet(clients: Sequence, keys: Sequence, *, steps: int,
                      conv_impl: str = "gemm",
                      fleet_cfg: Optional[FleetGANConfig] = None,
                      runtime: Optional[runtime_lib.ProgramRuntime] =
                      None) -> FleetGANReport:
    """Train + synthesize every eligible client's GAN as stacked fused
    programs and write ``gan_cfg``/``gan_params``/``aug_images``/
    ``aug_labels`` back onto the clients — the fleet equivalent of

        for i, c in enumerate(clients):
            if c.n >= strategies.GAN_MIN_POOL:
                c.prepare_gan(keys[i], steps=steps)

    Blocking composition of :func:`launch_gan_fleet` + ``resolve()``.
    Ineligible clients ride the one bucketed program fully masked
    (bitwise no-op steps) and keep their GAN fields unset — or, under
    ``FleetGANConfig(bucket_batches=False)``, are simply left out of
    the per-group exact programs. Returns a :class:`FleetGANReport`."""
    return launch_gan_fleet(clients, keys, steps=steps,
                            conv_impl=conv_impl, fleet_cfg=fleet_cfg,
                            runtime=runtime).resolve()
