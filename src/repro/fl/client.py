"""FL client: local TriplePlay training on a frozen (quantized) CLIP.

Per round each client:
 1. (tripleplay) trains/uses its conditional GAN to over-sample
    underrepresented classes until the local class histogram is balanced;
 2. runs local SGD/Adam steps on the adapter (+ vision LoRA) against the
    zero-shot class-prompt head;
 3. returns its *update* (delta of trainable params), blockwise-quantized
    when the strategy compresses communication.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adapter as adapter_lib
from repro.core import clip as clip_lib
from repro.core import gan as gan_lib
from repro.core import losses, optim
from repro.core.quant import (QTensor, dequantize_tree, quantize,
                              quantize_tree, tree_bytes)
from repro.fl import strategies as strategies_lib
from repro.fl.strategies import Strategy

LORA_RANK = 4


def init_trainable(rng, ccfg: clip_lib.CLIPConfig, strategy: Strategy):
    k1, k2 = jax.random.split(rng)
    tr: Dict[str, Any] = {"adapter": adapter_lib.init(
        k1, ccfg.d_model, n_heads=4, d_ff=ccfg.d_model)}
    if strategy.use_lora:
        L = ccfg.vision_layers
        d = ccfg.d_model

        def pair(k):
            return {"a": jax.random.normal(k, (d, LORA_RANK)) *
                    (1 / np.sqrt(d)),
                    "b": jnp.zeros((LORA_RANK, d))}

        per_layer = []
        for li, kl in enumerate(jax.random.split(k2, L)):
            per_layer.append({n: pair(jax.random.fold_in(kl, i))
                              for i, n in enumerate(("wq", "wk", "wv",
                                                     "wo"))})
        tr["lora"] = jax.tree.map(lambda *ls: jnp.stack(ls), *per_layer)
    return tr


def head_logits(frozen, trainable, feat, class_emb):
    """Pooled backbone features -> zero-shot class logits through the
    trainable adapter head (the part of the forward that always depends
    on trainables; the cohort engine feeds it hoisted features)."""
    feat = adapter_lib.apply(trainable["adapter"], feat[:, None, :],
                             n_heads=4, causal=False)[:, 0]
    emb = feat @ frozen["proj_v"]
    return clip_lib.zero_shot_logits(emb, class_emb, frozen["logit_scale"])


def forward_logits(frozen, trainable, ccfg, images, class_emb):
    """images -> zero-shot class logits through backbone+adapter."""
    lora = trainable.get("lora")
    feat = clip_lib.encode_image(frozen, ccfg, images, lora=lora)
    return head_logits(frozen, trainable, feat, class_emb)


@partial(jax.jit, static_argnums=(5,))
def _local_step(frozen, trainable, opt_state, batch, class_emb, ccfg, lr):
    images, labels = batch

    def loss_fn(tr):
        logits = forward_logits(frozen, tr, ccfg, images, class_emb)
        ce = losses.cross_entropy(logits, labels)
        return ce, losses.accuracy(logits, labels)

    (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        trainable)
    trainable, opt_state = optim.adam_update(grads, opt_state, trainable,
                                             lr=lr, grad_clip=1.0)
    return trainable, opt_state, loss, acc


@dataclass
class Client:
    cid: int
    images: np.ndarray
    labels: np.ndarray
    n_classes: int
    strategy: Strategy
    gan_params: Optional[dict] = None
    gan_cfg: Optional[gan_lib.GANConfig] = None
    aug_images: Optional[np.ndarray] = None
    aug_labels: Optional[np.ndarray] = None
    # availability-trace heterogeneity hook: this client runs
    # ``step_mult`` x the configured local steps per round (fast/slow
    # devices). Both executors read it — the cohort engine masks the
    # extra scan steps, the sequential path just runs fewer/more batches.
    step_mult: int = 1

    @property
    def n(self) -> int:
        return len(self.labels)

    def local_steps_for(self, base_steps: int) -> int:
        """Per-round local step count under this client's trace-assigned
        compute multiplier."""
        return int(base_steps) * max(1, int(self.step_mult))

    def prepare_gan(self, rng, *, steps: int = 150):
        """Train the local conditional GAN and synthesize a rebalancing
        set so every class reaches the local max count (paper §III-B).

        This is the sequential per-client path — one jitted
        ``gan.train_step`` dispatch per GAN step — kept as the parity
        oracle and benchmark baseline for the fused fleet engine
        (``fl.fleetgan.prepare_gan_fleet``), which trains every
        client's GAN inside one stacked cohort program on the same
        per-client RNG streams. Thresholds and batch sizing are the
        shared ``fl.strategies`` constants so both paths agree on
        eligibility and shapes."""
        self.gan_cfg = gan_lib.GANConfig(n_classes=self.n_classes)
        self.gan_params, _ = gan_lib.train_gan(
            rng, self.gan_cfg, jnp.asarray(self.images),
            jnp.asarray(self.labels), steps=steps,
            batch=strategies_lib.gan_batch_size(self.n))
        need = gan_lib.rebalance_labels(self.labels, self.n_classes)
        if len(need) == 0:
            self.aug_images = np.zeros((0, *self.images.shape[1:]),
                                       np.float32)
            self.aug_labels = np.zeros((0,), np.int32)
            return
        imgs = gan_lib.synthesize(jax.random.fold_in(rng, 1),
                                  self.gan_params["gen"], self.gan_cfg,
                                  jnp.asarray(need))
        self.aug_images = np.asarray(imgs, np.float32)
        self.aug_labels = need

    def pool(self):
        """Local training pool: real samples + GAN rebalancing set."""
        if self.strategy.use_gan and self.aug_images is not None and \
                len(self.aug_labels):
            return (np.concatenate([self.images, self.aug_images]),
                    np.concatenate([self.labels, self.aug_labels]))
        return self.images, self.labels

    _pool = pool  # backwards-compat alias

    def local_train(self, frozen, trainable, class_emb, ccfg, *,
                    steps: int, batch_size: int, lr: float, seed: int = 0,
                    indices: Optional[np.ndarray] = None):
        """Sequential reference path (one jitted step per batch).

        ``indices`` — optional (steps, batch) pool-index matrix. When
        given it replaces the seeded np.RandomState sampling, letting the
        batched cohort engine's jax.random sample sequence drive this
        path as the parity-test oracle.
        """
        imgs, labs = self.pool()
        if indices is None:
            rng = np.random.RandomState(seed)
            # full batch_size even when the pool is smaller (bootstrap
            # resampling) — the cohort engine needs fixed shapes, and
            # both engines must share one sampling semantic
            indices = rng.randint(0, len(labs), (steps, batch_size))
        opt = optim.adam_init(trainable)
        loss = acc = 0.0
        for idx in np.asarray(indices):
            trainable, opt, loss, acc = _local_step(
                frozen, trainable, opt,
                (jnp.asarray(imgs[idx]), jnp.asarray(labs[idx])),
                class_emb, ccfg, lr)
        return trainable, {"loss": float(loss), "acc": float(acc)}

    def make_update(self, before, after):
        """Delta of trainables, quantized per strategy. Returns
        (update_tree, payload_bytes)."""
        delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32),
                             after, before)
        delta = self.strategy.comm_quantize(delta)
        return delta, tree_bytes(delta)
