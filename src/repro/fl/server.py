"""FL server: weighted aggregation of (quantized) client updates.

Implements the paper's aggregation
    w_final = Σ_i (m_i / Σ_j m_j) · dequant(update_i)
applied in the trainable (LoRA/adapter) basis: updates are deltas, so the
new global trainables are  w_global + Σ weighted deltas. On the production
mesh the same reduction is a ``psum`` over the (pod, data) axes — see
``fed_round_spec`` in launch/train.py.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, dequantize, dequantize_tree


def aggregate(global_trainable, updates: Sequence[Tuple[int, object]]):
    """updates: list of (m_i = client sample count, delta tree)."""
    total = float(sum(m for m, _ in updates))
    acc = None
    for m, delta in updates:
        d = dequantize_tree(delta, jnp.float32)
        w = m / total
        acc = jax.tree.map(lambda x, a=None: w * x, d) if acc is None else \
            jax.tree.map(lambda a, x: a + w * x, acc, d)
    return jax.tree.map(lambda g, a: (g.astype(jnp.float32) + a).astype(
        g.dtype), global_trainable, acc)


def aggregate_stacked(global_trainable, weights, stacked_delta):
    """Batched FedAvg for the cohort engine: every delta leaf carries a
    leading cohort axis (possibly blockwise-quantized along its trailing
    dims), and the weighted sum is one ``tensordot`` per leaf instead of
    a Python loop over clients. Runs inside the jitted cohort round.

    ``weights`` — (n_clients,) float32, already normalized (m_i / Σ m_j).
    """
    def reduce_leaf(d):
        x = dequantize(d, jnp.float32) if isinstance(d, QTensor) else \
            d.astype(jnp.float32)
        return jnp.tensordot(weights, x, axes=1)

    agg = jax.tree.map(reduce_leaf, stacked_delta,
                       is_leaf=lambda l: isinstance(l, QTensor))
    return jax.tree.map(lambda g, a: (g.astype(jnp.float32) + a).astype(
        g.dtype), global_trainable, agg)


def secure_sum_bytes(updates) -> int:
    """Total uplink payload this round (comm-cost bookkeeping)."""
    from repro.core.quant import tree_bytes
    return int(sum(tree_bytes(d) for _, d in updates))
