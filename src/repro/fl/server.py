"""FL server: weighted aggregation of (quantized) client updates.

Implements the paper's aggregation
    w_final = Σ_i (m_i / Σ_j m_j) · dequant(update_i)
applied in the trainable (LoRA/adapter) basis: updates are deltas, so the
new global trainables are  w_global + Σ weighted deltas. On the production
mesh the same reduction is a ``psum`` over the (pod, data) axes — see
``fed_round_spec`` in launch/train.py.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import QTensor, dequantize, dequantize_tree


def check_weights(weights, n_updates: int):
    """Shared guard for ``aggregate`` / ``aggregate_stacked``: a
    mis-shaped or mis-normalized aggregation-weight vector silently
    rescales every update, so fail loudly instead. Shape is checked even
    under tracing (shapes are static); the numeric normalization check
    runs only on concrete host values — jitted callers (the fused cohort
    round) validate the weights host-side before dispatch.
    """
    shape = np.shape(weights)
    if shape != (n_updates,):
        raise ValueError(
            f"aggregation weights shape {shape} != ({n_updates},) — one "
            "weight per committed update")
    if isinstance(weights, jax.core.Tracer):
        return
    w = np.asarray(weights, np.float64)
    if not np.all(np.isfinite(w)) or np.any(w < 0):
        raise ValueError(f"aggregation weights must be finite and >= 0, "
                         f"got {w}")
    if abs(float(w.sum()) - 1.0) > 1e-3:
        raise ValueError(
            f"aggregation weights sum to {w.sum():.6f}, expected 1 "
            "(normalize m_i / sum m_j, or the staleness-discounted "
            "equivalent, before aggregating)")


def check_delta(delta, ref=None, *, ctx: str = "client delta"):
    """Guard one client's update tree before it can touch the global
    model: every float leaf must be finite (for QTensor leaves that is
    the dequantization ``scales`` — int codes cannot encode NaN), and
    with ``ref`` (the global trainable tree) given, the per-leaf shapes
    must match it. A single NaN delta would poison the aggregated global
    irreversibly (``aggregate`` sums it into every parameter), so this
    fails loudly; the chaos schedulers call :func:`delta_ok` instead to
    skip-and-ledger under ``ChaosConfig.tolerate_corrupt``."""
    leaves = jax.tree_util.tree_leaves_with_path(
        delta, is_leaf=lambda l: isinstance(l, QTensor))
    if ref is not None:
        ref_leaves = jax.tree_util.tree_leaves_with_path(ref)
        if len(ref_leaves) != len(leaves):
            raise ValueError(
                f"{ctx}: tree has {len(leaves)} leaves, global trainable "
                f"has {len(ref_leaves)}")
        for (path, l), (_, rl) in zip(leaves, ref_leaves):
            shape = tuple(l.orig_shape) if isinstance(l, QTensor) else \
                tuple(np.shape(l))
            if shape != tuple(np.shape(rl)):
                raise ValueError(
                    f"{ctx}: leaf {jax.tree_util.keystr(path)} has shape "
                    f"{shape}, global trainable expects "
                    f"{tuple(np.shape(rl))}")
    for path, l in leaves:
        arr = np.asarray(l.scales if isinstance(l, QTensor) else l)
        if arr.dtype.kind == "f" and not np.all(np.isfinite(arr)):
            raise ValueError(
                f"{ctx}: non-finite values at "
                f"{jax.tree_util.keystr(path)} — refusing to aggregate "
                "a corrupt update into the global model")


def delta_ok(delta, ref=None) -> bool:
    """Tolerant form of :func:`check_delta` for skip-and-ledger paths."""
    try:
        check_delta(delta, ref)
        return True
    except ValueError:
        return False


def aggregate(global_trainable, updates: Sequence[Tuple[float, object]]):
    """updates: list of (m_i, delta tree) — m_i is the client sample
    count (plain FedAvg) or any non-negative importance mass (the async
    scheduler passes staleness-discounted masses); weights are m_i
    normalized over the committed set."""
    masses = [float(m) for m, _ in updates]
    total = sum(masses)
    if not updates or total <= 0 or not np.all(np.isfinite(masses)) or \
            min(masses) < 0:
        raise ValueError(
            f"aggregate needs non-negative finite masses with a positive "
            f"total, got {masses}")
    ws = np.asarray(masses, np.float64) / total
    check_weights(ws.astype(np.float32), len(updates))
    acc = None
    for w, (_, delta) in zip(ws, updates):
        d = dequantize_tree(delta, jnp.float32)
        w = float(w)
        acc = jax.tree.map(lambda x: w * x, d) if acc is None else \
            jax.tree.map(lambda a, x: a + w * x, acc, d)
    return jax.tree.map(lambda g, a: (g.astype(jnp.float32) + a).astype(
        g.dtype), global_trainable, acc)


def aggregate_stacked(global_trainable, weights, stacked_delta):
    """Batched FedAvg for the cohort engine: every delta leaf carries a
    leading cohort axis (possibly blockwise-quantized along its trailing
    dims), and the weighted sum is one ``tensordot`` per leaf instead of
    a Python loop over clients. Runs inside the jitted cohort round, and
    eagerly in the async scheduler's buffer commit.

    ``weights`` — (n_clients,) float32, already normalized (m_i / Σ m_j
    or the staleness-discounted equivalent).
    """
    leaves = jax.tree.leaves(stacked_delta,
                             is_leaf=lambda l: isinstance(l, QTensor))
    n = leaves[0].shape[0] if leaves else 0
    for l in leaves:
        if l.shape[0] != n:
            raise ValueError("stacked delta leaves disagree on the "
                             f"cohort axis: {l.shape[0]} vs {n}")
    check_weights(weights, n)

    def reduce_leaf(d):
        x = dequantize(d, jnp.float32) if isinstance(d, QTensor) else \
            d.astype(jnp.float32)
        return jnp.tensordot(weights, x, axes=1)

    agg = jax.tree.map(reduce_leaf, stacked_delta,
                       is_leaf=lambda l: isinstance(l, QTensor))
    return jax.tree.map(lambda g, a: (g.astype(jnp.float32) + a).astype(
        g.dtype), global_trainable, agg)


def tree_partials(masses, stacked_delta, *, n_shards: int):
    """Shard-local stage of hierarchical FedAvg: split the stacked
    cohort axis into ``n_shards`` contiguous groups and reduce each
    group to a **partial weighted delta sum** plus its **partial weight
    mass** — the pair a shard uploads instead of its clients' stacked
    deltas. ``masses`` are non-negative importance masses (sample
    counts, or any already-discounted weighting; they need not sum
    to 1 — the global stage normalizes by the total mass).

    If the cohort width is not a shard multiple, the tail pads with
    zero-mass, zero-delta rows — exact, because a zero mass contributes
    ``0 * x == 0`` to its shard's partial sum and ``0`` to its mass.
    On a mesh-sharded cohort axis the reshape keeps every group's rows
    local to its shard, so the per-shard ``einsum`` never moves a
    stacked delta off-device; only the (n_shards, ...) partials cross
    shards in the global reduce.

    Returns ``(partials, mass_s)``: a delta-shaped tree whose leaves
    carry a leading ``(n_shards,)`` axis, and the (n_shards,) partial
    masses."""
    if n_shards < 1:
        raise ValueError(f"tree_partials needs n_shards >= 1, got "
                         f"{n_shards}")
    leaves = jax.tree.leaves(stacked_delta,
                             is_leaf=lambda l: isinstance(l, QTensor))
    n = leaves[0].shape[0] if leaves else 0
    for l in leaves:
        if l.shape[0] != n:
            raise ValueError("stacked delta leaves disagree on the "
                             f"cohort axis: {l.shape[0]} vs {n}")
    if np.shape(masses) != (n,):
        raise ValueError(
            f"masses shape {np.shape(masses)} != ({n},) — one mass per "
            "stacked update")
    if not isinstance(masses, jax.core.Tracer):
        m = np.asarray(masses, np.float64)
        if not np.all(np.isfinite(m)) or np.any(m < 0):
            raise ValueError(
                f"masses must be finite and >= 0, got {m}")
    pad = -(-n // n_shards) * n_shards - n
    m_r = jnp.pad(jnp.asarray(masses, jnp.float32), (0, pad)) \
        .reshape(n_shards, -1)
    mass_s = m_r.sum(axis=1)

    def leaf(d):
        x = dequantize(d, jnp.float32) if isinstance(d, QTensor) else \
            d.astype(jnp.float32)
        if pad:
            x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
        x = x.reshape(n_shards, -1, *x.shape[1:])
        return jnp.einsum("sb,sb...->s...", m_r, x)

    partials = jax.tree.map(leaf, stacked_delta,
                            is_leaf=lambda l: isinstance(l, QTensor))
    return partials, mass_s


def aggregate_tree(global_trainable, masses, stacked_delta, *,
                   n_shards: int):
    """Hierarchical (two-level) FedAvg: clients → shard-local partial
    sums (:func:`tree_partials`) → global reduce of the ``n_shards``
    partials, normalized by the total mass. Mathematically a
    re-association of :func:`aggregate_stacked` — the flat aggregator
    stays as the parity oracle (tree == flat within fp tolerance,
    pinned by the hypothesis property in ``tests/test_runtime.py``) —
    but on a mesh the full stacked delta is never reduced on one
    device: each shard reduces its own rows and only the small
    (n_shards, ...) partials cross the mesh."""
    partials, mass_s = tree_partials(masses, stacked_delta,
                                     n_shards=n_shards)
    total = mass_s.sum()
    agg = jax.tree.map(lambda p: p.sum(axis=0) / total, partials)
    return jax.tree.map(lambda g, a: (g.astype(jnp.float32) + a).astype(
        g.dtype), global_trainable, agg)


def secure_sum_bytes(updates) -> int:
    """Total uplink payload this round (comm-cost bookkeeping)."""
    from repro.core.quant import tree_bytes
    return int(sum(tree_bytes(d) for _, d in updates))
