"""The three experimental arms of the paper (Figs. 3-5).

- fedclip      : frozen CLIP + attention adapter, fp32 communication.
- qlora_nogan  : + NF4-quantized backbone + LoRA, quantized (int8) comm.
- tripleplay   : qlora_nogan + client-side GAN long-tail rebalancing.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Strategy:
    name: str
    use_lora: bool
    backbone_bits: int       # 0 = bf16/f32 backbone
    backbone_mode: str
    comm_bits: int           # 0 = fp32 updates
    use_gan: bool


STRATEGIES = {
    "fedclip": Strategy("fedclip", use_lora=False, backbone_bits=0,
                        backbone_mode="linear", comm_bits=0, use_gan=False),
    "qlora_nogan": Strategy("qlora_nogan", use_lora=True, backbone_bits=4,
                            backbone_mode="nf4", comm_bits=8,
                            use_gan=False),
    "tripleplay": Strategy("tripleplay", use_lora=True, backbone_bits=4,
                           backbone_mode="nf4", comm_bits=8, use_gan=True),
}
