"""The three experimental arms of the paper (Figs. 3-5).

- fedclip      : frozen CLIP + attention adapter, fp32 communication.
- qlora_nogan  : + NF4-quantized backbone + LoRA, quantized (int8) comm.
- tripleplay   : qlora_nogan + client-side GAN long-tail rebalancing.

The uplink compression parameters live here (not in the client) so the
sequential reference path and the batched cohort engine apply *identical*
quantization semantics — the parity tests depend on it.
"""
from __future__ import annotations

from dataclasses import dataclass

# Blockwise update-quantization layout shared by every strategy arm that
# compresses communication (client.make_update and fl.cohort).
COMM_BLOCK = 64
COMM_MIN_SIZE = 256
COMM_SKIP = ("slot",)

# Client-heterogeneity cap shared by the availability-trace generator
# (fl.sched.traces) and both round executors: per-client local-step
# multipliers are clipped to this, bounding the static scan length of the
# fused cohort program (local_steps * MAX_STEP_MULT) and keeping the
# sequential oracle's batch-index layout identical to the engine's.
MAX_STEP_MULT = 4

# GAN rebalancing thresholds shared by the sequential
# ``Client.prepare_gan`` loop and the fleet engine (``fl.fleetgan``) —
# the parity tests depend on both paths agreeing on who trains a GAN,
# on what batch size, and under which RNG stream.
GAN_MIN_POOL = 8          # clients with n < this skip GAN rebalancing
GAN_BATCH_MAX = 64        # GAN minibatch cap
GAN_RNG_OFFSET = 100      # client i's GAN key = fold_in(rng, OFFSET + i)


def gan_batch_size(n: int) -> int:
    """The GAN minibatch a client with ``n`` local samples trains on:
    ``prepare_gan``'s historical ``min(GAN_BATCH_MAX, max(GAN_MIN_POOL,
    n))`` composed with the ``min(batch, n)`` clamp inside
    ``gan.train_gan`` reduces to ``min(GAN_BATCH_MAX, n)``. The fleet
    engine groups clients by this value: it is the one shape the fused
    cohort program cannot pad without changing the math (losses are
    means over the batch)."""
    return min(GAN_BATCH_MAX, int(n))


@dataclass(frozen=True)
class Strategy:
    name: str
    use_lora: bool
    backbone_bits: int       # 0 = bf16/f32 backbone
    backbone_mode: str
    comm_bits: int           # 0 = fp32 updates
    use_gan: bool

    def comm_quantize(self, delta):
        """Quantize an update tree per this arm's uplink compression."""
        if not self.comm_bits:
            return delta
        from repro.core.quant import quantize_tree
        return quantize_tree(delta, bits=self.comm_bits, block=COMM_BLOCK,
                             min_size=COMM_MIN_SIZE, skip_names=COMM_SKIP)


STRATEGIES = {
    "fedclip": Strategy("fedclip", use_lora=False, backbone_bits=0,
                        backbone_mode="linear", comm_bits=0, use_gan=False),
    "qlora_nogan": Strategy("qlora_nogan", use_lora=True, backbone_bits=4,
                            backbone_mode="nf4", comm_bits=8,
                            use_gan=False),
    "tripleplay": Strategy("tripleplay", use_lora=True, backbone_bits=4,
                           backbone_mode="nf4", comm_bits=8, use_gan=True),
}
