"""Client availability / heterogeneity traces for the round scheduler.

Cross-device FL populations are not uniform: devices differ in how often
they are reachable (selection propensity), how fast they train (virtual
wall-clock per local step), and how much local compute they are willing
to spend (local-step multiplier). A trace bundles those three per-client
vectors; the scheduler policies consume them as follows:

 - ``availability`` — sync-partial samples K of N clients with
   probability proportional to it; async uses it to pick which clients
   start training first when concurrency is below N. With a diurnal
   cycle (``period > 0``) the effective propensity at virtual time t is
   ``availability_at(t)``: the static vector modulated by a per-client-
   phased sinusoid, so device classes in different "timezones" rotate
   through the selectable population.
 - ``speed`` — async's virtual-time event loop finishes client i's job
   ``speed[i] * local_steps_i`` virtual seconds after dispatch (plus a
   small key-derived jitter drawn in a replicated dispatch, so event
   times are mesh-invariant like every other random draw in the engine).
 - ``step_mult`` — client i runs ``local_steps * step_mult[i]`` local
   steps, clipped to ``strategies.MAX_STEP_MULT`` so the fused cohort
   scan keeps a bounded static length.
 - ``device_class`` — small int per client (phone / tablet / laptop ...)
   used by the chaos layer's per-class straggler multipliers and by
   ``History``'s per-class fairness / staleness / tail-accuracy columns.

Traces are plain numpy, deterministic in (n, seed), and never touch the
device: they are *simulation inputs*, not learned state. They round-trip
through JSON (``save_trace`` / ``load_trace``) so a scenario — including
the chaos benchmarks' — can be replayed from a file instead of a seed.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Sequence

import numpy as np

from repro.fl.strategies import MAX_STEP_MULT


@dataclass(frozen=True)
class AvailabilityTrace:
    availability: np.ndarray   # (n,) float > 0, selection propensity
    speed: np.ndarray          # (n,) float > 0, virtual secs / local step
    step_mult: np.ndarray      # (n,) int in [1, MAX_STEP_MULT]
    name: str = "custom"
    device_class: Any = None   # (n,) small int >= 0; default all-0
    phase: Any = None          # (n,) diurnal phase in [0, 1); default 0
    period: float = 0.0        # diurnal period in virtual secs; 0 = off
    amplitude: float = 0.0     # diurnal modulation depth in [0, 1)

    def __post_init__(self):
        n = len(self.availability)
        if not (len(self.speed) == len(self.step_mult) == n):
            raise ValueError("trace vectors disagree on n_clients")
        if np.any(np.asarray(self.availability) <= 0) or \
                np.any(np.asarray(self.speed) <= 0):
            raise ValueError("availability and speed must be positive")
        m = np.asarray(self.step_mult)
        if np.any(m < 1) or np.any(m > MAX_STEP_MULT):
            raise ValueError(
                f"step_mult must lie in [1, {MAX_STEP_MULT}], got {m}")
        dc = np.zeros(n, np.int32) if self.device_class is None else \
            np.asarray(self.device_class, np.int32)
        ph = np.zeros(n, np.float64) if self.phase is None else \
            np.asarray(self.phase, np.float64)
        if len(dc) != n or len(ph) != n:
            raise ValueError("device_class/phase disagree on n_clients")
        if np.any(dc < 0):
            raise ValueError(f"device_class must be >= 0, got {dc}")
        if not 0.0 <= float(self.amplitude) < 1.0:
            # amplitude < 1 keeps availability_at strictly positive, so
            # selection probabilities never degenerate mid-cycle
            raise ValueError(
                f"amplitude={self.amplitude} outside [0, 1)")
        object.__setattr__(self, "device_class", dc)
        object.__setattr__(self, "phase", ph)

    @property
    def n(self) -> int:
        return len(self.availability)

    @property
    def n_device_classes(self) -> int:
        return int(np.max(self.device_class)) + 1

    def availability_at(self, t: float = 0.0) -> np.ndarray:
        """Effective selection propensity at virtual time ``t``: the
        static vector, diurnally modulated when ``period > 0``. Strictly
        positive by the amplitude < 1 invariant."""
        a = np.asarray(self.availability, np.float64)
        if self.period <= 0 or self.amplitude <= 0:
            return a
        cyc = np.sin(2.0 * np.pi * (float(t) / float(self.period) +
                                    np.asarray(self.phase, np.float64)))
        return a * (1.0 + float(self.amplitude) * cyc)

    def selection_probs(self, t: float = 0.0) -> np.ndarray:
        a = self.availability_at(t)
        return (a / a.sum()).astype(np.float64)


def uniform_trace(n: int) -> AvailabilityTrace:
    """Idealized population: always available, unit speed, homogeneous
    local steps — the degenerate trace under which sync-partial at K=N
    reproduces the PR 1 full-cohort round exactly."""
    return AvailabilityTrace(
        availability=np.ones(n, np.float64),
        speed=np.ones(n, np.float64),
        step_mult=np.ones(n, np.int32),
        name="uniform")


def skewed_trace(n: int, seed: int = 0, *, zipf: float = 1.2,
                 speed_sigma: float = 0.6,
                 max_step_mult: int = 1) -> AvailabilityTrace:
    """Long-tail population: Zipf-distributed availability (a few clients
    dominate participation), lognormal speeds (stragglers several times
    slower than the median), and optional heterogeneous local-step
    multipliers. Deterministic in (n, seed)."""
    rs = np.random.RandomState(seed)
    avail = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** zipf
    rs.shuffle(avail)
    speed = np.exp(rs.normal(0.0, speed_sigma, n))
    mmax = int(np.clip(max_step_mult, 1, MAX_STEP_MULT))
    mult = rs.randint(1, mmax + 1, n).astype(np.int32)
    return AvailabilityTrace(availability=avail, speed=speed,
                             step_mult=mult, name=f"skewed(seed={seed})")


def diurnal_trace(n: int, seed: int = 0, *, period: float = 24.0,
                  amplitude: float = 0.8,
                  class_speed: Sequence[float] = (1.0, 2.0, 4.0),
                  zipf: float = 1.2, speed_sigma: float = 0.25,
                  max_step_mult: int = 1) -> AvailabilityTrace:
    """Fleet-realism population: Zipf base availability under a diurnal
    cycle (per-client phases — "timezones" — spread in [0, 1)), a
    device-class mix whose classes differ in base speed by
    ``class_speed`` (class 0 fastest), lognormal within-class speed
    spread, and optional heterogeneous step multipliers. Deterministic
    in (n, seed); the chaos layer keys its per-class straggler
    multipliers off ``device_class``."""
    rs = np.random.RandomState(seed)
    avail = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** zipf
    rs.shuffle(avail)
    dc = rs.randint(0, len(class_speed), n).astype(np.int32)
    speed = np.asarray(class_speed, np.float64)[dc] * \
        np.exp(rs.normal(0.0, speed_sigma, n))
    phase = rs.rand(n)
    mmax = int(np.clip(max_step_mult, 1, MAX_STEP_MULT))
    mult = rs.randint(1, mmax + 1, n).astype(np.int32)
    return AvailabilityTrace(
        availability=avail, speed=speed, step_mult=mult,
        name=f"diurnal(seed={seed})", device_class=dc, phase=phase,
        period=float(period), amplitude=float(amplitude))


def save_trace(trace: AvailabilityTrace, path) -> None:
    """Serialize a trace to JSON so a scenario replays from a file
    (availability, speed, step multipliers, device classes, diurnal
    parameters) instead of a seed."""
    payload = {
        "name": trace.name,
        "availability": [float(v) for v in trace.availability],
        "speed": [float(v) for v in trace.speed],
        "step_mult": [int(v) for v in trace.step_mult],
        "device_class": [int(v) for v in trace.device_class],
        "phase": [float(v) for v in trace.phase],
        "period": float(trace.period),
        "amplitude": float(trace.amplitude),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def load_trace(path) -> AvailabilityTrace:
    """Load a trace saved by :func:`save_trace` (validation re-runs in
    ``__post_init__``, so a hand-edited file still fails loudly)."""
    with open(path) as f:
        d = json.load(f)
    return AvailabilityTrace(
        availability=np.asarray(d["availability"], np.float64),
        speed=np.asarray(d["speed"], np.float64),
        step_mult=np.asarray(d["step_mult"], np.int32),
        name=str(d.get("name", "custom")),
        device_class=np.asarray(d["device_class"], np.int32)
        if "device_class" in d else None,
        phase=np.asarray(d["phase"], np.float64)
        if "phase" in d else None,
        period=float(d.get("period", 0.0)),
        amplitude=float(d.get("amplitude", 0.0)))


def resolve_trace(spec, n: int, *, seed: int = 0) -> AvailabilityTrace:
    """Accept None | "uniform" | "skewed" | "skewed-het" | "diurnal" |
    a ``.json`` trace-file path | AvailabilityTrace (validated against
    n). FLConfig.trace routes through here; "skewed-het" adds
    heterogeneous local-step multipliers (up to MAX_STEP_MULT) on top of
    the skewed availability/speed profile, exercising the masked-scan
    path from the public config; "diurnal" adds the device-class mix and
    availability cycle the chaos/fairness machinery keys off."""
    if spec is None or spec == "uniform":
        return uniform_trace(n)
    if spec == "skewed":
        return skewed_trace(n, seed=seed)
    if spec == "skewed-het":
        return skewed_trace(n, seed=seed, max_step_mult=MAX_STEP_MULT)
    if spec == "diurnal":
        return diurnal_trace(n, seed=seed)
    if isinstance(spec, str) and spec.endswith(".json"):
        spec = load_trace(spec)
    if isinstance(spec, AvailabilityTrace):
        if spec.n != n:
            raise ValueError(
                f"trace built for {spec.n} clients, population has {n}")
        return spec
    raise ValueError(f"unknown trace spec {spec!r}")
