"""Client availability / heterogeneity traces for the round scheduler.

Cross-device FL populations are not uniform: devices differ in how often
they are reachable (selection propensity), how fast they train (virtual
wall-clock per local step), and how much local compute they are willing
to spend (local-step multiplier). A trace bundles those three per-client
vectors; the scheduler policies consume them as follows:

 - ``availability`` — sync-partial samples K of N clients with
   probability proportional to it; async uses it to pick which clients
   start training first when concurrency is below N.
 - ``speed`` — async's virtual-time event loop finishes client i's job
   ``speed[i] * local_steps_i`` virtual seconds after dispatch (plus a
   small key-derived jitter drawn in a replicated dispatch, so event
   times are mesh-invariant like every other random draw in the engine).
 - ``step_mult`` — client i runs ``local_steps * step_mult[i]`` local
   steps, clipped to ``strategies.MAX_STEP_MULT`` so the fused cohort
   scan keeps a bounded static length.

Traces are plain numpy, deterministic in (n, seed), and never touch the
device: they are *simulation inputs*, not learned state.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.fl.strategies import MAX_STEP_MULT


@dataclass(frozen=True)
class AvailabilityTrace:
    availability: np.ndarray   # (n,) float > 0, selection propensity
    speed: np.ndarray          # (n,) float > 0, virtual secs / local step
    step_mult: np.ndarray      # (n,) int in [1, MAX_STEP_MULT]
    name: str = "custom"

    def __post_init__(self):
        n = len(self.availability)
        if not (len(self.speed) == len(self.step_mult) == n):
            raise ValueError("trace vectors disagree on n_clients")
        if np.any(np.asarray(self.availability) <= 0) or \
                np.any(np.asarray(self.speed) <= 0):
            raise ValueError("availability and speed must be positive")
        m = np.asarray(self.step_mult)
        if np.any(m < 1) or np.any(m > MAX_STEP_MULT):
            raise ValueError(
                f"step_mult must lie in [1, {MAX_STEP_MULT}], got {m}")

    @property
    def n(self) -> int:
        return len(self.availability)

    def selection_probs(self) -> np.ndarray:
        a = np.asarray(self.availability, np.float64)
        return (a / a.sum()).astype(np.float64)


def uniform_trace(n: int) -> AvailabilityTrace:
    """Idealized population: always available, unit speed, homogeneous
    local steps — the degenerate trace under which sync-partial at K=N
    reproduces the PR 1 full-cohort round exactly."""
    return AvailabilityTrace(
        availability=np.ones(n, np.float64),
        speed=np.ones(n, np.float64),
        step_mult=np.ones(n, np.int32),
        name="uniform")


def skewed_trace(n: int, seed: int = 0, *, zipf: float = 1.2,
                 speed_sigma: float = 0.6,
                 max_step_mult: int = 1) -> AvailabilityTrace:
    """Long-tail population: Zipf-distributed availability (a few clients
    dominate participation), lognormal speeds (stragglers several times
    slower than the median), and optional heterogeneous local-step
    multipliers. Deterministic in (n, seed)."""
    rs = np.random.RandomState(seed)
    avail = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** zipf
    rs.shuffle(avail)
    speed = np.exp(rs.normal(0.0, speed_sigma, n))
    mmax = int(np.clip(max_step_mult, 1, MAX_STEP_MULT))
    mult = rs.randint(1, mmax + 1, n).astype(np.int32)
    return AvailabilityTrace(availability=avail, speed=speed,
                             step_mult=mult, name=f"skewed(seed={seed})")


def resolve_trace(spec, n: int, *, seed: int = 0) -> AvailabilityTrace:
    """Accept None | "uniform" | "skewed" | "skewed-het" |
    AvailabilityTrace (validated against n). FLConfig.trace routes
    through here; "skewed-het" adds heterogeneous local-step multipliers
    (up to MAX_STEP_MULT) on top of the skewed availability/speed
    profile, exercising the masked-scan path from the public config."""
    if spec is None or spec == "uniform":
        return uniform_trace(n)
    if spec == "skewed":
        return skewed_trace(n, seed=seed)
    if spec == "skewed-het":
        return skewed_trace(n, seed=seed, max_step_mult=MAX_STEP_MULT)
    if isinstance(spec, AvailabilityTrace):
        if spec.n != n:
            raise ValueError(
                f"trace built for {spec.n} clients, population has {n}")
        return spec
    raise ValueError(f"unknown trace spec {spec!r}")
