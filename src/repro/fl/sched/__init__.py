"""Federated round scheduler: who trains when, and how updates land.

This subsystem sits between the simulator (``fl.simulator``) and the
round executors (the fused cohort engine ``fl.cohort``, or the
sequential per-client oracle) and turns "run R rounds" into an explicit
participation policy. Three policies share one API:

 - ``Scheduler.select(rnd, key) -> Cohort`` — pick the participating
   client subset for the next commit: positions (sorted — a cohort is a
   set), per-client local-step counts (availability-trace multipliers),
   and the server-version staleness of each update's base model.
 - ``Scheduler.commit(global_tr, updates, round_tag)`` — land the
   updates. Sync policies land *inside* the fused round dispatch
   (weighted FedAvg over the subset, weights renormalized sample
   counts); the async policy buffers per-client deltas and commits M at
   a time with staleness-discounted weights ``w_i ∝ m_i (1+τ_i)^(-β)``
   (FedBuff). At β=0 this is exactly sample-count FedAvg over the
   buffer.
 - ``Scheduler.step(global_tr, rnd, key)`` — the driver the simulator
   calls once per History row: one sync round or one async buffer
   flush. ``Scheduler.warmup`` compiles every fused program the policy
   will dispatch (on throwaway copies) so round timing is steady-state.

Policies:

 - ``full-sync`` (``FullSyncScheduler``) — every client every round;
   the pre-scheduler behavior expressed as the degenerate sync-partial
   policy (K=N, identity selection), so ``run_federated`` has exactly
   one engine path.
 - ``sync-partial`` (``SyncPartialScheduler``) — K of N clients per
   round, sampled uniformly or availability-trace-weighted, run as one
   fused subset round: the engine gathers the selected rows of the
   already-device-staged padded pools (no re-upload) at the
   power-of-two-bucketed cohort width (``fl.runtime.bucket_width`` —
   one compile per bucket, pad rows carry zero aggregation weight).
 - ``async`` (``AsyncBufferedScheduler``) — FedBuff-style buffered
   asynchrony on a deterministic virtual clock (``events.EventQueue``):
   trace-driven finish times, fused cohort *waves* per dispatch batch,
   staleness-discounted commits, freed slots back-filled by
   availability-weighted draws from the idle population.

The chaos layer (``chaos``) hardens all three policies against fleet
faults: deterministic per-client fault schedules (mid-round dropout
with exact partial-work recovery via the engines' masked scans,
dark-window unavailability, device-class stragglers, lost/corrupt
uplinks with bounded retry), drawn host-side at the true population
shape so the fused engine and the sequential oracle stay parity oracles
under chaos, and accounted in a ``FaultLedger`` that ``History.meta``
reports.

Invariants (see ROADMAP "Scheduler subsystem (PR 2)"): selection and
event times are drawn with ``jax.random`` on replicated host inputs
(mesh-invariant); subset rounds reuse the engine's staged pools and
batch-sampling key discipline so the sequential oracle reproduces them
exactly; quantization stays leading-axis-inert, so per-round uplink
bytes are exactly ``K x per-client payload``.
"""
from repro.fl.sched.chaos import (CHAOS_PRESETS, ChaosConfig,
                                  ChaosSchedule, FaultLedger,
                                  corrupt_delta, resolve_chaos)
from repro.fl.sched.events import EventQueue
from repro.fl.sched.policies import (AsyncBufferedScheduler, Cohort,
                                     CohortExec, FullSyncScheduler,
                                     Scheduler, SequentialExec,
                                     SyncPartialScheduler,
                                     make_scheduler, stack_client_deltas,
                                     staleness_weights)
from repro.fl.sched.traces import (AvailabilityTrace, diurnal_trace,
                                   load_trace, resolve_trace, save_trace,
                                   skewed_trace, uniform_trace)

__all__ = [
    "AsyncBufferedScheduler", "AvailabilityTrace", "CHAOS_PRESETS",
    "ChaosConfig", "ChaosSchedule", "Cohort", "CohortExec",
    "EventQueue", "FaultLedger", "FullSyncScheduler", "Scheduler",
    "SequentialExec", "SyncPartialScheduler", "corrupt_delta",
    "diurnal_trace", "load_trace", "make_scheduler", "resolve_chaos",
    "resolve_trace", "save_trace", "skewed_trace",
    "stack_client_deltas", "staleness_weights", "uniform_trace",
]
