"""Round-scheduler policies: who trains when, and how updates land.

A ``Scheduler`` sits between the simulator and the round executor and
factors a federated run into three verbs:

 - ``select(rnd, key) -> Cohort``: pick the participating client subset
   (and their per-client step counts / staleness tags) for the next
   commit. All randomness is drawn with ``jax.random`` on replicated
   host inputs — selection and event times are mesh-invariant, like the
   engine's batch sampling.
 - execution: the policy drives its executor — the fused cohort engine
   (``CohortExec``) or the per-client reference loop
   (``SequentialExec``) — in fixed-width cohort calls so device
   efficiency is independent of the policy.
 - ``commit(global_tr, updates, round_tag)``: land the updates. The
   sync policies land in-program (weighted FedAvg fused into the round
   dispatch, weights renormalized over the subset); the async policy
   buffers per-client deltas and commits M at a time with
   staleness-discounted weights ``w_i ∝ m_i (1+τ_i)^(-β)``.

``step(global_tr, rnd, key)`` is the driver the simulator calls once per
History row: one sync round, or one async buffer flush.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import QTensor
from repro.fl import cohort as cohort_lib
from repro.fl import server
from repro.fl.sched import chaos as chaos_lib
from repro.fl.sched.events import EventQueue
from repro.fl.sched.traces import AvailabilityTrace, resolve_trace

# fold_in tags separating the per-round key into independent streams:
# batch indices use the raw round key (so sync-partial at K=N draws the
# exact batches of the PR 1 full round), selection/event jitter fold.
_SEL_TAG = 101
_DISPATCH_TAG = 103
_JITTER_TAG = 107


@dataclass(frozen=True)
class Cohort:
    """One scheduled unit of local work: client positions (sorted — a
    subset is a set, so K=N canonicalizes to the identity), their local
    step counts, and the server-version staleness of their base model."""
    sel: np.ndarray
    n_steps: np.ndarray
    staleness: np.ndarray

    @property
    def k(self) -> int:
        return len(self.sel)


def staleness_weights(masses, staleness, beta: float) -> np.ndarray:
    """FedBuff-style discounted aggregation weights
    ``w_i ∝ m_i (1+τ_i)^(-β)``, normalized to sum 1. At β=0 this is
    exactly the sample-count FedAvg weighting over the buffer."""
    m = np.asarray(masses, np.float64)
    tau = np.asarray(staleness, np.float64)
    w = m * (1.0 + tau) ** (-float(beta))
    total = w.sum()
    if not np.isfinite(total) or total <= 0:
        raise ValueError(
            f"degenerate staleness weights: masses={m}, tau={tau}")
    return (w / total).astype(np.float32)


# ---------------------------------------------------------------------
# executors: how a scheduled cohort actually trains
# ---------------------------------------------------------------------

def stack_client_deltas(deltas: Sequence):
    """Restack per-client delta trees (as produced by
    ``cohort.slice_client_delta``) along a fresh leading cohort axis,
    keeping QTensor metadata consistent with ``comm_quantize_stacked``
    output so ``server.aggregate_stacked`` sees the usual layout."""
    def f(*leaves):
        l0 = leaves[0]
        if isinstance(l0, QTensor):
            return QTensor(
                q=jnp.stack([l.q for l in leaves]),
                scales=jnp.stack([l.scales for l in leaves]),
                bits=l0.bits, mode=l0.mode, block=l0.block,
                out_dtype=l0.out_dtype,
                orig_shape=(len(leaves),) + tuple(l0.orig_shape))
        return jnp.stack(leaves)

    return jax.tree.map(f, *deltas,
                        is_leaf=lambda l: isinstance(l, QTensor))


class CohortExec:
    """Fused-engine executor: one jitted dispatch per cohort call."""
    kind = "cohort"

    def __init__(self, engine):
        self.engine = engine

    def run_sync(self, global_tr, cohort: Cohort, key):
        return self.engine.run_subset_round(global_tr, cohort.sel, key,
                                            n_steps=cohort.n_steps)

    def run_full(self, global_tr, key):
        """PR 1's gather-free full-cohort program (homogeneous steps
        only) — avoids the runtime ``pool_staged[sel]`` device copy the
        subset program pays for selection."""
        return self.engine.run_round(global_tr, key)

    def run_wave(self, global_tr, cohort: Cohort, key):
        delta, m = self.engine.run_wave(global_tr, cohort.sel, key,
                                        n_steps=cohort.n_steps)
        slices = [cohort_lib.slice_client_delta(delta, j)
                  for j in range(cohort.k)]
        return slices, m

    def commit_buffer(self, global_tr, weights, deltas):
        stacked = stack_client_deltas(deltas)
        if getattr(self.engine, "shards", 1) > 1:
            # mesh-sharded engine: commit hierarchically so the host
            # buffer (whose size need not divide the shard count —
            # aggregate_tree zero-pads internally) never reduces flat on
            # one device
            return server.aggregate_tree(
                global_tr, jnp.asarray(weights, jnp.float32), stacked,
                n_shards=self.engine.shards)
        return server.aggregate_stacked(
            global_tr, jnp.asarray(weights, jnp.float32), stacked)

    def client_masses(self) -> np.ndarray:
        """Per-client sample counts over the full population (the m_i of
        every weighting rule; chaos prorates them by completed steps)."""
        return np.asarray(self.engine.client_n, np.float64)


class SequentialExec:
    """Reference executor: per-client Python loop over
    ``Client.local_train``, driven by the *same* jax.random batch-index
    sequence as the fused engine (``cohort.round_indices``), so the two
    executors are parity oracles for each other under every policy."""
    kind = "sequential"

    def __init__(self, *, clients, frozen, ccfg, class_emb, local_steps,
                 batch_size, lr):
        self.clients = list(clients)
        self.frozen = frozen
        self.ccfg = ccfg
        self.class_emb = class_emb
        self.local_steps = local_steps
        self.batch_size = batch_size
        self.lr = lr
        self.lens = np.asarray(
            [len(c.pool()[1]) for c in self.clients], np.int64)
        self.max_steps = local_steps * max(
            c.local_steps_for(1) for c in self.clients)

    def _train(self, global_tr, cohort: Cohort, key):
        idx = cohort_lib.round_indices(
            key, self.lens[cohort.sel], self.max_steps, self.batch_size)
        if int(np.max(cohort.n_steps)) > self.max_steps:
            # mirror the cohort executor's loud failure: a step profile
            # the sampled batch-index layout cannot honor must not
            # silently truncate (executor parity)
            raise ValueError(
                f"n_steps {cohort.n_steps} exceed the staged maximum "
                f"{self.max_steps}; set Client.step_mult to match the "
                "trace before building the executor")
        outs = []
        for j, ci in enumerate(np.asarray(cohort.sel)):
            c = self.clients[int(ci)]
            n_j = int(cohort.n_steps[j])
            tr_after, m = c.local_train(
                self.frozen, global_tr, self.class_emb, self.ccfg,
                steps=n_j, batch_size=self.batch_size, lr=self.lr,
                indices=idx[j][:n_j])
            upd, nbytes = c.make_update(global_tr, tr_after)
            outs.append((c, upd, nbytes, m))
        metrics = {
            "loss": np.asarray([o[3]["loss"] for o in outs]),
            "acc": np.asarray([o[3]["acc"] for o in outs]),
            "uplink_bytes": int(sum(o[2] for o in outs)),
            "sel": np.asarray(cohort.sel)}
        return outs, metrics

    def run_sync(self, global_tr, cohort: Cohort, key):
        outs, metrics = self._train(global_tr, cohort, key)
        new_tr = server.aggregate(
            global_tr, [(o[0].n, o[1]) for o in outs])
        return new_tr, metrics

    def run_wave(self, global_tr, cohort: Cohort, key):
        outs, metrics = self._train(global_tr, cohort, key)
        return [o[1] for o in outs], metrics

    def commit_buffer(self, global_tr, weights, deltas):
        # server.aggregate renormalizes masses; the discounted weights
        # already sum to 1, so they pass through unchanged.
        return server.aggregate(
            global_tr, list(zip(np.asarray(weights, np.float64),
                                deltas)))

    def client_masses(self) -> np.ndarray:
        return np.asarray([c.n for c in self.clients], np.float64)


# ---------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------

class Scheduler:
    """Base policy machinery. Subclasses implement ``select`` and (for
    buffered policies) ``commit``; ``step`` is the simulator-facing
    driver producing exactly one committed aggregation per call."""
    name = "base"

    def __init__(self, *, executor, trace: AvailabilityTrace,
                 local_steps: int, clients_per_round: int = 0,
                 chaos: Optional[chaos_lib.ChaosSchedule] = None):
        self.exec = executor
        self.trace = trace
        self.local_steps = local_steps
        self.n = trace.n
        k = clients_per_round or self.n
        if not (1 <= k <= self.n):
            raise ValueError(
                f"clients_per_round={clients_per_round} out of range for "
                f"{self.n} active clients")
        self.k = k
        self._mult = np.asarray(trace.step_mult, np.int32)
        # chaos: shared fault schedule (None = fault-free). One schedule
        # instance serves both executors, so the fused engine and the
        # sequential oracle experience bitwise the same faults.
        self.chaos = chaos
        if chaos is not None and chaos.n != self.n:
            raise ValueError(
                f"chaos schedule built for {chaos.n} clients, trace has "
                f"{self.n}")
        # lost-uplink retry queue (sync policies): cid -> next attempt
        # number; retried clients are re-selected first the next round
        self._retryq: Dict[int, int] = {}
        # sync virtual clock under chaos: a barrier round lasts as long
        # as its slowest (straggler-stretched) participant
        self._vt = 0.0
        # pre-drawn selections (pipelined mode): rnd -> Cohort. The
        # selection draw materializes a tiny jax.random program, and a
        # host sync on *any* program drains the whole in-flight device
        # queue — so the pipelined loop hoists every stateless draw to
        # before the first round dispatch (prepare_rounds), keeping the
        # steady state sync-free. Draws are bitwise the inline ones
        # (same keys, same ops, just evaluated early).
        self._presel: Dict[int, Cohort] = {}

    # -- helpers ------------------------------------------------------
    def _cohort_for(self, sel, staleness=None) -> Cohort:
        sel = np.asarray(sel, np.int32)
        order = np.argsort(sel, kind="stable")
        sel = sel[order]
        stal = np.zeros(len(sel), np.int32) if staleness is None else \
            np.asarray(staleness, np.int32)[order]
        return Cohort(sel=sel,
                      n_steps=self.local_steps * self._mult[sel],
                      staleness=stal)

    def _draw_clients(self, key, k: int, rnd: int = 0,
                      pool=None) -> np.ndarray:
        """Availability-weighted draw of k distinct client positions
        from ``pool`` (default: the whole population), on replicated
        inputs (mesh-invariant). ``rnd`` is the virtual time fed to the
        trace's diurnal availability cycle; for static traces it is
        inert, keeping pre-chaos draws bit-identical."""
        if pool is None:
            pool = np.arange(self.n, dtype=np.int32)
        pool = np.asarray(pool, np.int32)
        if k >= len(pool):
            return pool
        probs = np.asarray(self.trace.availability_at(float(rnd)),
                           np.float64)[pool]
        pick = jax.random.choice(
            key, len(pool), (k,), replace=False,
            p=jnp.asarray(probs / probs.sum()))
        return pool[np.asarray(pick)]

    # -- policy surface ----------------------------------------------
    def select(self, rnd: int, key) -> Cohort:
        raise NotImplementedError

    def prepare_rounds(self, round_keys) -> int:
        """Pre-draw the selection cohorts for ``round_keys`` (a list of
        ``(rnd, key)`` pairs) so the round loop never syncs on a
        selection draw. Only stateless policies can pre-draw — the base
        (and every stateful/chaotic policy) declines by returning 0;
        their rounds sync inline exactly as before."""
        return 0

    def commit(self, global_tr, updates, round_tag):
        """Land updates. Sync policies aggregate inside the fused round
        dispatch, so their commit is pure bookkeeping (identity)."""
        return global_tr

    def step(self, global_tr, rnd: int, key):
        raise NotImplementedError

    def warmup(self, global_tr, key=None):
        """Compile/warm every fused program this policy dispatches, on a
        throwaway copy of the global trainables (donation-safe), without
        advancing any scheduler state. Called once before timing starts
        so ``History.round_time_s`` is steady-state.

        The engine compiles through the shared program runtime
        (``fl.runtime``): AOT executables are the execution path, so one
        throwaway round per program both populates the cache that real
        rounds call into and charges the compile wall-clock to the
        runtime's per-kind ledger. A sync-partial policy warms its
        cohort-width *bucket* — every K in the same power-of-two bucket
        reuses the warmed program."""
        raise NotImplementedError


class SyncPartialScheduler(Scheduler):
    """Sample K of N clients per round (availability-weighted) and run
    them as one fused subset round; the update lands in-program with
    subset-renormalized FedAvg weights. K=N with a uniform trace is the
    degenerate full-sync policy and reproduces the PR 1 full-cohort
    round exactly (same batch key, identity selection)."""
    name = "sync-partial"

    def select(self, rnd: int, key) -> Cohort:
        pre = self._presel.pop(rnd, None)
        return pre if pre is not None else self._select_now(rnd, key)

    def prepare_rounds(self, round_keys) -> int:
        if self.chaos is not None:
            # chaos selection depends on the retry queue — stateful,
            # cannot be drawn ahead of the rounds that feed it
            return 0
        for rnd, key in round_keys:
            self._presel[rnd] = self._select_now(rnd, key)
        return len(round_keys)

    def _select_now(self, rnd: int, key) -> Cohort:
        ksel = jax.random.fold_in(key, _SEL_TAG)
        if self.chaos is None:
            return self._cohort_for(self._draw_clients(ksel, self.k,
                                                       rnd))
        # chaos: exclude dark-window clients from the draw, and re-select
        # lost-uplink clients first (bounded retry across rounds)
        ch = self.chaos
        dark = ch.dark_mask(rnd)
        ch.ledger.client_rounds_dark += int(dark.sum())
        pool = np.where(~dark)[0].astype(np.int32)
        if len(pool) == 0:
            # nobody reachable: take everyone rather than stall the run
            pool = np.arange(self.n, dtype=np.int32)
        forced = np.asarray(
            sorted(c for c in self._retryq if not dark[c]),
            np.int32)[:self.k]
        rest = pool[~np.isin(pool, forced)]
        k_rest = self.k - len(forced)
        drawn = self._draw_clients(ksel, k_rest, rnd, pool=rest) \
            if k_rest > 0 and len(rest) else \
            np.zeros((0,), np.int32)
        sel = np.concatenate([forced, drawn]) if len(forced) else drawn
        if len(sel) == 0:
            sel = forced if len(forced) else pool[:1]
        return self._cohort_for(sel)

    def _chaos_step(self, global_tr, rnd: int, key):
        """One sync round under fault injection. The round runs as a
        *wave* (an existing program kind — chaos adds zero compiles
        beyond the width/step-profile buckets) so per-client deltas are
        visible host-side for uplink loss/corruption injection; the
        survivors commit with sample-count weights prorated by completed
        steps, renormalized over the committed set."""
        ch = self.chaos
        cohort = self.select(rnd, key)
        full = np.asarray(cohort.n_steps, np.int64)
        cut, dropped = ch.cut_steps(rnd, cohort.sel, full)
        ch.ledger.n_dropped += int(dropped.sum())
        ch.ledger.partial_steps_recovered += int(cut[dropped].sum())
        work = Cohort(sel=cohort.sel, n_steps=cut.astype(np.int32),
                      staleness=cohort.staleness)
        deltas, m = self.exec.run_wave(global_tr, work, key)
        # the barrier waits for the slowest straggler-stretched client
        dur = (np.asarray(self.trace.speed, np.float64)[cohort.sel] *
               cut * ch.straggler_mult(rnd, cohort.sel))
        self._vt += float(dur.max()) if len(dur) else 1.0
        attempts = np.asarray([self._retryq.get(int(c), 0)
                               for c in cohort.sel], np.int64)
        ch.ledger.n_retries += int((attempts > 0).sum())
        masses = self.exec.client_masses()[cohort.sel] * \
            (cut / np.maximum(full, 1))
        keep, kept_deltas, kept_masses = [], [], []
        for j, cid in enumerate(np.asarray(cohort.sel)):
            cid = int(cid)
            if ch.uplink_lost(rnd, cid, int(attempts[j])):
                ch.ledger.uplinks_lost += 1
                self._retryq[cid] = int(attempts[j]) + 1
                continue
            self._retryq.pop(cid, None)
            d = deltas[j]
            if ch.corrupt_uplink(rnd, cid):
                ch.ledger.deltas_corrupt += 1
                d = chaos_lib.corrupt_delta(d)
            if not server.delta_ok(d, global_tr):
                if not ch.cfg.tolerate_corrupt:
                    server.check_delta(
                        d, global_tr,
                        ctx=f"client {cid} delta (round {rnd})")
                ch.ledger.deltas_skipped += 1
                continue
            keep.append(j)
            kept_deltas.append(d)
            kept_masses.append(masses[j])
        if keep:
            w = np.asarray(kept_masses, np.float64)
            w = (w / w.sum()).astype(np.float32)
            server.check_weights(w, len(keep))   # prorated, sum-checked
            new_tr = self.exec.commit_buffer(global_tr, w, kept_deltas)
        else:
            ch.ledger.commits_skipped += 1
            new_tr = global_tr
        keep = np.asarray(keep, np.int64)
        m = {
            "loss": np.asarray(m["loss"])[keep],
            "acc": np.asarray(m["acc"])[keep],
            "uplink_bytes": int(m["uplink_bytes"]),
            "participation": np.asarray(cohort.sel)[keep],
            "staleness": np.zeros(len(keep), np.int32),
            "vtime": float(self._vt)}
        return new_tr, m

    def step(self, global_tr, rnd: int, key):
        if self.chaos is not None:
            return self._chaos_step(global_tr, rnd, key)
        cohort = self.select(rnd, key)
        new_tr, m = self.exec.run_sync(global_tr, cohort, key)
        new_tr = self.commit(new_tr, None, rnd)
        m = dict(m, participation=cohort.sel,
                 staleness=cohort.staleness, vtime=float(rnd + 1))
        return new_tr, m

    def warmup(self, global_tr, key=None):
        if self.exec.kind != "cohort":
            return    # the sequential oracle has no fused round program
        key = jax.random.PRNGKey(0) if key is None else key
        cohort = self._cohort_for(np.arange(self.k, dtype=np.int32))
        copy = jax.tree.map(jnp.copy, global_tr)
        if self.chaos is not None:
            # chaos rounds dispatch the wave program (host-side commit),
            # so warm that bucket instead of the in-program sync round
            deltas, _ = self.exec.run_wave(copy, cohort, key)
            jax.block_until_ready(jax.tree.leaves(deltas))
            return
        out = self.exec.run_sync(copy, cohort, key)
        jax.block_until_ready(jax.tree.leaves(out[0]))


class FullSyncScheduler(SyncPartialScheduler):
    """Every client, every round — the pre-scheduler behavior expressed
    as the degenerate sync-partial policy (K=N, identity selection).
    With a homogeneous step profile it dispatches PR 1's gather-free
    full-round program (bit-identical to the K=N subset program — see
    tests — minus the runtime gather's device copy of the staged
    pools)."""
    name = "full-sync"

    def __init__(self, *, executor, trace, local_steps, chaos=None):
        super().__init__(executor=executor, trace=trace,
                         local_steps=local_steps, clients_per_round=0,
                         chaos=chaos)

    def _select_now(self, rnd: int, key) -> Cohort:
        if self.chaos is None:
            return self._cohort_for(np.arange(self.n, dtype=np.int32))
        # chaos full-sync: everyone reachable (dark windows shrink the
        # cohort; retry bookkeeping is inherited — a lost client is in
        # next round's identity selection anyway)
        dark = self.chaos.dark_mask(rnd)
        self.chaos.ledger.client_rounds_dark += int(dark.sum())
        sel = np.where(~dark)[0].astype(np.int32)
        if len(sel) == 0:
            sel = np.arange(self.n, dtype=np.int32)
        return self._cohort_for(sel)

    def _gather_free(self) -> bool:
        return self.exec.kind == "cohort" and \
            int(self._mult.max()) == 1 and self.chaos is None

    def step(self, global_tr, rnd: int, key):
        if not self._gather_free():
            return super().step(global_tr, rnd, key)
        cohort = self.select(rnd, key)
        new_tr, m = self.exec.run_full(global_tr, key)
        m = dict(m, participation=cohort.sel,
                 staleness=cohort.staleness, vtime=float(rnd + 1))
        return new_tr, m

    def warmup(self, global_tr, key=None):
        if not self._gather_free():
            return super().warmup(global_tr, key)
        key = jax.random.PRNGKey(0) if key is None else key
        copy = jax.tree.map(jnp.copy, global_tr)
        out = self.exec.run_full(copy, key)
        jax.block_until_ready(jax.tree.leaves(out[0]))


class AsyncBufferedScheduler(Scheduler):
    """FedBuff-style asynchronous aggregation on a virtual clock.

    ``concurrency`` clients train at once; each dispatched job finishes
    ``speed[i] * n_steps_i * (1 + jitter)`` virtual seconds later
    (jitter is a small key-derived uniform, drawn replicated). Finished
    updates enter a buffer with staleness ``τ = server_version -
    base_version``; when the buffer holds ``buffer_size`` updates the
    server commits them with weights ``w_i ∝ m_i (1+τ_i)^(-β)``, then
    back-fills the freed slots with an availability-weighted draw from
    the *idle* population (clients neither in flight nor buffered — the
    just-committed ones are eligible again, and clients outside the
    initial draw rotate in), dispatched from the new global model. Local
    training still runs as fused cohort *waves* — every dispatch batch
    shares its base model, so one jitted program of width
    ``concurrency`` (initial wave) and one of width ``buffer_size``
    (steady state) cover the whole run. One ``step`` = one commit = one
    History row.
    """
    name = "async"

    def __init__(self, *, executor, trace, local_steps,
                 clients_per_round: int = 0, staleness_beta: float = 0.5,
                 concurrency: int = 0, client_n: Sequence[float],
                 chaos=None):
        super().__init__(executor=executor, trace=trace,
                         local_steps=local_steps,
                         clients_per_round=clients_per_round,
                         chaos=chaos)
        self.buffer_size = self.k
        self.concurrency = min(self.n, concurrency or 2 * self.k)
        if self.concurrency < self.buffer_size:
            raise ValueError(
                f"async concurrency {self.concurrency} below buffer "
                f"size {self.buffer_size}: the buffer could never fill")
        self.beta = float(staleness_beta)
        self.client_n = np.asarray(client_n, np.float64)
        self.queue = EventQueue()
        self.version = 0
        self._inflight: Dict[int, dict] = {}
        self._buffer: List[dict] = []
        self._started = False
        # monotone dispatch counter: chaos fault draws for async work
        # are tagged per dispatch (offset into a range disjoint from the
        # sync policies' round tags), so the fault schedule is a pure
        # function of dispatch order — identical for both executors
        self._dseq = 0
        self._committed: List[dict] = []

    # -- event-loop internals -----------------------------------------
    def _durations(self, sel: np.ndarray, n_steps: np.ndarray, key,
                   tag=None):
        u = np.asarray(jax.random.uniform(
            jax.random.fold_in(key, _JITTER_TAG), (len(sel),)))
        speed = np.asarray(self.trace.speed)[sel]
        dur = speed * np.asarray(n_steps, np.float64) * (1.0 + 0.1 * u)
        if self.chaos is not None and tag is not None:
            dur = dur * self.chaos.straggler_mult(tag, sel)
        return dur

    def _dispatch(self, global_tr, sel, key):
        """Run one fused wave for ``sel`` from the current global model
        and schedule their finish events. Under chaos the dispatch draws
        its fault slice first: mid-round dropouts cut per-client step
        counts (the wave's masked scan recovers the partial work
        exactly), stragglers stretch the finish times, and the per-entry
        mass scale records the completed-step proration for commit."""
        cohort = self._cohort_for(sel)
        scale = np.ones(cohort.k, np.float64)
        tag = None
        if self.chaos is not None:
            ch = self.chaos
            tag = chaos_lib.ASYNC_TAG0 + self._dseq
            self._dseq += 1
            full = np.asarray(cohort.n_steps, np.int64)
            cut, dropped = ch.cut_steps(tag, cohort.sel, full)
            ch.ledger.n_dropped += int(dropped.sum())
            ch.ledger.partial_steps_recovered += int(cut[dropped].sum())
            scale = cut / np.maximum(full, 1)
            cohort = Cohort(sel=cohort.sel,
                            n_steps=cut.astype(np.int32),
                            staleness=cohort.staleness)
        deltas, m = self.exec.run_wave(global_tr, cohort, key)
        durations = self._durations(cohort.sel, cohort.n_steps, key,
                                    tag)
        for j, ci in enumerate(cohort.sel):
            ci = int(ci)
            self.queue.push(self.queue.now + float(durations[j]), ci)
            # loss/acc stay device scalars — materializing one here
            # would drain the whole in-flight queue (CPU backend) and
            # serialize the pipelined loop; History's float conversion
            # happens at the simulator's bulk ring flush
            self._inflight[ci] = {
                "delta": deltas[j], "base_version": self.version,
                "loss": m["loss"][j], "acc": m["acc"][j],
                "bytes": m["uplink_bytes"] // cohort.k,
                "scale": float(scale[j]), "tag": tag}

    def _fill_buffer(self):
        """Drain finish events until the buffer holds ``buffer_size``
        updates. Buffer order is finish order (deterministic: virtual
        time, then push sequence). Idempotent once full. Under chaos a
        popped event whose uplink is lost re-queues with exponential
        backoff on the virtual clock, carrying its attempt count in the
        event tag; the attempt at ``max_retries`` always delivers, so
        the loop can never live-lock."""
        while len(self._buffer) < self.buffer_size:
            if not len(self.queue):
                raise RuntimeError(
                    "async event queue drained with an unfilled buffer "
                    "(concurrency < buffer size, or select() called "
                    "before the first step dispatched work?)")
            t, cid, attempt = self.queue.pop()
            job = self._inflight[cid]
            if self.chaos is not None and \
                    self.chaos.uplink_lost(job["tag"], cid, attempt):
                ch = self.chaos
                ch.ledger.uplinks_lost += 1
                ch.ledger.n_retries += 1
                self.queue.push(
                    t + ch.cfg.retry_backoff * (2.0 ** attempt), cid,
                    attempt + 1)
                continue
            del self._inflight[cid]
            self._buffer.append(dict(job, cid=cid,
                                     tau=self.version -
                                     job["base_version"], finish=t,
                                     attempts=attempt))

    def _backfill_draw(self, key, rnd: int = 0) -> np.ndarray:
        """Pick ``buffer_size`` idle clients (not in flight, not
        buffered) to dispatch next, availability-weighted — the freed
        slots rotate across the whole population, not just the clients
        that happened to start first. Under chaos, dark-window clients
        are excluded when enough lit ones remain (darkness never stalls
        the pipeline)."""
        busy = set(self._inflight) | {e["cid"] for e in self._buffer}
        idle = np.asarray([i for i in range(self.n) if i not in busy],
                          np.int32)
        k = self.buffer_size
        if self.chaos is not None and len(idle):
            dark = self.chaos.dark_mask(rnd)
            self.chaos.ledger.client_rounds_dark += \
                int(dark[idle].sum())
            lit = idle[~dark[idle]]
            if len(lit) >= k:
                idle = lit
        if len(idle) < k:
            raise RuntimeError(
                f"{len(idle)} idle clients cannot back-fill {k} slots")
        if len(idle) == k:
            return idle
        probs = np.asarray(self.trace.availability_at(self.queue.now),
                           np.float64)[idle]
        pick = jax.random.choice(
            key, len(idle), (k,), replace=False,
            p=jnp.asarray(probs / probs.sum()))
        return idle[np.asarray(pick)]

    def select(self, rnd: int, key) -> Cohort:
        """View of the next commit's cohort (fills the buffer from
        pending finish events; no dispatch happens here, so repeated
        calls between commits return the same cohort)."""
        self._fill_buffer()
        entries = self._buffer[:self.buffer_size]
        return self._cohort_for([e["cid"] for e in entries],
                                staleness=[e["tau"] for e in entries])

    def commit(self, global_tr, updates, round_tag):
        """Staleness-discounted buffer flush: w_i ∝ m_i (1+τ_i)^(-β),
        applied in the buffer's finish order. Under chaos the masses are
        prorated by each entry's completed-step fraction, corrupt deltas
        are skipped-and-ledgered (or raised, strict mode), and a flush
        with zero survivors leaves the global — and the server version —
        untouched."""
        entries = updates
        if self.chaos is not None:
            ch = self.chaos
            kept = []
            for e in entries:
                d = e["delta"]
                if ch.corrupt_uplink(e["tag"], e["cid"]):
                    ch.ledger.deltas_corrupt += 1
                    d = chaos_lib.corrupt_delta(d)
                if not server.delta_ok(d, global_tr):
                    if not ch.cfg.tolerate_corrupt:
                        server.check_delta(
                            d, global_tr,
                            ctx=f"async client {e['cid']} delta")
                    ch.ledger.deltas_skipped += 1
                    continue
                kept.append(e)
            self._committed = kept
            if not kept:
                ch.ledger.commits_skipped += 1
                return global_tr
            entries = kept
            masses = self.client_n[[e["cid"] for e in entries]] * \
                np.asarray([e["scale"] for e in entries], np.float64)
        else:
            self._committed = list(entries)
            masses = self.client_n[[e["cid"] for e in entries]]
        w = staleness_weights(masses, [e["tau"] for e in entries],
                              self.beta)
        new_tr = self.exec.commit_buffer(
            global_tr, w, [e["delta"] for e in entries])
        self.version += 1
        return new_tr

    def step(self, global_tr, rnd: int, key):
        if not self._started:
            pool = None
            if self.chaos is not None:
                dark = self.chaos.dark_mask(rnd)
                self.chaos.ledger.client_rounds_dark += int(dark.sum())
                lit = np.where(~dark)[0].astype(np.int32)
                if len(lit) >= self.concurrency:
                    pool = lit
            sel = self._draw_clients(
                jax.random.fold_in(key, _SEL_TAG), self.concurrency,
                rnd, pool=pool)
            self._dispatch(global_tr, sel,
                           jax.random.fold_in(key, _DISPATCH_TAG))
            self._started = True
        self._fill_buffer()
        entries = self._buffer[:self.buffer_size]
        self._buffer = self._buffer[self.buffer_size:]
        new_tr = self.commit(global_tr, entries, rnd)
        # back-fill the freed slots from the idle population (the
        # committed clients plus anyone not yet started), training from
        # the new global at the current virtual time
        sel = self._backfill_draw(jax.random.fold_in(key, _SEL_TAG + 1),
                                  rnd)
        self._dispatch(new_tr, sel,
                       jax.random.fold_in(key, _DISPATCH_TAG + 1))
        # metrics cover the committed set (== the flushed buffer when
        # fault-free); uplink bytes count every delivery attempt of the
        # flushed entries — lost sends consumed real uplink
        logged = self._committed if self.chaos is not None else entries
        # loss/acc are lists of device scalars (see _dispatch): the
        # simulator materializes them at its ring flush, not per round
        m = {
            "loss": [e["loss"] for e in logged],
            "acc": [e["acc"] for e in logged],
            "uplink_bytes": int(sum(
                e["bytes"] * (1 + e.get("attempts", 0))
                for e in entries)),
            "participation": np.asarray([e["cid"] for e in logged],
                                        np.int32),
            "staleness": np.asarray([e["tau"] for e in logged],
                                    np.int32),
            "vtime": float(self.queue.now)}
        return new_tr, m

    def warmup(self, global_tr, key=None):
        if self.exec.kind != "cohort":
            return
        key = jax.random.PRNGKey(0) if key is None else key
        copy = jax.tree.map(jnp.copy, global_tr)
        for width in sorted({self.concurrency, self.buffer_size}):
            cohort = self._cohort_for(np.arange(width, dtype=np.int32))
            deltas, _ = self.exec.run_wave(copy, cohort, key)
            jax.block_until_ready(jax.tree.leaves(deltas))
        # the commit path is eager (host aggregation); nothing to warm.


def make_scheduler(participation: str, *, executor, trace,
                   local_steps: int, clients_per_round: int = 0,
                   staleness_beta: float = 0.5, concurrency: int = 0,
                   client_n: Optional[Sequence[float]] = None,
                   chaos: Optional[chaos_lib.ChaosSchedule] = None):
    """Policy factory keyed by ``FLConfig.participation``."""
    if participation == "full":
        if clients_per_round not in (0, trace.n):
            raise ValueError(
                f"clients_per_round={clients_per_round} is meaningless "
                "for participation='full' (every client trains every "
                "round) — use 'sync-partial' or 'async'")
        return FullSyncScheduler(executor=executor, trace=trace,
                                 local_steps=local_steps, chaos=chaos)
    if participation == "sync-partial":
        return SyncPartialScheduler(
            executor=executor, trace=trace, local_steps=local_steps,
            clients_per_round=clients_per_round, chaos=chaos)
    if participation == "async":
        if client_n is None:
            raise ValueError("async scheduling needs per-client sample "
                             "counts (client_n) for FedBuff weighting")
        return AsyncBufferedScheduler(
            executor=executor, trace=trace, local_steps=local_steps,
            clients_per_round=clients_per_round,
            staleness_beta=staleness_beta, concurrency=concurrency,
            client_n=client_n, chaos=chaos)
    raise ValueError(f"unknown participation policy {participation!r}")
