"""Round-scheduler policies: who trains when, and how updates land.

A ``Scheduler`` sits between the simulator and the round executor and
factors a federated run into three verbs:

 - ``select(rnd, key) -> Cohort``: pick the participating client subset
   (and their per-client step counts / staleness tags) for the next
   commit. All randomness is drawn with ``jax.random`` on replicated
   host inputs — selection and event times are mesh-invariant, like the
   engine's batch sampling.
 - execution: the policy drives its executor — the fused cohort engine
   (``CohortExec``) or the per-client reference loop
   (``SequentialExec``) — in fixed-width cohort calls so device
   efficiency is independent of the policy.
 - ``commit(global_tr, updates, round_tag)``: land the updates. The
   sync policies land in-program (weighted FedAvg fused into the round
   dispatch, weights renormalized over the subset); the async policy
   buffers per-client deltas and commits M at a time with
   staleness-discounted weights ``w_i ∝ m_i (1+τ_i)^(-β)``.

``step(global_tr, rnd, key)`` is the driver the simulator calls once per
History row: one sync round, or one async buffer flush.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import QTensor
from repro.fl import cohort as cohort_lib
from repro.fl import server
from repro.fl.sched.events import EventQueue
from repro.fl.sched.traces import AvailabilityTrace, resolve_trace

# fold_in tags separating the per-round key into independent streams:
# batch indices use the raw round key (so sync-partial at K=N draws the
# exact batches of the PR 1 full round), selection/event jitter fold.
_SEL_TAG = 101
_DISPATCH_TAG = 103
_JITTER_TAG = 107


@dataclass(frozen=True)
class Cohort:
    """One scheduled unit of local work: client positions (sorted — a
    subset is a set, so K=N canonicalizes to the identity), their local
    step counts, and the server-version staleness of their base model."""
    sel: np.ndarray
    n_steps: np.ndarray
    staleness: np.ndarray

    @property
    def k(self) -> int:
        return len(self.sel)


def staleness_weights(masses, staleness, beta: float) -> np.ndarray:
    """FedBuff-style discounted aggregation weights
    ``w_i ∝ m_i (1+τ_i)^(-β)``, normalized to sum 1. At β=0 this is
    exactly the sample-count FedAvg weighting over the buffer."""
    m = np.asarray(masses, np.float64)
    tau = np.asarray(staleness, np.float64)
    w = m * (1.0 + tau) ** (-float(beta))
    total = w.sum()
    if not np.isfinite(total) or total <= 0:
        raise ValueError(
            f"degenerate staleness weights: masses={m}, tau={tau}")
    return (w / total).astype(np.float32)


# ---------------------------------------------------------------------
# executors: how a scheduled cohort actually trains
# ---------------------------------------------------------------------

def stack_client_deltas(deltas: Sequence):
    """Restack per-client delta trees (as produced by
    ``cohort.slice_client_delta``) along a fresh leading cohort axis,
    keeping QTensor metadata consistent with ``comm_quantize_stacked``
    output so ``server.aggregate_stacked`` sees the usual layout."""
    def f(*leaves):
        l0 = leaves[0]
        if isinstance(l0, QTensor):
            return QTensor(
                q=jnp.stack([l.q for l in leaves]),
                scales=jnp.stack([l.scales for l in leaves]),
                bits=l0.bits, mode=l0.mode, block=l0.block,
                out_dtype=l0.out_dtype,
                orig_shape=(len(leaves),) + tuple(l0.orig_shape))
        return jnp.stack(leaves)

    return jax.tree.map(f, *deltas,
                        is_leaf=lambda l: isinstance(l, QTensor))


class CohortExec:
    """Fused-engine executor: one jitted dispatch per cohort call."""
    kind = "cohort"

    def __init__(self, engine):
        self.engine = engine

    def run_sync(self, global_tr, cohort: Cohort, key):
        return self.engine.run_subset_round(global_tr, cohort.sel, key,
                                            n_steps=cohort.n_steps)

    def run_full(self, global_tr, key):
        """PR 1's gather-free full-cohort program (homogeneous steps
        only) — avoids the runtime ``pool_staged[sel]`` device copy the
        subset program pays for selection."""
        return self.engine.run_round(global_tr, key)

    def run_wave(self, global_tr, cohort: Cohort, key):
        delta, m = self.engine.run_wave(global_tr, cohort.sel, key,
                                        n_steps=cohort.n_steps)
        slices = [cohort_lib.slice_client_delta(delta, j)
                  for j in range(cohort.k)]
        return slices, m

    def commit_buffer(self, global_tr, weights, deltas):
        return server.aggregate_stacked(
            global_tr, jnp.asarray(weights, jnp.float32),
            stack_client_deltas(deltas))


class SequentialExec:
    """Reference executor: per-client Python loop over
    ``Client.local_train``, driven by the *same* jax.random batch-index
    sequence as the fused engine (``cohort.round_indices``), so the two
    executors are parity oracles for each other under every policy."""
    kind = "sequential"

    def __init__(self, *, clients, frozen, ccfg, class_emb, local_steps,
                 batch_size, lr):
        self.clients = list(clients)
        self.frozen = frozen
        self.ccfg = ccfg
        self.class_emb = class_emb
        self.local_steps = local_steps
        self.batch_size = batch_size
        self.lr = lr
        self.lens = np.asarray(
            [len(c.pool()[1]) for c in self.clients], np.int64)
        self.max_steps = local_steps * max(
            c.local_steps_for(1) for c in self.clients)

    def _train(self, global_tr, cohort: Cohort, key):
        idx = cohort_lib.round_indices(
            key, self.lens[cohort.sel], self.max_steps, self.batch_size)
        if int(np.max(cohort.n_steps)) > self.max_steps:
            # mirror the cohort executor's loud failure: a step profile
            # the sampled batch-index layout cannot honor must not
            # silently truncate (executor parity)
            raise ValueError(
                f"n_steps {cohort.n_steps} exceed the staged maximum "
                f"{self.max_steps}; set Client.step_mult to match the "
                "trace before building the executor")
        outs = []
        for j, ci in enumerate(np.asarray(cohort.sel)):
            c = self.clients[int(ci)]
            n_j = int(cohort.n_steps[j])
            tr_after, m = c.local_train(
                self.frozen, global_tr, self.class_emb, self.ccfg,
                steps=n_j, batch_size=self.batch_size, lr=self.lr,
                indices=idx[j][:n_j])
            upd, nbytes = c.make_update(global_tr, tr_after)
            outs.append((c, upd, nbytes, m))
        metrics = {
            "loss": np.asarray([o[3]["loss"] for o in outs]),
            "acc": np.asarray([o[3]["acc"] for o in outs]),
            "uplink_bytes": int(sum(o[2] for o in outs)),
            "sel": np.asarray(cohort.sel)}
        return outs, metrics

    def run_sync(self, global_tr, cohort: Cohort, key):
        outs, metrics = self._train(global_tr, cohort, key)
        new_tr = server.aggregate(
            global_tr, [(o[0].n, o[1]) for o in outs])
        return new_tr, metrics

    def run_wave(self, global_tr, cohort: Cohort, key):
        outs, metrics = self._train(global_tr, cohort, key)
        return [o[1] for o in outs], metrics

    def commit_buffer(self, global_tr, weights, deltas):
        # server.aggregate renormalizes masses; the discounted weights
        # already sum to 1, so they pass through unchanged.
        return server.aggregate(
            global_tr, list(zip(np.asarray(weights, np.float64),
                                deltas)))


# ---------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------

class Scheduler:
    """Base policy machinery. Subclasses implement ``select`` and (for
    buffered policies) ``commit``; ``step`` is the simulator-facing
    driver producing exactly one committed aggregation per call."""
    name = "base"

    def __init__(self, *, executor, trace: AvailabilityTrace,
                 local_steps: int, clients_per_round: int = 0):
        self.exec = executor
        self.trace = trace
        self.local_steps = local_steps
        self.n = trace.n
        k = clients_per_round or self.n
        if not (1 <= k <= self.n):
            raise ValueError(
                f"clients_per_round={clients_per_round} out of range for "
                f"{self.n} active clients")
        self.k = k
        self._mult = np.asarray(trace.step_mult, np.int32)

    # -- helpers ------------------------------------------------------
    def _cohort_for(self, sel, staleness=None) -> Cohort:
        sel = np.asarray(sel, np.int32)
        order = np.argsort(sel, kind="stable")
        sel = sel[order]
        stal = np.zeros(len(sel), np.int32) if staleness is None else \
            np.asarray(staleness, np.int32)[order]
        return Cohort(sel=sel,
                      n_steps=self.local_steps * self._mult[sel],
                      staleness=stal)

    def _draw_clients(self, key, k: int) -> np.ndarray:
        """Availability-weighted draw of k distinct client positions, on
        replicated inputs (mesh-invariant)."""
        if k >= self.n:
            return np.arange(self.n, dtype=np.int32)
        probs = self.trace.selection_probs()
        return np.asarray(jax.random.choice(
            key, self.n, (k,), replace=False, p=jnp.asarray(probs)),
            np.int32)

    # -- policy surface ----------------------------------------------
    def select(self, rnd: int, key) -> Cohort:
        raise NotImplementedError

    def commit(self, global_tr, updates, round_tag):
        """Land updates. Sync policies aggregate inside the fused round
        dispatch, so their commit is pure bookkeeping (identity)."""
        return global_tr

    def step(self, global_tr, rnd: int, key):
        raise NotImplementedError

    def warmup(self, global_tr, key=None):
        """Compile/warm every fused program this policy dispatches, on a
        throwaway copy of the global trainables (donation-safe), without
        advancing any scheduler state. Called once before timing starts
        so ``History.round_time_s`` is steady-state.

        The engine compiles through the shared program runtime
        (``fl.runtime``): AOT executables are the execution path, so one
        throwaway round per program both populates the cache that real
        rounds call into and charges the compile wall-clock to the
        runtime's per-kind ledger. A sync-partial policy warms its
        cohort-width *bucket* — every K in the same power-of-two bucket
        reuses the warmed program."""
        raise NotImplementedError


class SyncPartialScheduler(Scheduler):
    """Sample K of N clients per round (availability-weighted) and run
    them as one fused subset round; the update lands in-program with
    subset-renormalized FedAvg weights. K=N with a uniform trace is the
    degenerate full-sync policy and reproduces the PR 1 full-cohort
    round exactly (same batch key, identity selection)."""
    name = "sync-partial"

    def select(self, rnd: int, key) -> Cohort:
        return self._cohort_for(
            self._draw_clients(jax.random.fold_in(key, _SEL_TAG),
                               self.k))

    def step(self, global_tr, rnd: int, key):
        cohort = self.select(rnd, key)
        new_tr, m = self.exec.run_sync(global_tr, cohort, key)
        new_tr = self.commit(new_tr, None, rnd)
        m = dict(m, participation=cohort.sel,
                 staleness=cohort.staleness, vtime=float(rnd + 1))
        return new_tr, m

    def warmup(self, global_tr, key=None):
        if self.exec.kind != "cohort":
            return    # the sequential oracle has no fused round program
        key = jax.random.PRNGKey(0) if key is None else key
        cohort = self._cohort_for(np.arange(self.k, dtype=np.int32))
        copy = jax.tree.map(jnp.copy, global_tr)
        out = self.exec.run_sync(copy, cohort, key)
        jax.block_until_ready(jax.tree.leaves(out[0]))


class FullSyncScheduler(SyncPartialScheduler):
    """Every client, every round — the pre-scheduler behavior expressed
    as the degenerate sync-partial policy (K=N, identity selection).
    With a homogeneous step profile it dispatches PR 1's gather-free
    full-round program (bit-identical to the K=N subset program — see
    tests — minus the runtime gather's device copy of the staged
    pools)."""
    name = "full-sync"

    def __init__(self, *, executor, trace, local_steps):
        super().__init__(executor=executor, trace=trace,
                         local_steps=local_steps, clients_per_round=0)

    def select(self, rnd: int, key) -> Cohort:
        return self._cohort_for(np.arange(self.n, dtype=np.int32))

    def _gather_free(self) -> bool:
        return self.exec.kind == "cohort" and int(self._mult.max()) == 1

    def step(self, global_tr, rnd: int, key):
        if not self._gather_free():
            return super().step(global_tr, rnd, key)
        cohort = self.select(rnd, key)
        new_tr, m = self.exec.run_full(global_tr, key)
        m = dict(m, participation=cohort.sel,
                 staleness=cohort.staleness, vtime=float(rnd + 1))
        return new_tr, m

    def warmup(self, global_tr, key=None):
        if not self._gather_free():
            return super().warmup(global_tr, key)
        key = jax.random.PRNGKey(0) if key is None else key
        copy = jax.tree.map(jnp.copy, global_tr)
        out = self.exec.run_full(copy, key)
        jax.block_until_ready(jax.tree.leaves(out[0]))


class AsyncBufferedScheduler(Scheduler):
    """FedBuff-style asynchronous aggregation on a virtual clock.

    ``concurrency`` clients train at once; each dispatched job finishes
    ``speed[i] * n_steps_i * (1 + jitter)`` virtual seconds later
    (jitter is a small key-derived uniform, drawn replicated). Finished
    updates enter a buffer with staleness ``τ = server_version -
    base_version``; when the buffer holds ``buffer_size`` updates the
    server commits them with weights ``w_i ∝ m_i (1+τ_i)^(-β)``, then
    back-fills the freed slots with an availability-weighted draw from
    the *idle* population (clients neither in flight nor buffered — the
    just-committed ones are eligible again, and clients outside the
    initial draw rotate in), dispatched from the new global model. Local
    training still runs as fused cohort *waves* — every dispatch batch
    shares its base model, so one jitted program of width
    ``concurrency`` (initial wave) and one of width ``buffer_size``
    (steady state) cover the whole run. One ``step`` = one commit = one
    History row.
    """
    name = "async"

    def __init__(self, *, executor, trace, local_steps,
                 clients_per_round: int = 0, staleness_beta: float = 0.5,
                 concurrency: int = 0, client_n: Sequence[float]):
        super().__init__(executor=executor, trace=trace,
                         local_steps=local_steps,
                         clients_per_round=clients_per_round)
        self.buffer_size = self.k
        self.concurrency = min(self.n, concurrency or 2 * self.k)
        if self.concurrency < self.buffer_size:
            raise ValueError(
                f"async concurrency {self.concurrency} below buffer "
                f"size {self.buffer_size}: the buffer could never fill")
        self.beta = float(staleness_beta)
        self.client_n = np.asarray(client_n, np.float64)
        self.queue = EventQueue()
        self.version = 0
        self._inflight: Dict[int, dict] = {}
        self._buffer: List[dict] = []
        self._started = False

    # -- event-loop internals -----------------------------------------
    def _durations(self, sel: np.ndarray, n_steps: np.ndarray, key):
        u = np.asarray(jax.random.uniform(
            jax.random.fold_in(key, _JITTER_TAG), (len(sel),)))
        speed = np.asarray(self.trace.speed)[sel]
        return speed * np.asarray(n_steps, np.float64) * (1.0 + 0.1 * u)

    def _dispatch(self, global_tr, sel, key):
        """Run one fused wave for ``sel`` from the current global model
        and schedule their finish events."""
        cohort = self._cohort_for(sel)
        deltas, m = self.exec.run_wave(global_tr, cohort, key)
        durations = self._durations(cohort.sel, cohort.n_steps, key)
        for j, ci in enumerate(cohort.sel):
            ci = int(ci)
            self.queue.push(self.queue.now + float(durations[j]), ci)
            self._inflight[ci] = {
                "delta": deltas[j], "base_version": self.version,
                "loss": float(m["loss"][j]), "acc": float(m["acc"][j]),
                "bytes": m["uplink_bytes"] // cohort.k}

    def _fill_buffer(self):
        """Drain finish events until the buffer holds ``buffer_size``
        updates. Buffer order is finish order (deterministic: virtual
        time, then push sequence). Idempotent once full."""
        while len(self._buffer) < self.buffer_size:
            if not len(self.queue):
                raise RuntimeError(
                    "async event queue drained with an unfilled buffer "
                    "(concurrency < buffer size, or select() called "
                    "before the first step dispatched work?)")
            t, cid = self.queue.pop()
            job = self._inflight.pop(cid)
            self._buffer.append(dict(job, cid=cid,
                                     tau=self.version -
                                     job["base_version"], finish=t))

    def _backfill_draw(self, key) -> np.ndarray:
        """Pick ``buffer_size`` idle clients (not in flight, not
        buffered) to dispatch next, availability-weighted — the freed
        slots rotate across the whole population, not just the clients
        that happened to start first."""
        busy = set(self._inflight) | {e["cid"] for e in self._buffer}
        idle = np.asarray([i for i in range(self.n) if i not in busy],
                          np.int32)
        k = self.buffer_size
        if len(idle) < k:
            raise RuntimeError(
                f"{len(idle)} idle clients cannot back-fill {k} slots")
        if len(idle) == k:
            return idle
        probs = np.asarray(self.trace.availability, np.float64)[idle]
        pick = jax.random.choice(
            key, len(idle), (k,), replace=False,
            p=jnp.asarray(probs / probs.sum()))
        return idle[np.asarray(pick)]

    def select(self, rnd: int, key) -> Cohort:
        """View of the next commit's cohort (fills the buffer from
        pending finish events; no dispatch happens here, so repeated
        calls between commits return the same cohort)."""
        self._fill_buffer()
        entries = self._buffer[:self.buffer_size]
        return self._cohort_for([e["cid"] for e in entries],
                                staleness=[e["tau"] for e in entries])

    def commit(self, global_tr, updates, round_tag):
        """Staleness-discounted buffer flush: w_i ∝ m_i (1+τ_i)^(-β),
        applied in the buffer's finish order."""
        entries = updates
        w = staleness_weights(
            self.client_n[[e["cid"] for e in entries]],
            [e["tau"] for e in entries], self.beta)
        new_tr = self.exec.commit_buffer(
            global_tr, w, [e["delta"] for e in entries])
        self.version += 1
        return new_tr

    def step(self, global_tr, rnd: int, key):
        if not self._started:
            sel = self._draw_clients(
                jax.random.fold_in(key, _SEL_TAG), self.concurrency)
            self._dispatch(global_tr, sel,
                           jax.random.fold_in(key, _DISPATCH_TAG))
            self._started = True
        self._fill_buffer()
        entries = self._buffer[:self.buffer_size]
        self._buffer = self._buffer[self.buffer_size:]
        new_tr = self.commit(global_tr, entries, rnd)
        # back-fill the freed slots from the idle population (the
        # committed clients plus anyone not yet started), training from
        # the new global at the current virtual time
        sel = self._backfill_draw(jax.random.fold_in(key, _SEL_TAG + 1))
        self._dispatch(new_tr, sel,
                       jax.random.fold_in(key, _DISPATCH_TAG + 1))
        m = {
            "loss": np.asarray([e["loss"] for e in entries]),
            "acc": np.asarray([e["acc"] for e in entries]),
            "uplink_bytes": int(sum(e["bytes"] for e in entries)),
            "participation": np.asarray([e["cid"] for e in entries],
                                        np.int32),
            "staleness": np.asarray([e["tau"] for e in entries],
                                    np.int32),
            "vtime": float(self.queue.now)}
        return new_tr, m

    def warmup(self, global_tr, key=None):
        if self.exec.kind != "cohort":
            return
        key = jax.random.PRNGKey(0) if key is None else key
        copy = jax.tree.map(jnp.copy, global_tr)
        for width in sorted({self.concurrency, self.buffer_size}):
            cohort = self._cohort_for(np.arange(width, dtype=np.int32))
            deltas, _ = self.exec.run_wave(copy, cohort, key)
            jax.block_until_ready(jax.tree.leaves(deltas))
        # the commit path is eager (host aggregation); nothing to warm.


def make_scheduler(participation: str, *, executor, trace,
                   local_steps: int, clients_per_round: int = 0,
                   staleness_beta: float = 0.5, concurrency: int = 0,
                   client_n: Optional[Sequence[float]] = None):
    """Policy factory keyed by ``FLConfig.participation``."""
    if participation == "full":
        if clients_per_round not in (0, trace.n):
            raise ValueError(
                f"clients_per_round={clients_per_round} is meaningless "
                "for participation='full' (every client trains every "
                "round) — use 'sync-partial' or 'async'")
        return FullSyncScheduler(executor=executor, trace=trace,
                                 local_steps=local_steps)
    if participation == "sync-partial":
        return SyncPartialScheduler(
            executor=executor, trace=trace, local_steps=local_steps,
            clients_per_round=clients_per_round)
    if participation == "async":
        if client_n is None:
            raise ValueError("async scheduling needs per-client sample "
                             "counts (client_n) for FedBuff weighting")
        return AsyncBufferedScheduler(
            executor=executor, trace=trace, local_steps=local_steps,
            clients_per_round=clients_per_round,
            staleness_beta=staleness_beta, concurrency=concurrency,
            client_n=client_n)
    raise ValueError(f"unknown participation policy {participation!r}")
