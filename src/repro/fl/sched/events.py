"""Deterministic virtual-time event queue for the async scheduler.

Simulated wall-clock only ever advances by popping the earliest pending
client-finish event — no real timers, no threads — so an async run is a
pure function of (seed, trace, config). Ties are broken by a
monotonically increasing push sequence number, which makes pop order
(and therefore buffer fill order, staleness, and the whole training
trajectory) bit-reproducible.
"""
from __future__ import annotations

import heapq
from typing import List, Tuple


class EventQueue:
    """Min-heap of (time, seq, cid, tag) client-finish events with a
    monotonic virtual clock ``now``. ``tag`` is an opaque small integer
    the scheduler threads through the queue — the chaos layer uses it as
    the delivery-attempt counter for lost-uplink retries, so backoff
    state rides the event itself and the queue stays stateless."""

    def __init__(self):
        self._heap: List[Tuple[float, int, int, int]] = []
        self._seq = 0
        self.now = 0.0

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, time: float, cid: int, tag: int = 0) -> None:
        if time < self.now:
            raise ValueError(
                f"event at t={time} is in the past (now={self.now})")
        heapq.heappush(self._heap,
                       (float(time), self._seq, int(cid), int(tag)))
        self._seq += 1

    def pop(self) -> Tuple[float, int, int]:
        """Pop the earliest (time, cid, tag) and advance the clock."""
        t, _, cid, tag = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        return t, cid, tag

    def peek(self) -> Tuple[float, int, int]:
        """The earliest pending (time, cid, tag) without popping it or
        advancing the clock — batching consumers (the serve-plane
        request driver) use it to drain everything that arrived before a
        dispatch point while leaving later events queued."""
        t, _, cid, tag = self._heap[0]
        return t, cid, tag
