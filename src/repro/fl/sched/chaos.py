"""Deterministic fault injection for the round scheduler (chaos layer).

Real fleets are not the idealized population the availability traces
describe: clients drop out mid-round after completing only part of
their local steps, go dark for whole rounds, straggle by device class,
and lose or corrupt their uplink payloads. This module draws all of
those faults as a *deterministic schedule* — a pure function of
``(chaos key, fault kind, round/dispatch tag, client position)`` — so a
chaos run is exactly as reproducible as a fault-free one, and the fused
cohort engine and the sequential oracle (which share one
:class:`ChaosSchedule` through the scheduler) experience bitwise the
same faults.

Draw discipline (ROADMAP "RNG discipline"): every fault vector is drawn
with ``jax.random`` on replicated host inputs at the TRUE population
shape ``(n,)`` — threefry is not shape-stable, so drawing per-cohort
would make the fault schedule depend on who else was selected. Cohorts
index into the population vector instead. Fault kinds fold distinct
prime tags into the chaos key so streams never collide with each other
or with the scheduler's selection/dispatch/jitter tags.

Recovery semantics the schedulers implement on top of this schedule:

- **Mid-round dropout** — a dropped client's local work is cut at its
  last completed step ``s``: the fused engines run the same
  fixed-length scan with ``active`` masked past ``s`` (a masked
  ``adam_scan``/``gan_scan`` step is a bitwise no-op on params and full
  optimizer state, so partial work is exact by construction), and its
  delta commits with sample-count weight prorated by ``s / full``.
- **Transient unavailability** — a dark window keeps a client out of
  selection for ``unavail_len`` consecutive rounds.
- **Stragglers** — lognormal per-dispatch slowdowns times a per-device-
  class multiplier stretch virtual durations; sync rounds pay the max
  (barrier), async rounds just reorder commits.
- **Lost uplinks** — a lost delta is not committed; the client re-queues
  with bounded exponential backoff on the virtual clock and the attempt
  at ``max_retries`` always delivers (retries bound *delay*, never
  liveness — the event loop and the round loop can always make
  progress).
- **Corrupt uplinks** — the delta's quantization scales are poisoned to
  NaN; ``server.check_delta`` rejects it loudly in strict mode or the
  scheduler skips-and-ledgers it under ``tolerate_corrupt=True``.

Every injected fault increments the mutable :class:`FaultLedger`, which
``History.meta["fault_ledger"]`` reports — a chaos run that silently
fell back to the fault-free path shows an empty ledger, which CI treats
as a failure.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import QTensor

# fold_in tags separating fault streams; primes disjoint from the
# scheduler's _SEL/_DISPATCH/_JITTER tags (101/103/107)
_DROP_TAG = 211      # mid-round dropout indicator
_CUT_TAG = 223       # dropout cut-point fraction
_STRAG_TAG = 227     # lognormal straggler multiplier
_LOST_TAG = 229      # uplink loss indicator (per attempt)
_CORR_TAG = 233      # uplink corruption indicator
_DARK_TAG = 239      # unavailability-window starts (per round)
_GAN_TAG = 241       # dropout between GAN launch and resolve

# async dispatches tag their fault draws by a monotone dispatch sequence
# offset far above any round index, so sync (round-tagged) and async
# (dispatch-tagged) streams can never collide
ASYNC_TAG0 = 1 << 20


@dataclass(frozen=True)
class ChaosConfig:
    """Fault-injection knobs. All probabilities are per client per
    round (sync) or per dispatch (async); zeros disable that fault."""
    dropout_prob: float = 0.0      # mid-round dropout (partial work)
    unavail_prob: float = 0.0      # dark-window start probability
    unavail_len: int = 2           # dark-window length in rounds
    straggler_sigma: float = 0.0   # lognormal slowdown sigma
    class_mult: Tuple[float, ...] = ()   # per-device-class speed mult
    uplink_loss_prob: float = 0.0  # delta lost in flight (per attempt)
    corrupt_prob: float = 0.0      # delta scales poisoned to NaN
    max_retries: int = 3           # lost-uplink retries before forced ok
    retry_backoff: float = 2.0     # virtual secs, doubled per attempt
    tolerate_corrupt: bool = True  # skip-and-ledger vs raise

    def __post_init__(self):
        for name in ("dropout_prob", "unavail_prob", "uplink_loss_prob",
                     "corrupt_prob"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name}={p} outside [0, 1]")
        if self.unavail_len < 1:
            raise ValueError(f"unavail_len={self.unavail_len} < 1")
        if self.max_retries < 1:
            raise ValueError(f"max_retries={self.max_retries} < 1")
        if self.retry_backoff <= 0:
            raise ValueError(
                f"retry_backoff={self.retry_backoff} must be positive")
        if any(m <= 0 for m in self.class_mult):
            raise ValueError(
                f"class_mult entries must be positive: {self.class_mult}")


CHAOS_PRESETS: Dict[str, ChaosConfig] = {
    "light": ChaosConfig(dropout_prob=0.1, straggler_sigma=0.3,
                         uplink_loss_prob=0.05),
    "heavy": ChaosConfig(dropout_prob=0.25, unavail_prob=0.15,
                         straggler_sigma=0.6, uplink_loss_prob=0.15,
                         corrupt_prob=0.05),
}


def resolve_chaos(spec) -> Optional[ChaosConfig]:
    """Accept None | preset name | ChaosConfig (FLConfig.chaos routes
    through here, like ``resolve_trace`` for traces)."""
    if spec is None:
        return None
    if isinstance(spec, ChaosConfig):
        return spec
    if isinstance(spec, str):
        if spec in CHAOS_PRESETS:
            return CHAOS_PRESETS[spec]
        raise ValueError(f"unknown chaos preset {spec!r} "
                         f"(have {sorted(CHAOS_PRESETS)})")
    raise ValueError(f"unknown chaos spec {spec!r}")


@dataclass
class FaultLedger:
    """Mutable per-run fault accounting, reported via
    ``History.meta["fault_ledger"]``. Counters only — the schedule
    itself is replayable from (config, key), so the ledger is a summary,
    not the source of truth."""
    n_dropped: int = 0               # mid-round dropouts
    partial_steps_recovered: int = 0  # local steps salvaged from them
    n_retries: int = 0               # lost-uplink re-sends
    uplinks_lost: int = 0            # lost delivery attempts
    deltas_corrupt: int = 0          # payloads poisoned in flight
    deltas_skipped: int = 0          # rejected by check_delta (tolerant)
    commits_skipped: int = 0         # rounds with zero surviving deltas
    client_rounds_dark: int = 0      # client-rounds inside dark windows
    gan_dropped: int = 0             # clients lost between GAN launch
                                     # and resolve (aug discarded)

    def as_dict(self) -> Dict[str, int]:
        return {k: int(v) for k, v in
                dataclasses.asdict(self).items()}

    def total(self) -> int:
        """Total injected faults — zero means the run silently took the
        fault-free path (CI fails on that under chaos)."""
        return sum(self.as_dict().values())


class ChaosSchedule:
    """Deterministic per-client fault schedule plus its ledger.

    One instance is shared by a scheduler and both of its executors; the
    fused engine and the sequential oracle therefore see identical
    faults and stay parity oracles under chaos. All draws happen
    host-side at the true population shape (see module docstring)."""

    def __init__(self, cfg: ChaosConfig, key, trace):
        self.cfg = cfg
        self.trace = trace
        self.n = trace.n
        self._key = key
        self.ledger = FaultLedger()
        self._dark_starts: Dict[int, np.ndarray] = {}

    # -- raw streams ---------------------------------------------------
    def _k(self, *tags):
        k = self._key
        for t in tags:
            k = jax.random.fold_in(k, int(t))
        return k

    def _u(self, *tags) -> np.ndarray:
        """Uniform(0,1) vector over the full population."""
        return np.asarray(
            jax.random.uniform(self._k(*tags), (self.n,)), np.float64)

    def _g(self, *tags) -> np.ndarray:
        """Standard-normal vector over the full population."""
        return np.asarray(
            jax.random.normal(self._k(*tags), (self.n,)), np.float64)

    # -- fault draws ---------------------------------------------------
    def cut_steps(self, tag: int, sel, n_steps):
        """Mid-round dropout: returns ``(cut, dropped)`` where ``cut``
        is each selected client's completed step count. A dropped client
        cuts uniformly in ``[1, full - 1]`` (it always completes at
        least one step and never its last — a zero-step participant is a
        no-show, which is the dark-window fault, not this one); others
        keep their full count."""
        sel = np.asarray(sel, np.int64)
        full = np.asarray(n_steps, np.int64)
        p = self.cfg.dropout_prob
        if p <= 0 or len(sel) == 0:
            return full.copy(), np.zeros(len(sel), bool)
        dropped = (self._u(_DROP_TAG, tag)[sel] < p) & (full > 1)
        frac = self._u(_CUT_TAG, tag)[sel]
        cut = np.where(dropped,
                       1 + np.floor(frac * (full - 1)).astype(np.int64),
                       full)
        return cut, dropped

    def straggler_mult(self, tag: int, sel) -> np.ndarray:
        """Per-dispatch duration multiplier: lognormal slowdown times
        the client's device-class multiplier."""
        sel = np.asarray(sel, np.int64)
        out = np.ones(len(sel), np.float64)
        if self.cfg.straggler_sigma > 0:
            out = np.exp(
                self.cfg.straggler_sigma * self._g(_STRAG_TAG, tag))[sel]
        if len(self.cfg.class_mult):
            cm = np.asarray(self.cfg.class_mult, np.float64)
            dc = np.asarray(self.trace.device_class, np.int64)[sel]
            out = out * cm[np.clip(dc, 0, len(cm) - 1)]
        return out

    def dark_mask(self, rnd: int) -> np.ndarray:
        """Transient-unavailability mask at round ``rnd``: a client is
        dark iff a window started within the last ``unavail_len``
        rounds. Window starts are drawn once per round and cached, so
        the mask is consistent across policies and repeat queries."""
        if self.cfg.unavail_prob <= 0:
            return np.zeros(self.n, bool)
        dark = np.zeros(self.n, bool)
        for r in range(max(0, rnd - self.cfg.unavail_len + 1), rnd + 1):
            starts = self._dark_starts.get(r)
            if starts is None:
                starts = self._u(_DARK_TAG, r) < self.cfg.unavail_prob
                self._dark_starts[r] = starts
            dark |= starts
        return dark

    def uplink_lost(self, tag: int, cid: int, attempt: int) -> bool:
        """Did client ``cid``'s delivery attempt number ``attempt`` (0 =
        first send) lose its payload? Bounded: the attempt at
        ``max_retries`` always delivers, so retries bound delay — never
        liveness — and the virtual clock stays deterministic."""
        if self.cfg.uplink_loss_prob <= 0 or \
                attempt >= self.cfg.max_retries:
            return False
        return bool(self._u(_LOST_TAG, tag, attempt)[int(cid)] <
                    self.cfg.uplink_loss_prob)

    def corrupt_uplink(self, tag: int, cid: int) -> bool:
        if self.cfg.corrupt_prob <= 0:
            return False
        return bool(self._u(_CORR_TAG, tag)[int(cid)] <
                    self.cfg.corrupt_prob)

    def gan_dropouts(self) -> np.ndarray:
        """Bool mask of clients that drop between fleet-GAN launch and
        resolve (their synthesized rebalancing sets are discarded; the
        raw pool trains on). Drawn once per run."""
        if self.cfg.dropout_prob <= 0:
            return np.zeros(self.n, bool)
        return self._u(_GAN_TAG, 0) < self.cfg.dropout_prob


def corrupt_delta(delta):
    """Flaky-uplink corruption stand-in: poison the first float leaf of
    a (possibly quantized) client delta with NaN — for QTensor leaves
    that is the dequantization ``scales``, i.e. exactly the bytes a
    flipped wire bit would hit. The poisoned tree keeps its treedef and
    shapes so only ``server.check_delta``'s finiteness guard (not a
    shape error downstream) can catch it."""
    state = {"done": False}

    def f(l):
        if state["done"]:
            return l
        if isinstance(l, QTensor):
            state["done"] = True
            return QTensor(q=l.q,
                           scales=jnp.full_like(l.scales, jnp.nan),
                           bits=l.bits, mode=l.mode, block=l.block,
                           out_dtype=l.out_dtype,
                           orig_shape=l.orig_shape)
        if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating):
            state["done"] = True
            return jnp.full_like(jnp.asarray(l), jnp.nan)
        return l

    out = jax.tree.map(f, delta,
                       is_leaf=lambda l: isinstance(l, QTensor))
    if not state["done"]:
        raise ValueError("corrupt_delta: no float leaf to poison")
    return out
