# Federated-learning runtime: partitioning, clients, server aggregation,
# the paper's three strategy arms, and the round simulator.
