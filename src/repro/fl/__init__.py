# Federated-learning runtime: partitioning, clients, server aggregation,
# the paper's three strategy arms, the batched cohort execution engine
# (cohort.py — vmap/scan-fused rounds), and the round simulator.
