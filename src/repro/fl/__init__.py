# Federated-learning runtime: partitioning, clients, server aggregation,
# the paper's three strategy arms, the batched cohort execution engine
# (cohort.py — vmap/scan-fused rounds), the round scheduler subsystem
# (sched/ — full-sync, sync-partial, and async-buffered participation
# policies over availability traces), and the round simulator.
