"""Build a small end-to-end serving plane from the training stack.

One function the example, benchmark, CLI ``--adapters`` mode, and tests
all share: partition a synthetic dataset over ``n_users``, train one
cohort wave per tenant family (adapter-only, and LoRA when ``mixed``),
hand the personalized trees to an :class:`AdapterStore`, and wrap a
:class:`ServeEngine` over it. Deterministic in ``seed``; everything
compiles through one shared :class:`ProgramRuntime` so the returned
plane's ledger covers training handoff and serving alike.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clip as clip_lib
from repro.data.synthetic import class_tokens, make_dataset
from repro.fl import client as client_lib
from repro.fl import cohort as cohort_lib
from repro.fl import runtime as runtime_lib
from repro.fl.serve import engine as engine_lib
from repro.fl.serve import store as store_lib
from repro.fl.strategies import STRATEGIES


def _train_family(frozen, ccfg, class_emb, data, *, arm: str, uids,
                  seed: int, local_steps: int, batch_size: int,
                  lr: float, runtime) -> Dict[int, Any]:
    """Round-robin shards of the dataset over one tenant family's users,
    run one personalization wave, return uid -> fp32 trainable."""
    strat = STRATEGIES[arm]
    n = len(uids)
    labels = data["labels"]
    clients = []
    for j, uid in enumerate(uids):
        sl = np.arange(j, len(labels), n)[:24]
        clients.append(client_lib.Client(
            cid=j, images=data["images"][sl], labels=labels[sl],
            n_classes=data["spec"].n_classes, strategy=strat))
    engine = cohort_lib.CohortEngine(
        frozen=frozen, ccfg=ccfg, class_emb=class_emb, clients=clients,
        cfg=cohort_lib.CohortConfig(
            strategy=strat, local_steps=local_steps,
            batch_size=batch_size, lr=lr, donate=False),
        runtime=runtime)
    global_tr = client_lib.init_trainable(
        jax.random.PRNGKey(seed + 1), ccfg, strat)
    return store_lib.personalized_trainables(
        engine, global_tr, jax.random.PRNGKey(seed + 2),
        uid_offset=min(uids))


def demo_plane(n_users: int = 8, *, mixed: bool = False, seed: int = 0,
               quant_bits: int = 8, max_entries: Optional[int] = None,
               max_batch: int = 16, local_steps: int = 2,
               batch_size: int = 8, lr: float = 3e-3,
               n_per_class: int = 20,
               runtime: Optional[runtime_lib.ProgramRuntime] = None
               ) -> Dict[str, Any]:
    """A ready-to-serve plane over ``n_users`` personalized tenants.
    ``mixed`` splits the population into an adapter-only (fedclip) half
    and a LoRA (qlora_nogan) half — two slab families in one store.
    ``max_entries`` defaults to the full population (no evictions);
    shrink it to exercise LRU behavior."""
    rt = runtime if runtime is not None else runtime_lib.ProgramRuntime()
    ccfg = clip_lib.CLIPConfig()
    frozen = clip_lib.init_clip(jax.random.PRNGKey(seed), ccfg)
    data = make_dataset("pacs", n_per_class=n_per_class, seed=seed,
                        longtail_gamma=4.0)
    spec = data["spec"]
    class_emb = clip_lib.text_embedding(
        frozen, ccfg,
        jnp.asarray(class_tokens(spec, np.arange(spec.n_classes))))

    kw = dict(seed=seed, local_steps=local_steps,
              batch_size=batch_size, lr=lr, runtime=rt)
    if mixed:
        n_a = max(1, n_users // 2)
        backing = _train_family(frozen, ccfg, class_emb, data,
                                arm="fedclip", uids=range(n_a), **kw)
        backing.update(_train_family(
            frozen, ccfg, class_emb, data, arm="qlora_nogan",
            uids=range(n_a, n_users), **kw))
    else:
        backing = _train_family(frozen, ccfg, class_emb, data,
                                arm="fedclip", uids=range(n_users),
                                **kw)

    cap = n_users if max_entries is None else int(max_entries)
    store = store_lib.AdapterStore(backing, max_entries=cap,
                                   quant_bits=quant_bits, runtime=rt)
    engine = engine_lib.ServeEngine(
        frozen=frozen, ccfg=ccfg, class_emb=class_emb, store=store,
        cfg=engine_lib.ServeConfig(max_batch=min(max_batch, cap)))
    return {"engine": engine, "store": store, "backing": backing,
            "frozen": frozen, "ccfg": ccfg, "class_emb": class_emb,
            "runtime": rt, "n_users": n_users,
            "n_classes": spec.n_classes,
            # request inputs: draw per-request images from the dataset
            "images": data["images"]}


def request_images(plane: Dict[str, Any], trace, *, seed: int = 0):
    """Deterministic per-request input images for a trace: request i
    gets a seeded draw from the demo dataset."""
    rs = np.random.RandomState(seed)
    pool = plane["images"]
    return pool[rs.randint(0, len(pool), trace.n)]
