"""Deterministic request-trace driver for the serving plane.

Latency numbers from a live request stream are not reproducible; a
*virtual-time* replay is. A :class:`RequestTrace` is a Zipf-popularity
user stream with exponential interarrivals whose rate is diurnally
modulated through the **same sinusoid machinery the scheduler traces
use** (``sched.traces.AvailabilityTrace.availability_at`` — the trace
generator literally instantiates a one-row availability trace as its
rate modulator), so request load peaks and troughs like client
availability does in ``diurnal_trace``.

:func:`replay` then drives a :class:`~repro.fl.serve.engine.ServeEngine`
through the trace on the scheduler's virtual clock
(``sched.events.EventQueue``): the server admits the earliest pending
request, drains every arrival at or before that dispatch point into the
flight (up to ``max_batch``), and advances a deterministic service-cost
model ``service_v = c0 + c1 * bucket`` — so flight composition, queue
depths, and per-request virtual latency are a pure function of
(trace, engine config, cost model). Real wall-clock per dispatch is
recorded *alongside* the virtual clock (it never influences batching),
which is what the benchmark's throughput numbers read.

Traces round-trip through JSON (``save_request_trace`` /
``load_request_trace``) like scheduler traces do, so a latency scenario
replays from a file instead of a seed.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.fl.sched.events import EventQueue
from repro.fl.sched.traces import AvailabilityTrace
from repro.fl import runtime as runtime_lib

# default virtual service-cost model: a dispatch costs c0 + c1 * bucket
# virtual seconds. Only the *shape* matters for reproducible batching
# (fixed overhead + per-row cost); the constants are arbitrary units.
SERVICE_C0 = 2e-3
SERVICE_C1 = 5e-4


@dataclass(frozen=True)
class RequestTrace:
    """A replayable request stream: ``uid[i]`` arrives at virtual time
    ``t[i]`` (nondecreasing). ``n_users`` is the population size the
    uids index into."""
    uid: np.ndarray
    t: np.ndarray
    n_users: int
    name: str = "custom"

    def __post_init__(self):
        uid = np.asarray(self.uid, np.int64)
        t = np.asarray(self.t, np.float64)
        if uid.shape != t.shape or uid.ndim != 1:
            raise ValueError("uid and t must be equal-length vectors")
        if len(t) and np.any(np.diff(t) < 0):
            raise ValueError("arrival times must be nondecreasing")
        if len(uid) and (uid.min() < 0 or uid.max() >= self.n_users):
            raise ValueError(
                f"uids outside [0, {self.n_users})")
        object.__setattr__(self, "uid", uid)
        object.__setattr__(self, "t", t)

    @property
    def n(self) -> int:
        return len(self.uid)

    def concurrency(self) -> int:
        """Distinct users in the trace — the 'concurrent tenants' count
        the multi-tenancy claims are stated over."""
        return len(np.unique(self.uid))


def zipf_request_trace(n_users: int, n_requests: int, *, seed: int = 0,
                       zipf: float = 1.1, rate: float = 32.0,
                       period: float = 0.0, amplitude: float = 0.0,
                       phase: float = 0.25) -> RequestTrace:
    """Zipf-popularity request stream: user popularity follows a
    shuffled Zipf law (a few hot users dominate — what gives an LRU
    adapter cache its hit rate), interarrivals are exponential with
    base ``rate`` requests per virtual second, diurnally modulated when
    ``period > 0`` (amplitude in [0, 1)) through a one-row
    ``AvailabilityTrace`` — the scheduler's own cycle model.
    Deterministic in (n_users, n_requests, seed)."""
    if n_users < 1 or n_requests < 1:
        raise ValueError("need at least one user and one request")
    rs = np.random.RandomState(seed)
    pop = 1.0 / np.arange(1, n_users + 1, dtype=np.float64) ** zipf
    rs.shuffle(pop)
    pop /= pop.sum()
    uids = rs.choice(n_users, size=n_requests, p=pop)
    mod = AvailabilityTrace(
        availability=np.ones(1), speed=np.ones(1),
        step_mult=np.ones(1, np.int32), phase=np.asarray([phase]),
        period=float(period), amplitude=float(amplitude),
        name="request-rate")
    t, now = np.zeros(n_requests), 0.0
    for i in range(n_requests):
        r = rate * float(mod.availability_at(now)[0])
        now += rs.exponential(1.0 / r)
        t[i] = now
    name = f"zipf(seed={seed})" if period <= 0 else \
        f"zipf-diurnal(seed={seed})"
    return RequestTrace(uid=uids, t=t, n_users=n_users, name=name)


def save_request_trace(trace: RequestTrace, path) -> None:
    with open(path, "w") as f:
        json.dump({"name": trace.name, "n_users": int(trace.n_users),
                   "uid": [int(u) for u in trace.uid],
                   "t": [float(v) for v in trace.t]}, f, indent=1)


def load_request_trace(path) -> RequestTrace:
    with open(path) as f:
        d = json.load(f)
    return RequestTrace(uid=np.asarray(d["uid"], np.int64),
                        t=np.asarray(d["t"], np.float64),
                        n_users=int(d["n_users"]),
                        name=str(d.get("name", "custom")))


def replay(engine, trace: RequestTrace, images, *,
           service: Tuple[float, float] = (SERVICE_C0, SERVICE_C1),
           collect_logits: bool = True) -> Dict[str, Any]:
    """Replay ``trace`` through ``engine`` on the virtual clock.
    ``images[i]`` is request i's input (aligned with the trace rows).

    Returns the replay record: per-request virtual latency (+ p50/p99),
    the deterministic flight schedule, measured wall time per dispatch,
    virtual-time throughput, and the store's hit/miss/eviction delta
    over the replay."""
    if len(images) != trace.n:
        raise ValueError(
            f"images ({len(images)}) must align with the trace rows "
            f"({trace.n})")
    c0, c1 = service
    q = EventQueue()
    for i, at in enumerate(trace.t):
        q.push(float(at), i)
    s0 = engine.store.stats()
    lat_v = np.zeros(trace.n)
    logits = [None] * trace.n if collect_logits else None
    flights = []
    free_v = 0.0
    wall_total = 0.0
    while len(q):
        at, rid, _ = q.pop()
        start = max(free_v, at)
        batch = [rid]
        # drain everything that arrived by the dispatch point — this is
        # where queueing delay buys batching
        while len(q) and len(batch) < engine.cfg.max_batch:
            t_next, _, _ = q.peek()
            if t_next > start:
                break
            _, r, _ = q.pop()
            batch.append(r)
        B = runtime_lib.bucket_width(len(batch), engine.cfg.max_batch)
        done = start + c0 + c1 * B
        w0 = time.perf_counter()
        out, info = engine.serve(
            [(int(trace.uid[r]), images[r]) for r in batch])
        wall = time.perf_counter() - w0
        wall_total += wall
        for j, r in enumerate(batch):
            lat_v[r] = done - trace.t[r]
            if collect_logits:
                logits[r] = out[j]
        flights.append({"start_v": start, "n": len(batch), "bucket": B,
                        "groups": info["groups"], "wall_s": wall})
        free_v = done
    s1 = engine.store.stats()
    makespan_v = free_v - float(trace.t[0]) if trace.n else 0.0
    rec = {
        "trace": trace.name,
        "n_requests": trace.n,
        "concurrency": trace.concurrency(),
        "n_flights": len(flights),
        "flights": flights,
        "lat_v": lat_v,
        "lat_v_p50": float(np.percentile(lat_v, 50)),
        "lat_v_p99": float(np.percentile(lat_v, 99)),
        "throughput_v": trace.n / max(makespan_v, 1e-12),
        "wall_s": wall_total,
        "throughput_wall": trace.n / max(wall_total, 1e-12),
        "store": {k: s1[k] - s0[k]
                  for k in ("hits", "misses", "evictions")},
    }
    rec["store"]["hit_rate"] = (
        rec["store"]["hits"] /
        max(rec["store"]["hits"] + rec["store"]["misses"], 1))
    if collect_logits:
        rec["logits"] = np.stack(logits)
    return rec
