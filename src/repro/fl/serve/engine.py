"""ServeEngine: multi-tenant batched inference over personalized adapters.

The cohort bucketing problem, re-aimed at requests. A flight of R ragged
requests (each a ``(uid, image)``) is answered by **one fused program
per tenant family**:

 1. every uid is fetched through the :class:`~repro.fl.serve.store
    .AdapterStore` (LRU admit/evict, quantized-at-rest slabs);
 2. rows group by slab family (adapter-only vs LoRA tenants run
    different towers);
 3. the request axis pads to ``bucket_width(R, max_batch)`` — the same
    power-of-two/floor-4 bucketing the cohort engine uses for client
    selections, so a request-size sweep reuses O(log max_batch) serve
    compiles and a full batch never pads;
 4. the **hoisted frozen CLIP prefix** runs once over the padded rows
    (``cohort.encode_rows`` — pooled features for adapter-only, patch
    tokens for LoRA: the identical staging programs training uses, so
    serve and train share ``stage_encode`` compiles);
 5. one dispatch gathers the slot rows out of the slab
    (``store.take_rows``) and ``jax.vmap``s the per-user head over the
    *adapter* axis — many distinct users, one program.

The per-user head is ``quant_head_logits``: ``head_logits`` with every
quantized-at-rest matrix contracted through ``ops.quant_matmul``
(in-kernel dequant). At S=1 — a single pooled CLIP feature — the
adapter's flash-attention softmax is over one position and identically
1, so Att(D) reduces *exactly* to the value path ``x @ wv``; the serve
head exploits that closed form (pinned against ``adapter.apply`` /
``cohort.client_logits`` by the parity tests).

Parity oracle: :func:`serve_sequential` answers one request at a time —
``encode -> adapter -> logits`` via ``client.forward_logits`` on the
fp32 backing trees, one jitted per-request dispatch (the honest
sequential baseline the benchmark compares against). The batched plane
must match it to tolerance (exact when the store is unquantized).

The LoRA tower (ROADMAP item 2's leftover) gets its serving win from
the shared forward, not serve-local code: ``clip._block`` routes every
per-tenant LoRA projection through ``core.lora.linear`` — the fused
base+delta op (``kernels.ops.lora_matmul``: one kernel, fp32
accumulation) — so the vmapped per-user towers execute fused gemms
instead of the einsum chain, and stacked quantized-at-rest slabs take
the vmapped Pallas ``quant_matmul`` path rather than a silent ref
fallback (``kernels.ops``).

Ledger: every dispatch charges ``serve_batch`` counters
(``n_flights``/``n_groups``/``n_requests``) via
``ProgramRuntime.count`` next to its compile counts — CI reads them to
fail if batching silently degenerates to per-user dispatch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clip as clip_lib
from repro.core import quant as qlib
from repro.fl import client as client_lib
from repro.fl import cohort as cohort_lib
from repro.fl import runtime as runtime_lib
from repro.fl.serve import store as store_lib
from repro.kernels import ops as kops

SERVE_KIND = "serve_batch"


def _mm(x, w):
    """Contraction against a possibly quantized-at-rest weight: QTensor
    leaves dequantize in-kernel through ``quant_matmul``; fp leaves are
    a plain matmul."""
    if isinstance(w, qlib.QTensor):
        return kops.quant_matmul(x, w)
    return x @ w


def quant_head_logits(frozen, trainable, feat, class_emb):
    """``client.head_logits`` for one pooled feature row against a
    (possibly quantized) adapter tree. Uses the exact S=1 reduction of
    the adapter's attention — softmax over a single position is 1, so
    Att(D) == V — which removes the wq/wk contractions entirely and
    leaves four quantizable matmuls for ``quant_matmul``."""
    a = trainable["adapter"]
    x = feat[None, :]
    v = _mm(x, a["wv"])
    x = x + _mm(v, a["wo"])
    h = jax.nn.relu(_mm(x, a["w1"]) + a["b1"])
    x = x + _mm(h, a["w2"]) + a["b2"]
    emb = x @ frozen["proj_v"]
    return clip_lib.zero_shot_logits(emb, class_emb,
                                     frozen["logit_scale"])[0]


@dataclass(frozen=True)
class ServeConfig:
    """Static serve-plane parameters (baked into the fused programs)."""
    max_batch: int = 64       # requests per dispatch (= bucket ceiling)


class ServeEngine:
    """Batched request executor over an :class:`AdapterStore`."""

    def __init__(self, *, frozen, ccfg, class_emb,
                 store: store_lib.AdapterStore,
                 cfg: ServeConfig = ServeConfig()):
        if cfg.max_batch < 1:
            raise ValueError(f"max_batch={cfg.max_batch} must be >= 1")
        if cfg.max_batch > store.max_entries:
            # one flight touches up to max_batch distinct users; a
            # flight wider than the store would evict its own residents
            # mid-fetch
            raise ValueError(
                f"max_batch={cfg.max_batch} exceeds the store's "
                f"max_entries={store.max_entries} — a single flight "
                "must fit in the resident set")
        self.frozen = frozen
        self.ccfg = ccfg
        self.class_emb = class_emb
        self.store = store
        self.cfg = cfg
        self.runtime = store.runtime
        self.n_requests = 0   # requests answered by the batched plane
        self.n_dispatches = 0  # fused serve programs dispatched

    # -- the fused serve program --------------------------------------
    def _build_serve(self, use_lora: bool):
        ccfg = self.ccfg

        def fn(slabs, slots, staged, frozen, class_emb):
            tr = store_lib.take_rows(slabs, slots)

            def one(t, x):
                if use_lora:
                    feat = clip_lib.encode_tokens(
                        frozen, ccfg, x[None], lora=t.get("lora"))[0]
                else:
                    feat = x
                return quant_head_logits(frozen, t, feat, class_emb)

            return jax.vmap(one)(tr, staged)

        return lambda: fn

    def _serve_group(self, famk, rows: List[Tuple[int, Any]]):
        """One family's share of a flight: rows is [(slot, image)] in
        request order, len <= max_batch."""
        fam = self.store.family(famk)
        use_lora = fam["use_lora"]
        G = len(rows)
        B = runtime_lib.bucket_width(G, self.cfg.max_batch)
        imgs = np.stack([im for _, im in rows]).astype(np.float32)
        # pad the request axis BEFORE the prefix encode so both the
        # staging program and the serve program see only bucket shapes
        imgs = runtime_lib.pad_leading(jnp.asarray(imgs), B)
        # pad slots with row 0's (a valid resident row — the pad output
        # is sliced off, it just must not gather out of bounds)
        slots = np.full(B, rows[0][0], np.int32)
        slots[:G] = [s for s, _ in rows]
        staged = cohort_lib.encode_rows(
            self.frozen, self.ccfg, use_lora=use_lora, rows=imgs,
            runtime=self.runtime)
        args = (fam["slabs"], jnp.asarray(slots), staged, self.frozen,
                self.class_emb)
        out = self.runtime.compile(
            SERVE_KIND, self._build_serve(use_lora), args,
            static_key=(self.ccfg, use_lora, self.store.quant_bits,
                        famk[0]))(*args)
        self.n_dispatches += 1
        self.runtime.count(SERVE_KIND, "n_groups")
        return np.asarray(out)[:G], B

    def serve(self, requests: Sequence[Tuple[int, Any]]):
        """Answer ``[(uid, image), ...]`` -> (logits ``(R, n_classes)``
        in request order, flight info). Flights wider than ``max_batch``
        split in arrival order."""
        if not len(requests):
            raise ValueError("empty request flight")
        logits: List[Any] = [None] * len(requests)
        info: Dict[str, Any] = {"n_requests": len(requests),
                                "flights": 0, "groups": 0,
                                "buckets": []}
        for lo in range(0, len(requests), self.cfg.max_batch):
            flight = requests[lo:lo + self.cfg.max_batch]
            # fetch in request order: LRU guarantees a flight's own
            # residents are never evicted by its later admissions
            placed = [self.store.fetch(uid) for uid, _ in flight]
            groups: "Dict[Tuple, List[int]]" = {}
            for j, (famk, _) in enumerate(placed):
                groups.setdefault(famk, []).append(j)
            for famk, rows_j in groups.items():
                out, B = self._serve_group(
                    famk, [(placed[j][1], flight[j][1])
                           for j in rows_j])
                for o, j in zip(out, rows_j):
                    logits[lo + j] = o
                info["groups"] += 1
                info["buckets"].append(B)
            info["flights"] += 1
            self.runtime.count(SERVE_KIND, "n_flights")
            self.runtime.count(SERVE_KIND, "n_requests", len(flight))
            self.n_requests += len(flight)
        return np.stack(logits), info


# -- sequential oracle -------------------------------------------------

_oracle_step = jax.jit(client_lib.forward_logits, static_argnums=(2,))


def serve_sequential(frozen, ccfg, class_emb, backing, requests):
    """Per-user reference plane: one request at a time, full
    ``encode -> adapter -> logits`` forward on the fp32 backing tree,
    one jitted dispatch per request. The batched engine must match this
    to tolerance (exactly, when the store is unquantized) — and beat it
    on throughput."""
    out = []
    for uid, img in requests:
        tr = backing[int(uid)]
        out.append(np.asarray(_oracle_step(
            frozen, tr, ccfg, jnp.asarray(img, jnp.float32)[None],
            class_emb)[0]))
    return np.stack(out)
