"""Personalized-adapter serving plane (``fl.serve``).

The inference-side inversion of the training stack: trained per-user
adapter/LoRA trees live quantized-at-rest in stacked device slabs
(:mod:`.store`), ragged request flights batch by shape bucket and vmap
over the adapter axis through one fused program per tenant family
(:mod:`.engine`), and reproducible latency comes from replaying
Zipf/diurnal request traces on the scheduler's virtual clock
(:mod:`.driver`). :mod:`.demo` wires a small end-to-end plane from the
training machinery.
"""
from repro.fl.serve.demo import demo_plane, request_images
from repro.fl.serve.driver import (RequestTrace, load_request_trace,
                                   replay, save_request_trace,
                                   zipf_request_trace)
from repro.fl.serve.engine import (ServeConfig, ServeEngine,
                                   quant_head_logits, serve_sequential)
from repro.fl.serve.store import (AdapterStore, personalized_trainables,
                                  quantize_at_rest, take_rows)

__all__ = [
    "AdapterStore", "RequestTrace", "ServeConfig", "ServeEngine",
    "demo_plane", "load_request_trace", "personalized_trainables",
    "quant_head_logits", "quantize_at_rest", "replay",
    "request_images", "save_request_trace", "serve_sequential",
    "take_rows", "zipf_request_trace",
]
