"""AdapterStore: device-resident cache of per-user personalized params.

Training (``fl.cohort``) produces one tiny trainable tree per user —
an attention-adapter head, plus vision-LoRA factors on the QLoRA arms —
against the shared frozen CLIP. Serving inverts the layout: instead of
broadcasting one global tree over a cohort axis, the store keeps the
*resident* users' trees stacked along a leading **slot axis** so a
batched serve program personalizes per request with one in-program
``jnp.take(slab, slots)`` gather — no per-user host->device transfer on
the request path.

Quantized at rest: eligible 2-D adapter matrices are stored blockwise
int8/int4 via ``kernels.ops.blockwise_quant`` (the Pallas kernel on TPU,
its jnp oracle on CPU) and are **never dequantized into a dense slab**
on the host — the serve program contracts activations against the
quantized slab rows through ``ops.quant_matmul``, so dequantization
happens in-kernel, per tile, at request time. Biases and other 1-D
leaves stay fp (the QLoRA convention), and LoRA factors stay fp at rest:
a rank-4 pair is ~KB-scale, below any eligibility floor, and the LoRA
tower consumes it densely inside ``encode_tokens``.

Mixed tenancy: adapter-only and LoRA users carry different tree
structures, so the store groups slabs by **family** (treedef + leaf
geometry). Slots are per-family; the LRU order and the ``max_entries``
capacity are global across families — admitting any user past capacity
evicts the globally least-recently-used resident, whatever its family.
Evicted users re-quantize deterministically from the host backing on
their next fetch, so eviction is a latency event, never a correctness
one.

Accounting: hits/misses/evictions are charged to the shared
:class:`repro.fl.runtime.ProgramRuntime` ledger (kind ``serve_store``
via ``ProgramRuntime.count``) next to the compile counts, so one
``stats()`` read covers the whole serving plane.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as qlib
from repro.fl import cohort as cohort_lib
from repro.fl import runtime as runtime_lib
from repro.kernels import ops as kops

# At-rest quantization layout: the uplink-compression constants'
# serve-side mirror (block along the contraction dim, small-leaf floor),
# plus "lora" in the skip set — see the module docstring.
SERVE_BLOCK = 64
SERVE_MIN_SIZE = 256
SERVE_SKIP = ("slot", "lora")

STORE_KIND = "serve_store"


def quantize_at_rest(tree, *, bits: int):
    """Quantize a per-user trainable tree for storage: every eligible
    >=2-D leaf goes blockwise int8/int4 (``bits`` 0 keeps the tree fp —
    the store's unquantized mode, used by exact-parity tests). 2-D
    leaves run through ``kernels.ops.blockwise_quant`` so TPU processes
    take the Pallas path; rare higher-rank eligible leaves fall back to
    the jnp quantizer with identical layout."""
    if bits == 0:
        return tree
    if bits not in (4, 8):
        raise ValueError(f"at-rest bits must be 0, 4 or 8, got {bits}")
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(k) for k in path)
        if not qlib._quantizable(pstr, leaf.shape, leaf.dtype,
                                 SERVE_MIN_SIZE, SERVE_SKIP):
            out.append(leaf)
            continue
        b = qlib._pick_block(leaf.shape[-2], SERVE_BLOCK)
        eff_bits = 8 if b % 2 else bits      # odd blocks can't pack
        if leaf.ndim == 2:
            out.append(kops.blockwise_quant(leaf, bits=eff_bits, block=b,
                                            mode="linear"))
        else:
            out.append(qlib.quantize(leaf, bits=eff_bits, block=b,
                                     mode="linear"))
    return jax.tree_util.tree_unflatten(treedef, out)


def _is_q(l) -> bool:
    return isinstance(l, qlib.QTensor)


def take_rows(slabs, slots):
    """Gather slot rows out of a slab tree (leading slot axis on every
    data array). QTensor leaves gather their ``q``/``scales`` payloads
    and keep the per-user metadata, so the gathered tree is exactly a
    stacked per-user tree — the serve program's vmap axis."""
    def f(l):
        if _is_q(l):
            return qlib.QTensor(
                q=jnp.take(l.q, slots, axis=0),
                scales=jnp.take(l.scales, slots, axis=0),
                bits=l.bits, mode=l.mode, block=l.block,
                out_dtype=l.out_dtype, orig_shape=l.orig_shape)
        return jnp.take(l, slots, axis=0)
    return jax.tree.map(f, slabs, is_leaf=_is_q)


def _slab_like(qtree, capacity: int):
    """Zero slab tree with ``capacity`` slots per leaf of a quantized
    per-user tree; QTensor leaves keep per-user metadata (``orig_shape``
    is the *per-user* weight shape, as ``slice_client_delta`` does for
    stacked deltas)."""
    def f(l):
        if _is_q(l):
            return qlib.QTensor(
                q=jnp.zeros((capacity,) + tuple(l.q.shape), l.q.dtype),
                scales=jnp.zeros((capacity,) + tuple(l.scales.shape),
                                 l.scales.dtype),
                bits=l.bits, mode=l.mode, block=l.block,
                out_dtype=l.out_dtype, orig_shape=l.orig_shape)
        return jnp.zeros((capacity,) + tuple(l.shape), l.dtype)
    return jax.tree.map(f, qtree, is_leaf=_is_q)


def _slab_set(slabs, slot: int, qtree):
    def f(s, l):
        if _is_q(s):
            return qlib.QTensor(
                q=s.q.at[slot].set(l.q),
                scales=s.scales.at[slot].set(l.scales),
                bits=s.bits, mode=s.mode, block=s.block,
                out_dtype=s.out_dtype, orig_shape=s.orig_shape)
        return s.at[slot].set(l)
    return jax.tree.map(f, slabs, qtree, is_leaf=_is_q)


def _family_key(qtree) -> Tuple:
    """Hashable slab-family identity: tree structure (which carries
    QTensor meta — bits/mode/block/orig_shape) + data-leaf geometry."""
    treedef = jax.tree_util.tree_structure(qtree)
    sig = tuple((tuple(l.shape), str(l.dtype))
                for l in jax.tree.leaves(qtree))
    return (treedef, sig)


class AdapterStore:
    """LRU cache of quantized per-user trainables in stacked device
    slabs. ``backing`` maps uid -> fp32 trainable tree (the training
    plane's output — see :func:`personalized_trainables`); a miss
    quantizes from it and writes one slot, a hit is pure bookkeeping.

    ``max_entries`` is the global resident capacity. Each slab family
    allocates ``max_entries`` slots (families appear lazily, and a
    single-family population — the common case — is exactly sized);
    the *global* LRU never lets total residency exceed ``max_entries``.
    """

    def __init__(self, backing: Mapping[int, Any], *, max_entries: int,
                 quant_bits: int = 8,
                 runtime: Optional[runtime_lib.ProgramRuntime] = None):
        if max_entries < 1:
            raise ValueError(
                f"max_entries={max_entries} must be >= 1")
        if quant_bits not in (0, 4, 8):
            raise ValueError(
                f"quant_bits={quant_bits} must be 0, 4 or 8")
        self.backing = backing
        self.max_entries = int(max_entries)
        self.quant_bits = int(quant_bits)
        self.runtime = runtime if runtime is not None else \
            runtime_lib.ProgramRuntime()
        # uid -> (family key, slot); OrderedDict order IS the LRU order
        self._res: "OrderedDict[int, Tuple[Tuple, int]]" = OrderedDict()
        self._fams: Dict[Tuple, Dict[str, Any]] = {}
        # last global snapshot seen by refresh_from_global (a device
        # copy — the trainer's own buffers get donated round-to-round)
        self._base = None

    # -- residency -----------------------------------------------------
    def __len__(self) -> int:
        return len(self._res)

    def resident(self) -> Tuple[int, ...]:
        """Resident uids, least-recently-used first."""
        return tuple(self._res)

    def fetch(self, uid: int) -> Tuple[Tuple, int]:
        """Return (family key, slot) for ``uid``, admitting (and, at
        capacity, evicting the global LRU) on a miss. Fetching the at
        most ``max_entries`` distinct users of one flight in order is
        safe: a fetched user moves to MRU, so admissions later in the
        same flight can never evict an earlier one."""
        uid = int(uid)
        ent = self._res.get(uid)
        if ent is not None:
            self._res.move_to_end(uid)
            self.runtime.count(STORE_KIND, "hits")
            return ent
        self.runtime.count(STORE_KIND, "misses")
        if uid not in self.backing:
            raise KeyError(f"uid {uid} has no trained adapter in the "
                           "backing map")
        qtree = quantize_at_rest(
            jax.tree.map(jnp.asarray, self.backing[uid]),
            bits=self.quant_bits)
        famk = _family_key(qtree)
        fam = self._fams.get(famk)
        if fam is None:
            fam = {"slabs": _slab_like(qtree, self.max_entries),
                   "free": list(range(self.max_entries - 1, -1, -1)),
                   "use_lora": "lora" in self.backing[uid]}
            self._fams[famk] = fam
        if len(self._res) >= self.max_entries:
            old_uid, (old_famk, old_slot) = self._res.popitem(last=False)
            self._fams[old_famk]["free"].append(old_slot)
            self.runtime.count(STORE_KIND, "evictions")
        slot = fam["free"].pop()
        fam["slabs"] = _slab_set(fam["slabs"], slot, qtree)
        self._res[uid] = (famk, slot)
        return famk, slot

    # -- serve-program inputs ------------------------------------------
    def family(self, famk: Tuple) -> Dict[str, Any]:
        """Family record: ``slabs`` (the device slab tree the serve
        program gathers from) and ``use_lora``."""
        return self._fams[famk]

    # -- refresh (trainer -> store handoff) ----------------------------
    def refresh(self, updates: Mapping[int, Any]) -> int:
        """Install new trainable snapshots for ``updates``' uids: the
        backing map always updates; a *resident* uid additionally gets
        its slab slot rewritten in place through the same deterministic
        ``quantize_at_rest`` path a miss takes — a refreshed resident
        and an evicted-then-refetched user hold bitwise the same slab
        rows. Residency, slot assignment, and LRU order are untouched:
        refresh is a latency event, never a correctness event. All
        device work is non-blocking (quantize + ``.at[slot].set``
        dispatches), so a mid-round refresh overlaps the next round's
        train dispatch. Returns the number of resident slots rewritten;
        charges ``refreshes``/``refreshed_resident`` to the runtime
        ledger."""
        if not isinstance(self.backing, dict):
            self.backing = dict(self.backing)
        n_res = 0
        for uid, tree in updates.items():
            uid = int(uid)
            self.backing[uid] = tree
            ent = self._res.get(uid)
            if ent is None:
                continue
            famk, slot = ent
            qtree = quantize_at_rest(
                jax.tree.map(jnp.asarray, tree), bits=self.quant_bits)
            if _family_key(qtree) != famk:
                raise ValueError(
                    f"refresh for uid {uid} changes its slab family "
                    "(tree structure / leaf geometry must be stable)")
            fam = self._fams[famk]
            fam["slabs"] = _slab_set(fam["slabs"], slot, qtree)
            n_res += 1
        self.runtime.count(STORE_KIND, "refreshes", len(updates))
        self.runtime.count(STORE_KIND, "refreshed_resident", n_res)
        return n_res

    def refresh_from_global(self, new_global) -> int:
        """Continuous trainer->store refresh: rebase every backed user
        by the global model's movement since the last refresh,
        ``new_i = old_i + (new_global - base)``, preserving each user's
        personalization delta. ``new_global`` is snapshotted as a device
        copy immediately (the trainer donates its global buffers into
        the next round's dispatch, so holding a reference would read
        freed memory); the first call just records the snapshot and
        refreshes nothing."""
        snap = jax.tree.map(jnp.copy, new_global)
        base, self._base = self._base, snap
        if base is None:
            return 0
        updates = {
            uid: jax.tree.map(lambda o, nw, b: o + (nw - b),
                              tree, snap, base)
            for uid, tree in self.backing.items()}
        return self.refresh(updates)

    # -- accounting ----------------------------------------------------
    def stats(self) -> Dict[str, int]:
        k = self.runtime.stats().get(STORE_KIND, {})
        return {"hits": int(k.get("hits", 0)),
                "misses": int(k.get("misses", 0)),
                "evictions": int(k.get("evictions", 0)),
                "refreshes": int(k.get("refreshes", 0)),
                "refreshed_resident": int(k.get("refreshed_resident", 0)),
                "resident": len(self._res),
                "families": len(self._fams)}

    def hit_rate(self) -> float:
        s = self.stats()
        n = s["hits"] + s["misses"]
        return s["hits"] / n if n else 0.0

    def bytes_at_rest(self) -> int:
        """True stored bytes of the occupied slots (packed QTensor
        payloads + fp leaves), i.e. per-resident-user cost x residency
        — the number the quantized-at-rest claim is about."""
        if not self._res:
            return 0
        total = 0
        per_fam: Dict[Tuple, int] = {}
        for famk, _ in self._res.values():
            if famk not in per_fam:
                slabs = self._fams[famk]["slabs"]
                per_fam[famk] = qlib.tree_bytes(
                    take_rows(slabs, jnp.asarray([0])))
            total += per_fam[famk]
        return int(total)


def personalized_trainables(engine, global_tr, key, *,
                            uid_offset: int = 0) -> Dict[int, Any]:
    """Train every client of a built :class:`~repro.fl.cohort
    .CohortEngine` one wave from ``global_tr`` and return the
    **personalized** per-user trees ``global + dequant(delta_i)`` —
    the training->serving handoff. Uids are client positions (plus
    ``uid_offset`` so mixed-tenancy demos can merge families into one
    backing map)."""
    sel = np.arange(engine.n_clients)
    delta, _ = engine.run_wave(global_tr, sel, key)
    out = {}
    for i in range(engine.n_clients):
        d = qlib.dequantize_tree(
            cohort_lib.slice_client_delta(delta, i), jnp.float32)
        out[uid_offset + i] = jax.tree.map(
            lambda g, dd: (g + dd).astype(jnp.float32), global_tr, d)
    return out
