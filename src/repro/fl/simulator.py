"""End-to-end federated simulation of the paper's experiments.

Builds a synthetic PACS/Office-Home-like long-tail dataset, partitions it
non-IID (Dirichlet + domain skew) across clients, instantiates a frozen
(optionally NF4-quantized) CLIP per the strategy arm, and runs
communication rounds of local training + weighted aggregation, recording
server accuracy, per-client loss/acc, uplink bytes, and a GPU-util proxy
(trainable-FLOP fraction per round).

Round execution defaults to the batched cohort engine (``fl.cohort``):
one jitted, buffer-donated device call per round. ``engine="sequential"``
keeps the original per-client Python loop as the reference oracle — both
executors are driven by the same jax.random batch-index sequence.
GAN-arm rebalancing likewise defaults to the fleet engine
(``fl.fleetgan``: every client's conditional GAN trained and sampled in
stacked fused programs); ``gan_engine="sequential"`` keeps the
per-client ``prepare_gan`` loop as its parity oracle, on identical
per-client RNG streams.

Participation is a scheduler policy (``fl.sched``): ``participation``
selects full-sync (every client, the degenerate policy), sync-partial
(K of N per round, availability-weighted), or async FedBuff-style
buffered aggregation with staleness-discounted weights on a virtual
clock. ``run_federated`` has exactly one round path — ``Scheduler.step``.

Every fused program (rounds, staging, sampling, fleet-GAN) compiles
through one bucketed program runtime per run (``fl.runtime``;
pass ``runtime=`` to share a cache across runs in a sweep), fleet-GAN
prep overlaps CLIP pool staging (non-blocking ``launch_gan_fleet``
resolved inside the cohort engine), and ``History.meta`` reports the
runtime's unified compile ledger: ``n_compiles``,
``n_compiles_by_kind``, ``compile_time_s``, and the ``gan_*`` share.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clip as clip_lib
from repro.core import losses, optim
from repro.core.quant import quantize_tree, tree_bytes
from repro.data.synthetic import class_tokens, make_dataset, make_eval_set
from repro.fl import client as client_lib
from repro.fl import cohort as cohort_lib
from repro.fl import fleetgan
from repro.fl import partition, server
from repro.fl import runtime as runtime_lib
from repro.fl import sched as sched_lib
from repro.fl import strategies as strategies_lib
from repro.fl.strategies import STRATEGIES, Strategy


@dataclass
class FLConfig:
    dataset: str = "pacs"
    strategy: str = "tripleplay"
    n_clients: int = 5
    rounds: int = 30
    local_steps: int = 8
    batch_size: int = 32
    lr: float = 2e-3
    alpha: float = 0.5            # Dirichlet non-IID concentration
    n_per_class: int = 60
    longtail_gamma: float = 8.0
    gan_steps: int = 150
    seed: int = 0
    eval_every: int = 1
    engine: str = "cohort"        # "cohort" | "sequential"
    # GAN-arm rebalancing executor: "fleet" trains every client's GAN in
    # stacked fused programs (fl.fleetgan); "sequential" is the
    # per-client prepare_gan loop kept as the parity oracle
    gan_engine: str = "fleet"
    # scheduler (fl.sched): who trains each round, how updates land
    participation: str = "full"   # "full" | "sync-partial" | "async"
    clients_per_round: int = 0    # K (sync-partial) / buffer M (async);
                                  # 0 = all active clients
    staleness_beta: float = 0.5   # async: w_i ∝ m_i (1+τ_i)^(-β)
    async_concurrency: int = 0    # async: clients in flight; 0 = 2K
    trace: Any = None             # None|"uniform"|"skewed"|"diurnal"|
                                  # path.json|sched.AvailabilityTrace
    # chaos fault injection (fl.sched.chaos): None (fault-free) |
    # preset name ("light"/"heavy") | sched.ChaosConfig
    chaos: Any = None
    # LRU bound on the shared program runtime's executable cache
    # (0 = unbounded); only used when no runtime= is passed in
    runtime_cache_entries: int = 0
    # round-loop execution mode: "pipelined" overlaps round r's server
    # eval, metric materialization, and serve-store refresh with round
    # r+1's selection/dispatch (selection draws hoisted, metrics landing
    # in a device-side ring materialized in bulk); "barrier" keeps the
    # serial loop — every round materialized before the next dispatch —
    # as the parity oracle. History values are bitwise identical.
    pipeline: str = "pipelined"
    # pipelined: bulk-materialize the metric ring every M rounds
    # (0 = only at run end). Each mid-run flush is one counted host
    # sync; the default keeps the steady state completely sync-free.
    metrics_flush_every: int = 0


@dataclass
class History:
    rounds: List[int] = field(default_factory=list)
    server_acc: List[float] = field(default_factory=list)
    tail_acc: List[float] = field(default_factory=list)   # class 0 (long tail)
    server_loss: List[float] = field(default_factory=list)
    client_loss: List[List[float]] = field(default_factory=list)
    client_acc: List[List[float]] = field(default_factory=list)
    uplink_bytes: List[int] = field(default_factory=list)
    round_time_s: List[float] = field(default_factory=list)
    util_proxy: List[float] = field(default_factory=list)
    # per committed round: participating client ids, staleness of each
    # committed update (server versions), and the virtual commit time
    participation: List[List[int]] = field(default_factory=list)
    staleness: List[List[int]] = field(default_factory=list)
    vtime: List[float] = field(default_factory=list)
    # per committed round, per device class (trace.device_class):
    # committed-update counts, mean staleness, mean client accuracy —
    # the fairness/staleness/tail columns the chaos benchmarks read
    class_counts: List[List[int]] = field(default_factory=list)
    class_staleness: List[List[float]] = field(default_factory=list)
    class_acc: List[List[float]] = field(default_factory=list)
    meta: Dict = field(default_factory=dict)


_CLIP_CACHE: Dict = {}


def pretrained_clip(dataset: str, ccfg: clip_lib.CLIPConfig, *,
                    seed: int = 1234, steps: int = 300, batch: int = 64,
                    runtime=None):
    """CLIP_pre stand-in: contrastively pretrain the dual encoder on a
    large balanced synthetic corpus (real CLIP weights are unavailable
    offline — DESIGN.md §7). Cached so all strategy arms share the exact
    same frozen backbone.

    The whole pretraining run is one ``adam_scan`` program with donated
    (params, opt) buffers, compiled through the shared program runtime
    (kind ``clip_pretrain``) — all batch indices are drawn up front
    (same MT19937 sequence as the former per-step loop) and the corpus
    is staged on device once. The params cache means a process's first
    run charges the compile; later cache hits charge nothing (the
    program never re-runs).
    """
    key = (dataset, seed, steps)
    if key in _CLIP_CACHE:
        return _CLIP_CACHE[key]
    rt = runtime if runtime is not None else runtime_lib.ProgramRuntime()
    pre = make_dataset(dataset, n_per_class=80, seed=seed,
                       longtail_gamma=1.0)
    params = clip_lib.init_clip(jax.random.PRNGKey(seed), ccfg)
    opt = optim.adam_init(params)
    n = len(pre["labels"])
    idx = jnp.asarray(
        np.random.RandomState(seed).randint(0, n, (steps, batch)))
    imgs = jnp.asarray(pre["images"])
    toks = jnp.asarray(pre["tokens"])

    def build():
        def train(params, opt, imgs, toks, idx):
            def grad_fn(p, ix):
                loss, g = jax.value_and_grad(
                    lambda q: clip_lib.contrastive_loss(
                        q, ccfg, imgs[ix], toks[ix]))(p)
                return g, loss
            return optim.adam_scan(grad_fn, params, opt, idx, lr=1e-3,
                                   grad_clip=1.0)[:2]
        return train

    args = (params, opt, imgs, toks, idx)
    params, _ = rt.compile("clip_pretrain", build, args,
                           static_key=(ccfg,),
                           donate_argnums=(0, 1))(*args)
    _CLIP_CACHE[key] = params
    return params


def _eval_stats(frozen, trainable, ccfg, class_emb, imgs, labs, mask):
    """Summed eval statistics over fixed-shape (n_batches, batch, ...)
    tensors; padding rows carry mask 0. One compile per run — the scan
    body reuses a single ``forward_logits`` program for every batch,
    remainder included."""
    def body(carry, xs):
        im, y, m = xs
        logits = client_lib.forward_logits(frozen, trainable, ccfg, im,
                                           class_emb)
        pred = jnp.argmax(logits, -1)
        n = jnp.sum(m)
        loss_sum = losses.cross_entropy(logits, y, m) * n
        acc_sum = jnp.sum((pred == y) * m)
        tail = (y == 0) * m
        carry = (carry[0] + acc_sum, carry[1] + loss_sum,
                 carry[2] + jnp.sum((pred == 0) * tail),
                 carry[3] + jnp.sum(tail))
        return carry, None

    zeros = (jnp.zeros(()),) * 4
    (acc, loss, tail_hit, tail_n), _ = jax.lax.scan(
        body, zeros, (imgs, labs, mask))
    return acc, loss, tail_hit, tail_n


def _eval_pack(eval_set, batch=128):
    """Stage the eval set once as fixed-shape device tensors
    ``(n_batches, batch, ...)`` with a validity mask — the round loop's
    eval dispatches reuse them instead of re-padding and re-uploading
    per eval round. Returns ``(imgs, labs, mask, n_true)``."""
    imgs, labs = eval_set["images"], eval_set["labels"]
    n = len(labs)
    nb = -(-n // batch)
    pad = nb * batch - n
    imgs_p = np.concatenate(
        [imgs, np.zeros((pad, *imgs.shape[1:]), imgs.dtype)])
    labs_p = np.concatenate([labs, np.zeros((pad,), labs.dtype)])
    mask = np.concatenate([np.ones(n, np.float32), np.zeros(pad,
                                                            np.float32)])
    return (jnp.asarray(imgs_p.reshape(nb, batch, *imgs.shape[1:])),
            jnp.asarray(labs_p.reshape(nb, batch)),
            jnp.asarray(mask.reshape(nb, batch)), n)


def _eval_dispatch(frozen, trainable, ccfg, class_emb, pack, runtime):
    """Non-blocking server-eval dispatch (kind ``server_eval``): returns
    a runtime Handle over the summed device statistics. The pipelined
    loop dispatches this right after the round program — before the next
    round's dispatch donates ``trainable`` — and materializes the
    handle at the ring flush."""
    args = (frozen, trainable, class_emb, pack[0], pack[1], pack[2])

    def build():
        return lambda fz, tr, ce, im, lb, mk: _eval_stats(
            fz, tr, ccfg, ce, im, lb, mk)

    return runtime.dispatch("server_eval", build, args,
                            static_key=(ccfg,))


def _eval_finalize(ev_out, n: int):
    """Normalize summed eval statistics into (acc, loss, tail_acc) —
    the one place the eval floats materialize, shared by both pipeline
    modes so deferred values stay bitwise the barrier ones."""
    acc, loss, tail_hit, tail_n = ev_out
    return (float(acc) / n, float(loss) / n,
            float(tail_hit) / max(float(tail_n), 1.0))


def _server_eval(frozen, trainable, ccfg, class_emb, eval_set,
                 batch=128, runtime=None):
    """Blocking server-side eval through the shared program runtime
    (kind ``server_eval``) so ``History.meta`` ledgers cover the eval
    program like every other fused program; a ``runtime=None`` call
    (standalone scripts) still compiles, it just discards the
    accounting."""
    rt = runtime if runtime is not None else runtime_lib.ProgramRuntime()
    pack = _eval_pack(eval_set, batch)
    h = _eval_dispatch(frozen, trainable, ccfg, class_emb, pack, rt)
    return _eval_finalize(h.out, pack[3])


def run_federated(cfg: FLConfig, *, runtime=None,
                  serve_store=None) -> History:
    """Run the federated simulation. ``serve_store`` optionally wires a
    :class:`repro.fl.serve.store.AdapterStore` into the round loop: each
    committed round rebase-refreshes the store from the new global
    (``AdapterStore.refresh_from_global`` — quantize + slab write for
    residents), dispatched non-blocking so in pipelined mode the refresh
    of round r overlaps round r+1's train dispatch."""
    strat = STRATEGIES[cfg.strategy]
    if cfg.pipeline not in ("pipelined", "barrier"):
        raise ValueError(f"unknown pipeline mode {cfg.pipeline!r}")
    rng = jax.random.PRNGKey(cfg.seed)
    data = make_dataset(cfg.dataset, n_per_class=cfg.n_per_class,
                        seed=cfg.seed, longtail_gamma=cfg.longtail_gamma)
    eval_set = make_eval_set(cfg.dataset, seed=cfg.seed + 1)
    spec = data["spec"]

    # one program runtime per run (unless the caller shares one across
    # runs — shape sweeps then share compiles): every fused program —
    # pretraining, rounds, staging, sampling, fleet-GAN, eval —
    # compiles through it, and meta reports its unified breakdown
    rt = runtime if runtime is not None else runtime_lib.ProgramRuntime(
        max_entries=cfg.runtime_cache_entries)

    ccfg = clip_lib.CLIPConfig()
    frozen = pretrained_clip(cfg.dataset, ccfg, seed=1234, runtime=rt)
    if strat.backbone_bits:
        # QLoRA: frozen backbone stored blockwise-quantized, dequantized
        # on the fly inside the forward (jnp path of the quant kernels)
        from repro.core.quant import dequantize_tree
        q = quantize_tree(frozen["vision"],
                          bits=strat.backbone_bits,
                          mode=strat.backbone_mode, block=64,
                          min_size=1024)
        backbone_bytes = tree_bytes(q)
        frozen = dict(frozen, vision=dequantize_tree(q))
    else:
        backbone_bytes = tree_bytes(frozen["vision"])

    # class-prompt embeddings from the frozen text tower (computed once)
    proto_tokens = class_tokens(spec, np.arange(spec.n_classes))
    class_emb = clip_lib.text_embedding(frozen, ccfg,
                                        jnp.asarray(proto_tokens))

    # non-IID partition: Dirichlet over classes composed with domain skew
    parts = partition.dirichlet_partition(
        data["labels"], cfg.n_clients, cfg.alpha, seed=cfg.seed)
    clients = []
    for i, idx in enumerate(parts):
        clients.append(client_lib.Client(
            cid=i, images=data["images"][idx], labels=data["labels"][idx],
            n_classes=spec.n_classes, strategy=strat))
    # very skewed Dirichlet draws can leave a shard empty; a client with
    # no data cannot train (either engine) and would get weight 0 anyway
    clients = [c for c in clients if c.n > 0]
    # availability/heterogeneity trace over the *active* population:
    # selection propensity, virtual speed, and local-step multipliers
    trace = sched_lib.resolve_trace(cfg.trace, len(clients),
                                    seed=cfg.seed)
    for i, c in enumerate(clients):
        c.step_mult = int(trace.step_mult[i])
    # chaos fault schedule: one deterministic ChaosSchedule per run,
    # keyed off its own fold of the run seed (disjoint from the round /
    # warmup / GAN streams), shared by the scheduler and both executors
    chaos_cfg = sched_lib.resolve_chaos(cfg.chaos)
    chaos_sched = None
    if chaos_cfg is not None:
        chaos_sched = sched_lib.ChaosSchedule(
            chaos_cfg, jax.random.fold_in(rng, 5), trace)
        # clients that drop between GAN launch and resolve lose their
        # synthesized rebalancing rows; drawn once, engine-independent
        gan_drop = chaos_sched.gan_dropouts() if strat.use_gan else None
        if gan_drop is not None:
            for i, c in enumerate(clients):
                if gan_drop[i] and c.n >= strategies_lib.GAN_MIN_POOL:
                    chaos_sched.ledger.gan_dropped += 1

    gan_meta: Dict[str, Any] = {}
    gan_job = None
    gan_rep = None
    if strat.use_gan:
        # both executors consume identical per-client RNG streams, so
        # the sequential loop is the fleet engine's parity oracle
        gan_keys = [jax.random.fold_in(
            rng, strategies_lib.GAN_RNG_OFFSET + i)
            for i in range(len(clients))]
        t0 = time.time()
        gan_drop_pos = np.where(gan_drop)[0] if chaos_sched is not None \
            and gan_drop is not None else np.zeros((0,), np.int64)
        if cfg.gan_engine == "fleet":
            if cfg.engine == "cohort":
                # non-blocking launch: the GAN programs run while the
                # cohort engine stages the CLIP pools below; the engine
                # resolves the job into the staged features
                gan_job = fleetgan.launch_gan_fleet(
                    clients, gan_keys, steps=cfg.gan_steps, runtime=rt)
                gan_job.mark_dropped(gan_drop_pos)
            else:
                job = fleetgan.launch_gan_fleet(
                    clients, gan_keys, steps=cfg.gan_steps, runtime=rt)
                job.mark_dropped(gan_drop_pos)
                gan_rep = job.resolve()
        elif cfg.gan_engine == "sequential":
            n_el = 0
            for i, c in enumerate(clients):
                if c.n >= strategies_lib.GAN_MIN_POOL and \
                        i not in set(int(p) for p in gan_drop_pos):
                    c.prepare_gan(gan_keys[i], steps=cfg.gan_steps)
                    n_el += 1
            gan_meta = {"gan_engine": "sequential",
                        "gan_eligible": n_el,
                        "gan_prep_time_s": time.time() - t0}
        else:
            raise ValueError(f"unknown gan_engine {cfg.gan_engine!r}")

    global_tr = client_lib.init_trainable(
        jax.random.fold_in(rng, 2), ccfg, strat)

    if cfg.engine == "cohort":
        engine = cohort_lib.CohortEngine(
            frozen=frozen, ccfg=ccfg, class_emb=class_emb,
            clients=clients,
            cfg=cohort_lib.CohortConfig(
                strategy=strat, local_steps=cfg.local_steps,
                batch_size=cfg.batch_size, lr=cfg.lr,
                # chaos cut-step profiles are heterogeneous even on a
                # homogeneous trace — compile the masked-scan variant
                force_het=chaos_sched is not None),
            runtime=rt, gan_job=gan_job)
        executor = sched_lib.CohortExec(engine)
        if gan_job is not None:
            gan_rep = gan_job.report       # resolved by the engine
    elif cfg.engine == "sequential":
        executor = sched_lib.SequentialExec(
            clients=clients, frozen=frozen, ccfg=ccfg,
            class_emb=class_emb, local_steps=cfg.local_steps,
            batch_size=cfg.batch_size, lr=cfg.lr)
    else:
        raise ValueError(f"unknown engine {cfg.engine!r}")

    if gan_rep is not None:
        gan_meta = {
            "gan_engine": "fleet",
            "gan_eligible": gan_rep.n_eligible,
            "gan_synth": gan_rep.n_synth,
            "gan_groups": [list(g) for g in gan_rep.groups],
            "gan_prep_time_s": gan_rep.prep_time_s,
            "gan_compile_time_s": gan_rep.compile_time_s,
        }

    trainable_params = sum(l.size for l in jax.tree.leaves(global_tr))
    frozen_params = sum(
        np.prod(l.shape) for l in jax.tree.leaves(frozen))
    hist = History(meta={
        "strategy": strat.name, "dataset": cfg.dataset,
        "n_clients": cfg.n_clients,
        "n_clients_active": len(clients),
        "engine": cfg.engine,
        "trainable_params": int(trainable_params),
        "frozen_params": int(frozen_params),
        "backbone_bytes": int(backbone_bytes),
        # GPU-util proxy (paper Fig. 3): the client's resident working set
        # — backbone storage (fp32 vs NF4) + trainable params + their Adam
        # moments — normalized by the fp32-everything footprint. QLoRA
        # shrinks the backbone 8x, which is the paper's utilization gap.
        "footprint_bytes": int(backbone_bytes + trainable_params * 12),
        "util_proxy_const": float(
            (backbone_bytes + trainable_params * 12) /
            (frozen_params * 4 + trainable_params * 12)),
        # GAN-prep accounting only for use_gan arms — strategy-flag
        # plumbing keeps these unset everywhere else
        **gan_meta,
    })

    # like the empty-shard drop above, clamp the cohort width to the
    # clients that actually survived partitioning; meta records the
    # effective K (sched.k). 'full' ignores K, so it sees the raw value
    # and a contradictory clients_per_round still fails loudly.
    k_eff = cfg.clients_per_round
    if cfg.participation != "full" and k_eff:
        k_eff = min(k_eff, len(clients))
    sched = sched_lib.make_scheduler(
        cfg.participation, executor=executor, trace=trace,
        local_steps=cfg.local_steps,
        clients_per_round=k_eff,
        staleness_beta=cfg.staleness_beta,
        concurrency=cfg.async_concurrency,
        client_n=[c.n for c in clients],
        chaos=chaos_sched)
    hist.meta.update({
        "participation": sched.name,
        "clients_per_round": sched.k,
        "trace": trace.name,
        "staleness_beta": float(cfg.staleness_beta),
        "device_classes": int(trace.n_device_classes),
    })

    # compile every fused program the policy dispatches before the clock
    # starts, so round_time_s is steady-state; the one-time compile cost
    # is read back from the shared runtime's AOT ledger (one cache,
    # per-kind breakdown) instead of ad-hoc wall-clock timers.
    sched.warmup(global_tr, jax.random.fold_in(rng, 4))

    def _compile_meta():
        _, gan_t = rt.subtotal("gan_")
        hist.meta["n_compiles"] = rt.n_compiles
        hist.meta["n_compiles_by_kind"] = {
            k: int(v["n_compiles"])
            for k, v in sorted(rt.stats().items())}
        # gan_meta already carries the fleet job's own
        # gan_compile_time_s delta of the same ledger (strategy-flag
        # plumbing keeps gan_* keys unset for non-GAN arms); everything
        # else is round/staging/sampling cost
        hist.meta["compile_time_s"] = rt.compile_time_s - gan_t

    _compile_meta()

    cids = np.asarray([c.cid for c in clients])
    n_dc = int(trace.n_device_classes)
    dclass = np.asarray(trace.device_class, np.int64)
    pipelined = cfg.pipeline == "pipelined"
    hist.meta["pipeline"] = cfg.pipeline

    def _record_round(m):
        # History row assembly — one code path for both pipeline modes,
        # so deferred (device-resident) metrics produce bitwise the
        # barrier values, just fetched late
        hist.uplink_bytes.append(int(m["uplink_bytes"]))
        hist.client_loss.append([float(v) for v in m["loss"]])
        hist.client_acc.append([float(v) for v in m["acc"]])
        hist.participation.append(
            [int(cids[p]) for p in m["participation"]])
        hist.staleness.append([int(s) for s in m["staleness"]])
        hist.vtime.append(float(m["vtime"]))
        # per-device-class fairness columns, from the committed updates
        # (positions, so the trace's device_class vector indexes them)
        pos = np.asarray(m["participation"], np.int64)
        stal = np.asarray(m["staleness"], np.float64)
        accs = np.asarray(m["acc"], np.float64)
        counts, c_stal, c_acc = [], [], []
        for d in range(n_dc):
            in_d = dclass[pos] == d if len(pos) else np.zeros(0, bool)
            k_d = int(in_d.sum())
            counts.append(k_d)
            c_stal.append(float(stal[in_d].mean()) if k_d else 0.0)
            c_acc.append(float(accs[in_d].mean()) if k_d else 0.0)
        hist.class_counts.append(counts)
        hist.class_staleness.append(c_stal)
        hist.class_acc.append(c_acc)

    def _record_eval(rnd, ev_out):
        acc, loss, tail = _eval_finalize(ev_out, ev_pack[3])
        hist.rounds.append(rnd)
        hist.server_acc.append(acc)
        hist.server_loss.append(loss)
        hist.tail_acc.append(tail)

    # eval tensors staged once; the per-round key sequence is a pure
    # function of the run seed, so it is precomputed and (pipelined
    # mode) handed to the policy to pre-draw its selection cohorts —
    # steady-state rounds then never sync on a selection draw
    ev_pack = _eval_pack(eval_set)
    base_key = jax.random.fold_in(rng, 3)
    round_keys = [(r, jax.random.fold_in(base_key, r))
                  for r in range(cfg.rounds)]
    prepared = sched.prepare_rounds(round_keys) if pipelined else 0

    # pipelined: per-round metrics (device scalars), the non-blocking
    # eval handle, and the dispatch wall land in a ring, bulk-
    # materialized every metrics_flush_every rounds or at run end
    ring: List[Dict] = []
    loop_syncs = 0

    def _flush_ring():
        if not ring:
            return
        rt.sync([(e["m"]["loss"], e["m"]["acc"],
                  None if e["eval"] is None else e["eval"].out)
                 for e in ring], tag="metrics_flush")
        for e in ring:
            _record_round(e["m"])
            hist.round_time_s.append(e["t"])
            hist.util_proxy.append(hist.meta["util_proxy_const"])
            if e["eval"] is not None:
                _record_eval(e["rnd"], e["eval"].out)
        ring.clear()

    sync0 = dict(runtime_lib.SYNC_TRACES)
    t_loop = time.time()
    for rnd, key in round_keys:
        t0 = time.time()
        global_tr, m = sched.step(global_tr, rnd, key)
        do_eval = rnd % cfg.eval_every == 0 or rnd == cfg.rounds - 1
        if pipelined:
            # eval reads global_tr *before* the next round's dispatch
            # donates it (in-order device queue); the serve refresh's
            # device ops are likewise enqueued pre-donation
            ev = _eval_dispatch(frozen, global_tr, ccfg, class_emb,
                                ev_pack, rt) if do_eval else None
            if serve_store is not None:
                serve_store.refresh_from_global(global_tr)
            ring.append({"rnd": rnd, "m": m, "eval": ev,
                         "t": time.time() - t0})
            if cfg.metrics_flush_every and \
                    len(ring) >= cfg.metrics_flush_every:
                _flush_ring()
                loop_syncs += 1
        else:
            # barrier: the serial parity oracle — this round's metrics
            # and eval materialize before the next round dispatches
            # (the pre-pipeline loop, now sync-counted)
            runtime_lib.sync_count("round_barrier")
            loop_syncs += 1
            _record_round(m)
            hist.round_time_s.append(time.time() - t0)
            # measured footprint constant (Fig. 3) — deterministic, no
            # synthetic wiggle
            hist.util_proxy.append(hist.meta["util_proxy_const"])
            if do_eval:
                ev = _eval_dispatch(frozen, global_tr, ccfg, class_emb,
                                    ev_pack, rt)
                _record_eval(rnd, ev.result())
            if serve_store is not None:
                serve_store.refresh_from_global(global_tr)
    _flush_ring()
    hist.meta["loop_wall_s"] = time.time() - t_loop
    hist.meta["sync_counts"] = {
        k: v - sync0.get(k, 0)
        for k, v in runtime_lib.SYNC_TRACES.items()
        if v - sync0.get(k, 0)}
    hist.meta["loop_syncs"] = int(loop_syncs)
    hist.meta["syncs_per_round"] = loop_syncs / max(cfg.rounds, 1)
    hist.meta["prepared_rounds"] = int(prepared)
    if serve_store is not None:
        hist.meta["serve_refreshes"] = int(
            serve_store.stats().get("refreshes", 0))
    # refresh the compile ledger: a policy that lazily compiled a new
    # width bucket mid-run (async back-fill at a fresh width) must show
    # up in the reported counts
    _compile_meta()
    hist.meta["n_cache_evictions"] = int(rt.n_evictions)
    if chaos_sched is not None:
        import dataclasses as _dc
        hist.meta["chaos"] = _dc.asdict(chaos_cfg)
        hist.meta["fault_ledger"] = chaos_sched.ledger.as_dict()
        # per-class fairness summary over the whole run: participation
        # share vs population share, mean staleness, mean client acc
        tot = np.asarray(hist.class_counts, np.float64).sum(0)
        report = []
        for d in range(n_dc):
            k_d = float(tot[d])
            s_col = [s[d] for s, c in
                     zip(hist.class_staleness, hist.class_counts)
                     if c[d] > 0]
            a_col = [a[d] for a, c in
                     zip(hist.class_acc, hist.class_counts) if c[d] > 0]
            report.append({
                "device_class": d,
                "population_share": float((dclass == d).mean()),
                "participation_share": float(
                    k_d / max(tot.sum(), 1.0)),
                "mean_staleness": float(np.mean(s_col)) if s_col
                else 0.0,
                "mean_client_acc": float(np.mean(a_col)) if a_col
                else 0.0})
        hist.meta["device_class_report"] = report
    return hist
