"""CLIP-style dual encoder — the paper's foundation model (ref [1]).

A compact, self-contained ViT image encoder + text transformer trained with
the symmetric contrastive loss, sized for CPU-scale FL simulation (the
full-size transformer stacks live in repro.models; this module is the
*functional* CLIP used by the federated experiments). Zero-shot
classification = cosine(image embedding, class-prompt text embeddings).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import losses
from repro.core import lora as lora_lib


@dataclass(frozen=True)
class CLIPConfig:
    image_size: int = 32
    patch: int = 8
    channels: int = 3
    vision_layers: int = 2
    text_layers: int = 2
    d_model: int = 64
    n_heads: int = 4
    d_ff: int = 128
    vocab: int = 512
    max_text_len: int = 8
    proj_dim: int = 32

    @property
    def n_patches(self) -> int:
        return (self.image_size // self.patch) ** 2


def _init_block(rng, d, d_ff, dtype=jnp.float32):
    ks = jax.random.split(rng, 6)
    s = lambda f: 1.0 / jnp.sqrt(f)
    return {"ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,)),
            "wq": jax.random.normal(ks[0], (d, d), dtype) * s(d),
            "wk": jax.random.normal(ks[1], (d, d), dtype) * s(d),
            "wv": jax.random.normal(ks[2], (d, d), dtype) * s(d),
            "wo": jax.random.normal(ks[3], (d, d), dtype) * s(d),
            "wu": jax.random.normal(ks[4], (d, d_ff), dtype) * s(d),
            "wd": jax.random.normal(ks[5], (d_ff, d), dtype) * s(d_ff)}


def init_clip(rng, cfg: CLIPConfig):
    ks = jax.random.split(rng, 10)
    d = cfg.d_model
    pdim = cfg.patch * cfg.patch * cfg.channels
    s = lambda f: 1.0 / jnp.sqrt(f)
    vision = {
        "patch_embed": jax.random.normal(ks[0], (pdim, d)) * s(pdim),
        "cls": jax.random.normal(ks[1], (d,)) * 0.02,
        "pos": jax.random.normal(ks[2], (cfg.n_patches + 1, d)) * 0.02,
        "blocks": jax.vmap(lambda k: _init_block(k, d, cfg.d_ff))(
            jax.random.split(ks[3], cfg.vision_layers)),
        "ln": jnp.zeros((d,)),
    }
    text = {
        "embed": jax.random.normal(ks[4], (cfg.vocab, d)) * 0.02,
        "pos": jax.random.normal(ks[5], (cfg.max_text_len, d)) * 0.02,
        "blocks": jax.vmap(lambda k: _init_block(k, d, cfg.d_ff))(
            jax.random.split(ks[6], cfg.text_layers)),
        "ln": jnp.zeros((d,)),
    }
    return {"vision": vision, "text": text,
            "proj_v": jax.random.normal(ks[7], (d, cfg.proj_dim)) * s(d),
            "proj_t": jax.random.normal(ks[8], (d, cfg.proj_dim)) * s(d),
            "logit_scale": jnp.asarray(jnp.log(1 / 0.07))}


def _ln(x, w, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * (1 + w)


# TriplePlay's fixed LoRA scaling alpha/r for the CLIP blocks: the lin
# closure historically hard-coded `delta * 2.0`; routing through
# lora_lib.linear keeps that exact factor (alpha = LORA_SCALE * r).
LORA_SCALE = 2.0


def _block(p, x, n_heads, causal=False, lora=None):
    B, S, d = x.shape
    dh = d // n_heads

    def lin(name, h):
        la = None if lora is None else lora.get(name)
        if la is not None:
            r = la["a"].shape[-1]
            # fused base+LoRA op (kernels.ops.lora_matmul): one kernel,
            # fp32 accumulation, custom VJP
            return lora_lib.linear(h, p[name], la,
                                   alpha=LORA_SCALE * r, rank=r)
        return lora_lib.linear(h, p[name])

    h = _ln(x, p["ln1"])
    q = lin("wq", h).reshape(B, S, n_heads, dh)
    k = lin("wk", h).reshape(B, S, n_heads, dh)
    v = lin("wv", h).reshape(B, S, n_heads, dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(dh))
    if causal:
        s = jnp.where(jnp.tril(jnp.ones((S, S), bool)), s, -1e30)
    a = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(B, S, d)
    x = x + lin("wo", o)
    h = _ln(x, p["ln2"])
    return x + lora_lib.linear(jax.nn.gelu(
        lora_lib.linear(h, p["wu"])), p["wd"])


def _run_blocks(blocks, x, n_heads, causal, lora=None):
    L = jax.tree.leaves(blocks)[0].shape[0]
    for i in range(L):
        bp = jax.tree.map(lambda l: l[i], blocks)
        bl = None if lora is None else jax.tree.map(lambda l: l[i], lora)
        x = _block(bp, x, n_heads, causal, bl)
    return x


def patchify(images, patch):
    """(B, H, W, C) -> (B, n_patches, patch*patch*C)."""
    B, H, W, C = images.shape
    gh, gw = H // patch, W // patch
    x = images.reshape(B, gh, patch, gw, patch, C)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(B, gh * gw, -1)


def embed_patches(params, cfg: CLIPConfig, images):
    """(B, H, W, C) -> (B, n_patches + 1, d) embedded tokens (patch
    projection + cls + positions). Trainable-independent: LoRA/adapters
    never touch it, so batched executors hoist it out of training loops
    (computed once per staged data pool)."""
    v = params["vision"]
    x = patchify(images, cfg.patch) @ v["patch_embed"]
    cls = jnp.broadcast_to(v["cls"], (x.shape[0], 1, cfg.d_model))
    return jnp.concatenate([cls, x], axis=1) + v["pos"][None]


def encode_tokens(params, cfg: CLIPConfig, x, *, lora=None,
                  pool: bool = True):
    """Vision tower over pre-embedded tokens from ``embed_patches``."""
    v = params["vision"]
    x = _run_blocks(v["blocks"], x, cfg.n_heads, False, lora)
    x = _ln(x, v["ln"])
    return x[:, 0] if pool else x            # cls token


def encode_image(params, cfg: CLIPConfig, images, *, lora=None,
                 pool: bool = True):
    return encode_tokens(params, cfg, embed_patches(params, cfg, images),
                         lora=lora, pool=pool)


def encode_text(params, cfg: CLIPConfig, tokens):
    t = params["text"]
    x = t["embed"][tokens] + t["pos"][None, :tokens.shape[1]]
    x = _run_blocks(t["blocks"], x, cfg.n_heads, True)
    x = _ln(x, t["ln"])
    return x[:, -1]                            # last token


def image_embedding(params, cfg: CLIPConfig, images, *, lora=None):
    return encode_image(params, cfg, images, lora=lora) @ params["proj_v"]


def text_embedding(params, cfg: CLIPConfig, tokens):
    return encode_text(params, cfg, tokens) @ params["proj_t"]


def contrastive_loss(params, cfg: CLIPConfig, images, tokens):
    ie = image_embedding(params, cfg, images)
    te = text_embedding(params, cfg, tokens)
    return losses.clip_contrastive(ie, te, params["logit_scale"])


def zero_shot_logits(img_emb, class_text_emb, logit_scale):
    ie = img_emb / (jnp.linalg.norm(img_emb, axis=-1, keepdims=True) + 1e-8)
    te = class_text_emb / (jnp.linalg.norm(
        class_text_emb, axis=-1, keepdims=True) + 1e-8)
    return jnp.exp(logit_scale) * ie @ te.T
