"""TriplePlay core: the paper's three mechanisms as composable JAX modules.

- ``adapter``: attention-based adapter (§III-A)
- ``lora`` / ``quant`` / ``qlora``: resource efficiency (§III-C)
- ``gan``: long-tail synthetic data (§III-B)
- ``clip``: the paper's foundation backbone (dual encoder)
"""
from repro.core import adapter, lora, losses, optim, quant  # noqa: F401
