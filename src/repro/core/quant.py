"""Blockwise quantization (int8 / int4 / NF4) — the paper's §III-C substrate.

TPU adaptation (see DESIGN.md §5): blocks run along the *contraction*
dimension of each weight in multiples of 128 so the Pallas ``quant_matmul``
kernel can dequantize one (block × tile) at a time in VMEM and feed the MXU.
int4/NF4 values are packed two-per-uint8, so ``memory_analysis`` of the
dry-run reflects the true 4-bit footprint.

Layout for a weight of shape (..., K, N) with block B along K:
  q      : (..., G, B, N) int8      [8-bit]        G = K // B
           (..., G, B//2, N) uint8  [4-bit packed]
  scales : (..., G, 1, N) float32   absmax / levels

``quantize_tree`` applies this to every ≥2-D leaf of a param tree
(1-D leaves — norms, biases — stay in full precision, as in QLoRA).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# NF4 codebook (QLoRA, Dettmers et al. 2023) — quantiles of N(0,1), ±1 ends.
NF4_CODE = np.array([
    -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
    -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
    0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
    0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
    0.7229568362236023, 1.0], dtype=np.float32)


@partial(jax.tree_util.register_dataclass,
         data_fields=["q", "scales"],
         meta_fields=["bits", "mode", "block", "out_dtype", "orig_shape"])
@dataclasses.dataclass
class QTensor:
    q: jax.Array
    scales: jax.Array
    bits: int
    mode: str           # "linear" | "nf4"
    block: int
    out_dtype: Any
    orig_shape: tuple

    @property
    def shape(self):
        return self.orig_shape

    @property
    def ndim(self):
        return len(self.orig_shape)

    def nbytes_packed(self) -> int:
        return int(np.prod(self.q.shape)) * self.q.dtype.itemsize + \
            int(np.prod(self.scales.shape)) * self.scales.dtype.itemsize


def _blocked(x: jax.Array, block: int):
    *lead, K, N = x.shape
    block = min(block, K)
    assert K % block == 0, f"contraction dim {K} not divisible by block {block}"
    return x.reshape(*lead, K // block, block, N), block


def pack4(q: jax.Array) -> jax.Array:
    """Pack int4 values in [-8, 7] two-per-uint8 along axis -2."""
    u = (q + 8).astype(jnp.uint8)
    hi, lo = u[..., 0::2, :], u[..., 1::2, :]
    return (hi << 4) | lo


def unpack4(p: jax.Array) -> jax.Array:
    hi = (p >> 4).astype(jnp.int8) - 8
    lo = (p & 0xF).astype(jnp.int8) - 8
    *lead, Bh, N = p.shape
    out = jnp.stack([hi, lo], axis=-2)             # (..., Bh, 2, N)
    return out.reshape(*lead, 2 * Bh, N)


def quantize(x: jax.Array, *, bits: int = 4, block: int = 128,
             mode: str = "linear") -> QTensor:
    orig_shape = tuple(x.shape)
    out_dtype = x.dtype
    xb, block = _blocked(x.astype(jnp.float32), block)
    absmax = jnp.max(jnp.abs(xb), axis=-2, keepdims=True)
    absmax = jnp.maximum(absmax, 1e-12)
    if mode == "nf4":
        assert bits == 4, "nf4 is a 4-bit codebook"
        scales = absmax
        normed = xb / scales                               # [-1, 1]
        code = jnp.asarray(NF4_CODE)
        idx = jnp.argmin(
            jnp.abs(normed[..., None] - code), axis=-1).astype(jnp.int8) - 8
        q = pack4(idx)
    elif bits == 8:
        scales = absmax / 127.0
        q = jnp.clip(jnp.round(xb / scales), -127, 127).astype(jnp.int8)
    elif bits == 4:
        scales = absmax / 7.0
        q = jnp.clip(jnp.round(xb / scales), -8, 7).astype(jnp.int8)
        q = pack4(q)
    else:
        raise ValueError(f"unsupported bits={bits}")
    return QTensor(q=q, scales=scales, bits=bits, mode=mode, block=block,
                   out_dtype=out_dtype, orig_shape=orig_shape)


def dequantize(qt: QTensor, dtype=None) -> jax.Array:
    dtype = dtype or qt.out_dtype
    if qt.bits == 4:
        vals = unpack4(qt.q)
        if qt.mode == "nf4":
            vals = jnp.take(jnp.asarray(NF4_CODE), (vals + 8).astype(jnp.int32))
        else:
            vals = vals.astype(jnp.float32)
    else:
        vals = qt.q.astype(jnp.float32)
    x = vals * qt.scales
    # Shape is derived from the live arrays (not the static orig_shape) so
    # that sliced / lax.scan-consumed / all-gathered QTensors dequantize
    # correctly; orig_shape is metadata for the unsliced tensor only.
    *lead, G, B, N = x.shape
    return x.reshape(*lead, G * B, N).astype(dtype)


def maybe_dequantize(w, dtype=None):
    return dequantize(w, dtype) if isinstance(w, QTensor) else w


# param-name fragments never quantized (QLoRA keeps these full-precision)
DEFAULT_SKIP = ("router", "conv", "dt_bias", "a_log", "d_skip", "lam",
                "ln", "norm", "embed", "pos", "head", "bias", "lora",
                "slot", "w_rg", "w_ig")


def _quantizable(path: str, shape, dtype, min_size: int,
                 skip_names=DEFAULT_SKIP) -> bool:
    if any(s in path.lower() for s in skip_names):
        return False
    if len(shape) < 2 or int(np.prod(shape)) < min_size:
        return False
    if not jnp.issubdtype(dtype, jnp.floating):
        return False
    return True


def _pick_block(K: int, block: int) -> int:
    b = min(block, K)
    while K % b:
        b //= 2
    return max(b, 1)


def quantize_tree(params, *, bits: int, block: int = 128,
                  mode: str = "linear", min_size: int = 4096,
                  skip_names=DEFAULT_SKIP):
    """Quantize every eligible ≥2-D leaf (QLoRA keeps norms/biases/
    routers/convs/embeddings in full precision — filtered by name)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda l: isinstance(l, QTensor))
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(k) for k in path)
        if isinstance(leaf, QTensor) or not _quantizable(
                pstr, leaf.shape, leaf.dtype, min_size, skip_names):
            out.append(leaf)
            continue
        b = _pick_block(leaf.shape[-2], block)
        eff_bits, eff_mode = bits, mode
        if b % 2:
            eff_bits, eff_mode = 8, "linear"  # can't pack odd blocks
        out.append(quantize(leaf, bits=eff_bits, block=b, mode=eff_mode))
    return jax.tree_util.tree_unflatten(treedef, out)


def qtensor_specs(shape, dtype, *, bits: int, block: int = 128,
                  mode: str = "linear") -> QTensor:
    """Abstract (ShapeDtypeStruct) QTensor matching ``quantize`` output."""
    *lead, K, N = shape
    b = _pick_block(K, block)
    if b % 2:
        bits, mode = 8, "linear"
    G = K // b
    if bits == 4:
        q = jax.ShapeDtypeStruct((*lead, G, b // 2, N), jnp.uint8)
    else:
        q = jax.ShapeDtypeStruct((*lead, G, b, N), jnp.int8)
    scales = jax.ShapeDtypeStruct((*lead, G, 1, N), jnp.float32)
    return QTensor(q=q, scales=scales, bits=bits, mode=mode, block=b,
                   out_dtype=dtype, orig_shape=tuple(shape))


def quantize_tree_specs(specs, *, bits: int, block: int = 128,
                        mode: str = "linear", min_size: int = 4096,
                        skip_names=DEFAULT_SKIP):
    """ShapeDtypeStruct analogue of ``quantize_tree`` (dry-run params)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda l: isinstance(
            l, (QTensor, jax.ShapeDtypeStruct)))
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(k) for k in path)
        if isinstance(leaf, jax.ShapeDtypeStruct) and _quantizable(
                pstr, leaf.shape, leaf.dtype, min_size, skip_names):
            out.append(qtensor_specs(leaf.shape, leaf.dtype, bits=bits,
                                     block=block, mode=mode))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def dequantize_tree(params, dtype=None):
    return jax.tree.map(
        lambda l: dequantize(l, dtype) if isinstance(l, QTensor) else l,
        params, is_leaf=lambda l: isinstance(l, QTensor))


def double_quantize(qt: QTensor, *, block: int = 256):
    """QLoRA double quantization: the f32 absmax scales are themselves
    int8-quantized (mean-offset absmax over flat blocks of ``block``),
    cutting per-block overhead from 32 to ~8.25 bits. Returns a plain
    dict (storage/communication container)."""
    s = qt.scales.astype(jnp.float32)
    flat = s.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    g = flat.reshape(-1, block)
    mean = g.mean(axis=1, keepdims=True)
    c = g - mean
    smax = jnp.maximum(jnp.abs(c).max(axis=1, keepdims=True), 1e-12) / 127.
    q = jnp.clip(jnp.round(c / smax), -127, 127).astype(jnp.int8)
    return {"q": qt.q, "s_q": q, "s_scale": smax[:, 0], "s_mean": mean[:, 0],
            "meta": dict(bits=qt.bits, mode=qt.mode, block=qt.block,
                         out_dtype=np.dtype(qt.out_dtype).name,
                         orig_shape=tuple(qt.orig_shape),
                         scales_shape=tuple(qt.scales.shape),
                         dq_block=block)}


def double_dequantize(dq: dict) -> QTensor:
    m = dq["meta"]
    flat = (dq["s_q"].astype(jnp.float32) * dq["s_scale"][:, None] +
            dq["s_mean"][:, None]).reshape(-1)
    n = int(np.prod(m["scales_shape"]))
    scales = flat[:n].reshape(m["scales_shape"])
    return QTensor(q=dq["q"], scales=scales, bits=m["bits"],
                   mode=m["mode"], block=m["block"],
                   out_dtype=np.dtype(m["out_dtype"]),
                   orig_shape=tuple(m["orig_shape"]))


def double_quant_bytes(dq: dict) -> int:
    b = dq["q"].size * dq["q"].dtype.itemsize
    b += dq["s_q"].size + dq["s_scale"].size * 4 + dq["s_mean"].size * 4
    return int(b)


def tree_bytes(params) -> int:
    """True communicated/stored bytes of a (possibly quantized) tree."""
    total = 0
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda l: isinstance(l, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes_packed()
        else:
            total += leaf.size * leaf.dtype.itemsize
    return int(total)
