"""Loss functions: LM/classification cross-entropy and the CLIP symmetric
contrastive (InfoNCE) loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """logits (..., V), integer labels (...). Mean over unmasked items.
    Computed in f32 for stability regardless of model dtype."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


def clip_contrastive(img_emb: jax.Array, txt_emb: jax.Array,
                     logit_scale: jax.Array) -> jax.Array:
    """Symmetric InfoNCE over a batch of paired embeddings (B, d)."""
    img = img_emb / (jnp.linalg.norm(img_emb, axis=-1, keepdims=True) + 1e-8)
    txt = txt_emb / (jnp.linalg.norm(txt_emb, axis=-1, keepdims=True) + 1e-8)
    logits = jnp.exp(logit_scale) * img @ txt.T           # (B, B)
    labels = jnp.arange(logits.shape[0])
    return 0.5 * (cross_entropy(logits, labels) +
                  cross_entropy(logits.T, labels))
