"""JAX version compatibility shims.

``shard_map`` was promoted from ``jax.experimental.shard_map`` to
``jax.shard_map`` in newer JAX; this container runs 0.4.x. Import it
from here so every caller works on both.
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:      # jax<=0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *args, **kwargs):
        # the replication-check kwarg was renamed check_rep -> check_vma
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(f, *args, **kwargs)
