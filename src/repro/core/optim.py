"""Minimal pure-JAX optimizers (no optax in this environment).

Used by FL clients (LoRA/adapter fine-tuning), the GAN, and the examples.
Optimizer state is a pytree mirroring the param tree, so it shards the same
way the params do under pjit.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adam_specs(param_specs) -> AdamState:
    """ShapeDtypeStruct AdamState mirroring a spec tree (dry-run)."""
    z = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_specs)
    return AdamState(jax.ShapeDtypeStruct((), jnp.int32), z,
                     jax.tree.map(lambda s: s, z))


def adam_init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamState(jnp.zeros((), jnp.int32), zeros,
                     jax.tree.map(jnp.copy, zeros))


def adam_update(grads, state: AdamState, params, *, lr, b1=0.9, b2=0.999,
                eps=1e-8, weight_decay=0.0, grad_clip=0.0):
    """Returns (new_params, new_state). ``lr`` may be a float or a
    ``step -> lr`` schedule callable."""
    step = state.step + 1
    if grad_clip:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr_t = lr(step) if callable(lr) else lr
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(
        g.astype(jnp.float32)), state.nu, grads)
    mu_hat_scale = 1.0 / (1 - b1 ** step.astype(jnp.float32))
    nu_hat_scale = 1.0 / (1 - b2 ** step.astype(jnp.float32))

    def upd(p, m, v):
        u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamState(step, mu, nu)


def adam_scan(grad_fn, params, state: AdamState, xs, *, lr, b1=0.9,
              b2=0.999, eps=1e-8, weight_decay=0.0, grad_clip=0.0,
              unroll=1, active=None):
    """Fused local-training loop: one ``adam_update`` per leading element
    of ``xs``, inside a single ``lax.scan`` — the scan-friendly form used
    by the cohort engine and the CLIP pretraining loop, so a whole
    optimisation run is one XLA program (jit/donation-friendly, and the
    ``(params, state)`` carry buffers are reused in place on device).

    ``grad_fn(params, x) -> (grads, aux)``; returns
    ``(params, state, aux_stacked)`` where each adam_update step matches
    the Python-loop semantics of calling ``adam_update`` per batch.

    ``active`` — optional per-step bool vector (same leading length as
    ``xs``). Steps with ``active[t] == False`` leave params and optimizer
    state (moments *and* step counter) untouched, so a scan of static
    length S with the first ``n`` steps active is bit-identical to a
    Python loop of ``n`` adam_update calls. This is how the cohort engine
    runs clients with heterogeneous local-step counts inside one
    fixed-shape program; aux is still emitted for masked steps (evaluated
    on the frozen params) — callers index the last *active* entry.
    """
    masked = active is not None

    def body(carry, x):
        p, s = carry
        if masked:
            x, live = x
        g, aux = grad_fn(p, x)
        p2, s2 = adam_update(g, s, p, lr=lr, b1=b1, b2=b2, eps=eps,
                             weight_decay=weight_decay,
                             grad_clip=grad_clip)
        if masked:
            p2 = jax.tree.map(lambda a, b: jnp.where(live, a, b), p2, p)
            s2 = jax.tree.map(lambda a, b: jnp.where(live, a, b), s2, s)
        return (p2, s2), aux

    (params, state), aux = jax.lax.scan(
        body, (params, state), (xs, active) if masked else xs,
        unroll=unroll)
    return params, state, aux


def step_mask(n_steps, length: int):
    """Canonical ``active`` mask for the masked scans: the first
    ``n_steps`` of ``length`` scan steps live, the tail no-ops.
    ``n_steps`` may be a traced scalar (the cohort engine passes
    per-client counts under vmap). This is the one definition of
    "cut at step s" shared by the fused engines, the chaos layer's
    partial-work recovery, and the recovery property tests — cutting a
    run at ``s`` via this mask is bitwise running exactly ``s`` steps
    (params, both Adam moments, and the step counter)."""
    return jnp.arange(length) < n_steps


def sgd_update(grads, params, *, lr):
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                      ).astype(p.dtype), params, grads)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def sched(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return sched
