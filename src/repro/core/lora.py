"""Low-rank adaptation (§III-C).

A LoRA pair for a frozen weight W (k, n) is {A: (k, r), B: (r, n)}; the
effective weight is W + (alpha/r)·A@B. A is Kaiming-init, B zero-init so
training starts at the pretrained function. Only LoRA (+ adapter) params
are trained and communicated in TriplePlay.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, maybe_dequantize


def _fused_enabled() -> bool:
    """Fused LoRA matmul routing, read *dynamically* so benches/CI can
    flip the legacy einsum chain back on (``REPRO_LORA_FUSED=0``) for
    chain-vs-fused comparisons without re-importing."""
    return os.environ.get("REPRO_LORA_FUSED", "1") != "0"


def init_pair(rng, k: int, n: int, rank: int, dtype=jnp.float32):
    a = jax.random.normal(rng, (k, rank), dtype) * (1.0 / jnp.sqrt(k))
    b = jnp.zeros((rank, n), dtype)
    return {"a": a, "b": b}


def pair_specs(k: int, n: int, rank: int, dtype=jnp.float32, lead=()):
    """Abstract ShapeDtypeStructs (for dry-run param trees)."""
    return {"a": jax.ShapeDtypeStruct((*lead, k, rank), dtype),
            "b": jax.ShapeDtypeStruct((*lead, rank, n), dtype)}


def apply(x: jax.Array, lora, *, alpha: float, rank: int) -> jax.Array:
    """Compute the low-rank delta (alpha/r)·(x@A)@B in f32, cast back.

    The upcast is on *x and both factors*: with bf16 trainables the old
    ``x.astype(lora["a"].dtype)`` accumulated the whole chain in bf16,
    silently breaking the f32 promise (regression-pinned in
    tests/test_lora_adapter.py)."""
    s = alpha / rank
    xf = x.astype(jnp.float32)
    h = jnp.einsum("...k,kr->...r", xf, lora["a"].astype(jnp.float32))
    d = jnp.einsum("...r,rn->...n", h, lora["b"].astype(jnp.float32))
    return (d * s).astype(x.dtype)


def linear(x: jax.Array, w, lora=None, *, alpha: float = 32.0,
           rank: int = 16) -> jax.Array:
    """y = x @ W(+dequant) [+ LoRA delta]. ``w`` may be a QTensor.

    With a LoRA pair attached this routes through the fused op
    (``kernels.ops.lora_matmul``): base gemm + low-rank delta in one
    kernel with fp32 accumulation and a custom VJP — the Pallas fused
    kernel on TPU/interpret, the fused jnp reference elsewhere. Set
    ``REPRO_LORA_FUSED=0`` to force the legacy einsum chain (bench /
    parity comparisons). Without LoRA, the QTensor path dispatches to
    the fused Pallas dequant-matmul (kernels/ops.py); elsewhere it
    dequantizes inline (same math).
    """
    from repro.kernels import ops as kops  # late import: no cycles
    if lora is not None and _fused_enabled():
        kops.trace_count("lora_linear_fused")
        return kops.lora_matmul(x, w, lora["a"], lora["b"],
                                scale=alpha / rank)
    if isinstance(w, QTensor):
        y = kops.quant_matmul(x, w)
    else:
        y = jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))
    if lora is not None:
        kops.trace_count("lora_linear_chain")
        y = y + apply(x, lora, alpha=alpha, rank=rank)
    return y


def merge(w, lora, *, alpha: float, rank: int) -> jax.Array:
    """Fold the LoRA delta into a dense weight (for deployment/eval)."""
    wd = maybe_dequantize(w, jnp.float32)
    return wd + (alpha / rank) * lora["a"].astype(jnp.float32) @ \
        lora["b"].astype(jnp.float32)
