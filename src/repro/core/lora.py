"""Low-rank adaptation (§III-C).

A LoRA pair for a frozen weight W (k, n) is {A: (k, r), B: (r, n)}; the
effective weight is W + (alpha/r)·A@B. A is Kaiming-init, B zero-init so
training starts at the pretrained function. Only LoRA (+ adapter) params
are trained and communicated in TriplePlay.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, maybe_dequantize


def init_pair(rng, k: int, n: int, rank: int, dtype=jnp.float32):
    a = jax.random.normal(rng, (k, rank), dtype) * (1.0 / jnp.sqrt(k))
    b = jnp.zeros((rank, n), dtype)
    return {"a": a, "b": b}


def pair_specs(k: int, n: int, rank: int, dtype=jnp.float32, lead=()):
    """Abstract ShapeDtypeStructs (for dry-run param trees)."""
    return {"a": jax.ShapeDtypeStruct((*lead, k, rank), dtype),
            "b": jax.ShapeDtypeStruct((*lead, rank, n), dtype)}


def apply(x: jax.Array, lora, *, alpha: float, rank: int) -> jax.Array:
    """Compute the low-rank delta (alpha/r)·(x@A)@B in f32, cast back."""
    s = alpha / rank
    h = jnp.einsum("...k,kr->...r", x.astype(lora["a"].dtype), lora["a"])
    return (jnp.einsum("...r,rn->...n", h, lora["b"]) * s).astype(x.dtype)


def linear(x: jax.Array, w, lora=None, *, alpha: float = 32.0,
           rank: int = 16) -> jax.Array:
    """y = x @ W(+dequant) [+ LoRA delta]. ``w`` may be a QTensor.

    On TPU the QTensor path dispatches to the fused Pallas dequant-matmul
    (kernels/ops.py); elsewhere it dequantizes inline (same math).
    """
    if isinstance(w, QTensor):
        from repro.kernels import ops as kops  # late import: no cycles
        y = kops.quant_matmul(x, w)
    else:
        y = jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))
    if lora is not None:
        y = y + apply(x, lora, alpha=alpha, rank=rank)
    return y


def merge(w, lora, *, alpha: float, rank: int) -> jax.Array:
    """Fold the LoRA delta into a dense weight (for deployment/eval)."""
    wd = maybe_dequantize(w, jnp.float32)
    return wd + (alpha / rank) * lora["a"].astype(jnp.float32) @ \
        lora["b"].astype(jnp.float32)
