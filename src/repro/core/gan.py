"""Conditional GAN for long-tail rebalancing (paper §III-B).

A small class-conditional DCGAN over 32×32 images: the generator learns the
client's local distribution; underrepresented classes are then over-sampled
with synthetic images (Fig. 1(b) of the paper). Trained client-side so raw
data never leaves the client (DESIGN.md §7).

min_G max_D V(D,G) = E_x[log D(x)] + E_z[log(1 - D(G(z)))], with the
non-saturating generator objective.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import optim


@dataclass(frozen=True)
class GANConfig:
    image_size: int = 32
    channels: int = 3
    n_classes: int = 7
    z_dim: int = 32
    g_dim: int = 32
    d_dim: int = 32
    lr: float = 2e-4


def init_gan(rng, cfg: GANConfig):
    ks = jax.random.split(rng, 12)
    s = lambda f: 1.0 / jnp.sqrt(f)
    g0 = cfg.g_dim
    gen = {
        "emb": jax.random.normal(ks[0], (cfg.n_classes, cfg.z_dim)) * 0.1,
        "fc": jax.random.normal(ks[1], (2 * cfg.z_dim, 4 * 4 * 2 * g0)) *
        s(2 * cfg.z_dim),
        "c1": jax.random.normal(ks[2], (4, 4, 2 * g0, g0)) * 0.05,   # 4->8
        "c2": jax.random.normal(ks[3], (4, 4, g0, g0)) * 0.05,       # 8->16
        "c3": jax.random.normal(ks[4], (4, 4, g0, cfg.channels)) * 0.05,
    }
    d0 = cfg.d_dim
    disc = {
        "c1": jax.random.normal(ks[5], (4, 4, cfg.channels, d0)) * 0.05,
        "c2": jax.random.normal(ks[6], (4, 4, d0, 2 * d0)) * 0.05,
        "c3": jax.random.normal(ks[7], (4, 4, 2 * d0, 4 * d0)) * 0.05,
        "fc": jax.random.normal(ks[8], (4 * 4 * 4 * d0, 1)) *
        s(4 * 4 * 4 * d0),
        "emb": jax.random.normal(ks[9], (cfg.n_classes, 4 * 4 * 4 * d0)) *
        0.01,
    }
    return {"gen": gen, "disc": disc}


def _convT(x, w, stride=2):
    return lax.conv_transpose(x, w, (stride, stride), "SAME",
                              dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv(x, w, stride=2):
    return lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def generate(gen, cfg: GANConfig, z, labels):
    """z: (B, z_dim); labels: (B,) -> images (B, 32, 32, 3) in [-1, 1]."""
    y = gen["emb"][labels]
    h = jnp.concatenate([z, y], -1) @ gen["fc"]
    h = jax.nn.relu(h).reshape(-1, 4, 4, 2 * cfg.g_dim)
    h = jax.nn.relu(_convT(h, gen["c1"]))
    h = jax.nn.relu(_convT(h, gen["c2"]))
    return jnp.tanh(_convT(h, gen["c3"]))


def discriminate(disc, cfg: GANConfig, images, labels, *,
                 with_features: bool = False):
    h = jax.nn.leaky_relu(_conv(images, disc["c1"]), 0.2)
    h = jax.nn.leaky_relu(_conv(h, disc["c2"]), 0.2)
    h = jax.nn.leaky_relu(_conv(h, disc["c3"]), 0.2)
    feat = h.reshape(h.shape[0], -1)
    logit = (feat @ disc["fc"])[:, 0]
    proj = jnp.sum(feat * disc["emb"][labels], -1)   # projection cGAN
    if with_features:
        return logit + proj, feat
    return logit + proj


def _bce(logits, target):
    return jnp.mean(jnp.maximum(logits, 0) - logits * target +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))


@partial(jax.jit, static_argnums=(3,))
def train_step(params, opt_states, batch, cfg: GANConfig, rng):
    """One alternating D/G update. batch = (images, labels)."""
    images, labels = batch
    B = images.shape[0]
    kz, kz2 = jax.random.split(rng)
    z = jax.random.normal(kz, (B, cfg.z_dim))

    def d_loss(disc):
        fake = generate(params["gen"], cfg, z, labels)
        lr_ = discriminate(disc, cfg, images, labels)
        lf = discriminate(disc, cfg, lax.stop_gradient(fake), labels)
        return _bce(lr_, 1.0) + _bce(lf, 0.0)

    dl, dg = jax.value_and_grad(d_loss)(params["disc"])
    disc, d_opt = optim.adam_update(dg, opt_states["disc"],
                                    params["disc"], lr=cfg.lr, b1=0.5)

    z2 = jax.random.normal(kz2, (B, cfg.z_dim))

    def g_loss(gen):
        fake = generate(gen, cfg, z2, labels)
        lf, feat_f = discriminate(disc, cfg, fake, labels,
                                  with_features=True)
        _, feat_r = discriminate(disc, cfg, images, labels,
                                 with_features=True)
        # feature matching (Salimans et al. 2016): anchors G's statistics
        # to the data manifold — without it the small generator collapses
        # into the zero-image saddle of the projection discriminator
        fm = jnp.mean((feat_r.mean(0) - feat_f.mean(0)) ** 2)
        return _bce(lf, 1.0) + 10.0 * fm

    gl, gg = jax.value_and_grad(g_loss)(params["gen"])
    gen, g_opt = optim.adam_update(gg, opt_states["gen"],
                                   params["gen"], lr=cfg.lr, b1=0.5)
    return ({"gen": gen, "disc": disc},
            {"gen": g_opt, "disc": d_opt},
            {"d_loss": dl, "g_loss": gl})


def train_gan(rng, cfg: GANConfig, images, labels, *, steps: int = 200,
              batch: int = 64):
    """Train on a client's local data; returns generator params."""
    k0, rng = jax.random.split(rng)
    params = init_gan(k0, cfg)
    opt = {"gen": optim.adam_init(params["gen"]),
           "disc": optim.adam_init(params["disc"])}
    n = images.shape[0]
    metrics = {}
    for i in range(steps):
        rng, kb, ks = jax.random.split(rng, 3)
        idx = jax.random.randint(kb, (min(batch, n),), 0, n)
        params, opt, metrics = train_step(
            params, opt, (images[idx], labels[idx]), cfg, ks)
    return params, metrics


def synthesize(rng, gen, cfg: GANConfig, labels):
    z = jax.random.normal(rng, (labels.shape[0], cfg.z_dim))
    return generate(gen, cfg, z, labels)
