"""Conditional GAN for long-tail rebalancing (paper §III-B).

A small class-conditional DCGAN over 32×32 images: the generator learns the
client's local distribution; underrepresented classes are then over-sampled
with synthetic images (Fig. 1(b) of the paper). Trained client-side so raw
data never leaves the client (DESIGN.md §7).

min_G max_D V(D,G) = E_x[log D(x)] + E_z[log(1 - D(G(z)))], with the
non-saturating generator objective.

Two execution granularities share the same step math:

- ``train_gan`` — the original per-step dispatch loop (one jitted
  ``train_step`` per batch). Kept verbatim as the parity oracle and the
  benchmark baseline for the fused path.
- ``gan_scan`` — the whole optimisation as one ``lax.scan`` (mirroring
  ``optim.adam_scan``): pre-drawn batch indices and per-step RNG keys
  stream in as scan inputs, and an optional ``active`` mask turns
  individual steps into bitwise no-ops on params + both Adam states —
  how the fleet engine (``fl.fleetgan``) carries ineligible clients
  inside a stacked cohort program. ``gan_key_stream`` /
  ``gan_batch_indices`` reproduce the exact ``train_gan`` RNG stream so
  both granularities consume identical keys and batches.
- ``gan_scan_bucketed`` / ``train_step_bucketed`` — the bucketed form:
  the minibatch pads to a shared bucket and every batch-mean loss is
  computed as the masked mean ``sum(per_row * mask) / n_true``, so
  padded rows contribute exactly zero gradient and all batch-size
  groups share one compile; per-step noise is pre-drawn at the true
  batch shape (``gan_z_stream`` — threefry is not shape-stable) to keep
  the RNG stream bitwise the sequential one.

``GANConfig.conv_impl`` selects the convolution lowering: ``"lax"`` (the
original ``lax.conv``/``conv_transpose`` primitives) or ``"gemm"``
(``kernels.gan_conv`` im2col / sub-pixel gemm forms — the only lowering
that stays fast under a ``vmap`` over per-client weights; see that
module's docstring).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import optim
from repro.kernels import gan_conv


@dataclass(frozen=True)
class GANConfig:
    image_size: int = 32
    channels: int = 3
    n_classes: int = 7
    z_dim: int = 32
    g_dim: int = 32
    d_dim: int = 32
    lr: float = 2e-4
    # "lax" | "gemm" (kernels.gan_conv phase-decomposed gemms) |
    # "gemm_int8" (same gemm forms with blockwise-int8 quantized
    # compute, fp32 accumulation — trains *with* quantized matmuls)
    conv_impl: str = "lax"


def init_gan(rng, cfg: GANConfig):
    ks = jax.random.split(rng, 12)
    s = lambda f: 1.0 / jnp.sqrt(f)
    g0 = cfg.g_dim
    gen = {
        "emb": jax.random.normal(ks[0], (cfg.n_classes, cfg.z_dim)) * 0.1,
        "fc": jax.random.normal(ks[1], (2 * cfg.z_dim, 4 * 4 * 2 * g0)) *
        s(2 * cfg.z_dim),
        "c1": jax.random.normal(ks[2], (4, 4, 2 * g0, g0)) * 0.05,   # 4->8
        "c2": jax.random.normal(ks[3], (4, 4, g0, g0)) * 0.05,       # 8->16
        "c3": jax.random.normal(ks[4], (4, 4, g0, cfg.channels)) * 0.05,
    }
    d0 = cfg.d_dim
    disc = {
        "c1": jax.random.normal(ks[5], (4, 4, cfg.channels, d0)) * 0.05,
        "c2": jax.random.normal(ks[6], (4, 4, d0, 2 * d0)) * 0.05,
        "c3": jax.random.normal(ks[7], (4, 4, 2 * d0, 4 * d0)) * 0.05,
        "fc": jax.random.normal(ks[8], (4 * 4 * 4 * d0, 1)) *
        s(4 * 4 * 4 * d0),
        "emb": jax.random.normal(ks[9], (cfg.n_classes, 4 * 4 * 4 * d0)) *
        0.01,
    }
    return {"gen": gen, "disc": disc}


def _convT(x, w, stride=2, impl="lax"):
    if impl == "gemm":
        return gan_conv.convT4x4_s2(x, w)
    if impl == "gemm_int8":
        return gan_conv.convT4x4_s2_int8(x, w)
    if impl != "lax":
        raise ValueError(f"unknown conv_impl {impl!r} "
                         "(expected lax | gemm | gemm_int8)")
    return lax.conv_transpose(x, w, (stride, stride), "SAME",
                              dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv(x, w, stride=2, impl="lax"):
    if impl == "gemm":
        return gan_conv.conv4x4_s2(x, w)
    if impl == "gemm_int8":
        return gan_conv.conv4x4_s2_int8(x, w)
    if impl != "lax":
        raise ValueError(f"unknown conv_impl {impl!r} "
                         "(expected lax | gemm | gemm_int8)")
    return lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def generate(gen, cfg: GANConfig, z, labels):
    """z: (B, z_dim); labels: (B,) -> images (B, 32, 32, 3) in [-1, 1]."""
    y = gen["emb"][labels]
    h = jnp.concatenate([z, y], -1) @ gen["fc"]
    h = jax.nn.relu(h).reshape(-1, 4, 4, 2 * cfg.g_dim)
    h = jax.nn.relu(_convT(h, gen["c1"], impl=cfg.conv_impl))
    h = jax.nn.relu(_convT(h, gen["c2"], impl=cfg.conv_impl))
    return jnp.tanh(_convT(h, gen["c3"], impl=cfg.conv_impl))


def discriminate(disc, cfg: GANConfig, images, labels, *,
                 with_features: bool = False):
    impl = cfg.conv_impl
    h = jax.nn.leaky_relu(_conv(images, disc["c1"], impl=impl), 0.2)
    h = jax.nn.leaky_relu(_conv(h, disc["c2"], impl=impl), 0.2)
    h = jax.nn.leaky_relu(_conv(h, disc["c3"], impl=impl), 0.2)
    feat = h.reshape(h.shape[0], -1)
    logit = (feat @ disc["fc"])[:, 0]
    proj = jnp.sum(feat * disc["emb"][labels], -1)   # projection cGAN
    if with_features:
        return logit + proj, feat
    return logit + proj


def _train_step_core(params, opt_states, batch, cfg: GANConfig, z, z2,
                     batch_mean, feat_mean):
    """The one alternating D/G update body shared by every execution
    granularity. ``batch_mean`` reduces per-row loss terms over the
    batch and ``feat_mean`` averages feature rows — the plain means for
    the exact-batch paths, masked mean-corrected forms for the bucketed
    path. The loss *definition* (objectives, feature-matching weight,
    Adam b1) lives only here, so the granularities cannot drift."""
    images, labels = batch

    def bce(logits, target):
        return batch_mean(jnp.maximum(logits, 0) - logits * target +
                          jnp.log1p(jnp.exp(-jnp.abs(logits))))

    def d_loss(disc):
        fake = generate(params["gen"], cfg, z, labels)
        lr_ = discriminate(disc, cfg, images, labels)
        lf = discriminate(disc, cfg, lax.stop_gradient(fake), labels)
        return bce(lr_, 1.0) + bce(lf, 0.0)

    dl, dg = jax.value_and_grad(d_loss)(params["disc"])
    disc, d_opt = optim.adam_update(dg, opt_states["disc"],
                                    params["disc"], lr=cfg.lr, b1=0.5)

    def g_loss(gen):
        fake = generate(gen, cfg, z2, labels)
        lf, feat_f = discriminate(disc, cfg, fake, labels,
                                  with_features=True)
        _, feat_r = discriminate(disc, cfg, images, labels,
                                 with_features=True)
        # feature matching (Salimans et al. 2016): anchors G's statistics
        # to the data manifold — without it the small generator collapses
        # into the zero-image saddle of the projection discriminator
        fm = jnp.mean((feat_mean(feat_r) - feat_mean(feat_f)) ** 2)
        return bce(lf, 1.0) + 10.0 * fm

    gl, gg = jax.value_and_grad(g_loss)(params["gen"])
    gen, g_opt = optim.adam_update(gg, opt_states["gen"],
                                   params["gen"], lr=cfg.lr, b1=0.5)
    return ({"gen": gen, "disc": disc},
            {"gen": g_opt, "disc": d_opt},
            {"d_loss": dl, "g_loss": gl})


def train_step_impl(params, opt_states, batch, cfg: GANConfig, rng):
    """One alternating D/G update. batch = (images, labels). Pure — the
    shared body of the per-step ``train_step`` dispatch and the fused
    ``gan_scan`` loop; noise is drawn in-program from ``rng`` at the
    exact batch shape."""
    B = batch[0].shape[0]
    kz, kz2 = jax.random.split(rng)
    z = jax.random.normal(kz, (B, cfg.z_dim))
    z2 = jax.random.normal(kz2, (B, cfg.z_dim))
    return _train_step_core(params, opt_states, batch, cfg, z, z2,
                            batch_mean=jnp.mean,
                            feat_mean=lambda f: f.mean(0))


train_step = jax.jit(train_step_impl, static_argnums=(3,))


def train_step_bucketed(params, opt_states, batch, cfg: GANConfig, z, z2,
                        n_true):
    """One alternating D/G update on a minibatch padded to a shared
    bucket: rows ``>= n_true`` of ``batch``/``z``/``z2`` are padding.

    The mean-correction contract: every batch-mean loss term of
    ``train_step_impl`` is computed as the *masked* mean
    ``sum(per_row * mask) / n_true`` — i.e. the padded-batch mean
    rescaled by ``bucket / n_true`` — and the feature-matching
    statistics are masked means likewise. Because the discriminator and
    generator are purely per-row networks, a padded row's contribution
    to every loss term is multiplied by exactly 0.0 before the
    reduction, so gradients (and therefore the Adam update on params +
    both moment/step states) match the unpadded ``train_step_impl`` on
    the true rows up to float reassociation of the batch reductions —
    this is what lets every GAN batch-size group share one compile.
    ``z``/``z2`` are the pre-drawn ``gan_z_stream`` noise (padded rows
    zero), keeping the RNG stream bitwise the sequential one."""
    B = batch[0].shape[0]
    mask = (jnp.arange(B) < n_true).astype(jnp.float32)
    n = jnp.asarray(n_true, jnp.float32)
    return _train_step_core(
        params, opt_states, batch, cfg, z, z2,
        batch_mean=lambda t: jnp.sum(t * mask) / n,
        feat_mean=lambda f: jnp.sum(f * mask[:, None], axis=0) / n)


def gan_key_stream(rng, steps: int):
    """The exact RNG stream ``train_gan`` consumes, as arrays: returns
    ``(init_key, batch_keys (steps, 2), step_keys (steps, 2))`` such
    that ``train_gan(rng, ...)`` is ``init_gan(init_key)`` followed by
    one ``train_step(..., step_keys[t])`` on the ``batch_keys[t]`` draw
    per step. Bitwise (threefry is deterministic), and vmappable over a
    stacked cohort of per-client rngs."""
    k0, r = jax.random.split(rng)

    def body(r, _):
        r, kb, ks = jax.random.split(r, 3)
        return r, (kb, ks)

    _, (kbs, kss) = lax.scan(body, r, None, length=steps)
    return k0, kbs, kss


def gan_batch_indices(batch_keys, n, batch: int):
    """Per-step pool indices ``(steps, batch)`` in ``[0, n)`` — bitwise
    the draws of the sequential ``train_gan`` loop. ``n`` may be traced
    (vmapped over clients sharing one compile): rows past ``n`` of a
    padded pool carry zero sampling probability by construction."""
    return jax.vmap(
        lambda k: jax.random.randint(k, (batch,), 0, n))(batch_keys)


def gan_z_stream(step_keys, batch: int, z_dim: int):
    """Pre-draw the per-step generator noise ``train_step_impl`` would
    draw in-program: for each step key ``k``, ``kz, kz2 = split(k)``
    then ``normal(kz, (batch, z_dim))`` / ``normal(kz2, ...)``. Returns
    ``(z (steps, batch, z_dim), z2 (steps, batch, z_dim))`` — bitwise
    the in-program draws. The bucketed fleet engine draws these eagerly
    at each client's TRUE batch size and pads afterwards, because
    threefry draws are not shape-stable: drawing at the padded bucket
    shape would change every client's noise stream and break parity
    with the sequential oracle."""
    def one(k):
        kz, kz2 = jax.random.split(k)
        return (jax.random.normal(kz, (batch, z_dim)),
                jax.random.normal(kz2, (batch, z_dim)))

    return jax.vmap(one)(step_keys)


def gan_scan(params, opt_states, cfg: GANConfig, images, labels, idx,
             step_keys, *, active=None):
    """Fused GAN training: one ``lax.scan`` of ``train_step_impl`` over
    pre-drawn batch indices ``idx (steps, batch)`` and per-step RNG keys
    ``step_keys (steps, 2)`` — the scan-friendly form of ``train_gan``
    (mirroring ``optim.adam_scan``), jit/donation-friendly and vmappable
    over a stacked cohort axis.

    ``active`` — optional per-step bool vector. Steps with
    ``active[t] == False`` leave params and both Adam states (moments
    *and* step counters) bitwise untouched; the fleet engine uses an
    all-False mask to carry clients below the GAN eligibility threshold
    inside a fixed-shape cohort program. Metrics are still emitted for
    masked steps (evaluated on the frozen params).
    """
    masked = active is not None

    def body(carry, x):
        p, o = carry
        if masked:
            ix, k, live = x
        else:
            ix, k = x
        p2, o2, m = train_step_impl(p, o, (images[ix], labels[ix]), cfg,
                                    k)
        if masked:
            p2 = jax.tree.map(lambda a, b: jnp.where(live, a, b), p2, p)
            o2 = jax.tree.map(lambda a, b: jnp.where(live, a, b), o2, o)
        return (p2, o2), m

    xs = (idx, step_keys, active) if masked else (idx, step_keys)
    (params, opt_states), ms = lax.scan(body, (params, opt_states), xs)
    return params, opt_states, ms


def gan_scan_bucketed(params, opt_states, cfg: GANConfig, images, labels,
                      idx, z, z2, n_true, *, active=None):
    """Bucketed form of :func:`gan_scan`: the minibatch axis of ``idx
    (steps, bucket)`` and the pre-drawn noise ``z``/``z2`` ``(steps,
    bucket, z_dim)`` is padded to a shared bucket, and every step runs
    :func:`train_step_bucketed` with the mean-correction mask derived
    from the (traced) true batch size ``n_true`` — so one compile serves
    every batch-size group of a client fleet. ``active`` masks whole
    steps into bitwise no-ops exactly as in :func:`gan_scan`."""
    masked = active is not None

    def body(carry, x):
        p, o = carry
        if masked:
            ix, za, zb, live = x
        else:
            ix, za, zb = x
        p2, o2, m = train_step_bucketed(
            p, o, (images[ix], labels[ix]), cfg, za, zb, n_true)
        if masked:
            p2 = jax.tree.map(lambda a, b: jnp.where(live, a, b), p2, p)
            o2 = jax.tree.map(lambda a, b: jnp.where(live, a, b), o2, o)
        return (p2, o2), m

    xs = (idx, z, z2, active) if masked else (idx, z, z2)
    (params, opt_states), ms = lax.scan(body, (params, opt_states), xs)
    return params, opt_states, ms


def rebalance_labels(labels, n_classes: int) -> np.ndarray:
    """Labels of the synthetic samples that top every class up to the
    local max count (paper §III-B) — the host-side ``need`` computation
    shared by ``Client.prepare_gan`` and the fleet engine."""
    hist = np.bincount(np.asarray(labels), minlength=n_classes)
    target = hist.max() if len(hist) else 0
    if not target:
        return np.array([], np.int32)
    return np.concatenate([
        np.full(max(0, int(target - hist[c])), c, np.int32)
        for c in range(n_classes)])


def train_gan(rng, cfg: GANConfig, images, labels, *, steps: int = 200,
              batch: int = 64):
    """Train on a client's local data; returns generator params."""
    k0, rng = jax.random.split(rng)
    params = init_gan(k0, cfg)
    opt = {"gen": optim.adam_init(params["gen"]),
           "disc": optim.adam_init(params["disc"])}
    n = images.shape[0]
    metrics = {}
    for i in range(steps):
        rng, kb, ks = jax.random.split(rng, 3)
        idx = jax.random.randint(kb, (min(batch, n),), 0, n)
        params, opt, metrics = train_step(
            params, opt, (images[idx], labels[idx]), cfg, ks)
    return params, metrics


def synthesize(rng, gen, cfg: GANConfig, labels):
    z = jax.random.normal(rng, (labels.shape[0], cfg.z_dim))
    return generate(gen, cfg, z, labels)
