"""Attention-based adapter (paper §III-A).

    Att(D)   = softmax(Q K^T / sqrt(dh)) V
    F_net(a) = ReLU(W1 a + b1) W2 + b2
    CLIP_adapted(D) = Adapter(CLIP_pre(D))

The adapter is a single multi-head attention + 2-layer ReLU FFN appended on
top of the frozen backbone's final hidden states. For decoder LMs the
attention is causal (no future leakage); for CLIP pooled features the input
is a length-1 sequence. Residual connections keep the identity path so
training starts near the pretrained function (wo/W2 are zero-init).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(rng, d: int, *, n_heads: int = 8, d_ff: int = 0,
         dtype=jnp.float32):
    d_ff = d_ff or d
    ks = jax.random.split(rng, 6)
    s = 1.0 / jnp.sqrt(d)
    return {
        "wq": jax.random.normal(ks[0], (d, d), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, d), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, d), dtype) * s,
        "wo": jnp.zeros((d, d), dtype),
        "w1": jax.random.normal(ks[3], (d, d_ff), dtype) * s,
        "b1": jnp.zeros((d_ff,), dtype),
        "w2": jnp.zeros((d_ff, d), dtype),
        "b2": jnp.zeros((d,), dtype),
    }


def specs(d: int, *, d_ff: int = 0, dtype=jnp.float32):
    d_ff = d_ff or d
    f = lambda *sh: jax.ShapeDtypeStruct(sh, dtype)
    return {"wq": f(d, d), "wk": f(d, d), "wv": f(d, d), "wo": f(d, d),
            "w1": f(d, d_ff), "b1": f(d_ff,), "w2": f(d_ff, d), "b2": f(d,)}


def apply(params, x: jax.Array, *, n_heads: int = 8,
          causal: bool = True) -> jax.Array:
    """x: (B, S, d) hidden states -> (B, S, d).

    The Att(D) term runs through the blocked flash-attention op so the
    adapter stays O(S) in memory even on 32k-token inputs."""
    from repro.kernels import ops as kops  # late import: no cycles
    B, S, d = x.shape
    dh = d // n_heads
    dt = x.dtype

    def proj(w):
        return (x @ w.astype(dt)).reshape(B, S, n_heads, dh)

    q, k, v = proj(params["wq"]), proj(params["wk"]), proj(params["wv"])
    a = kops.flash_attention(q, k, v, causal=causal and S > 1)
    a = a.reshape(B, S, d)
    x = x + a @ params["wo"].astype(dt)
    h = jax.nn.relu(x @ params["w1"].astype(dt) + params["b1"].astype(dt))
    return x + h @ params["w2"].astype(dt) + params["b2"].astype(dt)


def _ffn(params, x, dt):
    h = jax.nn.relu(x @ params["w1"].astype(dt) + params["b1"].astype(dt))
    return x + h @ params["w2"].astype(dt) + params["b2"].astype(dt)


def prefill(params, x: jax.Array, window: int, *, n_heads: int = 8):
    """Adapter output for the LAST position plus a ring KV cache over the
    final ``min(S, window)`` positions (so decoding stays windowed even for
    sub-quadratic backbones). x: (B, S, d) -> ((B, 1, d), cache)."""
    from repro.kernels import ops as kops
    from repro.models import layers as mlayers
    B, S, d = x.shape
    dh = d // n_heads
    dt = x.dtype
    M = window  # ring_from_full pads with empty slots when window > S
    k = (x @ params["wk"].astype(dt)).reshape(B, S, n_heads, dh)
    v = (x @ params["wv"].astype(dt)).reshape(B, S, n_heads, dh)
    cache = mlayers.ring_from_full(k, v, M)
    q = (x[:, -1:] @ params["wq"].astype(dt)).reshape(B, 1, n_heads, dh)
    a = kops.decode_attention(q, cache["k"], cache["v"],
                              cache["slot_pos"][None]).reshape(B, 1, d)
    y = x[:, -1:] + a @ params["wo"].astype(dt)
    return _ffn(params, y, dt), cache


def decode(params, x: jax.Array, cache, pos, *, n_heads: int = 8):
    """Single-token adapter step against the ring cache. x: (B, 1, d)."""
    from repro.kernels import ops as kops
    import jax.numpy as jnp
    from jax import lax
    B, _, d = x.shape
    dh = d // n_heads
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, 1, n_heads, dh)
    k = (x @ params["wk"].astype(dt)).reshape(B, 1, n_heads, dh)
    v = (x @ params["wv"].astype(dt)).reshape(B, 1, n_heads, dh)
    M = cache["k"].shape[1]
    slot = (pos % M).astype(jnp.int32)
    cache = {
        "k": lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1),
        "v": lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1),
        "slot_pos": lax.dynamic_update_slice_in_dim(
            cache["slot_pos"], pos[None].astype(jnp.int32), slot, axis=0),
    }
    a = kops.decode_attention(q, cache["k"].astype(dt),
                              cache["v"].astype(dt),
                              cache["slot_pos"][None]).reshape(B, 1, d)
    y = x + a @ params["wo"].astype(dt)
    return _ffn(params, y, dt), cache


def cache_specs(d: int, batch: int, window: int, dtype, *,
                n_heads: int = 8):
    dh = d // n_heads
    sh = (batch, window, n_heads, dh)
    return {"k": jax.ShapeDtypeStruct(sh, dtype),
            "v": jax.ShapeDtypeStruct(sh, dtype),
            "slot_pos": jax.ShapeDtypeStruct((window,), jnp.int32)}
