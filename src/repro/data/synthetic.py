"""Synthetic long-tailed, domain-shifted image datasets.

PACS and Office-Home are not available offline (DESIGN.md §7); these
generators preserve the *structure* the paper's claims depend on:
class-discriminative visual content, domain shift across sub-populations,
and a long-tail class (PACS's 'photo', Office-Home's 'Product' — here
class 0) that the GAN must rebalance.

Each class has a latent prototype texture; each domain applies a distinct
colour/frequency transform; samples add prototype jitter + pixel noise.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_classes: int
    n_domains: int
    image_size: int = 32
    # token ids for the class prompt "a photo of a <class>" stand-in
    text_len: int = 8


SPECS = {
    "pacs": DatasetSpec("pacs", n_classes=7, n_domains=4),
    "officehome": DatasetSpec("officehome", n_classes=16, n_domains=4),
}


def class_tokens(spec: DatasetSpec, labels: np.ndarray) -> np.ndarray:
    """Deterministic class-prompt token sequences (vocab 512)."""
    base = np.array([1, 2, 3, 4, 0, 0, 0, 0], np.int32)  # "a photo of a"
    toks = np.tile(base, (len(labels), 1))
    toks[:, 4] = 10 + labels          # class word
    toks[:, 5] = 5                    # eos
    return toks


def _prototype(rng, spec, c):
    g = np.linspace(-1, 1, spec.image_size)
    xx, yy = np.meshgrid(g, g)
    f1, f2 = rng.uniform(1, 4, 2)
    ph = rng.uniform(0, 2 * np.pi, 2)
    base = np.sin(f1 * np.pi * xx + ph[0]) * np.cos(f2 * np.pi * yy + ph[1])
    blob = np.exp(-((xx - rng.uniform(-.5, .5)) ** 2 +
                    (yy - rng.uniform(-.5, .5)) ** 2) / rng.uniform(.1, .4))
    proto = np.stack([base, blob, base * blob], -1)
    return proto / (np.abs(proto).max() + 1e-6)


def _domain_transform(rng, spec, d):
    mix = rng.uniform(-1, 1, (3, 3))
    mix = mix / np.abs(mix).sum(1, keepdims=True)
    bias = rng.uniform(-0.3, 0.3, 3)
    return mix, bias


def make_dataset(name: str, *, n_per_class: int = 60, seed: int = 0,
                 longtail_gamma: float = 8.0):
    """Returns dict(images (N,32,32,3) float32 [-1,1], labels, domains,
    tokens). Class 0 is underrepresented by ``longtail_gamma``×."""
    spec = SPECS[name]
    rng = np.random.RandomState(seed)
    protos = [_prototype(rng, spec, c) for c in range(spec.n_classes)]
    doms = [_domain_transform(rng, spec, d) for d in range(spec.n_domains)]
    images, labels, domains = [], [], []
    for c in range(spec.n_classes):
        n_c = max(4, int(n_per_class / (longtail_gamma if c == 0 else 1)))
        for _ in range(n_c):
            d = rng.randint(spec.n_domains)
            mix, bias = doms[d]
            img = protos[c] * rng.uniform(0.7, 1.3)
            img = img + 0.25 * _prototype(rng, spec, c) * rng.randn()
            img = np.einsum("hwc,cd->hwd", img, mix) + bias
            img = img + 0.15 * rng.randn(*img.shape)
            images.append(np.clip(img, -1, 1))
            labels.append(c)
            domains.append(d)
    images = np.asarray(images, np.float32)
    labels = np.asarray(labels, np.int32)
    domains = np.asarray(domains, np.int32)
    order = rng.permutation(len(labels))
    images, labels, domains = images[order], labels[order], domains[order]
    return {"images": images, "labels": labels, "domains": domains,
            "tokens": class_tokens(spec, labels), "spec": spec}


def make_eval_set(name: str, *, n_per_class: int = 20, seed: int = 1):
    """Balanced held-out set (no long tail) for server-side accuracy."""
    return make_dataset(name, n_per_class=n_per_class, seed=seed,
                        longtail_gamma=1.0)


def stage_client_pools(pools):
    """Pad ragged per-client (images, labels) pools to one fixed-shape
    cohort tensor so a whole federated round is a single device program.

    ``pools`` — sequence of (images (n_i, H, W, C), labels (n_i,)).
    Returns (images (n_clients, P, H, W, C) f32, labels (n_clients, P)
    i32, lens (n_clients,) i32) with P = max n_i. Padding rows are zeros
    and are never sampled: batch indices are drawn in [0, lens[i]).
    """
    n_clients = len(pools)
    P = max(len(labs) for _, labs in pools)
    sample_shape = pools[0][0].shape[1:]
    images = np.zeros((n_clients, P, *sample_shape), np.float32)
    labels = np.zeros((n_clients, P), np.int32)
    lens = np.zeros((n_clients,), np.int32)
    for i, (imgs, labs) in enumerate(pools):
        n = len(labs)
        images[i, :n] = imgs
        labels[i, :n] = labs
        lens[i] = n
    return images, labels, lens
