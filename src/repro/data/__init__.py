# Synthetic long-tail datasets + batching pipeline.
