"""Batching pipeline: shuffled epochs, client streams, host-side prefetch.

Keeps the FL clients and the LM drivers off hand-rolled ``randint``
sampling: deterministic per-seed order, without-replacement epochs,
drop-remainder batching, and a one-deep device prefetch (host→device copy
of batch k+1 overlaps step k — the CPU-container analogue of an input
pipeline; on TPU the same code overlaps infeed).
"""
from __future__ import annotations

import threading
from queue import Queue
from typing import Dict, Iterator, Optional

import jax
import numpy as np


class ArrayDataset:
    """Dict of equal-length arrays with shuffled epoch iteration."""

    def __init__(self, data: Dict[str, np.ndarray], *, seed: int = 0):
        lens = {k: len(v) for k, v in data.items()}
        assert len(set(lens.values())) == 1, lens
        self.data = data
        self.n = next(iter(lens.values()))
        self._rng = np.random.RandomState(seed)

    def batches(self, batch_size: int, *, epochs: Optional[int] = None,
                drop_remainder: bool = True) -> Iterator[Dict]:
        epoch = 0
        while epochs is None or epoch < epochs:
            order = self._rng.permutation(self.n)
            stop = self.n - (self.n % batch_size if drop_remainder else 0)
            for i in range(0, stop, batch_size):
                idx = order[i:i + batch_size]
                yield {k: v[idx] for k, v in self.data.items()}
            epoch += 1

    def split(self, fractions, *, seed: int = 0):
        """Deterministic subset split (e.g. train/eval)."""
        rng = np.random.RandomState(seed)
        order = rng.permutation(self.n)
        out, lo = [], 0
        for f in fractions:
            hi = lo + int(round(f * self.n))
            sel = order[lo:hi]
            out.append(ArrayDataset(
                {k: v[sel] for k, v in self.data.items()}, seed=seed))
            lo = hi
        return out


def client_streams(data: Dict[str, np.ndarray], parts, *, batch_size: int,
                   seed: int = 0):
    """One infinite batch iterator per FL client from a partition
    (repro.fl.partition output)."""
    streams = []
    for i, idx in enumerate(parts):
        ds = ArrayDataset({k: v[idx] for k, v in data.items()},
                          seed=seed * 1000 + i)
        bs = min(batch_size, max(1, len(idx)))
        streams.append(ds.batches(bs, epochs=None))
    return streams


def prefetch(it: Iterator, size: int = 2) -> Iterator:
    """Host-thread prefetch: device_put the next batch while the current
    one computes."""
    q: Queue = Queue(maxsize=size)
    _END = object()

    def worker():
        try:
            for x in it:
                q.put(jax.device_put(x))
        finally:
            q.put(_END)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        x = q.get()
        if x is _END:
            return
        yield x


def lm_sequences(rng: np.random.RandomState, vocab: int, *, n_docs: int,
                 seq: int, bias_lo: int = 0, bias_hi: Optional[int] = None):
    """Structured synthetic LM corpus (learnable bigram repeats) within a
    token sub-range — used for non-IID FL client corpora."""
    hi = bias_hi or vocab
    toks = rng.randint(bias_lo, hi, (n_docs, seq + 1))
    toks[:, 2::2] = toks[:, 1:-1:2]
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}
