"""Yi-9B — llama-arch dense decoder with GQA. [arXiv:2403.04652]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=10_000.0,
    source="arXiv:2403.04652",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="yi-9b-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=512, vocab_size=256,
        lora_rank=4, dtype="float32", seq_shard=False)
