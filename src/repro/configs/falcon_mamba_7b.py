"""Falcon-Mamba-7B — attention-free Mamba-1 SSM. [arXiv:2410.05355]"""
from repro.configs.base import ModelConfig, SSM

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,                    # mamba block subsumes the FFN
    vocab_size=65024,
    attn_pattern=(SSM,),
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    source="arXiv:2410.05355",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="falcon-mamba-reduced", n_layers=2, d_model=256,
        vocab_size=256, ssm_state=8, lora_rank=4, dtype="float32",
        seq_shard=False, scan_chunk=32)
