"""CLIP ViT-B/32-style dual encoder — the paper's own foundation model.
Used by the FL examples/benchmarks (at reduced scale on CPU).
[arXiv:2103.00020 via paper ref [1]]"""
from repro.configs.base import ModelConfig

# The dual-encoder is built in repro.core.clip; this ModelConfig describes
# the *text/vision transformer trunk* shape used when CLIP participates in
# the generic model registry (e.g. dry-run of the paper's own backbone).
CONFIG = ModelConfig(
    name="clip-b32",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=49408,
    mlp="gelu",
    source="arXiv:2103.00020",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="clip-b32-reduced", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=256, vocab_size=256,
        lora_rank=4, dtype="float32", seq_shard=False)
