"""Kimi-K2 — trillion-param MoE, 384 experts top-8 (+1 shared), first layer
dense (paper-table). [arXiv:2501.kimi2]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,                 # per-expert FFN width
    vocab_size=163840,
    n_experts=384,
    experts_per_token=8,
    n_shared_experts=1,
    first_k_dense=1,
    dense_d_ff=18432,
    rope_theta=50_000.0,
    source="arXiv:2501.kimi2",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="kimi-k2-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=128, vocab_size=256, n_experts=4,
        experts_per_token=2, n_shared_experts=1, first_k_dense=1,
        dense_d_ff=512, lora_rank=4, dtype="float32", seq_shard=False)
