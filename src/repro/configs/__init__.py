"""Architecture config registry.

``get_config(arch_id)`` returns the exact assigned configuration;
``get_reduced(arch_id)`` returns the CPU smoke-test variant of the same
family. ``ARCHS`` lists the 10 assigned architectures (clip-b32 — the
paper's own backbone — is additionally registered).
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, InputShape, INPUT_SHAPES  # noqa: F401

_MODULES = {
    "yi-9b": "yi_9b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "whisper-medium": "whisper_medium",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "llava-next-34b": "llava_next_34b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "starcoder2-15b": "starcoder2_15b",
    "clip-b32": "clip_b32",
}

ARCHS = tuple(k for k in _MODULES if k != "clip-b32")


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).reduced()
