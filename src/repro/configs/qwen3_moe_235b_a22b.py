"""Qwen3-MoE 235B-A22B-style — 128 experts, top-8, GQA. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,                 # per-expert FFN width
    vocab_size=151936,
    n_experts=128,
    experts_per_token=8,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-moe-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=128, vocab_size=256,
        n_experts=4, experts_per_token=2, lora_rank=4, dtype="float32",
        seq_shard=False)
