"""Whisper-medium — encoder-decoder audio transformer backbone.
Conv/mel frontend is a stub: input_specs provides precomputed frame
embeddings (B, 1500, d_model). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,               # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,             # MHA
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    n_frames=1500,
    use_rope=False,            # whisper uses absolute positions
    max_pos=32_768,            # decode_32k context (long_500k skipped: full attn)
    mlp="gelu",
    source="arXiv:2212.04356",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-reduced", n_layers=2, encoder_layers=2, d_model=256,
        n_heads=4, n_kv_heads=4, head_dim=64, d_ff=512, vocab_size=256,
        n_frames=32, max_pos=512, lora_rank=4, dtype="float32",
        seq_shard=False)
