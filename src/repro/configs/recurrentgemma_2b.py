"""RecurrentGemma-2B — RG-LRU + local attention hybrid, 2 recurrent blocks
per 1 local-attention block. [arXiv:2402.19427]"""
from repro.configs.base import ModelConfig, ATTN, RGLRU

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,              # MQA
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    attn_pattern=(RGLRU, RGLRU, ATTN),
    window=2048,               # local attention window -> sub-quadratic
    lru_width=2560,
    source="arXiv:2402.19427",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="recurrentgemma-reduced", n_layers=3, d_model=256, n_heads=4,
        n_kv_heads=1, head_dim=64, d_ff=512, vocab_size=256, window=64,
        lru_width=256, lora_rank=4, dtype="float32", seq_shard=False,
        scan_chunk=32)
