"""LLaVA-NeXT-34B — VLM; Yi-34B-style decoder backbone; vision tower +
projector are a stub (input_specs provides patch embeddings; anyres tiling
represented by the base 576-patch grid). [hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    n_patches=576,
    rope_theta=5_000_000.0,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llava-next-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=512, vocab_size=256, n_patches=16,
        lora_rank=4, dtype="float32", seq_shard=False)
