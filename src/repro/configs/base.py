"""Model / run configuration dataclasses.

Every assigned architecture gets one module in this package defining
``CONFIG`` with the exact published shape, plus ``reduced()`` returning the
smoke-test variant (≤2 layers, d_model ≤ 512, ≤4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Layer kinds used in per-layer patterns.
ATTN = "attn"
RGLRU = "rglru"
SSM = "ssm"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    source: str = ""  # citation per assignment

    # attention
    rope_theta: float = 10_000.0
    window: Optional[int] = None          # sliding-window size (None = full)
    attn_pattern: Tuple[str, ...] = (ATTN,)  # repeating per-layer pattern
    use_rope: bool = True                 # False -> learned absolute pos emb
    max_pos: int = 0                      # needed when use_rope=False
    mlp: str = "swiglu"                   # swiglu | gelu

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0
    dense_d_ff: int = 0                   # d_ff of the first_k_dense layers
    capacity_factor: float = 1.25
    moe_dispatch_bits: int = 0            # 0 | 8: int8 all-to-all payloads
                                          # (DeepSeek-V3-style low-precision
                                          # dispatch — beyond-paper §Perf)

    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0                  # 0 -> ceil(d_model/16)

    # hybrid (RG-LRU)
    lru_width: int = 0

    # enc-dec / modality frontend stubs
    encoder_layers: int = 0
    n_frames: int = 0                     # audio: precomputed frame embeds
    n_patches: int = 0                    # vlm: precomputed patch embeds

    # TriplePlay technique knobs
    lora_rank: int = 16
    lora_alpha: float = 32.0
    quant_bits: int = 0                   # 0 = bf16 backbone, 8, or 4
    quant_block: int = 128
    quant_mode: str = "linear"            # linear | nf4
    kv_quant_bits: int = 0                # 0 | 8: int8 KV/ring cache
    grad_accum: int = 1                   # microbatches per train step
    trainable_dtype: str = "float32"      # LoRA/adapter params (bfloat16
                                          # halves their collective bytes;
                                          # Adam moments stay f32)
    adapter_heads: int = 8
    adapter_d_ff: int = 0                 # 0 -> d_model
    adapter_window: int = 4096            # adapter attention window at serve
                                          # time (keeps SSM/SWA archs sub-
                                          # quadratic; train is full causal)

    # numerics / memory
    dtype: str = "bfloat16"
    remat: bool = True
    seq_shard: bool = True                # sequence-parallel residual stream
    scan_chunk: int = 256                 # SSM/LRU chunked-scan chunk length
    # dry-run cost calibration (see launch/dryrun.py): unroll the layer
    # stack and remove inner loops so XLA cost_analysis counts every FLOP
    # (loop bodies are otherwise counted once regardless of trip count)
    unroll_layers: bool = False
    calibrate: bool = False

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind string, expanding the repeating pattern."""
        pat = self.attn_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for 6·N·D model-flops) -------------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate backbone parameter count (embeddings included)."""
        d, V = self.d_model, self.vocab_size
        n = 2 * V * d  # embed + head (untied)
        if self.encoder_layers:
            n += self.max_pos * d + self.n_frames * 0
        per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.mlp == "swiglu":
            per_mlp = lambda ff: 3 * d * ff
        else:
            per_mlp = lambda ff: 2 * d * ff
        kinds = self.layer_kinds()
        for i, k in enumerate(kinds):
            if k == ATTN or self.family in ("dense", "moe", "vlm", "encdec"):
                if k == ATTN:
                    n += per_attn
            if k == SSM:
                di, N, R = self.d_inner, self.ssm_state, self.dt_rank
                n += d * 2 * di + di * self.ssm_conv + di * (R + 2 * N)
                n += R * di + di * N + 2 * di + di * d
                continue
            if k == RGLRU:
                w = self.lru_width or d
                n += 2 * d * w + w * d + 3 * w + 2 * w * (self.ssm_conv or 4)
                continue
            # feed-forward part of an attention layer
            if self.n_experts and i >= self.first_k_dense:
                e = self.experts_per_token if active_only else self.n_experts
                n += (e + self.n_shared_experts) * per_mlp(self.d_ff)
                n += d * self.n_experts  # router
            else:
                n += per_mlp(self.dense_d_ff or self.d_ff)
        if self.encoder_layers:  # add encoder stack (attention + mlp, no kv cache)
            n += self.encoder_layers * (per_attn + per_mlp(self.d_ff) + 2 * d * self.head_dim * 0)
            # cross-attention in every decoder layer
            n += self.n_layers * per_attn
        return int(n)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
