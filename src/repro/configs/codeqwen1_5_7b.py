"""CodeQwen1.5-7B — qwen1.5-arch dense decoder (MHA). [hf:Qwen/CodeQwen1.5-7B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,             # MHA per assignment (GQA kv=32)
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    rope_theta=1_000_000.0,
    source="hf:Qwen/CodeQwen1.5-7B",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="codeqwen-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=4, head_dim=64, d_ff=512, vocab_size=256,
        lora_rank=4, dtype="float32", seq_shard=False)
