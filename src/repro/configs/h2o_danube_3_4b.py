"""H2O-Danube3-4B — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    window=4096,               # mistral-style SWA -> sub-quadratic decode
    rope_theta=10_000.0,
    source="arXiv:2401.16818",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="h2o-danube-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=512, vocab_size=256, window=64,
        lora_rank=4, dtype="float32", seq_shard=False)
