"""StarCoder2-15B — dense decoder, GQA + RoPE, GELU MLP. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp="gelu",
    rope_theta=100_000.0,
    source="arXiv:2402.19173",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="starcoder2-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=512, vocab_size=256,
        lora_rank=4, dtype="float32", seq_shard=False)
